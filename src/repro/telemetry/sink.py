"""Host-side async telemetry sink: ring buffer + pluggable writers +
windowed aggregation.

The train loop calls ``emit(step, stats)`` once per step with the DEVICE
arrays the jitted step returned — emit only appends a reference to a bounded
ring buffer (no host sync, no I/O). A background drain thread (or an explicit
``drain()`` call, e.g. right before a controller decision) moves buffered
stats to the host in one ``jax.device_get`` per step, appends schema-valid
records to every writer, and maintains per-bucket sliding windows that the
``RankRefreshController`` consumes.

If the ring buffer overflows (drain thread starved), the OLDEST entries are
dropped — telemetry never blocks training — and ``dropped`` counts them.
"""
from __future__ import annotations

import collections
import csv
import dataclasses
import json
import threading
from typing import Any, Dict, List, Mapping, Optional, Sequence

import numpy as np

from .probes import stats_to_records, validate_record

Record = Dict[str, Any]


class JsonlWriter:
    """One JSON object per line; the canonical round-trippable format."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w")

    def write(self, rec: Record) -> None:
        self._f.write(json.dumps(rec) + "\n")

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class CsvWriter:
    """Flat CSV with the schema's field order; ``sigma`` is JSON-encoded in
    its column so the row stays one line."""

    def __init__(self, path: str):
        from .probes import RECORD_SCHEMA
        self.path = path
        self._fields = list(RECORD_SCHEMA)
        self._f = open(path, "w", newline="")
        self._w = csv.DictWriter(self._f, fieldnames=self._fields)
        self._w.writeheader()

    def write(self, rec: Record) -> None:
        row = dict(rec)
        row["sigma"] = json.dumps(rec["sigma"])
        self._w.writerow(row)

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        self._f.close()


def read_jsonl(path: str) -> List[Record]:
    """Load a JSONL telemetry file back into records (the round-trip side)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


@dataclasses.dataclass(frozen=True)
class WindowAggregate:
    """Sliding-window summary for one bucket — the controller's input."""

    n: int                   # records in the window
    last_step: int
    kappa_mean: float
    kappa_max: float
    energy_mean: float
    energy_min: float
    ortho_max: float
    sigma_mean: np.ndarray   # (r,) mean spectrum over the window, descending
    refresh_rate: float      # fraction of window steps whose refresh fired


class TelemetrySink:
    """Ring-buffer collector with pluggable writers and windowed aggregation.

    Thread model: ``emit`` is called from the train loop (cheap, lock +
    append). ``drain`` may be called from the background thread started by
    ``start()`` AND explicitly (controller checks, shutdown) — drains and
    writer access are serialized by a separate drain lock, and the emit lock
    is never held across device_get or writer I/O.
    """

    def __init__(self, writers: Sequence[Any] = (), capacity: int = 4096,
                 window: int = 8, validate: bool = True,
                 to_records: Optional[Any] = None,
                 validate_fn: Optional[Any] = None):
        # Pluggable record pipeline: the default is the training-side
        # spectral schema; the serving engine passes
        # telemetry.serving.{serving_stats_to_records, validate_serving_record}
        # to stream its own schema through the same transport.
        self._to_records = to_records if to_records is not None else stats_to_records
        self._validate_fn = validate_fn if validate_fn is not None else validate_record
        self.writers = list(writers)
        self.window = window
        self.validate = validate
        self._buf: collections.deque = collections.deque(maxlen=capacity)
        self._windows: Dict[str, collections.deque] = {}
        self._settings: Optional[Mapping[str, Any]] = None
        self._default_freq = 0
        self._emitted = 0
        self.records_written = 0
        self.dropped = 0
        self.last_error: Optional[BaseException] = None
        self._lock = threading.Lock()        # buffer + windows + writers
        self._drain_lock = threading.Lock()  # serializes drains
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- configuration ------------------------------------------------------
    def set_settings(self, settings: Mapping[str, Any],
                     default_freq: int = 0) -> None:
        """Current per-bucket settings (controller.BucketSetting) stamped
        into every record drained from now on."""
        with self._lock:
            self._settings = dict(settings)
            self._default_freq = default_freq

    # -- hot path -----------------------------------------------------------
    def emit(self, step: int, stats: Mapping[str, Any]) -> None:
        """Buffer one step's device stats. No host sync, no I/O."""
        with self._lock:
            if len(self._buf) == self._buf.maxlen:
                self.dropped += 1
            self._buf.append((int(step), stats, self._settings,
                              self._default_freq))
            self._emitted += 1

    # -- off the critical path ---------------------------------------------
    def drain(self) -> List[Record]:
        """Move everything buffered to the host: device_get, write records,
        update windows. Returns the records drained this call.

        ``self._lock`` is held only for the O(1) buffer swap and the window
        bookkeeping — never across device_get or writer I/O, so the train
        loop's ``emit`` cannot block on disk. Writers are serialized by
        ``self._drain_lock`` (also taken by ``close``)."""
        with self._drain_lock:
            with self._lock:
                items = list(self._buf)
                self._buf.clear()
            recs: List[Record] = []
            for step, stats, settings, default_freq in items:
                recs.extend(self._to_records(
                    step, stats, settings=settings,
                    default_update_freq=default_freq))
            if self.validate:
                for rec in recs:
                    self._validate_fn(rec)
            with self._lock:
                for rec in recs:
                    bucket = rec.get("bucket")
                    if bucket is None:      # non-bucketed schema (serving)
                        continue
                    win = self._windows.setdefault(
                        bucket,
                        collections.deque(maxlen=self.window))
                    win.append(rec)
                self.records_written += len(recs)
            for w in self.writers:
                for rec in recs:
                    w.write(rec)
            for w in self.writers:
                w.flush()
            return recs

    # -- background drain ---------------------------------------------------
    def start(self, interval: float = 0.25) -> None:
        """Spawn the daemon drain thread (drains every ``interval`` s).
        A drain failure (writer I/O error, schema violation) is recorded in
        ``last_error`` and the thread keeps running — telemetry must never
        take the training run down with it."""
        if self._thread is not None:
            return

        def loop():
            while not self._stop.wait(interval):
                try:
                    self.drain()
                except Exception as e:
                    self.last_error = e

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join()
            self._thread = None
            self._stop.clear()
        self.drain()

    def close(self) -> None:
        """Stop the drain thread, flush everything, close writers."""
        self.stop()
        with self._drain_lock:      # serialize against any in-flight drain
            for w in self.writers:
                w.close()
            self.writers = []

    def rewind(self, step: int) -> None:
        """Forget buffered/windowed records at or after ``step`` — called on
        fault-recovery restore so the replayed steps don't double-count in
        the controller's windows. Already-flushed writer output is NOT
        rewritten: the JSONL/CSV stream has at-least-once semantics and may
        contain the pre-fault records for replayed steps (dedupe downstream
        on (step, bucket), keeping the last occurrence)."""
        with self._drain_lock:
            with self._lock:
                kept = [it for it in self._buf if it[0] < step]
                self._buf.clear()
                self._buf.extend(kept)
                for win in self._windows.values():
                    recs = [r for r in win if r["step"] < step]
                    win.clear()
                    win.extend(recs)

    # -- windowed aggregation ----------------------------------------------
    def window_aggregate(self, bucket: str) -> Optional[WindowAggregate]:
        with self._lock:
            win = self._windows.get(bucket)
            if not win:
                return None
            recs = list(win)
        kappas = np.array([r["kappa"] for r in recs], dtype=np.float64)
        energies = np.array([r["energy"] for r in recs], dtype=np.float64)
        orthos = np.array([r["ortho_residual"] for r in recs],
                          dtype=np.float64)
        # rank may have changed inside the window (controller applied):
        # aggregate the spectrum over the trailing CONTIGUOUS run of
        # same-rank records — records before an r→r'→r flip-flop belong to a
        # different basis regime even when their rank matches.
        rank = len(recs[-1]["sigma"])
        sig = []
        for r in reversed(recs):
            if len(r["sigma"]) != rank:
                break
            sig.append(r["sigma"])
        sig.reverse()
        return WindowAggregate(
            n=len(recs),
            last_step=recs[-1]["step"],
            kappa_mean=float(kappas.mean()),
            kappa_max=float(kappas.max()),
            energy_mean=float(energies.mean()),
            energy_min=float(energies.min()),
            ortho_max=float(orthos.max()),
            sigma_mean=np.mean(np.asarray(sig, dtype=np.float64), axis=0),
            refresh_rate=float(np.mean([r["refresh_fired"] for r in recs])),
        )

    def window_aggregates(self) -> Dict[str, WindowAggregate]:
        with self._lock:
            buckets = list(self._windows)
        out = {}
        for b in buckets:
            agg = self.window_aggregate(b)
            if agg is not None:
                out[b] = agg
        return out
