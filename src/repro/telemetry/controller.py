"""The measurement→adaptation loop: per-bucket rank & refresh-cadence control.

``RankRefreshController.decide`` is a PURE function of the windowed stats and
the current per-bucket settings — deterministic by construction (no RNG, no
wall clock), which is what makes controller behaviour testable on synthetic
moments. Policy (see the package docstring for the rationale against the
paper's error bound):

  * grow rank   when the window's mean energy capture ‖QᵀG‖_F/‖G‖_F sags
                below ``energy_low`` — the basis is missing gradient mass;
  * shrink rank when the trailing ``tail_frac`` of the moment spectrum
                carries less than ``tail_mass_low`` of Σσ² — dead directions;
  * tighten K   (halve the refresh interval) when mean κ(MMᵀ) exceeds
                ``kappa_high`` — the regime where the paper's
                orthogonalization error bound degrades;
  * relax K     (double it) when κ stays below ``kappa_low``;
  * arm ς       (the in-step adaptive-refresh threshold
                ``SumoConfig.refresh_quality``, per bucket) when the
                window's WORST energy capture sags below ``quality_arm``
                while the mean stays healthy — the basis goes stale BETWEEN
                refreshes faster than the cadence can track, so the engines'
                own ‖QᵀG‖ < ς‖G‖ trigger takes over;
  * disarm ς    when the worst capture recovers above ``quality_disarm``.

Decisions are applied OUTSIDE the jitted step, at refresh boundaries, via two
host-side moves: (1) ``SumoConfig.bucket_overrides`` is rebuilt (a static
config field ⇒ a controlled recompile point), and (2) ``resize_opt_state``
resizes the bucket-resident Q/M stacks to the new rank. Grown basis columns
are zero until the bucket's next rSVD refresh re-derives the basis at the
new rank; shrinking rotates into the moment's own singular basis first, so
exactly the smallest-σ (negligible-mass) directions that justified the
shrink are dropped — see ``_spectral_shrink``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.optimizer import build_bucket_plan, is_matrix_param, path_str
from ..core.sumo import SumoState, sumo_state_layout
from .probes import tail_mass
from .sink import WindowAggregate


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    window: int = 8            # records per bucket required before deciding
    kappa_high: float = 1e6    # tighten refresh above this mean κ(MMᵀ)
    kappa_low: float = 1e2     # relax refresh below this
    energy_low: float = 0.30   # grow rank when mean energy capture sags below
    tail_frac: float = 0.25    # trailing spectrum fraction inspected for shrink
    tail_mass_low: float = 1e-3  # shrink rank when tail mass below this
    rank_step: int = 8         # grow/shrink granularity
    rank_min: int = 4
    freq_tighten: int = 2      # divide update_freq by this when κ is high
    freq_relax: int = 2        # multiply when κ is comfortably low
    freq_min: int = 5
    freq_max: int = 2000
    quality_arm: float = 0.50    # arm per-bucket refresh_quality when the
                                 # window's MIN energy capture sags below this
    quality_disarm: float = 0.85  # disarm (back to the global default) when
                                  # the min capture recovers above this
    quality_target: float = 0.50  # the ς value an armed bucket runs under


@dataclasses.dataclass(frozen=True)
class BucketSetting:
    """What one bucket currently runs under (+ its static dims).

    ``refresh_quality`` is the per-bucket adaptive-refresh threshold ς;
    0.0 means "keep SumoConfig.refresh_quality's global default" (same
    sentinel convention as rank/update_freq overrides of 0)."""

    rank: int
    update_freq: int
    long: int
    short: int
    refresh_quality: float = 0.0


@dataclasses.dataclass(frozen=True)
class BucketDecision:
    bucket: str
    rank: int
    update_freq: int
    refresh_quality: float = 0.0
    reasons: Tuple[str, ...] = ()

    def changed(self, setting: BucketSetting) -> bool:
        return (self.rank, self.update_freq, self.refresh_quality) != (
            setting.rank, setting.update_freq, setting.refresh_quality)


def initial_settings(params, rank: int, update_freq: int,
                     refresh_quality: float = 0.0
                     ) -> Dict[str, BucketSetting]:
    """Default per-bucket settings for a param tree: the bucket plan of its
    MATRIX leaves (same classification the optimizer uses) at the global
    rank/update_freq (and optionally a global ς)."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    shapes = [leaf.shape for path, leaf in leaves
              if is_matrix_param(path_str(path), leaf)]
    out = {}
    for b in build_bucket_plan(shapes):
        long_d, short_d = b.shape          # already canonical (long, short)
        out[b.key] = BucketSetting(
            rank=max(1, min(rank, short_d)), update_freq=update_freq,
            long=long_d, short=short_d, refresh_quality=refresh_quality)
    return out


def overrides_from_settings(settings: Mapping[str, BucketSetting]
                            ) -> Tuple[Tuple[str, int, int, float], ...]:
    """Settings dict -> the static SumoConfig.bucket_overrides tuple (sorted
    for a deterministic config hash). Entries are
    (bucket, rank, update_freq, refresh_quality); SumoConfig also still
    accepts legacy 3-entry tuples (e.g. from an old checkpoint manifest)."""
    return tuple(sorted(
        (k, s.rank, s.update_freq, s.refresh_quality)
        for k, s in settings.items()))


class RankRefreshController:
    """Consumes windowed SpectralStats, produces per-bucket decisions."""

    def __init__(self, config: ControllerConfig = ControllerConfig()):
        self.cfg = config

    def decide(self, windows: Mapping[str, WindowAggregate],
               current: Mapping[str, BucketSetting]
               ) -> Dict[str, BucketDecision]:
        cfg = self.cfg
        out: Dict[str, BucketDecision] = {}
        for bucket in sorted(current):
            setting = current[bucket]
            agg = windows.get(bucket)
            if agg is None or agg.n < cfg.window:
                out[bucket] = BucketDecision(bucket, setting.rank,
                                             setting.update_freq,
                                             setting.refresh_quality)
                continue
            rank, freq = setting.rank, setting.update_freq
            quality = setting.refresh_quality
            reasons = []
            # -- rank: grow on sagging energy capture, else shrink on a
            #    negligible spectral tail (grow wins — never shrink a basis
            #    that is already missing gradient mass).
            if agg.energy_mean < cfg.energy_low:
                new_rank = min(setting.short, rank + cfg.rank_step)
                if new_rank != rank:
                    reasons.append(
                        f"energy {agg.energy_mean:.3f} < {cfg.energy_low}: "
                        f"grow rank {rank}->{new_rank}")
                    rank = new_rank
            else:
                tm = tail_mass(agg.sigma_mean, cfg.tail_frac)
                if tm < cfg.tail_mass_low:
                    new_rank = max(cfg.rank_min, rank - cfg.rank_step)
                    if new_rank != rank:
                        reasons.append(
                            f"tail mass {tm:.2e} < {cfg.tail_mass_low}: "
                            f"shrink rank {rank}->{new_rank}")
                        rank = new_rank
            # -- refresh cadence from the condition-number regime
            if agg.kappa_mean > cfg.kappa_high:
                new_freq = max(cfg.freq_min, freq // cfg.freq_tighten)
                if new_freq != freq:
                    reasons.append(
                        f"kappa {agg.kappa_mean:.2e} > {cfg.kappa_high:.0e}: "
                        f"tighten refresh {freq}->{new_freq}")
                    freq = new_freq
            elif agg.kappa_mean < cfg.kappa_low:
                new_freq = min(cfg.freq_max, freq * cfg.freq_relax)
                if new_freq != freq:
                    reasons.append(
                        f"kappa {agg.kappa_mean:.2e} < {cfg.kappa_low:.0e}: "
                        f"relax refresh {freq}->{new_freq}")
                    freq = new_freq
            # -- per-bucket ς: the basis decays between refreshes when the
            #    WORST in-window capture sags while the mean stays fine
            #    (the mean case is the grow-rank signal above) — hand the
            #    engines' own in-step ‖QᵀG‖ < ς‖G‖ trigger the bucket.
            #    Arming only ever RAISES ς (a user-seeded stricter ς is left
            #    alone), and disarm resets exactly the value WE armed back
            #    to the 0.0 sentinel ("use the global default") — if a
            #    global SumoConfig.refresh_quality is in play, seed it via
            #    ``initial_settings(..., refresh_quality=)`` so the
            #    controller sees the effective value, not the sentinel.
            if (agg.energy_min < cfg.quality_arm
                    and agg.energy_mean >= cfg.energy_low
                    and quality < cfg.quality_target):
                reasons.append(
                    f"min energy {agg.energy_min:.3f} < {cfg.quality_arm}: "
                    f"arm refresh_quality {quality:.2f}->"
                    f"{cfg.quality_target:.2f}")
                quality = cfg.quality_target
            elif (quality == cfg.quality_target
                    and agg.energy_min >= cfg.quality_disarm):
                reasons.append(
                    f"min energy {agg.energy_min:.3f} >= "
                    f"{cfg.quality_disarm}: disarm refresh_quality "
                    f"{quality:.2f}->0.00")
                quality = 0.0
            out[bucket] = BucketDecision(bucket, rank, freq, quality,
                                         tuple(reasons))
        return out


# ---------------------------------------------------------------------------
# Applying decisions: bucket-resident state resize (the recompile-point move)
# ---------------------------------------------------------------------------

def _resize_rows(a: jnp.ndarray, axis: int, new: int) -> jnp.ndarray:
    old = a.shape[axis]
    if new == old:
        return a
    if new < old:
        sl = [slice(None)] * a.ndim
        sl[axis] = slice(0, new)
        return a[tuple(sl)]
    pad_shape = list(a.shape)
    pad_shape[axis] = new - old
    return jnp.concatenate(
        [a, jnp.zeros(pad_shape, a.dtype)], axis=axis)


def _spectral_shrink(Q: jnp.ndarray, M: jnp.ndarray, r_new: int):
    """Shrink (Q (B, long, r), M (B, r, short)) to rank ``r_new`` keeping the
    TOP singular directions of the moment.

    Naive column truncation would assume Q's columns are spectrally ordered —
    they are not (the rSVD basis is a QR of a random sketch). Instead rotate
    into M's own singular basis: M = U S Vᵀ gives Q' = Q U[:, :r'] (still
    orthonormal) and M' = S[:r'] Vᵀ[:r'], so Q'M' is exactly the best
    rank-r' approximation of the lifted moment QM, whatever the column
    order. One small (r × short) SVD per bucket member, on the host at
    decision time — never on the hot path."""
    U, s, Vt = jnp.linalg.svd(M, full_matrices=False)     # U: (B, r, r)
    Q_new = jnp.matmul(Q, U[..., :, :r_new])              # (B, long, r')
    M_new = s[..., :r_new, None] * Vt[..., :r_new, :]     # (B, r', short)
    return Q_new, M_new


def resize_sumo_state(state: SumoState,
                      rank_map: Mapping[str, int]) -> SumoState:
    """Resize a BUCKET-layout SumoState's Q/M (and stats.sigma) stacks to the
    ranks in ``rank_map``, applied between steps. Grow pads zero basis
    columns (dormant until the bucket's next refresh re-derives the basis at
    the new rank); shrink rotates into the moment's singular basis first
    (``_spectral_shrink``) so only the smallest-σ directions are dropped."""
    if sumo_state_layout(state) != "bucket":
        raise ValueError(
            "controller rank resize needs bucket-resident state "
            "(SumoConfig.state_layout='bucket')")
    Q, M = dict(state.Q), dict(state.M)
    stats = dict(state.stats) if isinstance(state.stats, dict) else state.stats
    for key, r_new in rank_map.items():
        if key not in Q:
            raise KeyError(f"rank_map bucket {key!r} not in state "
                           f"(have {sorted(Q)})")
        if r_new < Q[key].shape[-1]:
            Q[key], M[key] = _spectral_shrink(Q[key], M[key], r_new)
        else:
            Q[key] = _resize_rows(Q[key], 2, r_new)      # (B, long, r)
            M[key] = _resize_rows(M[key], 1, r_new)      # (B, r, short)
        if isinstance(stats, dict) and key in stats:
            stats[key] = stats[key]._replace(
                sigma=_resize_rows(stats[key].sigma, 0, r_new))
    return state._replace(Q=Q, M=M, stats=stats)


def resize_opt_state(opt_state, rank_map: Mapping[str, int]):
    """Apply ``resize_sumo_state`` to every SumoState inside an arbitrary
    optimizer-state tree (multi_transform dicts, chains, ...)."""
    return jax.tree_util.tree_map(
        lambda node: (resize_sumo_state(node, rank_map)
                      if isinstance(node, SumoState) else node),
        opt_state,
        is_leaf=lambda x: isinstance(x, SumoState) or x is None,
    )


def apply_decisions(
    opt_state,
    settings: Dict[str, BucketSetting],
    decisions: Mapping[str, BucketDecision],
) -> Tuple[Any, Dict[str, BucketSetting], Tuple[Tuple[str, int, int], ...],
           Dict[str, Tuple[str, ...]]]:
    """Fold changed decisions into (resized opt_state, new settings,
    new bucket_overrides tuple, reasons-by-bucket). No-op (same objects,
    empty reasons) when nothing changed."""
    changed = {b: d for b, d in decisions.items()
               if b in settings and d.changed(settings[b])}
    if not changed:
        return opt_state, settings, overrides_from_settings(settings), {}
    new_settings = dict(settings)
    rank_map = {}
    reasons: Dict[str, Tuple[str, ...]] = {}
    for b, d in changed.items():
        old = settings[b]
        new_settings[b] = dataclasses.replace(
            old, rank=d.rank, update_freq=d.update_freq,
            refresh_quality=d.refresh_quality)
        if d.rank != old.rank:
            rank_map[b] = d.rank
        reasons[b] = d.reasons
    if rank_map:
        opt_state = resize_opt_state(opt_state, rank_map)
    return (opt_state, new_settings,
            overrides_from_settings(new_settings), reasons)
