"""On-device spectral probes: extraction + host-side record conversion.

The probe VALUES are computed inside the bucketed SUMO engine
(``repro.core.sumo`` with ``SumoConfig.telemetry=True``) as a jit-safe aux
output — ``SumoState.stats`` maps each canonical "LONGxSHORT" bucket key to a
``SpectralStats``. This module is the host-side half: pulling those stats out
of an arbitrary optimizer-state tree, converting them into schema-stable
records (the JSONL/CSV unit), and the spectrum arithmetic the controller and
benchmarks share (tail mass, rank-one residual, κ from σ).

Nothing here runs on the hot path: ``extract_stats`` only re-arranges tree
references (no host sync), and ``stats_to_records`` — the one device→host
transfer — is called by the sink's drain, off the critical path.
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional

import jax
import numpy as np

from ..core.sumo import SpectralStats, SumoState

PyTree = Any

# The JSONL/CSV record schema, field -> python type. ``sigma`` is the
# (rank,)-length moment spectrum, descending; everything else is scalar.
# ``rank`` and ``update_freq`` record the SETTING the bucket ran under, so a
# controller decision is visible in the stream as a rank/freq step change.
RECORD_SCHEMA: Dict[str, type] = {
    "step": int,
    "bucket": str,
    "rank": int,
    "update_freq": int,
    "kappa": float,
    "energy": float,
    "ortho_residual": float,
    "moment_norm": float,
    "update_norm": float,
    "grad_norm": float,
    "refresh_fired": int,
    "sigma": list,
}


def validate_record(rec: Mapping[str, Any]) -> None:
    """Raise ValueError unless ``rec`` matches RECORD_SCHEMA exactly."""
    missing = set(RECORD_SCHEMA) - set(rec)
    extra = set(rec) - set(RECORD_SCHEMA)
    if missing or extra:
        raise ValueError(
            f"telemetry record keys mismatch: missing={sorted(missing)} "
            f"extra={sorted(extra)}")
    for field, typ in RECORD_SCHEMA.items():
        v = rec[field]
        if typ is float:
            ok = isinstance(v, (int, float)) and not isinstance(v, bool)
        elif typ is int:
            ok = isinstance(v, int) and not isinstance(v, bool)
        elif typ is list:
            ok = isinstance(v, list) and len(v) >= 1 and all(
                isinstance(x, (int, float)) for x in v)
        else:
            ok = isinstance(v, typ)
        if not ok:
            raise ValueError(
                f"telemetry record field {field!r}: {v!r} is not {typ.__name__}")


def extract_stats(opt_state: PyTree) -> Dict[str, SpectralStats]:
    """Collect the per-bucket SpectralStats dicts from every SumoState in an
    optimizer-state tree (e.g. the multi_transform dict the train step
    carries). Pure tree surgery — no device sync. Buckets from different
    SumoStates merge by key (later wins; in practice there is one SUMO)."""
    nodes = jax.tree_util.tree_flatten(
        opt_state, is_leaf=lambda x: isinstance(x, SumoState) or x is None
    )[0]
    out: Dict[str, SpectralStats] = {}
    for node in nodes:
        if isinstance(node, SumoState) and isinstance(node.stats, dict):
            out.update(node.stats)
    return out


def stats_to_records(
    step: int,
    stats: Mapping[str, SpectralStats],
    settings: Optional[Mapping[str, Any]] = None,
    default_update_freq: int = 0,
) -> List[dict]:
    """Device stats -> one schema-valid host record per bucket (sorted by
    bucket key for a deterministic stream). ``settings`` (bucket ->
    object with .rank/.update_freq, see controller.BucketSetting) stamps the
    setting each bucket ran under; without it rank falls back to len(sigma)
    and update_freq to ``default_update_freq``."""
    host = jax.device_get(dict(stats))   # ONE transfer for the whole step
    recs = []
    for bucket in sorted(host):
        s = host[bucket]
        sigma = np.asarray(s.sigma, dtype=np.float64)
        setting = settings.get(bucket) if settings else None
        recs.append({
            "step": int(step),
            "bucket": bucket,
            "rank": int(setting.rank) if setting else int(sigma.shape[0]),
            "update_freq": (int(setting.update_freq) if setting
                            else int(default_update_freq)),
            "kappa": float(s.kappa),
            "energy": float(s.energy),
            "ortho_residual": float(s.ortho_residual),
            "moment_norm": float(s.moment_norm),
            "update_norm": float(s.update_norm),
            "grad_norm": float(s.grad_norm),
            "refresh_fired": int(s.refresh_fired),
            "sigma": [float(x) for x in sigma],
        })
    return recs


# ---------------------------------------------------------------------------
# Spectrum arithmetic shared by the controller and benchmarks
# ---------------------------------------------------------------------------

def tail_mass(sigma, tail_frac: float = 0.25) -> float:
    """Fraction of the spectral energy Σσ² carried by the trailing
    ``tail_frac`` of the spectrum (σ descending). Near zero ⇒ the last
    directions are dead weight and the rank can shrink."""
    s = np.asarray(sigma, dtype=np.float64)
    k = max(1, int(np.ceil(len(s) * tail_frac)))
    total = float(np.sum(s ** 2)) + 1e-30
    return float(np.sum(s[-k:] ** 2)) / total


def kappa_from_sigma(sigma) -> float:
    """κ(MMᵀ) = (σ_max/σ_min)² from a descending spectrum."""
    s = np.asarray(sigma, dtype=np.float64)
    return float((s[0] / max(s[-1], 1e-12)) ** 2)


def rank_one_residual_from_sigma(sigma) -> float:
    """Paper Eq. (1): 1 − σ₁²/Σσ² — rank-collapse diagnostic from the same
    spectrum the probes emit (no private SVD re-implementation needed)."""
    s = np.asarray(sigma, dtype=np.float64)
    total = float(np.sum(s ** 2)) + 1e-30
    return 1.0 - float(s[0] ** 2) / total
