"""repro.telemetry — spectral telemetry + adaptive per-bucket rank/refresh
control for SUMO.

SUMO's theory (paper §3) bounds the orthogonalization approximation error by
the condition number of the moment matrix and motivates a dynamically adapted
low-dimensional subspace. This package closes that measurement→adaptation
loop online, in three layers:

1. **On-device probes** (``repro.core.sumo`` + ``probes``): with
   ``SumoConfig.telemetry=True`` the bucketed engine emits one
   ``SpectralStats`` per canonical "LONGxSHORT" bucket as a jit-safe aux
   output in ``SumoState.stats`` — zero extra SVDs (the moment spectrum is
   read off the factorization the orthogonalization already performs: the
   polar method's own r×r Gram eigh, the SVD method's own SVD; NS5 pays one
   r×r Gram eigh) and no host syncs on the hot path. Probes never feed back
   into the update, so the training trajectory is bit-identical probes-on vs
   probes-off (pinned by tests/test_telemetry.py).

2. **Host-side async sink** (``sink``): ``TelemetrySink`` buffers the device
   stats in a bounded ring (emit = lock + append, no sync, no I/O) and a
   background drain thread converts them to records, appends to pluggable
   ``JsonlWriter``/``CsvWriter`` outputs, and maintains per-bucket sliding
   ``WindowAggregate`` windows.

3. **Feedback controller** (``controller``): ``RankRefreshController``
   consumes the windowed stats and re-tunes each bucket's subspace rank,
   refresh cadence AND in-step adaptive-refresh threshold ς
   (``refresh_quality`` — armed when the window's worst energy capture sags
   between refreshes, disarmed on recovery); decisions flow back as the
   static ``SumoConfig.bucket_overrides`` 4-tuples
   (bucket, rank, K, ς — both engines honor them bit-identically) plus a
   host-side pad/truncate of the bucket-resident Q/M stacks
   (``resize_opt_state``), so state shapes change only at controlled
   recompile points — applied at refresh boundaries by ``train.loop``.

Record schema (one JSONL object / CSV row per bucket per step)
--------------------------------------------------------------
    step            int    optimizer step the stats describe
    bucket          str    canonical "LONGxSHORT" bucket id
    rank            int    subspace rank the bucket ran under
    update_freq     int    refresh cadence K the bucket ran under
    kappa           float  max over bucket of κ(MMᵀ) = (σ_max/σ_min)²
    energy          float  min over bucket of ‖QᵀG‖_F/‖G‖_F (energy capture)
    ortho_residual  float  max over bucket of ‖OOᵀ−I‖_F/√r (pre-limiter O)
    moment_norm     float  mean ‖M‖_F
    update_norm     float  mean ‖Δ‖_F of the applied update
    grad_norm       float  mean ‖G‖_F
    refresh_fired   int    1 iff the bucket's refresh cond fired this step
    sigma           list   (rank,) bucket-mean moment spectrum, descending

``probes.validate_record`` enforces this schema; ``sink.read_jsonl`` is the
round-trip loader.

Controller policy (deterministic; ControllerConfig for the thresholds)
----------------------------------------------------------------------
    grow rank    mean energy capture < energy_low      (basis missing mass)
    shrink rank  trailing tail_frac of σ carries < tail_mass_low of Σσ²
    tighten K    mean κ(MMᵀ) > kappa_high   (the paper's error-bound regime)
    relax K      mean κ(MMᵀ) < kappa_low

Wiring: ``TrainConfig(telemetry=True, telemetry_out=..., controller=True)``
in ``repro.train``, or ``--telemetry/--controller`` on
``python -m repro.launch.train``.
"""
from .controller import (
    BucketDecision,
    BucketSetting,
    ControllerConfig,
    RankRefreshController,
    apply_decisions,
    initial_settings,
    overrides_from_settings,
    resize_opt_state,
    resize_sumo_state,
)
from .probes import (
    RECORD_SCHEMA,
    extract_stats,
    kappa_from_sigma,
    rank_one_residual_from_sigma,
    stats_to_records,
    tail_mass,
    validate_record,
)
from .serving import (
    SERVING_EVENTS,
    SERVING_RECORD_SCHEMA,
    serving_record,
    serving_stats_to_records,
    validate_serving_record,
)
from .sink import CsvWriter, JsonlWriter, TelemetrySink, WindowAggregate, read_jsonl

# Re-export the on-device stats types (defined next to the engine that emits
# them, in repro.core.sumo) so telemetry is the one-stop public API.
from ..core.sumo import MatrixStats, SpectralStats

__all__ = [
    "SpectralStats", "MatrixStats",
    "RECORD_SCHEMA", "validate_record", "extract_stats", "stats_to_records",
    "tail_mass", "kappa_from_sigma", "rank_one_residual_from_sigma",
    "TelemetrySink", "JsonlWriter", "CsvWriter", "WindowAggregate",
    "read_jsonl",
    "SERVING_RECORD_SCHEMA", "SERVING_EVENTS", "serving_record",
    "serving_stats_to_records", "validate_serving_record",
]

__all__ += [
    "RankRefreshController", "ControllerConfig", "BucketSetting",
    "BucketDecision", "initial_settings", "overrides_from_settings",
    "resize_sumo_state", "resize_opt_state", "apply_decisions",
]
