"""Serving telemetry records — the JSONL schema the continuous-batching
engine streams through ``TelemetrySink``.

The sink stays the transport (ring buffer, drain thread, writers); serving
plugs in with ``TelemetrySink(to_records=serving_stats_to_records,
validate_fn=validate_serving_record)``. Serving records are already
host-side (latencies are wall-clock measurements), so the record converter
is a pass-through — no device_get needed on the drain.

Events
------
    queued        request entered the FIFO queue          (value: queue depth)
    prefill       request admitted + prefilled into slot  (value: prefill s)
    ttft          first token produced                    (value: seconds since arrival)
    finish        request completed                       (value: e2e seconds)
    decode_step   one continuous decode step              (value: step wall seconds)

Every record carries the scheduler/pool gauges at emit time (queue depth,
active slots, free blocks) so queueing behaviour and pool occupancy can be
read straight off the stream.
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Sequence

SERVING_RECORD_SCHEMA: Dict[str, type] = {
    "step": int,            # engine decode-step index at emit time
    "event": str,           # one of SERVING_EVENTS
    "request_id": int,      # -1 for engine-level events (decode_step)
    "t": float,             # engine-clock timestamp (seconds)
    "value": float,         # event-specific measurement (see module doc)
    "queue_depth": int,
    "active_slots": int,
    "free_blocks": int,
}

SERVING_EVENTS = ("queued", "prefill", "ttft", "finish", "decode_step")


def serving_record(step: int, event: str, request_id: int, t: float,
                   value: float, queue_depth: int, active_slots: int,
                   free_blocks: int) -> Dict[str, Any]:
    return {
        "step": int(step), "event": str(event), "request_id": int(request_id),
        "t": float(t), "value": float(value), "queue_depth": int(queue_depth),
        "active_slots": int(active_slots), "free_blocks": int(free_blocks),
    }


def validate_serving_record(rec: Mapping[str, Any]) -> None:
    """Raise ValueError unless ``rec`` matches SERVING_RECORD_SCHEMA."""
    missing = set(SERVING_RECORD_SCHEMA) - set(rec)
    extra = set(rec) - set(SERVING_RECORD_SCHEMA)
    if missing or extra:
        raise ValueError(
            f"serving record keys mismatch: missing={sorted(missing)} "
            f"extra={sorted(extra)}")
    for field, typ in SERVING_RECORD_SCHEMA.items():
        v = rec[field]
        if typ is float:
            ok = isinstance(v, (int, float)) and not isinstance(v, bool)
        elif typ is int:
            ok = isinstance(v, int) and not isinstance(v, bool)
        else:
            ok = isinstance(v, typ)
        if not ok:
            raise ValueError(f"serving record field {field!r}: expected "
                             f"{typ.__name__}, got {type(v).__name__} ({v!r})")
    if rec["event"] not in SERVING_EVENTS:
        raise ValueError(f"unknown serving event {rec['event']!r}; "
                         f"have {SERVING_EVENTS}")


def serving_stats_to_records(step: int, stats: Sequence[Mapping[str, Any]],
                             settings: Optional[Mapping[str, Any]] = None,
                             default_update_freq: int = 0) -> List[dict]:
    """Sink record-converter hook: serving stats are already host records."""
    del step, settings, default_update_freq
    return [dict(r) for r in stats]
