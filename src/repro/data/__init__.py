"""repro.data — deterministic synthetic token pipeline."""
from .pipeline import DataConfig, data_iterator, make_batch, make_sharded_batch

__all__ = ["DataConfig", "make_batch", "data_iterator", "make_sharded_batch"]
