"""Deterministic synthetic token pipeline (offline stand-in for C4/GLUE).

Real properties preserved:
  * sharded host loading — each data-parallel host materializes only its
    slice (jax.make_array_from_callback against the target sharding);
  * deterministic resume — batch content is a pure function of (seed, step),
    so restarting from a checkpoint replays the exact stream (fold_in, no
    stateful iterators to snapshot);
  * structure — a Zipf-ish unigram mixture with short-range repetition so
    LMs actually have signal to learn (used by the convergence benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    repeat_prob: float = 0.3      # P(copy a recent token) — learnable structure
    repeat_window: int = 8
    zipf_a: float = 1.2


def _batch_tokens(key, batch: int, seq_len: int, vocab: int,
                  cfg: DataConfig) -> jnp.ndarray:
    """Pure function: (key) -> (batch, seq_len) int32 tokens."""
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipf-ish marginal via exponential transform of uniform
    u = jax.random.uniform(k1, (batch, seq_len), minval=1e-6, maxval=1.0)
    base = jnp.floor(vocab * u ** cfg.zipf_a).astype(jnp.int32) % vocab
    # short-range repetition: with prob p, copy token from `d` steps back
    rep = jax.random.uniform(k2, (batch, seq_len)) < cfg.repeat_prob
    d = jax.random.randint(k3, (batch, seq_len), 1, cfg.repeat_window + 1)
    idx = jnp.maximum(jnp.arange(seq_len)[None, :] - d, 0)
    copied = jnp.take_along_axis(base, idx, axis=1)
    return jnp.where(rep, copied, base)


def make_batch(step: int, shape: ShapeConfig, arch: ArchConfig,
               data_cfg: DataConfig = DataConfig()) -> dict:
    """Global batch for `step` (pure, deterministic)."""
    key = jax.random.fold_in(jax.random.PRNGKey(data_cfg.seed), step)
    B, L = shape.global_batch, shape.seq_len
    batch: dict = {}
    if arch.family == "audio":
        kf, kl = jax.random.split(key)
        batch["frontend_embeds"] = (
            jax.random.normal(kf, (B, L, arch.d_model)) * 0.1
        ).astype(jnp.bfloat16 if arch.dtype == "bfloat16" else jnp.float32)
        batch["labels"] = jax.random.randint(kl, (B, L), 0, arch.vocab)
        return batch
    if arch.family == "vlm":
        kf, key = jax.random.split(key)
        n_f = arch.n_frontend_tokens
        batch["frontend_embeds"] = (
            jax.random.normal(kf, (B, n_f, arch.d_model)) * 0.1
        ).astype(jnp.bfloat16 if arch.dtype == "bfloat16" else jnp.float32)
        L = L - n_f
    toks = _batch_tokens(key, B, L + 1, arch.vocab, data_cfg)
    batch["tokens"] = toks[:, :-1]
    batch["labels"] = toks[:, 1:]
    return batch


def data_iterator(shape: ShapeConfig, arch: ArchConfig,
                  data_cfg: DataConfig = DataConfig(),
                  start_step: int = 0) -> Iterator[dict]:
    """Resumable stream: pass the restored step to replay deterministically."""
    step = start_step
    while True:
        yield make_batch(step, shape, arch, data_cfg)
        step += 1


def make_sharded_batch(step: int, shape: ShapeConfig, arch: ArchConfig,
                       shardings: Optional[dict] = None,
                       data_cfg: DataConfig = DataConfig()) -> dict:
    """Materialize each array directly into its target sharding. Each host
    only creates the shards it owns (multi-host path); on one host this is
    equivalent to device_put."""
    batch = make_batch(step, shape, arch, data_cfg)
    if not shardings:
        return batch
    out = {}
    for name, arr in batch.items():
        sh = shardings.get(name)
        if sh is None:
            out[name] = arr
            continue
        np_arr = np.asarray(arr)
        out[name] = jax.make_array_from_callback(
            np_arr.shape, sh, lambda idx, a=np_arr: a[idx]
        )
    return out
