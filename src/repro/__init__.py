"""repro — SUMO (Subspace-Aware Moment-Orthogonalization, NeurIPS 2025) as a
production-grade multi-pod JAX training/inference framework.

Subpackages:
    core      the paper's optimizer + baselines (AdamW/GaLore/Muon/LoRA)
    models    10-arch model zoo (dense/MoE/hybrid-SSM/xLSTM/audio/VLM)
    kernels   Pallas TPU kernels (NS5, projection, flash attention)
    parallel  (pod, data, model) sharding rules
    data      deterministic synthetic pipeline
    train     steps, loop, checkpointing, fault tolerance
    telemetry spectral probes, async sink, rank/refresh controller
    serve     batched prefill/decode engine
    configs   assigned architecture configs
    launch    mesh / dryrun / train / serve entry points
    roofline  trip-count-aware HLO cost analysis
"""

__version__ = "1.0.0"
