"""Pallas TPU kernel: fused Newton-Schulz-5 orthogonalization.

The Muon/SUMO-NS5 hot loop is 5 iterations of
    A = X Xᵀ ; B = b·A + c·A² ; X = a·X + B X
on an (r × n) moment with r ≤ 256. Unfused, each iteration round-trips X
through HBM 3×. This kernel keeps X **resident in VMEM for all 5 iterations**
(r·n ≤ 256×2048 fp32 = 2 MB plus the r×r Gram ≪ 16 MB VMEM), so HBM traffic
is exactly one read + one write of X — the memory-optimal schedule.

Grid: one program per batch element (stacked expert/leaf matrices vmap into
the leading axis). MXU alignment: r and n should be multiples of 128 for
peak utilisation; the wrapper pads as needed.

Validated against ref.py in interpret mode (CPU container); TPU is the
compile target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NS5_A, NS5_B, NS5_C = 3.4445, -4.7750, 2.0315


def _ns5_kernel(x_ref, o_ref, *, steps: int):
    """x_ref, o_ref: (1, r, n) VMEM blocks (leading batch block of 1)."""
    X = x_ref[0].astype(jnp.float32)
    # Frobenius normalization (spectral-norm upper bound)
    X = X / (jnp.sqrt(jnp.sum(X * X)) + 1e-7)

    def body(i, X):
        A = jnp.dot(X, X.T, preferred_element_type=jnp.float32)       # (r, r)
        A2 = jnp.dot(A, A, preferred_element_type=jnp.float32)
        B = NS5_B * A + NS5_C * A2
        return NS5_A * X + jnp.dot(B, X, preferred_element_type=jnp.float32)

    X = jax.lax.fori_loop(0, steps, body, X)
    o_ref[0] = X.astype(o_ref.dtype)


def ns5_pallas(M: jnp.ndarray, steps: int = 5, interpret: bool = False) -> jnp.ndarray:
    """Batched fused NS5. M: (..., r, n) with r <= n. Returns orthogonalized M."""
    orig_shape = M.shape
    r, n = orig_shape[-2], orig_shape[-1]
    assert r <= n, "ns5_pallas expects r <= n (transpose outside)"
    batch = 1
    for d in orig_shape[:-2]:
        batch *= d
    Mb = M.reshape(batch, r, n)

    kernel = functools.partial(_ns5_kernel, steps=steps)
    out = pl.pallas_call(
        kernel,
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, r, n), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, r, n), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, r, n), M.dtype),
        interpret=interpret,
    )(Mb)
    return out.reshape(orig_shape)
