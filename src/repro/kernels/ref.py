"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NS5_A, NS5_B, NS5_C = 3.4445, -4.7750, 2.0315


def ns5_ref(M: jnp.ndarray, steps: int = 5) -> jnp.ndarray:
    """Quintic Newton-Schulz, batched over leading dims. M: (..., r, n), r<=n."""

    def one(X):
        X = X.astype(jnp.float32)
        X = X / (jnp.sqrt(jnp.sum(X * X)) + 1e-7)
        for _ in range(steps):
            A = X @ X.T
            B = NS5_B * A + NS5_C * (A @ A)
            X = NS5_A * X + B @ X
        return X

    batch = M.shape[:-2]
    if batch:
        flat = M.reshape((-1,) + M.shape[-2:])
        out = jax.vmap(one)(flat)
        return out.reshape(M.shape).astype(M.dtype)
    return one(M).astype(M.dtype)


def project_ref(Q: jnp.ndarray, G: jnp.ndarray) -> jnp.ndarray:
    """Ĝ = Qᵀ G in fp32."""
    return (Q.astype(jnp.float32).T @ G.astype(jnp.float32)).astype(G.dtype)


def backproject_ref(Q: jnp.ndarray, O: jnp.ndarray) -> jnp.ndarray:
    return (Q.astype(jnp.float32) @ O.astype(jnp.float32)).astype(O.dtype)


def flash_attention_ref(q, k, v, causal: bool = True, sliding_window=None):
    """Full-materialization attention oracle (same semantics as the kernel)."""
    from ..models.layers import attention_ref

    return attention_ref(q, k, v, causal=causal, sliding_window=sliding_window)
