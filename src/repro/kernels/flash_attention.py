"""Pallas TPU kernel: blocked online-softmax (flash) attention, forward.

Tiling: grid (batch·heads, q_blocks, k_blocks) with k as the innermost axis so
the running (max, sum, accumulator) for one q-block stays in VMEM scratch for
the whole row of k-blocks. Causal + sliding-window masking is applied from
the block indices; fully-masked k-blocks are skipped at grid level for the
causal case by clamping the k range (block-sparse lower triangle).

BlockSpec tiling (per program): q (1, bq, hd), k/v (1, bk, hd) in VMEM. MXU
wants bq, bk multiples of 128 and hd ∈ {64, 128, 256}.

This is the TPU adaptation of FlashAttention: the CUDA shared-memory staging
becomes HBM→VMEM BlockSpecs, warp-level reductions become full-block vector
ops on the VPU, and the MXU eats the (bq×hd)·(hd×bk) panels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  n_k: int, bq: int, bk: int, causal: bool,
                  window: int, scale: float, lk: int):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < lk                      # KV padding
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,            # (B, Lq, H, hd)
    k: jnp.ndarray,            # (B, Lk, KV, hd)
    v: jnp.ndarray,
    causal: bool = True,
    sliding_window=None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Lq, H, hd = q.shape
    _, Lk, KV, _ = k.shape
    n_rep = H // KV
    # fold heads into batch; repeat kv heads to match (GQA)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Lq, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), n_rep, axis=1).reshape(B * H, Lk, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), n_rep, axis=1).reshape(B * H, Lk, hd)

    bq = min(block_q, Lq)
    bk = min(block_k, Lk)
    pad_q = (-Lq) % bq
    pad_k = (-Lk) % bk
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    n_q = (Lq + pad_q) // bq
    n_k = (Lk + pad_k) // bk

    kernel = functools.partial(
        _flash_kernel, n_k=n_k, bq=bq, bk=bk, causal=causal,
        window=(sliding_window or 0), scale=1.0 / (hd ** 0.5), lk=Lk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Lq + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :Lq].reshape(B, H, Lq, hd).transpose(0, 2, 1, 3)
    return out
