"""Pallas TPU kernel: tiled subspace projection  Ĝ = Qᵀ G.

SUMO/GaLore Block-1 hot spot: Q (m × r) tall-skinny basis against the gradient
G (m × n). The contraction axis is the LONG axis m (up to ~150k for vocab-
sharded matrices), so the kernel tiles m into VMEM-sized panels and
accumulates the (r × n-tile) partial products in a VMEM scratch accumulator —
one pass over G (the big operand), no HBM round-trips for partials.

Grid: (n_tiles, m_tiles); m is the inner (fastest) axis so the accumulator
for a given n-tile stays live across the whole reduction.

Also provides the back-projection  U = Q O  (m × n from (m×r)·(r×n)) via the
same tiling transposed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _proj_kernel(q_ref, g_ref, o_ref, acc_ref, *, n_m: int):
    """q_ref: (bm, r), g_ref: (bm, bn), o_ref: (r, bn), acc: (r, bn) f32."""
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        q_ref[...].astype(jnp.float32).T,
        g_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(mi == n_m - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def project_pallas(
    Q: jnp.ndarray,            # (m, r)
    G: jnp.ndarray,            # (m, n)
    block_m: int = 1024,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Ĝ = Qᵀ G -> (r, n)."""
    m, r = Q.shape
    m2, n = G.shape
    assert m == m2
    bm = min(block_m, m)
    bn = min(block_n, n)
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    if pad_m:
        Q = jnp.pad(Q, ((0, pad_m), (0, 0)))
        G = jnp.pad(G, ((0, pad_m), (0, 0)))
    if pad_n:
        G = jnp.pad(G, ((0, 0), (0, pad_n)))
    n_m = (m + pad_m) // bm
    n_n = (n + pad_n) // bn

    kernel = functools.partial(_proj_kernel, n_m=n_m)
    out = pl.pallas_call(
        kernel,
        grid=(n_n, n_m),
        in_specs=[
            pl.BlockSpec((bm, r), lambda ni, mi: (mi, 0)),
            pl.BlockSpec((bm, bn), lambda ni, mi: (mi, ni)),
        ],
        out_specs=pl.BlockSpec((r, bn), lambda ni, mi: (0, ni)),
        out_shape=jax.ShapeDtypeStruct(((r), n + pad_n), G.dtype),
        scratch_shapes=[pltpu.VMEM((r, bn), jnp.float32)],
        interpret=interpret,
    )(Q, G)
    return out[:, :n]


def _backproj_kernel(q_ref, o_ref, u_ref):
    """q_ref: (bm, r), o_ref: (r, bn), u_ref: (bm, bn). Single-shot matmul —
    r is small, so no reduction tiling is needed."""
    u_ref[...] = jnp.dot(
        q_ref[...].astype(jnp.float32),
        o_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(u_ref.dtype)


def backproject_pallas(
    Q: jnp.ndarray,            # (m, r)
    O: jnp.ndarray,            # (r, n)
    block_m: int = 1024,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """U = Q O -> (m, n)."""
    m, r = Q.shape
    r2, n = O.shape
    assert r == r2
    bm = min(block_m, m)
    bn = min(block_n, n)
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    if pad_m:
        Q = jnp.pad(Q, ((0, pad_m), (0, 0)))
    if pad_n:
        O = jnp.pad(O, ((0, 0), (0, pad_n)))
    out = pl.pallas_call(
        _backproj_kernel,
        grid=((m + pad_m) // bm, (n + pad_n) // bn),
        in_specs=[
            pl.BlockSpec((bm, r), lambda mi, ni: (mi, 0)),
            pl.BlockSpec((r, bn), lambda mi, ni: (0, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m + pad_m, n + pad_n), O.dtype),
        interpret=interpret,
    )(Q, O)
    return out[:m, :n]
