"""repro.kernels — Pallas TPU kernels for the perf-critical compute:
fused Newton-Schulz5 (Muon/SUMO-NS5 ablation), subspace projection (Block 1),
flash attention (model backbone). Each has a pure-jnp oracle in ref.py."""
from . import ref
from .ops import (
    backproject,
    flash_attention,
    newton_schulz5,
    project,
    resolve_projection_impl,
    subspace_backproject,
    subspace_project,
)

__all__ = ["newton_schulz5", "project", "backproject", "flash_attention", "ref",
           "subspace_project", "subspace_backproject", "resolve_projection_impl"]
