"""jit'd public wrappers for the Pallas kernels.

On the CPU container the kernels execute in interpret mode (the kernel body
runs as traced Python — same numerics, no Mosaic); on TPU they compile for
real. ``interpret`` resolves automatically from the default backend.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .ns5 import ns5_pallas
from .projection import backproject_pallas, project_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Optimizer-facing dispatch: the SUMO bucketed engine routes its per-bucket
# projection Ĝ = QᵀG and back-projection U = QO through these so the Pallas
# kernels serve the training hot path (compiled on TPU, interpret mode when
# forced on CPU) while CPU runs default to the plain-matmul reference.
# ---------------------------------------------------------------------------

PROJECTION_IMPLS = ("auto", "pallas", "reference")


def resolve_projection_impl(impl: str) -> str:
    """'auto' → 'pallas' on TPU, 'reference' elsewhere; validates the rest."""
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl not in ("pallas", "reference"):
        raise ValueError(
            f"unknown projection impl {impl!r} (have {PROJECTION_IMPLS})")
    return impl


def subspace_project(Q: jnp.ndarray, G: jnp.ndarray, impl: str = "auto",
                     axis_name: str | None = None):
    """Ĝ = Qᵀ G for one (long, r) basis against one (long, short) gradient.

    Safe under jax.vmap: the Pallas path batches via pallas_call's batching
    rule (an extra grid dimension), the reference path is a plain dot.

    ``axis_name``: when Q and G are row-sharded over a shard_map mesh axis
    (the 2D-mesh SUMO path, long dim over `model`), each shard's matmul
    yields a PARTIAL (r, short) panel; one psum over the axis finishes the
    contraction — an r-width collective, never the (long, short) gradient.
    """
    if resolve_projection_impl(impl) == "pallas":
        out = project(Q, G)
    else:
        out = ref.project_ref(Q, G)
    if axis_name is not None:
        out = jax.lax.psum(out, axis_name)
    return out


def subspace_backproject(Q: jnp.ndarray, O: jnp.ndarray, impl: str = "auto"):
    """U = Q O (same dispatch contract as subspace_project).

    Needs no axis_name: with Q row-sharded and O replicated the product is
    the local row block of U — the back-projection is collective-free.
    """
    if resolve_projection_impl(impl) == "pallas":
        return backproject(Q, O)
    return ref.backproject_ref(Q, O)


@partial(jax.jit, static_argnames=("steps", "interpret"))
def newton_schulz5(M: jnp.ndarray, steps: int = 5, interpret: bool | None = None):
    """Fused NS5 orthogonalization. M: (..., r, n) with r <= n."""
    itp = _auto_interpret() if interpret is None else interpret
    return ns5_pallas(M, steps=steps, interpret=itp)


@partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def project(Q, G, block_m: int = 1024, block_n: int = 512, interpret=None):
    """Ĝ = Qᵀ G."""
    itp = _auto_interpret() if interpret is None else interpret
    return project_pallas(Q, G, block_m=block_m, block_n=block_n, interpret=itp)


@partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def backproject(Q, O, block_m: int = 1024, block_n: int = 512, interpret=None):
    """U = Q O."""
    itp = _auto_interpret() if interpret is None else interpret
    return backproject_pallas(Q, O, block_m=block_m, block_n=block_n, interpret=itp)


@partial(jax.jit, static_argnames=("causal", "sliding_window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, causal: bool = True, sliding_window=None,
                    block_q: int = 512, block_k: int = 512, interpret=None):
    """Blocked online-softmax attention forward. q: (B, Lq, H, hd)."""
    itp = _auto_interpret() if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, sliding_window=sliding_window,
        block_q=block_q, block_k=block_k, interpret=itp,
    )
