"""Fault-tolerance machinery: preemption/failure injection, straggler
detection, and the restart supervisor.

On a real 1000-node deployment the coordinator observes missing heartbeats /
slow all-reduces; in this container the same control flow is driven by a
deterministic fault injector, so the recovery path (checkpoint restore +
deterministic data replay) is exercised end-to-end by tests and examples.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence


class SimulatedPreemption(RuntimeError):
    """A node vanished (SIGTERM from the scheduler, hardware fault, ...)."""


class StragglerTimeout(RuntimeError):
    """A step exceeded the straggler threshold; treat the worker as sick."""


@dataclasses.dataclass
class FaultInjector:
    """Raises SimulatedPreemption at the given global steps (once each)."""

    preempt_at: Sequence[int] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.preempt_at and step not in self._fired:
            self._fired.add(step)
            raise SimulatedPreemption(f"simulated preemption at step {step}")


class StragglerMonitor:
    """Flags steps slower than `factor` × the running median step time.

    Mitigation policy on a TPU pod: a straggling step cannot be skipped
    (SPMD), so the supervisor restarts the sick worker from the last
    checkpoint — the same path as a preemption. `warmup` steps are exempt
    (compilation).
    """

    def __init__(self, factor: float = 5.0, warmup: int = 2, enabled: bool = True):
        self.factor = factor
        self.warmup = warmup
        self.enabled = enabled
        self.times: list[float] = []
        self.events: list[tuple[int, float]] = []

    def note_recompile(self) -> None:
        """Forget timing history so the warmup exemption re-applies — call
        after any deliberate recompile (controller rebuild, restart), which
        would otherwise look like a 100× straggler step."""
        self.times.clear()

    def observe(self, step: int, seconds: float) -> None:
        if not self.enabled:
            return
        if len(self.times) >= self.warmup:
            med = sorted(self.times)[len(self.times) // 2]
            if seconds > self.factor * med and med > 0:
                self.events.append((step, seconds))
                raise StragglerTimeout(
                    f"step {step} took {seconds:.3f}s (> {self.factor}× median {med:.3f}s)"
                )
        self.times.append(seconds)
        if len(self.times) > 64:
            self.times.pop(0)


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    straggler_events: int = 0
    final_step: int = 0


def supervise(
    run_from: Callable[[int], int],
    max_restarts: int = 8,
) -> SupervisorReport:
    """Restart loop: run_from(start_step) -> final_step, restarted on
    preemption/straggler faults. run_from is responsible for restoring from
    the latest checkpoint when start_step > 0 (or always)."""
    report = SupervisorReport()
    start = 0
    while True:
        try:
            report.final_step = run_from(start)
            return report
        except (SimulatedPreemption, StragglerTimeout) as e:
            report.restarts += 1
            if isinstance(e, StragglerTimeout):
                report.straggler_events += 1
            if report.restarts > max_restarts:
                raise RuntimeError(f"exceeded {max_restarts} restarts") from e
            start = -1   # sentinel: resume from latest checkpoint
