"""Fault-tolerant checkpoint manager.

Design (DESIGN.md §5):
  * mesh-independent — arrays are saved as full logical values (gathered from
    shards), so a checkpoint written on a 256-chip mesh restores onto 512
    chips or 1 CPU (elastic scaling / downsizing after node loss);
  * atomic — write to `<dir>/tmp.<step>` then os.rename, so a preemption
    mid-write can never corrupt the latest checkpoint;
  * rotated — keeps the newest `keep` checkpoints;
  * async — `save(..., blocking=False)` hands the write to a daemon thread
    (the train loop overlaps the next steps with the I/O), with a barrier on
    the next save to bound in-flight writes;
  * resume metadata — step and data-stream position are in the manifest, so
    the deterministic data pipeline replays exactly.

Format: one .npz of flattened path->array plus a manifest.json.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "|"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None
    )[0]
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                        for k in path)
        if leaf is None:
            flat[f"__none__{key}"] = np.zeros((0,))
        else:
            flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(template: PyTree, flat: dict[str, np.ndarray]) -> PyTree:
    """Rebuild using template's structure (dtypes/shapes validated)."""
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=lambda x: x is None
    )
    out = []
    for path, leaf in paths_leaves:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                        for k in path)
        if leaf is None:
            out.append(None)
            continue
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch at {key}: ckpt {arr.shape} vs template {leaf.shape}"
            )
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- paths -------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state: PyTree, extra: Optional[dict] = None,
             blocking: bool = True) -> None:
        flat = _flatten(state)   # gather on the caller thread (device -> host)
        manifest = {"step": step, **(extra or {})}

        def _write():
            tmp = os.path.join(self.directory, f"tmp.{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "state.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._rotate()

        self.wait()                 # bound in-flight async writes to one
        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _rotate(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> tuple[PyTree, dict]:
        """Returns (state, manifest). `shardings` (same structure as template)
        re-shards onto the CURRENT mesh — checkpoints don't remember meshes."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with np.load(os.path.join(d, "state.npz")) as z:
            flat = {k: z[k] for k in z.files if not k.startswith("__none__")}
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s) if x is not None else None,
                state, shardings, is_leaf=lambda x: x is None,
            )
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        return state, manifest
