"""Fault-tolerant checkpoint manager.

Design (DESIGN.md §5):
  * mesh-independent — arrays are saved as full logical values (gathered from
    shards), so a checkpoint written on a 256-chip mesh restores onto 512
    chips or 1 CPU (elastic scaling / downsizing after node loss);
  * atomic — write to `<dir>/tmp.<step>` then os.rename, so a preemption
    mid-write can never corrupt the latest checkpoint;
  * rotated — keeps the newest `keep` checkpoints;
  * async — `save(..., blocking=False)` hands the write to a daemon thread
    (the train loop overlaps the next steps with the I/O), with a barrier on
    the next save to bound in-flight writes;
  * resume metadata — step and data-stream position are in the manifest, so
    the deterministic data pipeline replays exactly;
  * SUMO layout migration — a checkpoint whose SUMO optimizer state was saved
    in the per-leaf layout restores into a bucket-resident template (and the
    reverse) via `_migrate_sumo_layouts`: the flat entries are re-stacked /
    re-sliced to the template's layout before unflattening, so flipping
    `SumoConfig.state_layout` between runs never invalidates checkpoints.
  * cross-MESH-SHAPE restore — bucket-resident Q stacks carry the writing
    mesh's edge-padded long dim (core.sumo.padded_long: all-zero pad rows so
    ragged long dims shard over `model`). The bucket key is the TRUE
    "LONGxSHORT" shape, so `_normalize_sumo_long_pads` can slice a padded
    stack back to true rows and re-pad it to whatever the restore TEMPLATE's
    mesh needs, with no mesh metadata stored: a checkpoint written on
    (data=8, model=1) restores onto (data=2, model=4) and vice versa, bit
    exactly (pad rows are zero by construction on both sides). The padding
    each save carries is recorded in the manifest (`sumo_long_pad`) for
    humans/tooling; restore never needs it.

Format: one .npz of flattened path->array plus a manifest.json.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from ..core.optimizer import BUCKET_KEY_RE, bucket_key
from ..core.sumo import SpectralStats, SumoState, sumo_state_layout

PyTree = Any
_SEP = "|"

# A SumoState.stats leaf in the flattened key space:
# [<prefix>|]stats|LONGxSHORT|<SpectralStats field>
_SUMO_STATS_KEY_RE = re.compile(
    r"(^|\|)stats\|\d+x\d+\|(%s)$" % "|".join(SpectralStats._fields))

# A bucket-resident SumoState.Q stack: [<prefix>|]Q|LONGxSHORT. The captured
# group is the TRUE long dim — the self-describing datum the cross-mesh
# long-pad migration slices/re-pads against.
_SUMO_BUCKET_Q_RE = re.compile(r"(?:^|\|)Q\|(\d+)x\d+$")

# The DP-compression CompressionState the train loop saves under the
# "comp_state" slot. Its EF residuals are a CORRECTION term, not model state:
# a checkpoint written before dp_compress existed (or with it off) restores
# into a dp template by keeping the template's zero residuals — EF simply
# cold-starts, which only costs a few steps of compression error.
_COMP_STATE_KEY_RE = re.compile(r"^comp_state(\||$)")
# Worker-stacked EF residuals specifically: comp_state|error|<param path>,
# leading dim = the writing run's data-axis size.
_COMP_ERROR_KEY_RE = re.compile(r"^comp_state\|error\|")


def _path_key(path) -> str:
    return _SEP.join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                     for k in path)


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None
    )[0]
    for path, leaf in leaves:
        key = _path_key(path)
        if leaf is None:
            flat[f"__none__{key}"] = np.zeros((0,))
        else:
            flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(template: PyTree, flat: dict[str, np.ndarray]) -> PyTree:
    """Rebuild using template's structure (dtypes/shapes validated)."""
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=lambda x: x is None
    )
    out = []
    for path, leaf in paths_leaves:
        key = _path_key(path)
        if leaf is None:
            out.append(None)
            continue
        if key not in flat:
            # Telemetry stats are derived per-step diagnostics, not training
            # state: a checkpoint written with probes off restores into a
            # probes-on template by keeping the template's zero-filled stats
            # (the reverse direction just ignores the extra saved entries).
            # Anchored to the exact SumoState.stats shape —
            # ...|stats|LONGxSHORT|<SpectralStats field> — so a model subtree
            # that happens to be named "stats" still raises on missing leaves.
            if _SUMO_STATS_KEY_RE.search(key):
                out.append(leaf)
                continue
            # Pre-dp (or dp-off) checkpoints carry no comp_state: keep the
            # template's fresh EF state (zero residuals, step 0) — see
            # _COMP_STATE_KEY_RE.
            if _COMP_STATE_KEY_RE.match(key):
                out.append(leaf)
                continue
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch at {key}: ckpt {arr.shape} vs template {leaf.shape}"
            )
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# SUMO cross-mesh long-pad migration (edge-padded bucket Q stacks)
# ---------------------------------------------------------------------------

def _normalize_sumo_long_pads(template: PyTree, flat: dict) -> dict:
    """Re-pad/slice bucket-resident SUMO Q stacks against the restore
    template's mesh padding.

    A Q stack saved as (B, padded_long, r) under key ``...|Q|LONGxSHORT``
    records its TRUE long dim in the key, so this needs no mesh metadata:
    pad rows beyond the true long dim are sliced off, then zero rows are
    appended up to whatever padded long the template (built by
    ``sumo(..., mesh=...)`` for the CURRENT mesh) expects. Saved pad rows
    are zero by the engine's invariant, so both directions are lossless;
    non-bucket entries and matching shapes pass through untouched. Runs
    before (and, via the caller, after) the layout migration, which only
    understands true-shaped stacks."""
    tmpl_longs: dict[str, int] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            template, is_leaf=lambda x: x is None)[0]:
        key = _path_key(path)
        if leaf is not None and _SUMO_BUCKET_Q_RE.search(key) \
                and getattr(leaf, "ndim", 0) == 3:
            tmpl_longs[key] = int(leaf.shape[-2])
    out = dict(flat)
    for key, arr in flat.items():
        m = _SUMO_BUCKET_Q_RE.search(key)
        if m is None or arr.ndim != 3:
            continue
        true_long = int(m.group(1))
        if arr.shape[-2] < true_long:
            # only rows BEYOND the true long dim are pads; fewer rows than
            # the key promises is a truncated/corrupt stack — zero-filling
            # it would silently resume training from a basis with missing
            # rows, so fail loudly like any other shape mismatch.
            raise ValueError(
                f"checkpoint bucket stack {key!r} has {arr.shape[-2]} rows "
                f"but its key records a true long dim of {true_long} — "
                "truncated or corrupt checkpoint")
        target = tmpl_longs.get(key, true_long)
        if arr.shape[-2] == target:
            continue
        if arr.shape[-2] > true_long:          # drop the writer's pad rows
            arr = arr[:, :true_long, :]
        if target > arr.shape[-2]:             # re-pad for the reader's mesh
            pad = np.zeros(
                (arr.shape[0], target - arr.shape[-2], arr.shape[-1]),
                arr.dtype)
            arr = np.concatenate([arr, pad], axis=1)
        out[key] = arr
    return out


def _long_pad_manifest(flat: dict) -> dict:
    """{flat key: {"true": L, "padded": Lp}} for every bucket Q stack saved
    with an edge-padded long dim — recorded in the manifest so a human (or
    external tooling) can see which mesh shape padded the checkpoint;
    restore itself re-derives everything from the keys."""
    pads = {}
    for key, arr in flat.items():
        m = _SUMO_BUCKET_Q_RE.search(key)
        if m is not None and arr.ndim == 3 and arr.shape[-2] != int(m.group(1)):
            pads[key] = {"true": int(m.group(1)), "padded": int(arr.shape[-2])}
    return pads


# ---------------------------------------------------------------------------
# DP-compression EF residual migration (elastic data-axis size)
# ---------------------------------------------------------------------------

def _migrate_comp_worker_axis(template: PyTree, flat: dict) -> dict:
    """Redistribute worker-stacked EF residuals across a different data-axis
    size.

    ``comp_state|error|...`` entries are (n_workers, *grad_shape) — one EF
    residual per DP worker. Restoring onto W' != W workers keeps the SUM of
    the residuals (the quantity the decompressed mean is off by: the mean
    gradient error equals sum(e_w)/batch-weighting, and compress/EF are
    linear in e), splitting it evenly: e'_i = sum_w(e_w) / W'. The global
    correction the next steps apply is then unchanged, only its per-worker
    attribution resets. Matching worker counts pass through untouched."""
    tmpl_workers: dict[str, tuple] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            template, is_leaf=lambda x: x is None)[0]:
        key = _path_key(path)
        if leaf is not None and _COMP_ERROR_KEY_RE.match(key):
            tmpl_workers[key] = tuple(leaf.shape)
    out = dict(flat)
    for key, arr in flat.items():
        if not _COMP_ERROR_KEY_RE.match(key) or key not in tmpl_workers:
            continue
        want = tmpl_workers[key]
        have = tuple(arr.shape)
        if have == want:
            continue
        if arr.ndim != len(want) or have[1:] != want[1:]:
            raise ValueError(
                f"comp_state residual {key!r}: ckpt shape {have} vs template "
                f"{want} — only the leading worker dim may differ")
        w_new = int(want[0])
        total = arr.sum(axis=0, dtype=arr.dtype)
        out[key] = np.broadcast_to(total / w_new, want).astype(arr.dtype)
    return out


# ---------------------------------------------------------------------------
# SUMO state-layout migration (per-leaf <-> bucket-resident)
# ---------------------------------------------------------------------------

def _flat_sumo_layout(flat: dict, pfx: str) -> Optional[str]:
    """Layout of the SumoState saved under `pfx` in `flat`: 'bucket' iff every
    Q entry is keyed by a canonical 'LONGxSHORT' bucket id; None if absent."""
    suffixes = [k[len(pfx) + 2:] for k in flat if k.startswith(f"{pfx}Q{_SEP}")]
    if not suffixes:
        return None
    return "bucket" if all(BUCKET_KEY_RE.match(s) for s in suffixes) else "leaf"


def _migrate_sumo_layouts(template: PyTree, flat: dict) -> dict:
    """Rewrite `flat` entries for every SumoState subtree whose on-disk layout
    differs from the template's.

    Both directions are pure data movement and need no stored plan: the
    bucket key is a function of the state shapes alone (Q is (long, r), M is
    (r, short), orientation-free), and the slot order within a bucket is the
    leaf flatten order — identical at save and restore time because both are
    flattenings of the same (static) param structure. Masked leaves (None,
    saved as `__none__` markers) occupy no bucket slots on either side.
    """
    out = dict(flat)
    nodes = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=lambda x: isinstance(x, SumoState) or x is None
    )[0]
    for path, node in nodes:
        if not isinstance(node, SumoState):
            continue
        prefix = _path_key(path)
        pfx = f"{prefix}{_SEP}" if prefix else ""
        src = _flat_sumo_layout(flat, pfx)
        dst = sumo_state_layout(node)
        if src is None or src == dst:
            continue
        if dst == "bucket":
            # per-leaf ckpt -> bucket-resident template: stack leaf entries
            # into buckets in their flat (== flatten) order.
            buckets: dict[str, tuple[list, list, list]] = {}
            for qk in [k for k in flat if k.startswith(f"{pfx}Q{_SEP}")]:
                suffix = qk[len(pfx) + 2:]
                mk = f"{pfx}M{_SEP}{suffix}"
                pk = f"{pfx}prev_norm{_SEP}{suffix}"
                Qa, Ma, pna = flat[qk], flat[mk], flat[pk]
                bkey = bucket_key(Qa.shape[-2], Ma.shape[-1])
                qs, ms, pns = buckets.setdefault(bkey, ([], [], []))
                qs.append(Qa.reshape((-1,) + Qa.shape[-2:]))
                ms.append(Ma.reshape((-1,) + Ma.shape[-2:]))
                pns.append(pna.reshape(-1))
                for k in (qk, mk, pk):
                    del out[k]
            for bkey, (qs, ms, pns) in buckets.items():
                out[f"{pfx}Q{_SEP}{bkey}"] = np.concatenate(qs, axis=0)
                out[f"{pfx}M{_SEP}{bkey}"] = np.concatenate(ms, axis=0)
                out[f"{pfx}prev_norm{_SEP}{bkey}"] = np.concatenate(pns, axis=0)
        else:
            # bucket-resident ckpt -> per-leaf template: slice each leaf's
            # slots back out, walking template leaves in flatten order.
            none_leaf = lambda x: x is None
            q_leaves = jax.tree_util.tree_flatten_with_path(node.Q, is_leaf=none_leaf)[0]
            m_leaves = jax.tree_util.tree_flatten_with_path(node.M, is_leaf=none_leaf)[0]
            pn_leaves = jax.tree_util.tree_flatten_with_path(node.prev_norm,
                                                             is_leaf=none_leaf)[0]
            offsets: dict[str, int] = {}
            for (lpath, qt), (_, mt), (_, pt) in zip(q_leaves, m_leaves, pn_leaves):
                if qt is None:
                    continue
                bkey = bucket_key(qt.shape[-2], mt.shape[-1])
                cnt = 1
                for d in qt.shape[:-2]:
                    cnt *= int(d)
                off = offsets.get(bkey, 0)
                offsets[bkey] = off + cnt
                suffix = _path_key(lpath)
                sl = slice(off, off + cnt)
                out[f"{pfx}Q{_SEP}{suffix}"] = (
                    flat[f"{pfx}Q{_SEP}{bkey}"][sl].reshape(qt.shape))
                out[f"{pfx}M{_SEP}{suffix}"] = (
                    flat[f"{pfx}M{_SEP}{bkey}"][sl].reshape(mt.shape))
                out[f"{pfx}prev_norm{_SEP}{suffix}"] = (
                    flat[f"{pfx}prev_norm{_SEP}{bkey}"][sl].reshape(pt.shape))
            for bkey in offsets:
                for field in ("Q", "M", "prev_norm"):
                    out.pop(f"{pfx}{field}{_SEP}{bkey}", None)
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- paths -------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_manifest(self, step: Optional[int] = None) -> dict:
        """Manifest alone, without restoring state — lets callers adapt the
        restore TEMPLATE to what the checkpoint recorded (e.g. the
        controller's per-bucket settings that shaped the optimizer state)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return json.load(f)

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state: PyTree, extra: Optional[dict] = None,
             blocking: bool = True) -> None:
        flat = _flatten(state)   # gather on the caller thread (device -> host)
        manifest = {"step": step, **(extra or {})}
        pads = _long_pad_manifest(flat)
        if pads:
            manifest["sumo_long_pad"] = pads

        def _write():
            tmp = os.path.join(self.directory, f"tmp.{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "state.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._rotate()

        self.wait()                 # bound in-flight async writes to one
        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _rotate(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> tuple[PyTree, dict]:
        """Returns (state, manifest). `shardings` (same structure as template)
        re-shards onto the CURRENT mesh — checkpoints don't remember meshes."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with np.load(os.path.join(d, "state.npz")) as z:
            # insertion order == save-time flatten order (zip member order) —
            # the layout migration's slot ordering relies on this.
            flat = {k: z[k] for k in z.files if not k.startswith("__none__")}
        # Cross-mesh-shape restore: bucket Q stacks re-pad/slice to the
        # template's edge padding first (the layout migration below only
        # understands true-shaped stacks, and `_unflatten_into` would reject
        # a pad-induced shape mismatch as corruption).
        flat = _normalize_sumo_long_pads(template, flat)
        # Elastic DP restore: worker-stacked EF residuals written with a
        # different data-axis size redistribute (sum-preserving) BEFORE the
        # unflatten — a worker-dim mismatch is a ValueError there, not the
        # KeyError the layout retry path catches.
        flat = _migrate_comp_worker_axis(template, flat)
        try:
            state = _unflatten_into(template, flat)
        except KeyError:
            # SUMO state layout changed between save and restore (per-leaf vs
            # bucket-resident): migrate the flat entries, then retry — any
            # genuinely missing leaf still raises from the second attempt.
            # (Normalize again: a leaf-layout checkpoint restacks to TRUE
            # long dims, which a 2D-mesh bucket template needs re-padded.)
            state = _unflatten_into(template, _normalize_sumo_long_pads(
                template, _migrate_sumo_layouts(template, flat)))
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s) if x is not None else None,
                state, shardings, is_leaf=lambda x: x is None,
            )
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        return state, manifest
