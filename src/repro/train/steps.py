"""Train/eval step builders: loss + grad + optimizer update, with gradient
accumulation (microbatch scan) and the optimizer factory used by the
launcher, benchmarks and examples."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import (
    GaloreConfig,
    SumoConfig,
    adamw,
    apply_updates,
    galore_optimizer,
    global_norm,
    muon_optimizer,
    sumo_optimizer,
)
from ..models import loss_fn
from ..telemetry.probes import extract_stats


def make_optimizer(name: str, learning_rate, params, cfg: Optional[ArchConfig] = None,
                   rank: int = 128, update_freq: int = 200, weight_decay: float = 0.0,
                   bucketed: bool = True, state_layout: str = "auto",
                   mesh=None, **kw):
    """Factory: sumo | sumo-ns5 | galore | muon | adamw.

    ``bucketed`` selects SUMO's stacked same-shape update engine (one refresh
    cond/rSVD per bucket); False falls back to the per-leaf reference engine.
    ``state_layout`` picks where SUMO's Q/M/prev_norm live ("auto" =
    bucket-resident under the bucketed engine, per-leaf otherwise); ``mesh``
    enables SUMO's shard_map bucket-update path. Non-SUMO optimizers ignore
    all three. Extra ``**kw`` reach SumoConfig — notably ``telemetry=True``
    (spectral probes) and ``bucket_overrides`` (the controller's per-bucket
    rank/refresh settings).
    """
    name = name.lower()
    if name == "sumo":
        return sumo_optimizer(
            learning_rate, params,
            SumoConfig(rank=rank, update_freq=update_freq, bucketed=bucketed,
                       state_layout=state_layout, weight_decay=weight_decay,
                       orth_method="polar", **kw),
            mesh=mesh,
        )
    if name == "sumo-svd":
        return sumo_optimizer(
            learning_rate, params,
            SumoConfig(rank=rank, update_freq=update_freq, bucketed=bucketed,
                       state_layout=state_layout, weight_decay=weight_decay,
                       orth_method="svd", **kw),
            mesh=mesh,
        )
    if name == "sumo-ns5":
        return sumo_optimizer(
            learning_rate, params,
            SumoConfig(rank=rank, update_freq=update_freq, bucketed=bucketed,
                       state_layout=state_layout, weight_decay=weight_decay,
                       orth_method="ns5", **kw),
            mesh=mesh,
        )
    if name == "galore":
        return galore_optimizer(
            learning_rate, params,
            GaloreConfig(rank=rank, update_freq=update_freq,
                         weight_decay=weight_decay, **kw),
        )
    if name == "muon":
        return muon_optimizer(learning_rate, params, weight_decay=weight_decay, **kw)
    if name == "adamw":
        return adamw(learning_rate, weight_decay=weight_decay, **kw)
    raise ValueError(f"unknown optimizer {name!r}")


def make_train_step(cfg: ArchConfig, tx, attn_impl: str = "flash",
                    accum: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    accum > 1 splits the batch into `accum` microbatches along dim 0 and
    accumulates grads with a lax.scan — constant memory in accum.
    """

    def loss(p, b):
        return loss_fn(p, cfg, b, attn_impl=attn_impl)

    def train_step(params, opt_state, batch):
        if accum == 1:
            l, grads = jax.value_and_grad(loss)(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]), batch
            )

            def body(carry, mb):
                tot_l, tot_g = carry
                l, g = jax.value_and_grad(loss)(params, mb)
                tot_g = jax.tree_util.tree_map(jnp.add, tot_g, g)
                return (tot_l + l, tot_g), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (l, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero_g), micro)
            l = l / accum
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)

        updates, new_opt_state = tx.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        metrics = {
            "loss": l,
            "grad_norm": global_norm(grads),
            "update_norm": global_norm(updates),
        }
        # Spectral telemetry rides along as ordinary jit outputs (device
        # arrays, no host sync here); the loop hands them to the async sink.
        tel = extract_stats(new_opt_state)
        if tel:
            metrics["telemetry"] = tel
        return new_params, new_opt_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig, attn_impl: str = "chunked") -> Callable:
    def eval_step(params, batch):
        return loss_fn(params, cfg, batch, attn_impl=attn_impl)

    return eval_step
