"""Train/eval step builders: loss + grad + optimizer update, with gradient
accumulation (microbatch scan), the optimizer factory used by the launcher,
benchmarks and examples, and the compressed data-parallel gradient exchange
(``dp=``): loss/grad/compress/pmean/decompress run inside a shard_map over
the mesh's ``data`` axis, the optimizer update outside it."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import (
    GaloreConfig,
    SumoConfig,
    adamw,
    apply_updates,
    galore_optimizer,
    global_norm,
    muon_optimizer,
    sumo_optimizer,
)
from ..models import loss_fn
from ..telemetry.probes import extract_stats


def make_optimizer(name: str, learning_rate, params, cfg: Optional[ArchConfig] = None,
                   rank: int = 128, update_freq: int = 200, weight_decay: float = 0.0,
                   bucketed: bool = True, state_layout: str = "auto",
                   mesh=None, **kw):
    """Factory: sumo | sumo-ns5 | galore | muon | adamw.

    ``bucketed`` selects SUMO's stacked same-shape update engine (one refresh
    cond/rSVD per bucket); False falls back to the per-leaf reference engine.
    ``state_layout`` picks where SUMO's Q/M/prev_norm live ("auto" =
    bucket-resident under the bucketed engine, per-leaf otherwise); ``mesh``
    enables SUMO's shard_map bucket-update path. Non-SUMO optimizers ignore
    all three. Extra ``**kw`` reach SumoConfig — notably ``telemetry=True``
    (spectral probes) and ``bucket_overrides`` (the controller's per-bucket
    rank/refresh settings).
    """
    name = name.lower()
    if name == "sumo":
        return sumo_optimizer(
            learning_rate, params,
            SumoConfig(rank=rank, update_freq=update_freq, bucketed=bucketed,
                       state_layout=state_layout, weight_decay=weight_decay,
                       orth_method="polar", **kw),
            mesh=mesh,
        )
    if name == "sumo-svd":
        return sumo_optimizer(
            learning_rate, params,
            SumoConfig(rank=rank, update_freq=update_freq, bucketed=bucketed,
                       state_layout=state_layout, weight_decay=weight_decay,
                       orth_method="svd", **kw),
            mesh=mesh,
        )
    if name == "sumo-ns5":
        return sumo_optimizer(
            learning_rate, params,
            SumoConfig(rank=rank, update_freq=update_freq, bucketed=bucketed,
                       state_layout=state_layout, weight_decay=weight_decay,
                       orth_method="ns5", **kw),
            mesh=mesh,
        )
    if name == "galore":
        return galore_optimizer(
            learning_rate, params,
            GaloreConfig(rank=rank, update_freq=update_freq,
                         weight_decay=weight_decay, **kw),
        )
    if name == "muon":
        return muon_optimizer(learning_rate, params, weight_decay=weight_decay, **kw)
    if name == "adamw":
        return adamw(learning_rate, weight_decay=weight_decay, **kw)
    raise ValueError(f"unknown optimizer {name!r}")


class DpCompression(NamedTuple):
    """Spec for the compressed DP gradient exchange inside the train step:
    ``mesh`` must carry ``data_axis``; ``cfg`` is the
    ``parallel.compression.CompressionConfig`` (``use_sketch=False`` expects
    the resident SUMO bases as the step's ``bases`` argument — see
    ``core.sumo.sumo_dp_bases``)."""
    mesh: Any
    cfg: Any                     # parallel.compression.CompressionConfig
    data_axis: str = "data"


def make_train_step(cfg: ArchConfig, tx, attn_impl: str = "flash",
                    accum: int = 1, dp: Optional[DpCompression] = None
                    ) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    accum > 1 splits the batch into `accum` microbatches along dim 0 and
    accumulates grads with a lax.scan — constant memory in accum.

    ``dp`` switches the step to the COMPRESSED data-parallel gradient
    exchange (ROADMAP item 1): the signature becomes
    ``train_step(params, opt_state, comp_state, batch, bases)
    -> (params, opt_state, comp_state, metrics)``. Per-worker gradients are
    materialized with an EXPLICIT worker axis — the batch reshapes to
    (n_workers, per_worker, ...) and the loss/backward runs under
    ``jax.vmap`` with params broadcast, so the gradient stacks come out
    (n_workers, *shape) with the worker dim sharded over ``data`` and each
    worker's backward running on its own devices (no cross-``data`` gradient
    traffic: worker rows are independent). The gradient mean is then
    replaced by a shard_map that is MANUAL over ``data`` only (every other
    mesh axis stays automatic, so Megatron-sharded gradient leaves pass
    through untouched) wrapping ``parallel.compression.exchange_shard`` —
    compress, ``lax.pmean`` of the r×short payload, decompress, per-worker
    EF residual into ``comp_state``. ``tx.update`` runs on the replicated
    mean OUTSIDE the shard_map, so the optimizer's own collective story is
    untouched (and separately budget-audited). ``bases`` is the replicated
    resident-basis tree for ``use_sketch=False`` (None under the seeded
    sketch).

    Why the loss/backward is NOT inside the shard_map: this jaxlib's GSPMD
    partitioner hard-crashes (``Check failed: sharding.IsManualSubgroup()``)
    on a ``lax.scan`` whose xs are sharded over an AUTO axis of a
    partially-manual shard_map — i.e. the transformer block scan over
    Megatron-sharded stacked weights at model_parallel > 1. The vmapped
    worker axis expresses the same per-worker computation in fully
    automatic SPMD, where scan-over-sharded-xs is the long-tested path; the
    no-full-gradient-collective property this buys is machine-checked by
    ``analysis.collectives.steady_dp_compressed_budget`` on the compiled
    step rather than assumed from the program structure.
    """

    def loss(p, b):
        return loss_fn(p, cfg, b, attn_impl=attn_impl)

    def loss_and_grads(params, batch):
        if accum == 1:
            return jax.value_and_grad(loss)(params, batch)
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]), batch
        )

        def body(carry, mb):
            tot_l, tot_g = carry
            l, g = jax.value_and_grad(loss)(params, mb)
            tot_g = jax.tree_util.tree_map(jnp.add, tot_g, g)
            return (tot_l + l, tot_g), None

        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (l, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero_g), micro)
        return l / accum, jax.tree_util.tree_map(lambda g: g / accum, grads)

    def finish(l, grads, params, opt_state):
        updates, new_opt_state = tx.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        metrics = {
            "loss": l,
            "grad_norm": global_norm(grads),
            "update_norm": global_norm(updates),
        }
        # Spectral telemetry rides along as ordinary jit outputs (device
        # arrays, no host sync here); the loop hands them to the async sink.
        tel = extract_stats(new_opt_state)
        if tel:
            metrics["telemetry"] = tel
        return new_params, new_opt_state, metrics

    if dp is None:
        def train_step(params, opt_state, batch):
            l, grads = loss_and_grads(params, batch)
            return finish(l, grads, params, opt_state)

        return train_step

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..parallel.compression import (
        CompressionState,
        exchange_shard,
        step_bases,
    )

    axis = dp.data_axis
    n_workers = int(dp.mesh.shape[axis])
    auto = frozenset(a for a in dp.mesh.axis_names if a != axis)
    none_leaf = lambda x: x is None
    squeeze = lambda t: jax.tree_util.tree_map(
        lambda x: None if x is None else x[0], t, is_leaf=none_leaf)
    expand = lambda t: jax.tree_util.tree_map(
        lambda x: None if x is None else x[None], t, is_leaf=none_leaf)

    def exchange_body(grads, comp_state, bases):
        # Each shard sees its own worker row: (1, *shape) -> squeeze.
        local = CompressionState(step=comp_state.step,
                                 error=squeeze(comp_state.error))
        mean_g, new_local = exchange_shard(squeeze(grads), local, dp.cfg,
                                           axis, bases=bases)
        new_comp = CompressionState(step=new_local.step,
                                    error=expand(new_local.error))
        return mean_g, new_comp

    comp_spec = CompressionState(step=P(), error=P(axis))
    exchange = shard_map(
        exchange_body, dp.mesh,
        in_specs=(P(axis), comp_spec, P()),
        out_specs=(P(), comp_spec),
        check_rep=False,
        **({"auto": auto} if auto else {}),
    )

    def dp_train_step(params, opt_state, comp_state, batch, bases):
        # Explicit worker axis: (B, ...) -> (W, B/W, ...), loss/backward
        # vmapped with params broadcast. The worker dim shards over `data`
        # (the loop enforces global_batch % data == 0), so this is ordinary
        # data parallelism with the per-worker gradients kept apart instead
        # of psummed by the partitioner.
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape((n_workers, x.shape[0] // n_workers)
                                + x.shape[1:]), batch)
        losses, grads = jax.vmap(loss_and_grads, in_axes=(None, 0))(params,
                                                                    micro)
        # per-shard means over equal shard sizes -> their mean is the
        # global per-token mean exactly (one scalar all-reduce).
        l = jnp.mean(losses)
        # Effective bases (sketches generated / zero resident Qs
        # bootstrapped) prepared OUTSIDE the shard_map: replicated
        # deterministic compute, no collective — see step_bases.
        eff_bases = step_bases(params, comp_state.step, dp.cfg, bases=bases)
        grads, new_comp = exchange(grads, comp_state, eff_bases)
        new_params, new_opt_state, metrics = finish(l, grads, params,
                                                    opt_state)
        return new_params, new_opt_state, new_comp, metrics

    return dp_train_step


def make_eval_step(cfg: ArchConfig, attn_impl: str = "chunked") -> Callable:
    def eval_step(params, batch):
        return loss_fn(params, cfg, batch, attn_impl=attn_impl)

    return eval_step
