"""repro.train — train-step builders, checkpointing, fault tolerance, loop."""
from .checkpoint import CheckpointManager
from .failures import (
    FaultInjector,
    SimulatedPreemption,
    StragglerMonitor,
    StragglerTimeout,
    supervise,
)
from .loop import TrainConfig, TrainResult, train
from .steps import make_eval_step, make_optimizer, make_train_step

__all__ = [
    "make_train_step", "make_eval_step", "make_optimizer",
    "CheckpointManager", "FaultInjector", "StragglerMonitor",
    "SimulatedPreemption", "StragglerTimeout", "supervise",
    "TrainConfig", "TrainResult", "train",
]
