"""The training loop: jitted step + checkpointing + fault tolerance +
deterministic data replay + spectral telemetry and the adaptive rank/refresh
controller. Used by examples/ and launch/train.py.

Telemetry wiring: ``TrainConfig.telemetry`` turns on SUMO's on-device
spectral probes; each step's per-bucket stats are handed (still as device
arrays — no extra host sync) to an async ``TelemetrySink`` whose background
thread drains them to JSONL off the critical path. ``TrainConfig.controller``
additionally runs a ``RankRefreshController`` every ``controller_interval``
steps (default: the refresh cadence, so decisions land on refresh
boundaries): changed decisions rebuild the optimizer with new
``bucket_overrides`` (a static config ⇒ one controlled recompile), resize the
bucket-resident state, and are recorded in ``TrainResult.controller_events``.
Checkpoints record the per-bucket settings that shaped their optimizer state
in the manifest, and fault recovery adopts them before building the restore
template — restores work on either side of a controller decision.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..data import DataConfig, make_batch
from ..models import init_params
from ..telemetry import (
    ControllerConfig,
    JsonlWriter,
    RankRefreshController,
    TelemetrySink,
    apply_decisions,
    initial_settings,
    overrides_from_settings,
)
from ..analysis.recompile import mark_step
from .checkpoint import CheckpointManager
from .failures import FaultInjector, StragglerMonitor, supervise
from .steps import make_optimizer, make_train_step


@dataclasses.dataclass
class TrainConfig:
    optimizer: str = "sumo"
    learning_rate: float = 3e-3
    rank: int = 128
    update_freq: int = 200
    weight_decay: float = 0.0
    # SUMO state layout ("auto" | "leaf" | "bucket"): checkpoints written in
    # either layout restore into either (checkpoint.py migrates on restore).
    state_layout: str = "auto"
    total_steps: int = 100
    accum: int = 1
    attn_impl: str = "flash"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    ckpt_async: bool = True
    log_every: int = 10
    seed: int = 0
    # -- spectral telemetry (SUMO only) ------------------------------------
    telemetry: bool = False            # emit per-bucket SpectralStats
    telemetry_out: Optional[str] = None  # JSONL path (None = collect only)
    telemetry_window: int = 8          # sliding-window size per bucket
    # -- adaptive rank/refresh controller (implies telemetry) --------------
    controller: bool = False
    controller_interval: int = 0       # steps between checks; 0 = update_freq
    controller_config: Optional[ControllerConfig] = None
    # -- sharded training on the (data, model) host mesh -------------------
    # > 0 builds a (data, model) host mesh (launch.mesh.make_host_mesh) that
    # the WHOLE step consumes: params are placed by parallel.sharding's
    # Megatron param specs, optimizer state by opt_state_specs (bucket-
    # resident SUMO state: B over `data`, Q's edge-padded long dim over
    # `model` — ragged long dims included), batches shard over `data`, and
    # the SUMO bucket update runs under shard_map (model_parallel > 1 = the
    # 2D distributed-rSVD path). Checkpoints restore re-sharded onto this
    # mesh, including checkpoints written on a different mesh shape.
    # 0 = single-device (the default).
    model_parallel: int = 0
    # Raise instead of clamping when model_parallel doesn't divide the
    # device count (launch.mesh.make_host_mesh strict mode).
    strict_mesh: bool = False
    # -- compressed DP gradient exchange (ROADMAP item 1) ------------------
    # Replace the full-gradient data-parallel mean with compress -> pmean of
    # the r×short payload -> decompress inside the step's shard_map over
    # `data` (parallel.compression.exchange_shard), with the per-worker EF
    # residual carried as a CompressionState slot of the train state
    # (donated and checkpointed like the rest). Requires model_parallel > 0
    # (use 1 for pure DP: the mesh is (data=N, model=1)).
    dp_compress: bool = False
    dp_compress_rank: int = 32
    # "sketch": zero-coordination seeded sketch basis. "sumo-q": reuse the
    # optimizer's resident rSVD Q (core.sumo.sumo_dp_bases) — extracted and
    # replicated once per refresh boundary (the one broadcast per refresh),
    # never inside the steady-state step.
    dp_compress_basis: str = "sketch"
    dp_compress_min_dim: int = 256
    dp_compress_ef: bool = True


@dataclasses.dataclass
class TrainResult:
    losses: list
    final_step: int
    restarts: int
    params: object
    opt_state: object
    telemetry_records: int = 0
    controller_events: list = dataclasses.field(default_factory=list)


def train(
    arch: ArchConfig,
    shape: ShapeConfig,
    tcfg: TrainConfig,
    fault_injector: Optional[FaultInjector] = None,
    log_fn: Callable[[str], None] = print,
) -> TrainResult:
    key = jax.random.PRNGKey(tcfg.seed)
    params0 = init_params(arch, key)

    telemetry_on = tcfg.telemetry or tcfg.controller
    if telemetry_on and not tcfg.optimizer.startswith("sumo"):
        raise ValueError(
            f"telemetry/controller require a SUMO optimizer, "
            f"got {tcfg.optimizer!r}")
    if tcfg.controller and tcfg.state_layout == "leaf":
        # fail fast: rank resizes need the bucket-resident stacks — don't
        # let a run crash hours in at the first grow/shrink decision.
        raise ValueError(
            "controller rank adaptation requires bucket-resident SUMO state "
            "(state_layout 'auto' or 'bucket', got 'leaf')")

    # Per-bucket settings (rank/update_freq) — the controller's mutable view.
    settings = initial_settings(params0, tcfg.rank, tcfg.update_freq)

    if tcfg.dp_compress:
        if tcfg.model_parallel <= 0:
            raise ValueError(
                "dp_compress runs inside the step's shard_map over the "
                "(data, model) host mesh — set model_parallel > 0 "
                "(1 = pure data parallelism)")
        if tcfg.dp_compress_basis not in ("sketch", "sumo-q"):
            raise ValueError(
                f"unknown dp_compress_basis {tcfg.dp_compress_basis!r} "
                "(have: sketch, sumo-q)")
        if (tcfg.dp_compress_basis == "sumo-q"
                and not tcfg.optimizer.startswith("sumo")):
            raise ValueError(
                "dp_compress_basis='sumo-q' reuses the optimizer's resident "
                f"rSVD Q — requires a SUMO optimizer, got {tcfg.optimizer!r}")

    mesh = None
    dp = None
    comp_cfg = None
    place_params = place_opt = place_batch = place_comp = lambda x: x
    if tcfg.model_parallel > 0:
        from ..launch.mesh import make_host_mesh
        from ..parallel.sharding import (
            batch_spec,
            comp_state_specs,
            opt_state_specs,
            tree_param_specs,
            tree_shardings,
        )
        mesh = make_host_mesh(model=tcfg.model_parallel,
                              strict=tcfg.strict_mesh)

        def _place(tree, specs):
            """device_put each leaf onto its NamedSharding (None leaves and
            None specs pass through)."""
            sh = tree_shardings(specs, mesh)
            return jax.tree_util.tree_map(
                lambda x, s: x if x is None or s is None
                else jax.device_put(x, s),
                tree, sh, is_leaf=lambda x: x is None)

        place_params = lambda p: _place(p, tree_param_specs(p, mesh, arch))
        # opt_state_specs re-derives specs from the CURRENT state shapes —
        # called per placement so controller resizes and padded bucket
        # stacks always get fresh, consistent specs.
        place_opt = lambda s: _place(s, opt_state_specs(s, mesh, arch))
        place_batch = lambda b: {
            k: jax.device_put(v, jax.sharding.NamedSharding(
                mesh, batch_spec(mesh, v.ndim,
                                 v.ndim > 0
                                 and v.shape[0] % mesh.shape["data"] == 0)))
            for k, v in b.items()}

        if tcfg.dp_compress:
            from ..parallel.compression import (
                CompressionConfig,
                init_worker_state,
            )
            from .steps import DpCompression
            n_data = int(mesh.shape["data"])
            if shape.global_batch % n_data:
                raise ValueError(
                    f"dp_compress shards the batch MANUALLY over data: "
                    f"global_batch {shape.global_batch} must divide by the "
                    f"data axis ({n_data})")
            comp_cfg = CompressionConfig(
                rank=tcfg.dp_compress_rank, seed=tcfg.seed,
                min_dim=tcfg.dp_compress_min_dim,
                error_feedback=tcfg.dp_compress_ef,
                use_sketch=(tcfg.dp_compress_basis == "sketch"))
            dp = DpCompression(mesh, comp_cfg)
            fresh_comp = lambda: init_worker_state(params0, comp_cfg, n_data)
            place_comp = lambda s: _place(s, comp_state_specs(s, mesh))

    # sumo-q basis reuse: a SEPARATE tiny jitted program extracts the
    # per-leaf bases from the resident (sharded) bucket stacks, and the
    # result is replicated once — the advertised one broadcast per refresh.
    # The steady-state step consumes the replicated tree as a plain input,
    # so its compiled program has no basis collective at all
    # (machine-checked by steady_dp_compressed_budget).
    extract_bases = None
    if dp is not None and not comp_cfg.use_sketch:
        from ..core.optimizer import partition_params
        from ..core.sumo import sumo_dp_bases
        labels = partition_params(params0)
        masked_tmpl = jax.tree_util.tree_map(
            lambda p, lab: p if lab == "matrix" else None, params0, labels)
        _extract = jax.jit(lambda st: sumo_dp_bases(st, masked_tmpl))
        rep_sh = jax.sharding.NamedSharding(mesh,
                                            jax.sharding.PartitionSpec())

        def extract_bases(opt_state):
            return jax.tree_util.tree_map(
                lambda x: None if x is None else jax.device_put(x, rep_sh),
                _extract(opt_state["matrix"]),
                is_leaf=lambda x: x is None)

    def _refresh_freqs():
        """Every refresh cadence currently in play (global + per-bucket
        controller overrides) — after a step s with s % f == 0 for any of
        them, some bucket's Q may have refreshed, so sumo-q bases re-extract.
        (Adaptive-quality refreshes can fire off-cadence; the bases then stay
        stale-but-worker-consistent until the next boundary, which EF
        absorbs — same contract as a plain sketch basis.)"""
        freqs = {tcfg.update_freq}
        for st_ in settings.values():
            f = getattr(st_, "update_freq", 0)
            if f:
                freqs.add(f)
        return freqs

    def build(overrides):
        """(tx, jitted step_fn) for the current bucket overrides — each
        rebuild is the controlled recompile point."""
        kw = {}
        if telemetry_on:
            kw["telemetry"] = True
            kw["bucket_overrides"] = overrides
        tx = make_optimizer(
            tcfg.optimizer, tcfg.learning_rate, params0,
            rank=tcfg.rank, update_freq=tcfg.update_freq,
            weight_decay=tcfg.weight_decay, state_layout=tcfg.state_layout,
            mesh=mesh,
            **kw,
        )
        step_fn = jax.jit(
            make_train_step(arch, tx, attn_impl=tcfg.attn_impl,
                            accum=tcfg.accum, dp=dp),
            # dp adds comp_state as arg 2 — its EF residuals are step-local
            # scratch between steps, so donate them too.
            donate_argnums=(0, 1, 2) if dp is not None else (0, 1),
        )
        return tx, step_fn

    tx, step_fn = build(overrides_from_settings(settings) if telemetry_on
                        else ())

    sink = ctrl = None
    ctrl_interval = 0
    if telemetry_on:
        ccfg = tcfg.controller_config or ControllerConfig()
        window = tcfg.telemetry_window
        if tcfg.controller and window < ccfg.window:
            # a sink window smaller than the controller's would keep
            # WindowAggregate.n below the decide threshold forever —
            # silently disabling the controller. Widen it.
            window = ccfg.window
        writers = [JsonlWriter(tcfg.telemetry_out)] if tcfg.telemetry_out else []
        sink = TelemetrySink(writers=writers, window=window)
        sink.set_settings(settings, default_freq=tcfg.update_freq)
        sink.start()
        if tcfg.controller:
            ctrl = RankRefreshController(ccfg)
            ctrl_interval = tcfg.controller_interval or tcfg.update_freq

    ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep) if tcfg.ckpt_dir else None
    monitor = StragglerMonitor(enabled=fault_injector is not None)
    losses: list = []
    restarts = [0]
    holder = {}
    controller_events: list = []

    def run_from(start_step: int) -> int:
        nonlocal tx, step_fn, settings
        # A restart is a fresh process in production: forget step timings so
        # the resume step's (re)compile doesn't read as a straggler.
        monitor.note_recompile()
        # params0 must survive this run's donation (the jitted step donates
        # its params argument) so later cold restarts and restore templates
        # still work — hand the loop a copy, keep the original alive.
        fresh_params = lambda: jax.tree_util.tree_map(
            lambda x: x.copy(), params0)
        comp_state = None
        if start_step == -1:  # resume from latest checkpoint
            restarts[0] += 1
            if ckpt.latest_step() is None:
                params = place_params(fresh_params())
                opt_state = place_opt(tx.init(params0))
                if dp is not None:
                    comp_state = place_comp(fresh_comp())
                step = 0
                log_fn(f"[recovery] no checkpoint yet — cold restart (#{restarts[0]})")
            else:
                if telemetry_on:
                    # The manifest records the per-bucket settings the
                    # checkpoint's state was SHAPED by (saved below) — adopt
                    # them before building the restore template, otherwise a
                    # checkpoint on the far side of a controller rank change
                    # would fail the template's shape check.
                    saved = ckpt.read_manifest().get("bucket_overrides") or []
                    ckpt_settings = initial_settings(params0, tcfg.rank,
                                                     tcfg.update_freq)
                    for entry in saved:
                        b, r, f = entry[:3]
                        # legacy pre-quality manifests have 3-entry rows
                        q = float(entry[3]) if len(entry) > 3 else 0.0
                        if b in ckpt_settings:
                            ckpt_settings[b] = dataclasses.replace(
                                ckpt_settings[b], rank=r, update_freq=f,
                                refresh_quality=q)
                    if ckpt_settings != settings:
                        settings = ckpt_settings
                        sink.set_settings(settings,
                                          default_freq=tcfg.update_freq)
                        tx, step_fn = build(overrides_from_settings(settings))
                        log_fn("[recovery] controller settings restored "
                               "from checkpoint manifest")
                # The template is built by THIS run's optimizer for THIS
                # run's mesh, so a checkpoint written on a different mesh
                # shape (differently padded bucket stacks) migrates inside
                # restore; placement then shards it onto the current mesh.
                template = {"params": params0, "opt_state": tx.init(params0)}
                if dp is not None:
                    # EF residuals restore worker-aware: checkpoint.py
                    # redistributes a checkpoint written with a different
                    # data-axis size (sum-preserving) and tolerates a missing
                    # comp_state entirely (pre-dp checkpoints cold-start EF).
                    template["comp_state"] = fresh_comp()
                state, manifest = ckpt.restore(template)
                params = place_params(state["params"])
                opt_state = place_opt(state["opt_state"])
                if dp is not None:
                    comp_state = place_comp(state["comp_state"])
                step = manifest["step"]
                if sink is not None:
                    # replayed steps re-emit: drop their pre-fault records
                    # from the controller windows (the JSONL stream keeps
                    # at-least-once semantics — see TelemetrySink.rewind)
                    sink.rewind(step)
                log_fn(f"[recovery] restored step {step} after fault "
                       f"(restart #{restarts[0]})")
        else:
            params = place_params(fresh_params())
            opt_state = place_opt(tx.init(params0))
            if dp is not None:
                comp_state = place_comp(fresh_comp())
            step = start_step

        # sumo-q: bases valid as of the restored/initial optimizer state —
        # the one broadcast; re-extracted only at refresh boundaries below.
        bases = extract_bases(opt_state) if extract_bases is not None else None

        while step < tcfg.total_steps:
            if fault_injector is not None:
                fault_injector.check(step)
            batch = place_batch(
                make_batch(step, shape, arch, DataConfig(seed=tcfg.seed)))
            t0 = time.perf_counter()
            mark_step(step)  # step-tags compiles for analysis.recompile
            if dp is not None:
                params, opt_state, comp_state, metrics = step_fn(
                    params, opt_state, comp_state, batch, bases)
            else:
                params, opt_state, metrics = step_fn(params, opt_state, batch)
            tel = metrics.pop("telemetry", None)
            if sink is not None and tel is not None:
                # Device-side copy before buffering: the stats in metrics
                # alias SumoState.stats, whose buffers are DONATED back into
                # the next step — without the copy the async drain could
                # device_get already-deleted buffers on backends where
                # donation is real (TPU/GPU). Tiny arrays, async, no host
                # sync.
                sink.emit(step, jax.tree_util.tree_map(
                    lambda x: x.copy(), tel))
            loss = float(metrics["loss"])
            monitor.observe(step, time.perf_counter() - t0)
            losses.append((step, loss))
            if step % tcfg.log_every == 0:
                log_fn(f"step {step:5d} loss {loss:.4f} "
                       f"gnorm {float(metrics['grad_norm']):.3f}")
            step += 1
            if extract_bases is not None and any(
                    (step - 1) % f == 0 for f in _refresh_freqs()):
                # SUMO refreshes during steps where its internal counter hits
                # the cadence (loop steps 0, f, 2f, …) — the step that just
                # ran may have rotated Q, so rebroadcast before the next one.
                bases = extract_bases(opt_state)
            if ctrl is not None and step % ctrl_interval == 0:
                sink.drain()   # decisions see everything up to this step
                decisions = ctrl.decide(sink.window_aggregates(), settings)
                opt_state, settings, overrides, reasons = apply_decisions(
                    opt_state, settings, decisions)
                if reasons and mesh is not None:
                    # resized stacks come back unplaced — re-derive specs
                    # from the new shapes and re-shard before the recompile
                    opt_state = place_opt(opt_state)
                if reasons:
                    sink.set_settings(settings,
                                      default_freq=tcfg.update_freq)
                    tx, step_fn = build(overrides)
                    monitor.note_recompile()   # next step pays a compile
                    if extract_bases is not None:
                        # resized Q stacks ⇒ stale basis shapes; rebroadcast
                        bases = extract_bases(opt_state)
                    for bucket, why in sorted(reasons.items()):
                        controller_events.append((step, bucket) + why)
                        log_fn(f"[controller] step {step} {bucket}: "
                               + "; ".join(why))
            if ckpt and (step % tcfg.ckpt_every == 0 or step == tcfg.total_steps):
                extra = {"arch": arch.name, "optimizer": tcfg.optimizer}
                if telemetry_on:
                    # shape provenance for the recovery path above
                    extra["bucket_overrides"] = [
                        list(o) for o in overrides_from_settings(settings)]
                payload = {"params": params, "opt_state": opt_state}
                if dp is not None:
                    payload["comp_state"] = comp_state
                ckpt.save(step, payload,
                          extra=extra, blocking=not tcfg.ckpt_async)
        if ckpt:
            ckpt.wait()
        holder["params"], holder["opt_state"] = params, opt_state
        return step

    try:
        if fault_injector is not None:
            if ckpt is None:
                raise ValueError("fault tolerance requires ckpt_dir")
            report = supervise(run_from)
            final = report.final_step
        else:
            final = run_from(0)
    finally:
        if sink is not None:
            try:
                sink.close()
            except Exception as e:   # telemetry must never eat the result
                log_fn(f"[telemetry] sink close failed: {e!r}")

    return TrainResult(
        losses=losses, final_step=final, restarts=restarts[0],
        params=holder.get("params"), opt_state=holder.get("opt_state"),
        telemetry_records=sink.records_written if sink is not None else 0,
        controller_events=controller_events,
    )
