"""The training loop: jitted step + checkpointing + fault tolerance +
deterministic data replay. Used by examples/ and launch/train.py."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..data import DataConfig, make_batch
from ..models import init_params
from .checkpoint import CheckpointManager
from .failures import FaultInjector, StragglerMonitor, supervise
from .steps import make_optimizer, make_train_step


@dataclasses.dataclass
class TrainConfig:
    optimizer: str = "sumo"
    learning_rate: float = 3e-3
    rank: int = 128
    update_freq: int = 200
    weight_decay: float = 0.0
    # SUMO state layout ("auto" | "leaf" | "bucket"): checkpoints written in
    # either layout restore into either (checkpoint.py migrates on restore).
    state_layout: str = "auto"
    total_steps: int = 100
    accum: int = 1
    attn_impl: str = "flash"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    ckpt_async: bool = True
    log_every: int = 10
    seed: int = 0


@dataclasses.dataclass
class TrainResult:
    losses: list
    final_step: int
    restarts: int
    params: object
    opt_state: object


def train(
    arch: ArchConfig,
    shape: ShapeConfig,
    tcfg: TrainConfig,
    fault_injector: Optional[FaultInjector] = None,
    log_fn: Callable[[str], None] = print,
) -> TrainResult:
    key = jax.random.PRNGKey(tcfg.seed)
    params0 = init_params(arch, key)
    tx = make_optimizer(
        tcfg.optimizer, tcfg.learning_rate, params0,
        rank=tcfg.rank, update_freq=tcfg.update_freq,
        weight_decay=tcfg.weight_decay, state_layout=tcfg.state_layout,
    )
    step_fn = jax.jit(
        make_train_step(arch, tx, attn_impl=tcfg.attn_impl, accum=tcfg.accum),
        donate_argnums=(0, 1),
    )
    ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep) if tcfg.ckpt_dir else None
    monitor = StragglerMonitor(enabled=fault_injector is not None)
    losses: list = []
    restarts = [0]
    holder = {}

    def run_from(start_step: int) -> int:
        if start_step == -1:  # resume from latest checkpoint
            restarts[0] += 1
            if ckpt.latest_step() is None:
                params, opt_state = params0, tx.init(params0)
                step = 0
                log_fn(f"[recovery] no checkpoint yet — cold restart (#{restarts[0]})")
            else:
                template = {"params": params0, "opt_state": tx.init(params0)}
                state, manifest = ckpt.restore(template)
                params, opt_state = state["params"], state["opt_state"]
                step = manifest["step"]
                log_fn(f"[recovery] restored step {step} after fault "
                       f"(restart #{restarts[0]})")
        else:
            params, opt_state = params0, tx.init(params0)
            step = start_step

        while step < tcfg.total_steps:
            if fault_injector is not None:
                fault_injector.check(step)
            batch = make_batch(step, shape, arch, DataConfig(seed=tcfg.seed))
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            monitor.observe(step, time.perf_counter() - t0)
            losses.append((step, loss))
            if step % tcfg.log_every == 0:
                log_fn(f"step {step:5d} loss {loss:.4f} "
                       f"gnorm {float(metrics['grad_norm']):.3f}")
            step += 1
            if ckpt and (step % tcfg.ckpt_every == 0 or step == tcfg.total_steps):
                ckpt.save(step, {"params": params, "opt_state": opt_state},
                          extra={"arch": arch.name, "optimizer": tcfg.optimizer},
                          blocking=not tcfg.ckpt_async)
        if ckpt:
            ckpt.wait()
        holder["params"], holder["opt_state"] = params, opt_state
        return step

    if fault_injector is not None:
        if ckpt is None:
            raise ValueError("fault tolerance requires ckpt_dir")
        report = supervise(run_from)
        final = report.final_step
    else:
        final = run_from(0)

    return TrainResult(
        losses=losses, final_step=final, restarts=restarts[0],
        params=holder.get("params"), opt_state=holder.get("opt_state"),
    )
