"""Shared model primitives: norms, rotary embeddings, GQA attention, MLPs.

Pure-functional pytree style: ``init_*`` builds param dicts, ``apply`` fns are
closed over nothing. Naming matters: fallback-optimizer routing keys off path
substrings ("embed", "norm", "bias", ...) — see core/optimizer.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig

# ---------------------------------------------------------------------------
# activation-sharding hints (§Perf hillclimb: explicit constraints stop the
# SPMD partitioner from conservatively all-gathering the MLP hidden and the
# attention context inside the layer scan). Set by launch/dryrun + train.
# ---------------------------------------------------------------------------

_DP_AXES: Optional[tuple] = None     # e.g. ("pod", "data")
_TP_AXIS: Optional[str] = None       # e.g. "model"
_AXIS_SIZES: dict = {}


def set_sharding_hints(dp_axes: Optional[tuple], tp_axis: Optional[str],
                       axis_sizes: Optional[dict] = None) -> None:
    global _DP_AXES, _TP_AXIS, _AXIS_SIZES
    _DP_AXES = tuple(dp_axes) if dp_axes else None
    _TP_AXIS = tp_axis
    _AXIS_SIZES = dict(axis_sizes or {})


def clear_sharding_hints() -> None:
    set_sharding_hints(None, None, None)


def _axes_size(axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return _AXIS_SIZES.get(axes, 1)
    n = 1
    for a in axes:
        n *= _AXIS_SIZES.get(a, 1)
    return n


def constrain(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """Apply with_sharding_constraint using the hint axes; 'dp'/'tp' tokens in
    spec resolve to the configured axes. Dims the axis doesn't divide stay
    unconstrained; no-op entirely when hints are unset."""
    if _TP_AXIS is None and _DP_AXES is None:
        return x
    from jax.sharding import PartitionSpec as P

    resolved = []
    for dim, s in zip(x.shape, spec):
        axes = {"dp": _DP_AXES, "tp": _TP_AXIS}.get(s, s) if isinstance(s, str) else s
        n = _axes_size(axes)
        resolved.append(axes if (n > 1 and dim % n == 0) else None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*resolved))
    except Exception:
        return x


def fsdp_gather(w: jnp.ndarray, tp_dim: int) -> jnp.ndarray:
    """ZeRO-3 gather-at-use: FSDP-stored weights (extra `data`-axis shard) are
    constrained back to their pure tensor-parallel sharding right before the
    matmul, so the partitioner inserts ONE weight all-gather (params/L bytes)
    instead of activation-sized partial-sum all-reduces over the data axis
    (measured 4× byte blowup on deepseek-33b train_4k without this)."""
    spec = [None] * w.ndim
    spec[tp_dim if tp_dim >= 0 else w.ndim + tp_dim] = "tp"
    return constrain(w, *spec)


def dtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def pdtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / jnp.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    p = {"norm_scale": jnp.ones((d,), pdtype_of(cfg))}
    if cfg.norm == "layernorm":
        p["norm_bias"] = jnp.zeros((d,), pdtype_of(cfg))
    return p


def apply_norm(p, x: jnp.ndarray, cfg: ArchConfig, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) / jnp.sqrt(var + eps)
        y = y * p["norm_scale"].astype(jnp.float32) + p["norm_bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf / jnp.sqrt(ms + eps) * p["norm_scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """qk-norm: per-head RMS norm over the head_dim axis (qwen3 style)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf / jnp.sqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(cfg: ArchConfig) -> jnp.ndarray:
    """Inverse frequencies for the rotated fraction of head_dim."""
    hd = cfg.hd
    rot = int(hd * cfg.rotary_pct) // 2 * 2
    if rot == 0:
        return jnp.zeros((0,), jnp.float32)
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """x: (..., L, H, hd); positions: broadcastable to (..., L)."""
    inv = rope_frequencies(cfg)
    rot = inv.shape[0] * 2
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv[None, :]  # (..., L, rot/2)
    cos = jnp.cos(ang)[..., None, :]                                  # (..., L, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, L, KV, hd) -> (B, L, KV*n_rep, hd) by head repetition."""
    if n_rep == 1:
        return k
    B, L, KV, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, L, KV, n_rep, hd)).reshape(
        B, L, KV * n_rep, hd
    )


def attention_ref(
    q: jnp.ndarray,             # (B, Lq, H, hd)
    k: jnp.ndarray,             # (B, Lk, KV, hd)
    v: jnp.ndarray,             # (B, Lk, KV, hd)
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Reference full-materialization attention (oracle + small shapes)."""
    B, Lq, H, hd = q.shape
    KV = k.shape[2]
    k = _repeat_kv(k, H // KV)
    v = _repeat_kv(v, H // KV)
    scale = 1.0 / jnp.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    Lk = k.shape[1]
    q_pos = jnp.arange(Lq) + q_offset
    k_pos = jnp.arange(Lk)
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if sliding_window is not None:
        mask &= k_pos[None, :] > (q_pos[:, None] - sliding_window)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_offset: int = 0,
    chunk_q: int = 512,
    chunk_k: int = 1024,
) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure jnp (lax.scan over KV
    chunks, lax.map over Q chunks). O(L·chunk) memory instead of O(L²):
    the TPU-portable fallback when the Pallas kernel isn't available, and
    exactly what the dry-run lowers (memory analysis reflects flash-like
    footprint).
    """
    B, Lq, H, hd = q.shape
    KV = k.shape[2]
    n_rep = H // KV
    Lk = k.shape[1]
    chunk_q = min(chunk_q, Lq)
    chunk_k = min(chunk_k, Lk)
    # pad to multiples
    pad_q = (-Lq) % chunk_q
    pad_k = (-Lk) % chunk_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // chunk_q, kp.shape[1] // chunk_k
    scale = 1.0 / jnp.sqrt(hd)

    kc = kp.reshape(B, nk, chunk_k, KV, hd)
    vc = vp.reshape(B, nk, chunk_k, KV, hd)

    def q_block(args):
        qi, q_blk = args                      # q_blk: (B, cq, H, hd)
        q32 = q_blk.astype(jnp.float32) * scale
        q_pos = qi * chunk_q + jnp.arange(chunk_q) + q_offset

        def kv_step(carry, inp):
            acc, m, l = carry                 # acc: (B,cq,H,hd) m,l: (B,cq,H)
            ki, k_blk, v_blk = inp
            k_pos = ki * chunk_k + jnp.arange(chunk_k)
            kr = _repeat_kv(k_blk, n_rep).astype(jnp.float32)
            vr = _repeat_kv(v_blk, n_rep).astype(jnp.float32)
            s = jnp.einsum("bqhd,bkhd->bqhk", q32, kr)  # (B,cq,H,ck)
            mask = jnp.ones((chunk_q, chunk_k), bool)
            mask &= k_pos[None, :] < Lk                  # kv padding
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if sliding_window is not None:
                mask &= k_pos[None, :] > (q_pos[:, None] - sliding_window)
            s = jnp.where(mask[None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bqhk,bkhd->bqhd", p, vr)
            return (acc, m_new, l_new), None

        init = (
            jnp.zeros((B, chunk_q, H, hd), jnp.float32),
            jnp.full((B, chunk_q, H), NEG_INF, jnp.float32),
            jnp.zeros((B, chunk_q, H), jnp.float32),
        )
        (acc, m, l), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kc.swapaxes(0, 1), vc.swapaxes(0, 1))
        )
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    q_blocks = qp.reshape(B, nq, chunk_q, H, hd).swapaxes(0, 1)  # (nq, B, cq, H, hd)
    out = jax.lax.map(q_block, (jnp.arange(nq), q_blocks))
    out = out.swapaxes(0, 1).reshape(B, nq * chunk_q, H, hd)
    return out[:, :Lq]


def attention(q, k, v, *, impl: str = "flash", **kw) -> jnp.ndarray:
    if impl == "ref":
        return attention_ref(q, k, v, **kw)
    if impl == "chunked":
        return attention_chunked(q, k, v, **kw)
    if impl == "flash":
        from .flash import flash_attention as fa
        kw.pop("q_offset", None)
        return fa(q, k, v, kw.get("causal", True), kw.get("sliding_window"))
    if impl == "pallas":
        from ..kernels.ops import flash_attention as fa
        return fa(q, k, v, **kw)
    raise ValueError(f"unknown attention impl {impl!r}")


def decode_attention(
    q: jnp.ndarray,             # (B, 1, H, hd)
    k_cache: jnp.ndarray,       # (B, S, KV, hd)
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,     # () int — valid prefix length (or ring filled)
    sliding_window: Optional[int] = None,
    ring: bool = False,
) -> jnp.ndarray:
    """One-token attention against a (possibly ring-buffered) KV cache."""
    B, S, KV, hd = k_cache.shape
    H = q.shape[2]
    kr = _repeat_kv(k_cache, H // KV).astype(jnp.float32)
    vr = _repeat_kv(v_cache, H // KV).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, kr)  # (B,H,1,S)
    pos = jnp.arange(S)
    if ring:
        valid = pos[None, None, None, :] < jnp.minimum(cache_len, S)
    else:
        valid = pos[None, None, None, :] < cache_len
        if sliding_window is not None:
            valid &= pos[None, None, None, :] >= (cache_len - sliding_window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (init + apply)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    pd = pdtype_of(cfg)
    p = {
        "wq": dense_init(ks[0], d, H * hd, pd),
        "wk": dense_init(ks[1], d, KV * hd, pd),
        "wv": dense_init(ks[2], d, KV * hd, pd),
        "wo": dense_init(ks[3], H * hd, d, pd),
    }
    if cfg.qk_norm:
        p["q_norm_scale"] = jnp.ones((hd,), pd)
        p["k_norm_scale"] = jnp.ones((hd,), pd)
    return p


def attn_qkv(p, x: jnp.ndarray, positions: jnp.ndarray, cfg: ArchConfig):
    """Project + rope + qk-norm. x: (B, L, d) -> q (B,L,H,hd), k/v (B,L,KV,hd)."""
    B, L, _ = x.shape
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    dt = x.dtype
    q = constrain((x @ fsdp_gather(p["wq"].astype(dt), 1)).reshape(B, L, H, hd),
                  "dp", None, "tp", None)
    k = constrain((x @ fsdp_gather(p["wk"].astype(dt), 1)).reshape(B, L, KV, hd),
                  "dp", None, "tp", None)
    v = constrain((x @ fsdp_gather(p["wv"].astype(dt), 1)).reshape(B, L, KV, hd),
                  "dp", None, "tp", None)
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm_scale"])
        k = rms_head_norm(k, p["k_norm_scale"])
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    return q, k, v


def attention_sharded(q, k, v, cfg: ArchConfig, impl: str = "flash"):
    """attention() with head-count padding so the head axis shards over the
    tensor axis even when H ∤ tp (deepseek 56H, smollm 15H on a 16-way axis:
    without this, every device computes ALL heads — measured 16× replicated
    attention FLOPs/bytes, §Perf). GQA kv heads are pre-expanded so the
    padded grouping stays correct; padded heads have q=0 and are sliced off.
    """
    tp = _axes_size(_TP_AXIS)
    H = q.shape[2]
    if tp > 1 and H % tp != 0:
        KV = k.shape[2]
        if KV != H:
            k = _repeat_kv(k, H // KV)
            v = _repeat_kv(v, H // KV)
        Hp = -(-H // tp) * tp
        padh = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, Hp - H), (0, 0)))
        q, k, v = padh(q), padh(k), padh(v)
        q = constrain(q, "dp", None, "tp", None)
        k = constrain(k, "dp", None, "tp", None)
        v = constrain(v, "dp", None, "tp", None)
        out = attention(q, k, v, impl=impl, causal=cfg.causal,
                        sliding_window=cfg.sliding_window)
        return out[:, :, :H]
    return attention(q, k, v, impl=impl, causal=cfg.causal,
                     sliding_window=cfg.sliding_window)


def apply_attention_block(
    p, x: jnp.ndarray, cfg: ArchConfig, *, impl: str = "chunked",
    positions: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    B, L, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    q, k, v = attn_qkv(p, x, positions, cfg)
    out = attention_sharded(q, k, v, cfg, impl=impl)
    out = constrain(out, "dp", None, "tp", None)
    out = out.reshape(B, L, cfg.n_heads * cfg.hd)
    return constrain(out @ fsdp_gather(p["wo"].astype(x.dtype), 0), "dp", None, None)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d_model: Optional[int] = None,
             d_ff: Optional[int] = None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d, f, pd),
            "w_up": dense_init(ks[1], d, f, pd),
            "w_down": dense_init(ks[2], f, d, pd),
        }
    return {
        "w_up": dense_init(ks[0], d, f, pd),
        "w_down": dense_init(ks[1], f, d, pd),
    }


def apply_mlp(p, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    dt = x.dtype
    if "w_gate" in p:
        g = jax.nn.silu(constrain(
            x @ fsdp_gather(p["w_gate"].astype(dt), 1), "dp", None, "tp"))
        u = constrain(x @ fsdp_gather(p["w_up"].astype(dt), 1), "dp", None, "tp")
        return constrain(
            (g * u) @ fsdp_gather(p["w_down"].astype(dt), 0), "dp", None, None)
    h = jax.nn.gelu(constrain(
        x @ fsdp_gather(p["w_up"].astype(dt), 1), "dp", None, "tp"))
    return constrain(h @ fsdp_gather(p["w_down"].astype(dt), 0), "dp", None, None)
