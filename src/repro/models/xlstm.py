"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory, sequential) in the paper's [7:1] alternation.

TPU adaptation (DESIGN.md §3): the mLSTM recurrence
    C_t = f_t C_{t-1} + i_t v_t k_tᵀ,  n_t = f_t n_{t-1} + i_t k_t
is the same algebra as Mamba2's SSD, so training uses the same chunked
matmul-dominant scheme (intra-chunk quadratic + inter-chunk scan) — here with
per-head k/q ("B/C") since xLSTM keys are per-head. Decode is the O(1)
recurrent update, which is what makes long_500k runnable for this arch.

sLSTM is inherently sequential (recurrent weights on h_{t-1}); it runs as a
lax.scan over time with block-diagonal per-head recurrent matrices — the
architecture's own constraint, not an implementation shortcut.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import dense_init, pdtype_of

_GATE_CLIP = 8.0  # stabilizes exponential input gating (see module docstring)


# ---------------------------------------------------------------------------
# chunked per-head linear attention with scalar decay (shared by mLSTM)
# ---------------------------------------------------------------------------

def linear_attn_chunked(q, k, v, w, log_a, chunk: int = 128,
                        return_state: bool = False):
    """y_t = Σ_{j<=t} (Π_{s=j+1..t} a_s) w_j (q_t·k_j) v_j   — per head.

    q,k: (B,L,H,Dk), v: (B,L,H,Dv), w,log_a: (B,L,H). Returns (B,L,H,Dv) fp32
    (and the final state (B,H,Dk,Dv) when return_state — parallel prefill).
    Padding is state-exact: padded steps get w=0 and log_a=0 (a=1).
    """
    Bsz, L, H, Dk = q.shape
    Dv = v.shape[-1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        padt = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        q, k, v, w, log_a = map(padt, (q, k, v, w, log_a))
    Lp = L + pad
    nC = Lp // Q
    f32 = lambda t: t.astype(jnp.float32)
    qc = f32(q).reshape(Bsz, nC, Q, H, Dk)
    kc = f32(k).reshape(Bsz, nC, Q, H, Dk)
    vc = f32(v).reshape(Bsz, nC, Q, H, Dv)
    wc = f32(w).reshape(Bsz, nC, Q, H)
    la = f32(log_a).reshape(Bsz, nC, Q, H)

    cs = jnp.cumsum(la, axis=2)                                    # (B,nC,Q,H)
    # intra-chunk: D[i,j] = cs[i] - cs[j] for j <= i
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]             # (B,nC,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    Lmat = jnp.where(mask, jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcqhd,bckhd->bcqkh", qc, kc)
    y_intra = jnp.einsum("bcqkh,bcqkh,bckh,bckhv->bcqhv", Lmat, scores, wc, vc)

    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)                  # (B,nC,Q,H)
    S_chunk = jnp.einsum("bckhd,bckh,bckh,bckhv->bchdv", kc, decay_to_end, wc, vc)
    a_chunk = jnp.exp(cs[:, :, -1])                                # (B,nC,H)

    def step(S_prev, inp):
        a_c, S_c = inp
        return a_c[:, :, None, None] * S_prev + S_c, S_prev

    S0 = jnp.zeros((Bsz, H, Dk, Dv), jnp.float32)
    S_final, S_before = jax.lax.scan(
        step, S0, (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(S_chunk, 1, 0))
    )
    S_before = jnp.moveaxis(S_before, 0, 1)                        # (B,nC,H,Dk,Dv)
    y_inter = jnp.einsum("bcqhd,bcqh,bchdv->bcqhv", qc, jnp.exp(cs), S_before)
    y = (y_intra + y_inter).reshape(Bsz, Lp, H, Dv)[:, :L]
    if return_state:
        return y, S_final
    return y


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

class MLSTMCache(NamedTuple):
    C: jnp.ndarray    # (B, H, Dk, Dv) matrix memory
    n: jnp.ndarray    # (B, H, Dk) normalizer


def init_mlstm(key, cfg: ArchConfig):
    d = cfg.d_model
    pf = cfg.xlstm.proj_factor_mlstm
    d_in = int(d * pf)
    H = cfg.n_heads
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(ks[0], d, 2 * d_in, pd),     # [x_path, gate z]
        "wq": dense_init(ks[1], d_in, d_in, pd),
        "wk": dense_init(ks[2], d_in, d_in, pd),
        "wv": dense_init(ks[3], d_in, d_in, pd),
        "w_gates": dense_init(ks[4], d_in, 2 * H, pd),     # [ĩ, f̃] per head
        "gate_bias": jnp.concatenate([
            jnp.zeros((H,)), 3.0 * jnp.ones((H,))          # forget bias -> remember
        ]).astype(pd),
        "out_norm_scale": jnp.ones((d_in,), pd),
        "down_proj": dense_init(ks[5], d_in, d, pd),
    }


def _mlstm_qkv_gates(p, xp, cfg):
    B, L, d_in = xp.shape
    H = cfg.n_heads
    hd = d_in // H
    dt = xp.dtype
    q = (xp @ p["wq"].astype(dt)).reshape(B, L, H, hd) / jnp.sqrt(hd).astype(dt)
    k = (xp @ p["wk"].astype(dt)).reshape(B, L, H, hd)
    v = (xp @ p["wv"].astype(dt)).reshape(B, L, H, hd)
    gates = (xp @ p["w_gates"].astype(dt)).astype(jnp.float32) + p["gate_bias"].astype(
        jnp.float32
    )[None, None]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)            # (B,L,H) each
    w = jnp.exp(jnp.clip(i_raw, -_GATE_CLIP, _GATE_CLIP))  # input gate (exp, clipped)
    log_a = jax.nn.log_sigmoid(f_raw)                      # forget gate
    return q, k, v, w, log_a


def apply_mlstm(p, x: jnp.ndarray, cfg: ArchConfig, return_cache: bool = False):
    dt = x.dtype
    up = x @ p["up_proj"].astype(dt)
    xp, z = jnp.split(up, 2, axis=-1)
    q, k, v, w, log_a = _mlstm_qkv_gates(p, xp, cfg)
    Q = cfg.xlstm.chunk
    # fused numerator + normalizer: augment v with a ones channel so the
    # (Q×Q) decay/score panels are computed ONCE for both (§Perf: halves the
    # intra-chunk panel traffic vs two linear_attn passes)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    if return_cache:
        y_aug, S_fin = linear_attn_chunked(q, k, v_aug, w, log_a, chunk=Q,
                                           return_state=True)
        cache = MLSTMCache(C=S_fin[..., :-1], n=S_fin[..., -1])
    else:
        y_aug = linear_attn_chunked(q, k, v_aug, w, log_a, chunk=Q)
    num, den = y_aug[..., :-1], y_aug[..., -1:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    B, L, H, hd = y.shape
    y = y.reshape(B, L, H * hd)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y / jnp.sqrt(ms + 1e-6) * p["out_norm_scale"].astype(jnp.float32)
    out = y.astype(dt) @ p["down_proj"].astype(dt)
    if return_cache:
        return out, cache
    return out


def init_mlstm_cache(cfg: ArchConfig, batch: int) -> MLSTMCache:
    d_in = int(cfg.d_model * cfg.xlstm.proj_factor_mlstm)
    H = cfg.n_heads
    hd = d_in // H
    return MLSTMCache(
        C=jnp.zeros((batch, H, hd, hd), jnp.float32),
        n=jnp.zeros((batch, H, hd), jnp.float32),
    )


def decode_mlstm(p, x: jnp.ndarray, cache: MLSTMCache, cfg: ArchConfig):
    """x: (B, 1, d) -> (y, cache)."""
    dt = x.dtype
    up = x @ p["up_proj"].astype(dt)
    xp, z = jnp.split(up, 2, axis=-1)
    q, k, v, w, log_a = _mlstm_qkv_gates(p, xp, cfg)
    qs, ks_, vs = (t[:, 0].astype(jnp.float32) for t in (q, k, v))  # (B,H,hd)
    a = jnp.exp(log_a[:, 0])                                        # (B,H)
    wi = w[:, 0]
    C = cache.C * a[:, :, None, None] + jnp.einsum("bh,bhd,bhv->bhdv", wi, ks_, vs)
    n = cache.n * a[:, :, None] + wi[:, :, None] * ks_
    num = jnp.einsum("bhdv,bhd->bhv", C, qs)
    den = jnp.einsum("bhd,bhd->bh", n, qs)
    y = num / jnp.maximum(jnp.abs(den), 1.0)[:, :, None]
    B = x.shape[0]
    y = y.reshape(B, 1, -1)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y / jnp.sqrt(ms + 1e-6) * p["out_norm_scale"].astype(jnp.float32)
    return y.astype(dt) @ p["down_proj"].astype(dt), MLSTMCache(C=C, n=n)


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

class SLSTMCache(NamedTuple):
    c: jnp.ndarray    # (B, d)
    n: jnp.ndarray
    h: jnp.ndarray
    m: jnp.ndarray    # stabilizer


def init_slstm(key, cfg: ArchConfig):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 4)
    pf = cfg.xlstm.proj_factor_slstm
    d_ff = int(d * pf)
    return {
        "w_in": dense_init(ks[0], d, 4 * d, pd),              # z, i, f, o pre-acts
        "r_blocks": (jax.random.normal(ks[1], (H, dh, 4 * dh)) / jnp.sqrt(dh)).astype(pd),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)), jnp.zeros((d,))]
        ).astype(pd),
        "out_norm_scale": jnp.ones((d,), pd),
        "ff_up": dense_init(ks[2], d, d_ff, pd),
        "ff_down": dense_init(ks[3], d_ff, d, pd),
    }


def _slstm_cell(p, x_t, state: SLSTMCache, cfg: ArchConfig):
    """One timestep. x_t: (B, 4d) pre-activation from the input projection."""
    B = x_t.shape[0]
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    h_heads = state.h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", h_heads, p["r_blocks"].astype(jnp.float32))
    pre = x_t.astype(jnp.float32) + rec.reshape(B, 4 * d) + p["gate_bias"].astype(
        jnp.float32
    )[None]
    z_t, i_t, f_t, o_t = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_t)
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + state.m, i_t)                 # stabilizer
    i = jnp.exp(i_t - m_new)
    f = jnp.exp(log_f + state.m - m_new)
    c = f * state.c + i * z
    n = f * state.n + i
    h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1.0)
    return SLSTMCache(c=c, n=n, h=h, m=m_new)


def apply_slstm(p, x: jnp.ndarray, cfg: ArchConfig, return_cache: bool = False):
    B, L, d = x.shape
    dt = x.dtype
    # NOTE (§Perf, refuted hypotheses): pinning the scan operand/output to
    # batch-only sharding was tried twice and measured WORSE (the partitioner
    # responded with per-timestep weight-gradient all-reduces, +60% coll).
    # The winning config is: replicate r_blocks (sharding.py) and let the
    # partitioner keep the gate pre-activations model-sharded — the residual
    # per-step AR is 51 GB/step total, 4% of the cell's collective bytes.
    xin = x @ p["w_in"].astype(dt)                             # (B, L, 4d)

    def step(state, x_t):
        state = _slstm_cell(p, x_t, state, cfg)
        return state, state.h

    init = init_slstm_cache(cfg, B)
    final, hs = jax.lax.scan(step, init, jnp.moveaxis(xin, 1, 0))
    y = jnp.moveaxis(hs, 0, 1)                                 # (B, L, d) fp32
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y / jnp.sqrt(ms + 1e-6) * p["out_norm_scale"].astype(jnp.float32)
    y = y.astype(dt)
    h = jax.nn.gelu(y @ p["ff_up"].astype(dt))
    out = h @ p["ff_down"].astype(dt)
    if return_cache:
        return out, final
    return out


def init_slstm_cache(cfg: ArchConfig, batch: int) -> SLSTMCache:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMCache(c=z, n=z, h=z, m=jnp.full((batch, d), -1e9, jnp.float32))


def decode_slstm(p, x: jnp.ndarray, cache: SLSTMCache, cfg: ArchConfig):
    dt = x.dtype
    xin = (x @ p["w_in"].astype(dt))[:, 0]                     # (B, 4d)
    state = _slstm_cell(p, xin, cache, cfg)
    y = state.h[:, None]                                       # (B,1,d)
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y / jnp.sqrt(ms + 1e-6) * p["out_norm_scale"].astype(jnp.float32)
    y = y.astype(dt)
    h = jax.nn.gelu(y @ p["ff_up"].astype(dt))
    return h @ p["ff_down"].astype(dt), state


# ---------------------------------------------------------------------------
# full xLSTM language model: groups of (slstm_every-1 mLSTM + 1 sLSTM)
# ---------------------------------------------------------------------------

class XLSTMLMCache(NamedTuple):
    mlstm: MLSTMCache     # stacked (n_groups, per_group, ...)
    slstm: SLSTMCache     # stacked (n_groups, ...)
    length: jnp.ndarray


def _xlstm_layout(cfg: ArchConfig) -> tuple[int, int]:
    per = cfg.xlstm.slstm_every              # block group size, e.g. 8
    assert cfg.n_layers % per == 0, "n_layers must be divisible by slstm_every"
    return cfg.n_layers // per, per - 1      # (n_groups, mlstm per group)


def init_xlstm_lm(key, cfg: ArchConfig):
    from .layers import dense_init, embed_init, init_norm, pdtype_of

    n_groups, n_ml = _xlstm_layout(cfg)
    ks = jax.random.split(key, 5)
    mkeys = jax.random.split(ks[0], n_groups * n_ml).reshape(n_groups, n_ml, 2)
    skeys = jax.random.split(ks[1], n_groups)

    def init_mblock(k):
        return {"norm": init_norm(cfg), "mlstm": init_mlstm(k, cfg)}

    def init_sblock(k):
        return {"norm": init_norm(cfg), "slstm": init_slstm(k, cfg)}

    return {
        "embed_tokens": embed_init(ks[2], cfg.vocab, cfg.d_model, pdtype_of(cfg)),
        "mlstm_groups": jax.vmap(jax.vmap(init_mblock))(mkeys),
        "slstm_blocks": jax.vmap(init_sblock)(skeys),
        "final_norm": init_norm(cfg),
        "lm_head": dense_init(ks[3], cfg.d_model, cfg.vocab, pdtype_of(cfg)),
    }


def forward_hidden(params, cfg: ArchConfig, batch: dict, attn_impl: str = "chunked"):
    del attn_impl
    from .layers import apply_norm, dtype_of

    dt = dtype_of(cfg)
    x = params["embed_tokens"].astype(dt)[batch["tokens"]]

    def m_block(x, bp):
        h = apply_norm(bp["norm"], x, cfg)
        return x + apply_mlstm(bp["mlstm"], h, cfg), None

    m_fn = jax.checkpoint(m_block) if cfg.remat else m_block

    def group(x, gp):
        mgp, sgp = gp
        x, _ = jax.lax.scan(m_fn, x, mgp)
        h = apply_norm(sgp["norm"], x, cfg)
        x = x + apply_slstm(sgp["slstm"], h, cfg)
        return x, None

    g_fn = jax.checkpoint(group) if cfg.remat else group
    x, _ = jax.lax.scan(g_fn, x, (params["mlstm_groups"], params["slstm_blocks"]))
    x = apply_norm(params["final_norm"], x, cfg)
    return x, jnp.zeros(())


def prefill(params, cfg: ArchConfig, batch: dict, cache_len: int,
            attn_impl: str = "chunked"):
    """Parallel prefill: one chunked forward pass over the prompt extracting
    every block's final recurrent state (mLSTM matrix memory + normalizer via
    the chunked linear-attention scan; sLSTM final cell from its time scan).
    Returns (last-token logits, XLSTMLMCache)."""
    del attn_impl
    from .layers import apply_norm, dtype_of

    dt = dtype_of(cfg)
    x = params["embed_tokens"].astype(dt)[batch["tokens"]]

    def m_block(x, bp):
        h = apply_norm(bp["norm"], x, cfg)
        y, mc = apply_mlstm(bp["mlstm"], h, cfg, return_cache=True)
        return x + y, mc

    def group(x, gp):
        mgp, sgp = gp
        x, mc = jax.lax.scan(m_block, x, mgp)
        h = apply_norm(sgp["norm"], x, cfg)
        y, sc = apply_slstm(sgp["slstm"], h, cfg, return_cache=True)
        return x + y, (mc, sc)

    x, (ml, sl) = jax.lax.scan(
        group, x, (params["mlstm_groups"], params["slstm_blocks"])
    )
    x = apply_norm(params["final_norm"], x, cfg)
    logits = x[:, -1:].astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    L = batch["tokens"].shape[1]
    return logits, XLSTMLMCache(mlstm=ml, slstm=sl,
                                length=jnp.asarray(L, jnp.int32))


def init_xlstm_cache(cfg: ArchConfig, batch: int) -> XLSTMLMCache:
    n_groups, n_ml = _xlstm_layout(cfg)
    ml = init_mlstm_cache(cfg, batch)
    sl = init_slstm_cache(cfg, batch)
    # broadcast the true initial values (the sLSTM stabilizer m starts at -1e9,
    # NOT 0 — zeros would silently change the n-floor normalization)
    stack = lambda t, shape: jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l, shape + l.shape).copy(), t
    )
    return XLSTMLMCache(
        mlstm=stack(ml, (n_groups, n_ml)),
        slstm=stack(sl, (n_groups,)),
        length=jnp.zeros((), jnp.int32),
    )


def decode_step(params, cfg: ArchConfig, token: jnp.ndarray, cache: XLSTMLMCache):
    from .layers import apply_norm, dtype_of

    dt = dtype_of(cfg)
    x = params["embed_tokens"].astype(dt)[token]     # (B,1,d)

    def m_block(x, layer):
        bp, mc = layer
        h = apply_norm(bp["norm"], x, cfg)
        y, mc_new = decode_mlstm(bp["mlstm"], h, mc, cfg)
        return x + y, mc_new

    def group(x, layer):
        mgp, sgp, g_mc, s_c = layer
        x, mc_new = jax.lax.scan(m_block, x, (mgp, g_mc))
        h = apply_norm(sgp["norm"], x, cfg)
        y, s_new = decode_slstm(sgp["slstm"], h, s_c, cfg)
        return x + y, (mc_new, s_new)

    x, (ml, sl) = jax.lax.scan(
        group, x,
        (params["mlstm_groups"], params["slstm_blocks"], cache.mlstm, cache.slstm),
    )
    x = apply_norm(params["final_norm"], x, cfg)
    logits = x.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    return logits[:, 0], XLSTMLMCache(mlstm=ml, slstm=sl, length=cache.length + 1)
