"""Mamba2 (SSD) block — chunked, matmul-dominant formulation for TPU.

Training path follows the SSD "minimal" algorithm (Dao & Gu 2024) with chunk
length Q: intra-chunk quadratic attention-like matmuls + an inter-chunk state
recurrence carried by lax.scan over chunks. Everything is MXU-shaped einsums —
this is the TPU-native adaptation of the CUDA selective-scan (DESIGN.md §3).

Decode path is the O(1) recurrent update: S ← a·S + dt·B⊗x, y = C·S — what
makes zamba2/long_500k feasible.

Shapes: heads H = d_inner / P, state N, B/C shared across heads (1 group).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import dense_init, pdtype_of


class MambaCache(NamedTuple):
    conv: jnp.ndarray    # (B, conv_width-1, d_conv_channels)
    ssm: jnp.ndarray     # (B, H, P, N)


def init_mamba2(key, cfg: ArchConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    N = s.state_dim
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 6)
    # in_proj produces [z (gate), x, B, C, dt] fused as one matrix
    d_proj = 2 * d_in + 2 * N + H
    conv_ch = d_in + 2 * N     # conv over x, B, C (mamba2 convention)
    return {
        "in_proj": dense_init(ks[0], d, d_proj, pd),
        "conv1d_w": (jax.random.normal(ks[1], (s.conv_width, conv_ch)) * 0.1).astype(pd),
        "conv1d_bias": jnp.zeros((conv_ch,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(pd),   # per-head decay
        "D_skip": jnp.ones((H,), pd),
        "dt_bias": jnp.zeros((H,), pd),
        "out_norm_scale": jnp.ones((d_in,), pd),
        "out_proj": dense_init(ks[2], d_in, d, pd),
    }


def _split_proj(proj, cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    N = s.state_dim
    z, xBC, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * N], axis=-1)
    return z, xBC, dt, d_in, H, N


def _causal_conv(xBC, w, b, cache=None):
    """Depthwise causal conv, width K. xBC: (B, L, ch). cache: (B, K-1, ch)."""
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = cache.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)          # (B, L+K-1, ch)
    out = sum(xp[:, i : i + xBC.shape[1]] * w[i][None, None] for i in range(K))
    new_cache = xp[:, -(K - 1) :]
    return jax.nn.silu(out + b[None, None]), new_cache


def _segsum(log_a):
    """Cumulative log-decay matrix: L[i,j] = sum_{j<k<=i} log_a[k], -inf for j>i.
    log_a: (..., Q) -> (..., Q, Q)."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]        # sum over (j, i]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, return_state: bool = False):
    """SSD scan. x: (B,L,H,P), dt: (B,L,H), A: (H,) >0 decay rates,
    Bm/Cm: (B,L,N). Returns y: (B,L,H,P) (and the final SSM state (B,H,P,N)
    when return_state — used by the parallel prefill).

    Discretization: a_t = exp(-dt_t · A); input scaled by dt_t.
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nC = Lp // Q

    xf = x.astype(jnp.float32).reshape(Bsz, nC, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nC, Q, H)
    Bf = Bm.astype(jnp.float32).reshape(Bsz, nC, Q, N)
    Cf = Cm.astype(jnp.float32).reshape(Bsz, nC, Q, N)
    log_a = -dtf * A[None, None, None, :]             # (B, nC, Q, H) (negative)

    # ---- intra-chunk (quadratic within chunk, attention-like) -------------
    Lmat = jnp.exp(_segsum(jnp.moveaxis(log_a, -1, -2)))      # (B,nC,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cf, Bf)            # (B,nC,Q,Q)
    y_intra = jnp.einsum(
        "bchqk,bcqk,bckh,bckhp->bcqhp",
        Lmat, scores, dtf, xf,
    )

    # ---- chunk summary states ----------------------------------------------
    # decay from position k to end of chunk: exp(sum_{j>k} log_a)
    cums = jnp.cumsum(log_a, axis=2)
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)         # (B,nC,Q,H)
    S_chunk = jnp.einsum("bckn,bckh,bckh,bckhp->bchpn",
                         Bf, decay_to_end, dtf, xf)           # (B,nC,H,P,N)
    a_chunk = jnp.exp(cums[:, :, -1, :])                      # (B,nC,H) total decay

    # ---- inter-chunk recurrence (scan over chunks) -------------------------
    def step(S_prev, inp):
        a_c, S_c = inp                                        # (B,H), (B,H,P,N)
        S_new = a_c[:, :, None, None] * S_prev + S_c
        return S_new, S_prev

    S0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    S_final, S_before = jax.lax.scan(
        step, S0, (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(S_chunk, 1, 0))
    )
    S_before = jnp.moveaxis(S_before, 0, 1)                   # (B,nC,H,P,N)

    # ---- inter-chunk contribution ------------------------------------------
    decay_from_start = jnp.exp(cums)                          # (B,nC,Q,H)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         Cf, decay_from_start, S_before)

    y = (y_intra + y_inter).reshape(Bsz, Lp, H, P)[:, :L]
    if return_state:
        # NOTE: with padding, padded steps have dt=0 ⇒ a=1, input weight 0 —
        # they do not perturb the state, so S_final is exact.
        return y, S_final
    return y


def apply_mamba2(p, x: jnp.ndarray, cfg: ArchConfig, return_cache: bool = False):
    """Training/prefill forward. x: (B, L, d) -> (B, L, d)
    (+ final MambaCache when return_cache — the parallel prefill path)."""
    s = cfg.ssm
    dt_ = x.dtype
    proj = x @ p["in_proj"].astype(dt_)
    z, xBC, dt_raw, d_in, H, N = _split_proj(proj, cfg)
    xBC_pre = xBC
    xBC, _ = _causal_conv(xBC, p["conv1d_w"].astype(dt_), p["conv1d_bias"].astype(dt_))
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    B_, L, _ = x.shape
    xh = xs.reshape(B_, L, H, s.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = jnp.exp(p["A_log"].astype(jnp.float32))
    if return_cache:
        y, S_final = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk, return_state=True)
        K = s.conv_width
        if L >= K - 1:
            conv_state = xBC_pre[:, L - (K - 1):]
        else:
            conv_state = jnp.pad(xBC_pre, ((0, 0), (K - 1 - L, 0), (0, 0)))
        cache = MambaCache(conv=conv_state, ssm=S_final)
    else:
        y = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk)           # (B,L,H,P) fp32
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, L, d_in)
    # gated RMSNorm (mamba2 output norm)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y / jnp.sqrt(ms + 1e-6) * p["out_norm_scale"].astype(jnp.float32)
    out = (y.astype(dt_)) @ p["out_proj"].astype(dt_)
    if return_cache:
        return out, cache
    return out


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> MambaCache:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.state_dim
    return MambaCache(
        conv=jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
        ssm=jnp.zeros((batch, H, s.head_dim, s.state_dim), jnp.float32),
    )


def decode_mamba2(p, x: jnp.ndarray, cache: MambaCache, cfg: ArchConfig):
    """One-token recurrent step. x: (B, 1, d) -> (y (B,1,d), new cache)."""
    s = cfg.ssm
    dt_ = x.dtype
    proj = x @ p["in_proj"].astype(dt_)
    z, xBC, dt_raw, d_in, H, N = _split_proj(proj, cfg)
    xBC, conv_new = _causal_conv(
        xBC, p["conv1d_w"].astype(dt_), p["conv1d_bias"].astype(dt_), cache.conv
    )
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    B_ = x.shape[0]
    xh = xs.reshape(B_, H, s.head_dim).astype(jnp.float32)            # L=1 squeezed
    dt = jax.nn.softplus(
        dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                                  # (B, H)
    A = jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(-dt * A[None, :])                                      # (B, H)
    Bf = Bm[:, 0].astype(jnp.float32)                                  # (B, N)
    Cf = Cm[:, 0].astype(jnp.float32)
    S = cache.ssm * a[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bf
    )
    y = jnp.einsum("bhpn,bn->bhp", S, Cf)
    y = y + p["D_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B_, 1, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y / jnp.sqrt(ms + 1e-6) * p["out_norm_scale"].astype(jnp.float32)
    out = (y.astype(dt_)) @ p["out_proj"].astype(dt_)
    return out, MambaCache(conv=conv_new, ssm=S)
