"""Token-choice top-k Mixture-of-Experts with GROUPED sort-based dispatch.

TPU adaptation notes (DESIGN.md §3 + §Perf iterations):
  * sort-based capacity dispatch: no (T, E, C) one-hot tensor — bookkeeping is
    O(T·k) vectors, the expert matmul is one batched einsum.
  * GROUPED routing: tokens are routed within `groups` independent groups
    aligned with the data-parallel batch sharding. All sorting, position
    bookkeeping, gathers and scatters are then *shard-local* (batched ops
    sharded on their leading group axis — zero collectives). Without this the
    partitioner lowered the global argsort/gather/scatter into ~3.6 TB/step of
    all-reduces on mixtral train_4k (measured, §Perf).
  * expert weights: hidden dim sharded over `model` (Megatron), replicated
    over `data` (FSDP-sharded storage when cfg.fsdp); every group computes
    with all experts — classic "data-parallel dispatch + tensor-parallel
    experts", the right regime for E ≪ chips.

Auxiliary load-balancing loss (Switch-style) is returned alongside.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import constrain, dense_init, pdtype_of


def init_moe(key, cfg: ArchConfig):
    assert cfg.moe is not None
    E, d, f = cfg.moe.num_experts, cfg.d_model, cfg.d_ff
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d)
    return {
        "router": dense_init(ks[0], d, E, pd),
        # stacked expert weights: leading E axis (vmapped by the optimizer too)
        "experts": {
            "w_gate": (jax.random.normal(ks[1], (E, d, f)) * scale).astype(pd),
            "w_up": (jax.random.normal(ks[2], (E, d, f)) * scale).astype(pd),
            "w_down": (jax.random.normal(ks[3], (E, f, d)) / jnp.sqrt(f)).astype(pd),
        },
    }


def _capacity(n_tokens: int, cfg: ArchConfig, multiple: int = 8) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    return max(multiple, -(-c // multiple) * multiple)


def _dispatch_group(xt, probs, C: int, cfg: ArchConfig):
    """Shard-local dispatch for ONE group. xt: (t, d), probs: (t, E).
    Returns (buf (E, C, d), e_sorted, pos_in_e, tok_sorted, gate_sorted, keep)."""
    m = cfg.moe
    t, d = xt.shape
    k, E = m.top_k, m.num_experts
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                  # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )
    flat_e = expert_idx.reshape(-1)                                  # (t*k,)
    flat_g = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    g_sorted = flat_g[order]
    seg_start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    pos_in_e = jnp.arange(t * k) - seg_start[e_sorted]
    keep = pos_in_e < C
    pos_in_e = jnp.where(keep, pos_in_e, 0)
    xs = xt[tok_sorted] * keep[:, None].astype(xt.dtype)             # (t*k, d)
    buf = jnp.zeros((E, C, d), xt.dtype).at[e_sorted, pos_in_e].set(
        xs, mode="drop", unique_indices=False
    )
    return buf, e_sorted, pos_in_e, tok_sorted, g_sorted, keep


def _combine_group(eo, e_sorted, pos_in_e, tok_sorted, g_sorted, keep, t: int):
    """eo: (E, C, d) expert outputs -> (t, d) token outputs."""
    slot_out = eo[e_sorted, pos_in_e] * (
        g_sorted * keep.astype(jnp.float32)
    )[:, None].astype(eo.dtype)
    return jnp.zeros((t, eo.shape[-1]), eo.dtype).at[tok_sorted].add(slot_out)


def apply_moe(p, x: jnp.ndarray, cfg: ArchConfig,
              groups: Optional[int] = None):
    """x: (B, L, d) -> (out (B, L, d), aux_loss ())."""
    from .layers import _DP_AXES, _axes_size

    m = cfg.moe
    B, L, d = x.shape
    T = B * L
    E = m.num_experts
    dt = x.dtype

    if groups is None:
        groups = _axes_size(_DP_AXES)         # align with the batch sharding
    G = max(1, groups)
    while B % G != 0:                          # groups must tile the batch dim
        G //= 2
    # decode-sized calls (a handful of tokens): grouping + sharding constraints
    # cost more in resharding than they save — route locally, unconstrained
    # (measured: mixtral decode_32k regressed 2.1× with constraints on)
    small = T < 2048
    if small:
        G = 1
    cns = (lambda t, *spec: t) if small else constrain
    tG = T // G
    # small-expert regime (see sharding.py): expert weights replicated, the
    # CAPACITY dim shards over the tensor axis instead of d_ff
    from .layers import _TP_AXIS
    tp_size = _axes_size(_TP_AXIS)
    cap_tp = (not small) and tp_size > 1 and cfg.d_ff // tp_size < 128
    C = _capacity(tG, cfg, multiple=(tp_size * 8 if cap_tp else 8))
    cap_spec = "tp" if cap_tp else None
    ff_spec = None if cap_tp else "tp"

    xt = cns(x.reshape(G, tG, d), "dp", None, None)
    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)       # (G, t, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # ---- load-balancing aux loss (Switch): E · Σ_e f_e · p̄_e (global) ------
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)

    # ---- shard-local dispatch (vmapped over groups) --------------------------
    buf, e_s, pos, tok_s, g_s, keep = jax.vmap(
        lambda xg, pg: _dispatch_group(xg, pg, C, cfg)
    )(xt, probs)
    buf = cns(buf, "dp", None, cap_spec, None)                 # (G,E,C,d)

    # ---- expert compute: batched SwiGLU (groups × experts) -------------------
    W = p["experts"]
    g = jax.nn.silu(cns(
        jnp.einsum("gecd,edf->gecf", buf, W["w_gate"].astype(dt)),
        "dp", None, cap_spec, ff_spec))
    u = cns(jnp.einsum("gecd,edf->gecf", buf, W["w_up"].astype(dt)),
                  "dp", None, cap_spec, ff_spec)
    # (§Perf "MoE deferred unshard" — keeping d sharded through the combine —
    # was tried and REFUTED: the partitioner re-sharded around the gathers and
    # collective bytes rose 11%; the eager layout below is the measured best.)
    # unshard the capacity dim BEFORE the combine: one buffer all-gather per
    # layer beats the cross-shard gather/scatter all-reduces the partitioner
    # otherwise emits (measured 600→4 GB/layer on granite, §Perf)
    eo = cns(jnp.einsum("gecf,efd->gecd", g * u, W["w_down"].astype(dt)),
                   "dp", None, None, None)                           # (G,E,C,d)

    # ---- shard-local combine ---------------------------------------------------
    out = jax.vmap(lambda e, a, b, c, gg, kk: _combine_group(e, a, b, c, gg, kk, tG))(
        eo, e_s, pos, tok_s, g_s, keep
    )
    out = cns(out, "dp", None, None)
    return out.reshape(B, L, d), aux
