"""Zamba2 hybrid assembly: a Mamba2 backbone with a SHARED full transformer
block (attention + MLP, one set of weights) applied after every
``cfg.attn_every`` Mamba blocks — the Zamba2 weight-sharing trick.

Layout for n_layers=81, attn_every=6: 13 groups of (6 mamba + shared-attn)
plus a 3-block mamba tail. Groups are scanned (stacked params), the shared
block is a closure constant — HLO stays small at 81 layers.

The shared attention uses a sliding-window KV ring cache (cfg.sliding_window)
so long_500k decode is O(window), while the Mamba state is O(1) — this arch
is one of the designated long-context cells.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import (
    apply_attention_block,
    apply_mlp,
    apply_norm,
    attn_qkv,
    decode_attention,
    dense_init,
    dtype_of,
    embed_init,
    init_attention,
    init_mlp,
    init_norm,
    pdtype_of,
)
from .mamba2 import (
    MambaCache,
    apply_mamba2,
    decode_mamba2,
    init_mamba2,
    init_mamba_cache,
)


def _group_shape(cfg: ArchConfig) -> tuple[int, int]:
    n_groups = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers % cfg.attn_every
    return n_groups, tail


def _init_mamba_block(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {"norm": init_norm(cfg), "mamba": init_mamba2(k1, cfg)}


def init_zamba2(key, cfg: ArchConfig):
    n_groups, tail = _group_shape(cfg)
    ks = jax.random.split(key, 6)
    gkeys = jax.random.split(ks[0], n_groups * cfg.attn_every).reshape(
        n_groups, cfg.attn_every, 2
    )
    groups = jax.vmap(jax.vmap(lambda k: _init_mamba_block(k, cfg)))(gkeys)
    params = {
        "embed_tokens": embed_init(ks[1], cfg.vocab, cfg.d_model, pdtype_of(cfg)),
        "mamba_groups": groups,
        "shared_attn": {
            "attn_norm": init_norm(cfg),
            "attn": init_attention(ks[2], cfg),
            "mlp_norm": init_norm(cfg),
            "mlp": init_mlp(ks[3], cfg),
        },
        "final_norm": init_norm(cfg),
        "lm_head": dense_init(ks[4], cfg.d_model, cfg.vocab, pdtype_of(cfg)),
    }
    if tail:
        tkeys = jax.random.split(ks[5], tail)
        params["mamba_tail"] = jax.vmap(lambda k: _init_mamba_block(k, cfg))(tkeys)
    return params


def _mamba_block(bp, x, cfg):
    h = apply_norm(bp["norm"], x, cfg)
    return x + apply_mamba2(bp["mamba"], h, cfg)


def _shared_block(sp, x, cfg, attn_impl):
    h = apply_norm(sp["attn_norm"], x, cfg)
    x = x + apply_attention_block(sp["attn"], h, cfg, impl=attn_impl)
    h = apply_norm(sp["mlp_norm"], x, cfg)
    return x + apply_mlp(sp["mlp"], h, cfg)


def forward_hidden(params, cfg: ArchConfig, batch: dict,
                   attn_impl: str = "chunked"):
    dt = dtype_of(cfg)
    x = params["embed_tokens"].astype(dt)[batch["tokens"]]
    sp = params["shared_attn"]

    def inner(x, bp):
        return _mamba_block(bp, x, cfg), None

    inner_fn = jax.checkpoint(inner) if cfg.remat else inner

    def group(x, gp):
        x, _ = jax.lax.scan(inner_fn, x, gp)
        x = _shared_block(sp, x, cfg, attn_impl)
        return x, None

    group_fn = jax.checkpoint(group) if cfg.remat else group
    x, _ = jax.lax.scan(group_fn, x, params["mamba_groups"])
    if "mamba_tail" in params:
        x, _ = jax.lax.scan(inner_fn, x, params["mamba_tail"])
    x = apply_norm(params["final_norm"], x, cfg)
    return x, jnp.zeros(())


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

class ZambaCache(NamedTuple):
    mamba_groups: MambaCache      # stacked (n_groups, attn_every, ...)
    mamba_tail: MambaCache        # stacked (tail, ...) — empty tail => zeros((0,...))
    attn_k: jnp.ndarray           # (n_groups, B, S, KV, hd) ring buffers
    attn_v: jnp.ndarray
    length: jnp.ndarray


def init_zamba_cache(cfg: ArchConfig, batch: int, seq_len: int) -> ZambaCache:
    n_groups, tail = _group_shape(cfg)
    S = min(cfg.sliding_window or seq_len, seq_len)
    dt = dtype_of(cfg)

    def stack(n):
        base = init_mamba_cache(cfg, batch, dt)
        return jax.tree_util.tree_map(
            lambda l: jnp.zeros((n,) + l.shape, l.dtype), base
        )

    inner_stack = jax.tree_util.tree_map(
        lambda l: jnp.zeros((n_groups, cfg.attn_every) + l.shape, l.dtype),
        init_mamba_cache(cfg, batch, dt),
    )
    return ZambaCache(
        mamba_groups=inner_stack,
        mamba_tail=stack(max(tail, 0)),
        attn_k=jnp.zeros((n_groups, batch, S, cfg.n_kv_heads, cfg.hd), dt),
        attn_v=jnp.zeros((n_groups, batch, S, cfg.n_kv_heads, cfg.hd), dt),
        length=jnp.zeros((), jnp.int32),
    )


def prefill(params, cfg: ArchConfig, batch: dict, cache_len: int,
            attn_impl: str = "chunked"):
    """Parallel prefill: one chunked forward pass over the whole prompt that
    also extracts every recurrent state (final SSM state per mamba block, a
    ring-layout sliding-window KV cache per shared-attn invocation). Returns
    (last-token logits, ZambaCache) — O(L) memory, no token-by-token loop."""
    dt = dtype_of(cfg)
    x = params["embed_tokens"].astype(dt)[batch["tokens"]]
    B, L, _ = x.shape
    sp = params["shared_attn"]
    S = min(cfg.sliding_window or cache_len, cache_len)
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    if L >= S:
        slots = jnp.arange(S)
        ring_src = slots + ((L - 1 - slots) // S) * S

    def inner(x, bp):
        h = apply_norm(bp["norm"], x, cfg)
        y, mc = apply_mamba2(bp["mamba"], h, cfg, return_cache=True)
        return x + y, mc

    def group(x, layer):
        gp = layer
        x, mc = jax.lax.scan(inner, x, gp)
        h = apply_norm(sp["attn_norm"], x, cfg)
        q, k, v = attn_qkv(sp["attn"], h, positions, cfg)
        from .layers import attention_sharded
        o = attention_sharded(q, k, v, cfg, impl=attn_impl)
        o = o.reshape(B, L, cfg.n_heads * cfg.hd) @ sp["attn"]["wo"].astype(x.dtype)
        x = x + o
        h = apply_norm(sp["mlp_norm"], x, cfg)
        x = x + apply_mlp(sp["mlp"], h, cfg)
        if L >= S:
            k_keep, v_keep = k[:, ring_src], v[:, ring_src]
        else:
            k_keep = jnp.pad(k, ((0, 0), (0, S - L), (0, 0), (0, 0)))
            v_keep = jnp.pad(v, ((0, 0), (0, S - L), (0, 0), (0, 0)))
        return x, (mc, k_keep, v_keep)

    x, (g_mc, ks, vs) = jax.lax.scan(group, x, params["mamba_groups"])
    if "mamba_tail" in params:
        x, tail_mc = jax.lax.scan(inner, x, params["mamba_tail"])
    else:
        n_groups, tail = _group_shape(cfg)
        tail_mc = jax.tree_util.tree_map(
            lambda l: jnp.zeros((max(tail, 0),) + l.shape, l.dtype),
            init_mamba_cache(cfg, B, dt),
        )
    x = apply_norm(params["final_norm"], x, cfg)
    logits = x[:, -1:].astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    cache = ZambaCache(
        mamba_groups=g_mc, mamba_tail=tail_mc, attn_k=ks, attn_v=vs,
        length=jnp.asarray(L, jnp.int32),
    )
    return logits, cache


def decode_step(params, cfg: ArchConfig, token: jnp.ndarray, cache: ZambaCache):
    B = token.shape[0]
    dt = dtype_of(cfg)
    x = params["embed_tokens"].astype(dt)[token]          # (B, 1, d)
    sp = params["shared_attn"]
    S = cache.attn_k.shape[2]
    pos = jnp.broadcast_to(cache.length[None, None], (B, 1))
    write_at = cache.length % S

    def inner(x, layer):
        bp, mc = layer
        h = apply_norm(bp["norm"], x, cfg)
        y, mc_new = decode_mamba2(bp["mamba"], h, mc, cfg)
        return x + y, mc_new

    def group(x, layer):
        gp, g_mc, k_cache, v_cache = layer
        x, mc_new = jax.lax.scan(inner, x, (gp, g_mc))
        h = apply_norm(sp["attn_norm"], x, cfg)
        q, k, v = attn_qkv(sp["attn"], h, pos, cfg)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, write_at, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, write_at, 0, 0))
        o = decode_attention(q, k_cache, v_cache, cache.length + 1,
                             sliding_window=cfg.sliding_window, ring=True)
        o = o.reshape(B, 1, cfg.n_heads * cfg.hd) @ sp["attn"]["wo"].astype(x.dtype)
        x = x + o
        h = apply_norm(sp["mlp_norm"], x, cfg)
        x = x + apply_mlp(sp["mlp"], h, cfg)
        return x, (mc_new, k_cache, v_cache)

    x, (g_mc, ks, vs) = jax.lax.scan(
        group, x, (params["mamba_groups"], cache.mamba_groups,
                   cache.attn_k, cache.attn_v)
    )
    tail_mc = cache.mamba_tail
    if "mamba_tail" in params:
        x, tail_mc = jax.lax.scan(inner, x, (params["mamba_tail"], cache.mamba_tail))
    x = apply_norm(params["final_norm"], x, cfg)
    logits = x.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    return logits[:, 0], ZambaCache(
        mamba_groups=g_mc, mamba_tail=tail_mc,
        attn_k=ks, attn_v=vs, length=cache.length + 1,
    )


def slot_decode_step(params, cfg: ArchConfig, token: jnp.ndarray,
                     cache: ZambaCache, lengths: jnp.ndarray):
    """Continuous-batching variant of ``decode_step``: each batch slot
    carries its OWN context length ``lengths[s]`` (RoPE position, ring
    write offset and attention mask are all per-slot), so mixed-progress
    requests can share one fixed-shape compiled step. The Mamba states are
    O(1) and need no length at all; only the shared-attention ring cares.
    ``cache.length`` is ignored (the serving engine tracks lengths
    host-side) and returned incremented for interface compatibility."""
    B = token.shape[0]
    dt = dtype_of(cfg)
    x = params["embed_tokens"].astype(dt)[token]          # (B, 1, d)
    sp = params["shared_attn"]
    S = cache.attn_k.shape[2]
    pos = lengths[:, None]                                # (B, 1)
    write_at = lengths % S                                # (B,)
    rows = jnp.arange(B)
    att_len = (lengths + 1)[:, None, None, None]          # (B,1,1,1)

    def inner(x, layer):
        bp, mc = layer
        h = apply_norm(bp["norm"], x, cfg)
        y, mc_new = decode_mamba2(bp["mamba"], h, mc, cfg)
        return x + y, mc_new

    def group(x, layer):
        gp, g_mc, k_cache, v_cache = layer
        x, mc_new = jax.lax.scan(inner, x, (gp, g_mc))
        h = apply_norm(sp["attn_norm"], x, cfg)
        q, k, v = attn_qkv(sp["attn"], h, pos, cfg)
        k_cache = k_cache.at[rows, write_at].set(k[:, 0])
        v_cache = v_cache.at[rows, write_at].set(v[:, 0])
        o = decode_attention(q, k_cache, v_cache, att_len,
                             sliding_window=cfg.sliding_window, ring=True)
        o = o.reshape(B, 1, cfg.n_heads * cfg.hd) @ sp["attn"]["wo"].astype(x.dtype)
        x = x + o
        h = apply_norm(sp["mlp_norm"], x, cfg)
        x = x + apply_mlp(sp["mlp"], h, cfg)
        return x, (mc_new, k_cache, v_cache)

    x, (g_mc, ks, vs) = jax.lax.scan(
        group, x, (params["mamba_groups"], cache.mamba_groups,
                   cache.attn_k, cache.attn_v)
    )
    tail_mc = cache.mamba_tail
    if "mamba_tail" in params:
        x, tail_mc = jax.lax.scan(inner, x, (params["mamba_tail"], cache.mamba_tail))
    x = apply_norm(params["final_norm"], x, cfg)
    logits = x.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    return logits[:, 0], ZambaCache(
        mamba_groups=g_mc, mamba_tail=tail_mc,
        attn_k=ks, attn_v=vs, length=cache.length + 1,
    )
