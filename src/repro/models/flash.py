"""Flash attention with custom VJP — O(L·chunk) memory in BOTH directions.

The naive differentiable chunked attention stores every (bq × bk) probability
panel for the backward pass (O(L²) residuals — 47 GB/device at 4k seq for a
360M model, measured in the dry-run). This implementation saves only
(q, k, v, out, lse) and RECOMPUTES the panels in the backward pass, i.e. the
FlashAttention-2 backward, expressed as jnp scans so it lowers everywhere
(and mirrors what the Pallas kernel does on real TPU).

Forward:  out, lse    (lse = m + log l, the softmax log-normalizer per row)
Backward: D = rowsum(dout ⊙ out); per kv-chunk
          p  = exp(q kᵀ·s − lse);  dv += pᵀ dout;  dp = dout vᵀ
          ds = p ⊙ (dp − D);       dk += dsᵀ q·s;  dq += ds k·s
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    B, L, KV, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, L, KV, n_rep, hd)).reshape(
        B, L, KV * n_rep, hd
    )


def _mask(q_pos, k_pos, causal, window, lk):
    m = k_pos[None, :] < lk
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def _fwd_impl(q, k, v, causal, window, chunk_q, chunk_k):
    """Returns (out (B,Lq,H,hd), lse (B,Lq,H))."""
    B, Lq, H, hd = q.shape
    KV, Lk = k.shape[2], k.shape[1]
    n_rep = H // KV
    cq, ck = min(chunk_q, Lq), min(chunk_k, Lk)
    pq, pk = (-Lq) % cq, (-Lk) % ck
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (Lq + pq) // cq, (Lk + pk) // ck
    scale = 1.0 / jnp.sqrt(hd)
    kc = kp.reshape(B, nk, ck, KV, hd).swapaxes(0, 1)
    vc = vp.reshape(B, nk, ck, KV, hd).swapaxes(0, 1)

    def q_block(args):
        qi, q_blk = args
        q32 = q_blk.astype(jnp.float32) * scale
        q_pos = qi * cq + jnp.arange(cq)

        def kv_step(carry, inp):
            acc, m, l = carry
            ki, k_blk, v_blk = inp
            k_pos = ki * ck + jnp.arange(ck)
            kr = _repeat_kv(k_blk, n_rep).astype(jnp.float32)
            vr = _repeat_kv(v_blk, n_rep).astype(jnp.float32)
            s = jnp.einsum("bqhd,bkhd->bqhk", q32, kr)
            msk = _mask(q_pos, k_pos, causal, window, Lk)
            s = jnp.where(msk[None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bqhk,bkhd->bqhd", p, vr)
            return (acc, m_new, l_new), None

        init = (
            jnp.zeros((B, cq, H, hd), jnp.float32),
            jnp.full((B, cq, H), NEG_INF, jnp.float32),
            jnp.zeros((B, cq, H), jnp.float32),
        )
        (acc, m, l), _ = jax.lax.scan(kv_step, init, (jnp.arange(nk), kc, vc))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse

    q_blocks = qp.reshape(B, nq, cq, H, hd).swapaxes(0, 1)
    out, lse = jax.lax.map(q_block, (jnp.arange(nq), q_blocks))
    out = out.swapaxes(0, 1).reshape(B, nq * cq, H, hd)[:, :Lq]
    lse = lse.swapaxes(0, 1).reshape(B, nq * cq, H)[:, :Lq]
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None,
                    chunk_q: int = 512, chunk_k: int = 1024):
    out, _ = _fwd_impl(q, k, v, causal, window, chunk_q, chunk_k)
    return out


def _fa_fwd(q, k, v, causal, window, chunk_q, chunk_k):
    out, lse = _fwd_impl(q, k, v, causal, window, chunk_q, chunk_k)
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, chunk_q, chunk_k, res, dout):
    q, k, v, out, lse = res
    B, Lq, H, hd = q.shape
    KV, Lk = k.shape[2], k.shape[1]
    n_rep = H // KV
    cq, ck = min(chunk_q, Lq), min(chunk_k, Lk)
    pq, pk = (-Lq) % cq, (-Lk) % ck
    scale = 1.0 / jnp.sqrt(hd)

    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    dop = jnp.pad(dout, ((0, 0), (0, pq), (0, 0), (0, 0)))
    lsep = jnp.pad(lse, ((0, 0), (0, pq), (0, 0)), constant_values=0.0)
    # D = rowsum(dout * out)
    D = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    Dp = jnp.pad(D, ((0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (Lq + pq) // cq, (Lk + pk) // ck

    kc = kp.reshape(B, nk, ck, KV, hd).swapaxes(0, 1)
    vc = vp.reshape(B, nk, ck, KV, hd).swapaxes(0, 1)
    qc = qp.reshape(B, nq, cq, H, hd).swapaxes(0, 1)
    dc = dop.reshape(B, nq, cq, H, hd).swapaxes(0, 1)
    lc = lsep.reshape(B, nq, cq, H).swapaxes(0, 1)
    Dc = Dp.reshape(B, nq, cq, H).swapaxes(0, 1)

    def q_step(carry, inp):
        dk_acc, dv_acc = carry                     # (B, nk, ck, H, hd) fp32
        qi, q_blk, do_blk, lse_blk, D_blk = inp
        q32 = q_blk.astype(jnp.float32)
        do32 = do_blk.astype(jnp.float32)
        q_pos = qi * cq + jnp.arange(cq)

        def kv_step(dq_acc, inp2):
            ki, k_blk, v_blk = inp2
            k_pos = ki * ck + jnp.arange(ck)
            kr = _repeat_kv(k_blk, n_rep).astype(jnp.float32)   # (B,ck,H,hd)
            vr = _repeat_kv(v_blk, n_rep).astype(jnp.float32)
            s = jnp.einsum("bqhd,bkhd->bqhk", q32 * scale, kr)
            msk = _mask(q_pos, k_pos, causal, window, Lk)
            s = jnp.where(msk[None, :, None, :], s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])                 # (B,cq,H,ck)
            dv_c = jnp.einsum("bqhk,bqhd->bkhd", p, do32)
            dp = jnp.einsum("bqhd,bkhd->bqhk", do32, vr)
            ds = p * (dp - D_blk[..., None])
            dk_c = jnp.einsum("bqhk,bqhd->bkhd", ds, q32) * scale
            dq_acc = dq_acc + jnp.einsum("bqhk,bkhd->bqhd", ds, kr) * scale
            return dq_acc, (dk_c, dv_c)

        dq0 = jnp.zeros((B, cq, H, hd), jnp.float32)
        dq_blk, (dk_c, dv_c) = jax.lax.scan(
            kv_step, dq0, (jnp.arange(nk), kc, vc)
        )
        return (dk_acc + dk_c, dv_acc + dv_c), dq_blk

    dk0 = jnp.zeros((nk, B, ck, H, hd), jnp.float32)
    dv0 = jnp.zeros((nk, B, ck, H, hd), jnp.float32)
    (dkf, dvf), dqs = jax.lax.scan(
        q_step, (dk0, dv0), (jnp.arange(nq), qc, dc, lc, Dc)
    )
    dq = dqs.swapaxes(0, 1).reshape(B, nq * cq, H, hd)[:, :Lq].astype(q.dtype)
    dk_full = dkf.swapaxes(0, 1).reshape(B, nk * ck, H, hd)[:, :Lk]
    dv_full = dvf.swapaxes(0, 1).reshape(B, nk * ck, H, hd)[:, :Lk]
    # fold repeated kv-head grads back to KV heads (GQA)
    if n_rep > 1:
        dk_full = dk_full.reshape(B, Lk, KV, n_rep, hd).sum(axis=3)
        dv_full = dv_full.reshape(B, Lk, KV, n_rep, hd).sum(axis=3)
    return dq, dk_full.astype(k.dtype), dv_full.astype(v.dtype)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
