"""Unified model API — dispatches on cfg.family.

    init_params(cfg, key)                         -> params
    forward_hidden(params, cfg, batch)            -> (hidden, aux)
    loss_fn(params, cfg, batch)                   -> scalar
    init_decode_cache(cfg, batch, seq_len)        -> cache
    decode_step(params, cfg, token, cache)        -> (logits, cache)
    prefill(params, cfg, batch, cache_len)        -> (logits, cache)   (attn archs)
    input_specs(cfg, shape)                       -> ShapeDtypeStructs (launch/)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from . import transformer as tfm
from . import xlstm as xl
from . import zamba2 as zb


def init_params(cfg: ArchConfig, key: Optional[jax.Array] = None):
    key = key if key is not None else jax.random.PRNGKey(0)
    if cfg.family == "hybrid":
        return zb.init_zamba2(key, cfg)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return xl.init_xlstm_lm(key, cfg)
    return tfm.init_transformer(key, cfg)


def forward_hidden(params, cfg: ArchConfig, batch: dict, attn_impl: str = "chunked"):
    if cfg.family == "hybrid":
        return zb.forward_hidden(params, cfg, batch, attn_impl)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return xl.forward_hidden(params, cfg, batch, attn_impl)
    return tfm.forward_hidden(params, cfg, batch, attn_impl)


def head_matrix(params, cfg: ArchConfig):
    return tfm.head_matrix(params, cfg)


def loss_fn(params, cfg: ArchConfig, batch: dict,
            attn_impl: str = "chunked", aux_weight: float = 0.01):
    h, aux = forward_hidden(params, cfg, batch, attn_impl)
    labels = batch["labels"]
    if cfg.family == "vlm":
        n_f = batch["frontend_embeds"].shape[1]
        pad = jnp.full(labels.shape[:1] + (n_f,), -100, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce = tfm.chunked_softmax_xent(h, head_matrix(params, cfg), labels)
    return ce + aux_weight * aux


def forward_logits(params, cfg: ArchConfig, batch: dict, attn_impl: str = "chunked"):
    h, _ = forward_hidden(params, cfg, batch, attn_impl)
    return h.astype(jnp.float32) @ head_matrix(params, cfg).astype(jnp.float32)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ArchConfig, batch: int, seq_len: int):
    if cfg.family == "hybrid":
        return zb.init_zamba_cache(cfg, batch, seq_len)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return xl.init_xlstm_cache(cfg, batch)
    return tfm.init_cache(cfg, batch, seq_len)


def decode_step(params, cfg: ArchConfig, token: jnp.ndarray, cache):
    if cfg.family == "hybrid":
        return zb.decode_step(params, cfg, token, cache)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return xl.decode_step(params, cfg, token, cache)
    return tfm.decode_step(params, cfg, token, cache)


def prefill(params, cfg: ArchConfig, batch: dict, cache_len: int,
            attn_impl: str = "chunked"):
    if cfg.family == "hybrid":
        return zb.prefill(params, cfg, batch, cache_len, attn_impl)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return xl.prefill(params, cfg, batch, cache_len, attn_impl)
    return tfm.prefill(params, cfg, batch, cache_len, attn_impl)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins for the dry-run (no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Abstract input batch for (cfg × shape) — tokens/labels for train and
    prefill; a single-token batch for decode shapes (serve_step semantics).
    VLM/audio frontends provide precomputed embeddings (stub)."""
    B, L = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs: dict[str, Any] = {}
        if cfg.family == "audio":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct((B, L, cfg.d_model), jnp.bfloat16)
            specs["labels"] = jax.ShapeDtypeStruct((B, L), i32)
        elif cfg.family == "vlm":
            n_f = cfg.n_frontend_tokens
            specs["frontend_embeds"] = jax.ShapeDtypeStruct((B, n_f, cfg.d_model), jnp.bfloat16)
            specs["tokens"] = jax.ShapeDtypeStruct((B, L - n_f), i32)
            specs["labels"] = jax.ShapeDtypeStruct((B, L - n_f), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, L), i32)
            specs["labels"] = jax.ShapeDtypeStruct((B, L), i32)
        return specs
    # decode kinds: one new token against a cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def decode_cache_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Abstract decode cache for the dry-run (eval_shape — no allocation)."""
    return jax.eval_shape(
        lambda: init_decode_cache(cfg, shape.global_batch, shape.seq_len)
    )
