"""Unified model API — dispatches on cfg.family.

    init_params(cfg, key)                         -> params
    forward_hidden(params, cfg, batch)            -> (hidden, aux)
    loss_fn(params, cfg, batch)                   -> scalar
    init_decode_cache(cfg, batch, seq_len)        -> cache
    decode_step(params, cfg, token, cache)        -> (logits, cache)
    prefill(params, cfg, batch, cache_len)        -> (logits, cache)   (attn archs)
    input_specs(cfg, shape)                       -> ShapeDtypeStructs (launch/)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from . import transformer as tfm
from . import xlstm as xl
from . import zamba2 as zb


def init_params(cfg: ArchConfig, key: Optional[jax.Array] = None):
    key = key if key is not None else jax.random.PRNGKey(0)
    if cfg.family == "hybrid":
        return zb.init_zamba2(key, cfg)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return xl.init_xlstm_lm(key, cfg)
    return tfm.init_transformer(key, cfg)


def forward_hidden(params, cfg: ArchConfig, batch: dict, attn_impl: str = "chunked"):
    if cfg.family == "hybrid":
        return zb.forward_hidden(params, cfg, batch, attn_impl)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return xl.forward_hidden(params, cfg, batch, attn_impl)
    return tfm.forward_hidden(params, cfg, batch, attn_impl)


def head_matrix(params, cfg: ArchConfig):
    return tfm.head_matrix(params, cfg)


def loss_fn(params, cfg: ArchConfig, batch: dict,
            attn_impl: str = "chunked", aux_weight: float = 0.01):
    h, aux = forward_hidden(params, cfg, batch, attn_impl)
    labels = batch["labels"]
    if cfg.family == "vlm":
        n_f = batch["frontend_embeds"].shape[1]
        pad = jnp.full(labels.shape[:1] + (n_f,), -100, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce = tfm.chunked_softmax_xent(h, head_matrix(params, cfg), labels)
    return ce + aux_weight * aux


def forward_logits(params, cfg: ArchConfig, batch: dict, attn_impl: str = "chunked"):
    h, _ = forward_hidden(params, cfg, batch, attn_impl)
    return h.astype(jnp.float32) @ head_matrix(params, cfg).astype(jnp.float32)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ArchConfig, batch: int, seq_len: int):
    if cfg.family == "hybrid":
        return zb.init_zamba_cache(cfg, batch, seq_len)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return xl.init_xlstm_cache(cfg, batch)
    return tfm.init_cache(cfg, batch, seq_len)


def decode_step(params, cfg: ArchConfig, token: jnp.ndarray, cache):
    if cfg.family == "hybrid":
        return zb.decode_step(params, cfg, token, cache)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return xl.decode_step(params, cfg, token, cache)
    return tfm.decode_step(params, cfg, token, cache)


def prefill(params, cfg: ArchConfig, batch: dict, cache_len: int,
            attn_impl: str = "chunked"):
    if cfg.family == "hybrid":
        return zb.prefill(params, cfg, batch, cache_len, attn_impl)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return xl.prefill(params, cfg, batch, cache_len, attn_impl)
    return tfm.prefill(params, cfg, batch, cache_len, attn_impl)


# -- continuous-batching serving (repro.serve) ------------------------------
#
# Attention families decode against a paged block pool (per-slot block
# tables, per-slot lengths); recurrent/hybrid families decode slot-indexed
# state with per-slot lengths. Both keep the compiled shape fixed while
# requests join and leave between steps.

def _is_recurrent(cfg: ArchConfig) -> bool:
    return cfg.family == "hybrid" or (cfg.family == "ssm" and cfg.xlstm is not None)


def init_kv_pool(cfg: ArchConfig, n_blocks: int, block_size: int):
    if _is_recurrent(cfg):
        raise ValueError(f"{cfg.name}: recurrent families use init_decode_cache "
                         "slot state, not a paged KV pool")
    return tfm.init_kv_pool(cfg, n_blocks, block_size)


def write_prefill_blocks(k_pool, v_pool, k, v, block_ids):
    return tfm.write_prefill_blocks(k_pool, v_pool, k, v, block_ids)


def paged_decode_step(params, cfg: ArchConfig, token: jnp.ndarray,
                      k_pool, v_pool, tables, lengths):
    if _is_recurrent(cfg):
        raise ValueError(f"{cfg.name}: recurrent families use slot_decode_step")
    return tfm.paged_decode_step(params, cfg, token, k_pool, v_pool,
                                 tables, lengths)


def slot_decode_step(params, cfg: ArchConfig, token: jnp.ndarray, cache,
                     lengths: jnp.ndarray):
    """Per-slot-length decode for the O(1)-state families. ``token`` is
    (S, 1) int32, ``lengths`` (S,) int32. xLSTM state is position-free, so
    its stock decode_step serves unchanged; zamba2 needs per-slot RoPE
    positions and ring offsets for its shared-attention window."""
    if cfg.family == "hybrid":
        return zb.slot_decode_step(params, cfg, token, cache, lengths)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        del lengths                     # recurrence is position-free
        return xl.decode_step(params, cfg, token, cache)
    raise ValueError(f"{cfg.name}: attention families use paged_decode_step")


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins for the dry-run (no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Abstract input batch for (cfg × shape) — tokens/labels for train and
    prefill; a single-token batch for decode shapes (serve_step semantics).
    VLM/audio frontends provide precomputed embeddings (stub)."""
    B, L = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs: dict[str, Any] = {}
        if cfg.family == "audio":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct((B, L, cfg.d_model), jnp.bfloat16)
            specs["labels"] = jax.ShapeDtypeStruct((B, L), i32)
        elif cfg.family == "vlm":
            n_f = cfg.n_frontend_tokens
            specs["frontend_embeds"] = jax.ShapeDtypeStruct((B, n_f, cfg.d_model), jnp.bfloat16)
            specs["tokens"] = jax.ShapeDtypeStruct((B, L - n_f), i32)
            specs["labels"] = jax.ShapeDtypeStruct((B, L - n_f), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, L), i32)
            specs["labels"] = jax.ShapeDtypeStruct((B, L), i32)
        return specs
    # decode kinds: one new token against a cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def decode_cache_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Abstract decode cache for the dry-run (eval_shape — no allocation)."""
    return jax.eval_shape(
        lambda: init_decode_cache(cfg, shape.global_batch, shape.seq_len)
    )
