"""repro.models — pure-JAX model zoo for the 10 assigned architectures."""
from .model import (
    decode_cache_specs,
    decode_step,
    forward_hidden,
    forward_logits,
    init_decode_cache,
    init_kv_pool,
    init_params,
    input_specs,
    loss_fn,
    paged_decode_step,
    prefill,
    slot_decode_step,
    write_prefill_blocks,
)
from .transformer import paged_write_targets

__all__ = [
    "init_params", "forward_hidden", "forward_logits", "loss_fn",
    "init_decode_cache", "decode_step", "prefill",
    "init_kv_pool", "paged_decode_step", "slot_decode_step",
    "write_prefill_blocks", "paged_write_targets",
    "input_specs", "decode_cache_specs",
]
