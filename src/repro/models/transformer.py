"""Decoder/encoder transformer assembly for the dense, MoE, VLM and audio
families. Layers are STACKED (leading n_layers axis) and iterated with
jax.lax.scan — one traced block regardless of depth, which keeps HLO size and
compile time flat across the 24–81-layer assigned archs. Activation
checkpointing (jax.checkpoint) wraps the scan body when cfg.remat.

Cross-entropy is computed CHUNKED over the sequence so the (B, L, vocab)
logit tensor is never materialized — decisive for vocab 100k–152k archs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import (
    apply_attention_block,
    attention_sharded,
    apply_mlp,
    apply_norm,
    attn_qkv,
    decode_attention,
    dense_init,
    dtype_of,
    embed_init,
    init_attention,
    init_mlp,
    init_norm,
    pdtype_of,
)
from .moe import apply_moe, init_moe


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "attn_norm": init_norm(cfg),
        "attn": init_attention(k1, cfg),
        "mlp_norm": init_norm(cfg),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k3, cfg)
    return p


def init_transformer(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    block_keys = jax.random.split(ks[0], cfg.n_layers)
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(block_keys)  # stacked
    params = {
        "blocks": blocks,
        "final_norm": init_norm(cfg),
    }
    if cfg.frontend == "none" or cfg.family == "vlm":
        params["embed_tokens"] = embed_init(ks[1], cfg.vocab, cfg.d_model, pdtype_of(cfg))
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab, pdtype_of(cfg))
    if cfg.frontend != "none":
        # stub frontend: a single projection applied to precomputed embeddings
        params["frontend_proj"] = dense_init(ks[3], cfg.d_model, cfg.d_model, pdtype_of(cfg))
    return params


# ---------------------------------------------------------------------------
# embedding / head helpers
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ArchConfig, batch: dict) -> jnp.ndarray:
    """Assemble the input embedding sequence (B, L_total, d).

    vlm: [frontend patch embeds ; token embeds]; audio: frontend frames only;
    text: token embeds only.
    """
    dt = dtype_of(cfg)
    parts = []
    if cfg.frontend != "none":
        fe = batch["frontend_embeds"].astype(dt)
        parts.append(fe @ params["frontend_proj"].astype(dt))
    if "tokens" in batch and "embed_tokens" in params:
        parts.append(params["embed_tokens"].astype(dt)[batch["tokens"]])
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)


def head_matrix(params, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return params["embed_tokens"].T
    return params["lm_head"]


def chunked_softmax_xent(
    h: jnp.ndarray,            # (B, L, d) final hidden states
    W: jnp.ndarray,            # (d, V)
    labels: jnp.ndarray,       # (B, L) int32; -100 = ignore
    chunk: int = 512,
) -> jnp.ndarray:
    """Streamed cross-entropy: logits are produced chunk-by-chunk and reduced
    immediately (never materializing B×L×V)."""
    B, L, d = h.shape
    c = min(chunk, L)
    pad = (-L) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    n = (L + pad) // c
    hc = h.reshape(B, n, c, d).swapaxes(0, 1)          # (n, B, c, d)
    lc = labels.reshape(B, n, c).swapaxes(0, 1)

    def body(carry, inp):
        tot, cnt = carry
        hb, lb = inp
        logits = (hb.astype(jnp.float32)) @ W.astype(jnp.float32)   # (B, c, V)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1
        )[..., 0]
        valid = lb >= 0
        nll = jnp.where(valid, lse - tgt, 0.0)
        return (tot + jnp.sum(nll), cnt + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros((), jnp.int32)), (hc, lc))
    return tot / jnp.maximum(cnt, 1)


# ---------------------------------------------------------------------------
# forward (train / prefill trunk)
# ---------------------------------------------------------------------------

def _block_apply(bp, x, cfg: ArchConfig, attn_impl: str):
    """One transformer block. Returns (x_out, aux_loss).

    The residual stream is constrained sequence-parallel (seq over the tensor
    axis) between blocks — Megatron-SP: norms/residual adds run seq-sharded,
    and the partitioner turns the per-matmul all-reduces into the cheaper
    all-gather + reduce-scatter pair at the block boundaries (§Perf)."""
    from .layers import constrain

    x = constrain(x, "dp", "tp", None)
    h = apply_norm(bp["attn_norm"], x, cfg)
    x = x + apply_attention_block(bp["attn"], h, cfg, impl=attn_impl)
    x = constrain(x, "dp", "tp", None)
    h = apply_norm(bp["mlp_norm"], x, cfg)
    if "moe" in bp:
        y, aux = apply_moe(bp["moe"], h, cfg)
    else:
        y, aux = apply_mlp(bp["mlp"], h, cfg), jnp.zeros(())
    return x + y, aux


def forward_hidden(params, cfg: ArchConfig, batch: dict,
                   attn_impl: str = "chunked") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Embed + all blocks + final norm. Returns (hidden (B,L,d), aux_loss)."""
    x = embed_inputs(params, cfg, batch)

    def body(carry, bp):
        x, aux = carry
        x, a = _block_apply(bp, x, cfg, attn_impl)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros(())), params["blocks"])
    x = apply_norm(params["final_norm"], x, cfg)
    return x, aux


def forward_logits(params, cfg: ArchConfig, batch: dict,
                   attn_impl: str = "chunked") -> jnp.ndarray:
    h, _ = forward_hidden(params, cfg, batch, attn_impl)
    return h.astype(jnp.float32) @ head_matrix(params, cfg).astype(jnp.float32)


def loss_fn(params, cfg: ArchConfig, batch: dict,
            attn_impl: str = "chunked", aux_weight: float = 0.01) -> jnp.ndarray:
    h, aux = forward_hidden(params, cfg, batch, attn_impl)
    labels = batch["labels"]
    if cfg.frontend != "none" and cfg.family == "vlm":
        # frontend tokens carry no labels
        n_f = batch["frontend_embeds"].shape[1]
        pad = jnp.full(labels.shape[:1] + (n_f,), -100, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce = chunked_softmax_xent(h, head_matrix(params, cfg), labels)
    return ce + aux_weight * aux


# ---------------------------------------------------------------------------
# KV-cache serving
# ---------------------------------------------------------------------------

class TransformerCache(NamedTuple):
    k: jnp.ndarray       # (nL, B, S, KV, hd)
    v: jnp.ndarray
    length: jnp.ndarray  # () int32 — tokens written so far


def cache_capacity(cfg: ArchConfig, seq_len: int) -> int:
    """Ring-buffer size: the sliding window if set, else the full context."""
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(cfg: ArchConfig, batch: int, seq_len: int) -> TransformerCache:
    S = cache_capacity(cfg, seq_len)
    shape = (cfg.n_layers, batch, S, cfg.n_kv_heads, cfg.hd)
    dt = dtype_of(cfg)
    return TransformerCache(
        k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
        length=jnp.zeros((), jnp.int32),
    )


def prefill(params, cfg: ArchConfig, batch: dict, cache_len: int,
            attn_impl: str = "chunked"):
    """Run the full prompt, return (last-token logits, filled cache)."""
    x = embed_inputs(params, cfg, batch)
    B, L, _ = x.shape
    S = cache_capacity(cfg, cache_len)
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))

    # Ring-layout slot map (SWA only): token at absolute position p lives in
    # slot p % S; slot s holds the newest token p with p ≡ s (mod S). For the
    # linear (full-attention) cache we keep the first S tokens in order.
    ring = cfg.sliding_window is not None
    if L >= S and ring:
        slots = jnp.arange(S)
        ring_src = slots + ((L - 1 - slots) // S) * S       # positions to keep

    def body(x, bp):
        h = apply_norm(bp["attn_norm"], x, cfg)
        q, k, v = attn_qkv(bp["attn"], h, positions, cfg)
        o = attention_sharded(q, k, v, cfg, impl=attn_impl)
        o = o.reshape(B, L, cfg.n_heads * cfg.hd) @ bp["attn"]["wo"].astype(x.dtype)
        x = x + o
        h = apply_norm(bp["mlp_norm"], x, cfg)
        if "moe" in bp:
            y, _ = apply_moe(bp["moe"], h, cfg)
        else:
            y = apply_mlp(bp["mlp"], h, cfg)
        if L >= S and ring:
            k_keep, v_keep = k[:, ring_src], v[:, ring_src]
        elif L >= S:
            k_keep, v_keep = k[:, :S], v[:, :S]
        else:
            k_keep = jnp.pad(k, ((0, 0), (0, S - L), (0, 0), (0, 0)))
            v_keep = jnp.pad(v, ((0, 0), (0, S - L), (0, 0), (0, 0)))
        return x + y, (k_keep, v_keep)

    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    x = apply_norm(params["final_norm"], x, cfg)
    logits = x[:, -1:].astype(jnp.float32) @ head_matrix(params, cfg).astype(jnp.float32)
    cache = TransformerCache(k=ks, v=vs, length=jnp.asarray(min(L, S), jnp.int32))
    return logits, cache


# ---------------------------------------------------------------------------
# paged KV-cache serving (serve/: continuous batching)
# ---------------------------------------------------------------------------

def init_kv_pool(cfg: ArchConfig, n_blocks: int, block_size: int):
    """Shared K/V block pools: (n_layers, n_blocks, block_size, KV, hd).
    Block 0 is the reserved null block (serve.kv_cache) — free slots point
    their whole table at it so their writes never touch a live request."""
    shape = (cfg.n_layers, n_blocks, block_size, cfg.n_kv_heads, cfg.hd)
    dt = dtype_of(cfg)
    return jnp.zeros(shape, dt), jnp.zeros(shape, dt)


def write_prefill_blocks(k_pool, v_pool, k, v, block_ids):
    """Scatter one request's prefilled K/V (n_layers, 1, Lb, KV, hd) into its
    freshly allocated pool blocks. Lb must be a whole number of blocks (the
    engine buckets prompts to block multiples)."""
    nL, _, Lb, KV, hd = k.shape
    bs = k_pool.shape[2]
    nb = block_ids.shape[0]
    kb = k[:, 0].reshape(nL, nb, bs, KV, hd)
    vb = v[:, 0].reshape(nL, nb, bs, KV, hd)
    return k_pool.at[:, block_ids].set(kb), v_pool.at[:, block_ids].set(vb)


def paged_write_targets(tables, lengths, block_size: int):
    """Physical (block, offset) each slot's unconditional decode write targets.

    Slot s writes its new token's K/V at physical block
    ``tables[s, lengths[s] // block_size]``, offset ``lengths[s] % block_size``.
    The block lookup is a one-hot select + sum rather than
    ``jnp.take_along_axis``: a gather is opaque to the structured-zeros
    interpreter (``analysis.inertness`` maps it to TOP), while this
    formulation lets the null-block invariant — a free slot's all-zero table
    row and zero length give ``blk == off == 0``, so its write lands in the
    reserved null block and can never touch a live request — be *proven*
    mechanically from the jaxpr (``prove_null_block_inertness``). The two are
    equivalent for in-range indices, which the engine guarantees (admission
    reserves worst-case blocks; out of range the one-hot yields the null
    block, strictly safer than gather's index clamp).
    """
    j = jax.lax.div(lengths, jnp.int32(block_size))     # floor for lengths >= 0
    sel = jnp.arange(tables.shape[1], dtype=lengths.dtype)[None, :] == j[:, None]
    blk = jnp.sum(jnp.where(sel, tables, 0), axis=1)
    off = lengths - j * jnp.int32(block_size)
    return blk, off


def paged_decode_step(params, cfg: ArchConfig, token: jnp.ndarray,
                      k_pool, v_pool, tables, lengths):
    """One decode step for S batch slots against the paged KV pool.

    token: (S,) int32 — current input token per slot.
    k_pool/v_pool: (nL, n_blocks, bs, KV, hd) shared block pools.
    tables: (S, max_blocks) int32 — logical block j of slot s lives in
        physical block ``tables[s, j]`` (0 = null block for free slots and
        unallocated tail entries).
    lengths: (S,) int32 — per-slot context length (tokens already cached).

    Per layer: the new token's K/V is scattered to block
    ``tables[s, lengths[s] // bs]`` offset ``lengths[s] % bs``, then the
    slot's blocks are gathered in logical order and masked decode attention
    runs against them with the slot's own length and RoPE position — mixed
    lengths, joins and evictions are pure data, the compiled shape never
    changes. Returns (logits (S, V) fp32, k_pool, v_pool).
    """
    S = token.shape[0]
    bs = k_pool.shape[2]
    n_ctx = tables.shape[1] * bs
    dt = dtype_of(cfg)
    x = params["embed_tokens"].astype(dt)[token[:, None]]       # (S, 1, d)
    pos = lengths[:, None]                                      # (S, 1)
    blk, off = paged_write_targets(tables, lengths, bs)
    att_len = (lengths + 1)[:, None, None, None]                # (S,1,1,1)

    def body(x, layer):
        bp, kp, vp = layer
        h = apply_norm(bp["attn_norm"], x, cfg)
        q, k, v = attn_qkv(bp["attn"], h, pos, cfg)             # k: (S,1,KV,hd)
        kp = kp.at[blk, off].set(k[:, 0])
        vp = vp.at[blk, off].set(v[:, 0])
        k_ctx = kp[tables].reshape(S, n_ctx, cfg.n_kv_heads, cfg.hd)
        v_ctx = vp[tables].reshape(S, n_ctx, cfg.n_kv_heads, cfg.hd)
        o = decode_attention(q, k_ctx, v_ctx, att_len,
                             sliding_window=cfg.sliding_window)
        o = o.reshape(S, 1, cfg.n_heads * cfg.hd) @ bp["attn"]["wo"].astype(x.dtype)
        x = x + o
        h = apply_norm(bp["mlp_norm"], x, cfg)
        if "moe" in bp:
            y, _ = apply_moe(bp["moe"], h, cfg)
        else:
            y = apply_mlp(bp["mlp"], h, cfg)
        return x + y, (kp, vp)

    x, (kps, vps) = jax.lax.scan(body, x, (params["blocks"], k_pool, v_pool))
    x = apply_norm(params["final_norm"], x, cfg)
    logits = x.astype(jnp.float32) @ head_matrix(params, cfg).astype(jnp.float32)
    return logits[:, 0], kps, vps


def decode_step(params, cfg: ArchConfig, token: jnp.ndarray, cache: TransformerCache):
    """One autoregressive step. token: (B, 1) int32. Returns (logits, cache).

    With a sliding window the cache is a ring buffer (write at length % S);
    otherwise it is linear (write at length).
    """
    B = token.shape[0]
    dt = dtype_of(cfg)
    x = params["embed_tokens"].astype(dt)[token]          # (B, 1, d)
    S = cache.k.shape[2]
    pos = jnp.broadcast_to(cache.length[None, None], (B, 1))
    ring = cfg.sliding_window is not None
    write_at = cache.length % S if ring else jnp.minimum(cache.length, S - 1)

    def body(x, layer):
        bp, k_cache, v_cache = layer
        h = apply_norm(bp["attn_norm"], x, cfg)
        q, k, v = attn_qkv(bp["attn"], h, pos, cfg)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, write_at, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, write_at, 0, 0))
        o = decode_attention(
            q, k_cache, v_cache, cache.length + 1,
            sliding_window=cfg.sliding_window, ring=ring,
        )
        o = o.reshape(B, 1, cfg.n_heads * cfg.hd) @ bp["attn"]["wo"].astype(x.dtype)
        x = x + o
        h = apply_norm(bp["mlp_norm"], x, cfg)
        if "moe" in bp:
            y, _ = apply_moe(bp["moe"], h, cfg)
        else:
            y = apply_mlp(bp["mlp"], h, cfg)
        return x + y, (k_cache, v_cache)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache.k, cache.v))
    x = apply_norm(params["final_norm"], x, cfg)
    logits = x.astype(jnp.float32) @ head_matrix(params, cfg).astype(jnp.float32)
    new_cache = TransformerCache(k=ks, v=vs, length=cache.length + 1)
    return logits[:, 0], new_cache
