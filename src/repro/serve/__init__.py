"""repro.serve — serving engines: static padded batches and continuous
batching over a paged KV / slot-state cache (see SERVING.md)."""
from .engine import (
    SERVE_DECODE_FN,
    ContinuousConfig,
    ContinuousEngine,
    Engine,
    ServeConfig,
    StaticEngine,
    serving_kind,
)
from .kv_cache import (
    NULL_BLOCK,
    BlockPool,
    SlotStateCache,
    blocks_for_request,
    bucket_len,
    cache_batch_axes,
    is_recurrent,
)
from .scheduler import Request, RequestState, Scheduler

__all__ = [
    "Engine", "StaticEngine", "ServeConfig",
    "ContinuousEngine", "ContinuousConfig", "serving_kind", "SERVE_DECODE_FN",
    "BlockPool", "SlotStateCache", "NULL_BLOCK",
    "bucket_len", "blocks_for_request", "cache_batch_axes", "is_recurrent",
    "Request", "RequestState", "Scheduler",
]
