"""repro.serve — batched prefill/decode serving engine."""
from .engine import Engine, ServeConfig

__all__ = ["Engine", "ServeConfig"]
