"""Continuous-batching request scheduler: FIFO queue + block-budget
admission control + slot assignment.

Lifecycle (SERVING.md): ``QUEUED -> PREFILL -> DECODE -> DONE``. Requests
wait in a strict FIFO queue; ``admit()`` moves the head into a free batch
slot iff the pool can reserve its WORST-CASE block need up front
(``kv_cache.blocks_for_request``), so an admitted request can never run the
pool dry mid-decode. The head blocks the line when it doesn't fit — later,
smaller requests are NOT admitted around it (no starvation of large
requests; documented trade-off).

The scheduler is pure Python: it owns no device arrays and is fully
unit-testable without jax. The engine calls ``admit()`` between decode
steps — joins and evictions land at step boundaries only, as data changes
(slot tables / masks), never as shape changes.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from .kv_cache import BlockPool


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    """One generation request plus its runtime bookkeeping."""
    rid: int
    prompt: np.ndarray                  # (Lp,) int32
    max_new_tokens: int
    temperature: float = 0.0            # 0 = greedy
    seed: int = 0
    arrival: float = 0.0                # submit timestamp (engine clock)
    # -- runtime (engine/scheduler-owned) ----------------------------------
    state: RequestState = RequestState.QUEUED
    slot: int = -1
    block_ids: List[int] = dataclasses.field(default_factory=list)
    tokens: List[int] = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.state is RequestState.DONE


class Scheduler:
    """FIFO admission over ``num_slots`` batch slots and a shared BlockPool."""

    def __init__(self, num_slots: int, pool: BlockPool,
                 block_cost: Callable[[Request], int]):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        self.num_slots = num_slots
        self.pool = pool
        self.block_cost = block_cost
        self.queue: Deque[Request] = collections.deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        self._free_slots: List[int] = sorted(range(num_slots), reverse=True)

    # -- properties ---------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    @property
    def num_active(self) -> int:
        return len(self.active)

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.active)

    # -- transitions --------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request. Raises if it could NEVER be admitted (worst-case
        block need exceeds the whole pool) — catching the deadlock at submit
        time instead of wedging the FIFO head forever."""
        need = self.block_cost(req)
        if need > self.pool.capacity:
            raise ValueError(
                f"request {req.rid} needs {need} blocks but the pool only has "
                f"{self.pool.capacity} — raise n_blocks or shrink the request")
        req.state = RequestState.QUEUED
        self.queue.append(req)

    def admit(self) -> List[Request]:
        """Move FIFO-head requests into free slots while their worst-case
        block reservation fits. Returns the newly admitted requests (state
        PREFILL, slot + block_ids assigned)."""
        out: List[Request] = []
        while self.queue and self._free_slots:
            req = self.queue[0]
            blocks = self.pool.alloc(self.block_cost(req))
            if blocks is None:
                break                       # strict FIFO: head blocks the line
            self.queue.popleft()
            req.slot = self._free_slots.pop()
            req.block_ids = blocks
            req.state = RequestState.PREFILL
            self.active[req.slot] = req
            out.append(req)
        return out

    def release(self, req: Request) -> None:
        """Finish a request: free its blocks and recycle its slot."""
        if self.active.get(req.slot) is not req:
            raise ValueError(f"request {req.rid} is not active in slot {req.slot}")
        self.pool.free(req.block_ids)
        req.block_ids = []
        del self.active[req.slot]
        self._free_slots.append(req.slot)
        self._free_slots.sort(reverse=True)
        req.slot = -1
        req.state = RequestState.DONE
