"""Paged KV / recurrent-state cache for continuous-batching serving.

Memory model
------------
The device-resident decode cache is a POOL of fixed-size blocks shared by
every in-flight request, indexed through per-request **block tables** — the
vLLM paged-KV layout adapted to fixed-shape jit:

* **Attention families** (dense/moe): per layer, K and V pools of shape
  ``(n_layers, n_blocks, block_size, KV, hd)``. Logical context position
  ``p`` of the request in slot ``s`` lives at physical
  ``pool[:, table[s, p // block_size], p % block_size]``. Mixed-length
  sequences allocate only the blocks they need instead of padding every
  request to the batch max.

* **Recurrent / hybrid families** (ssm/xlstm, zamba2): decode state is O(1)
  (plus an O(window) attention ring for the hybrid), stored slot-indexed
  with a fixed per-request footprint. They go through the SAME allocator
  API as the degenerate one-block-per-request case, so admission control is
  uniform across families; the block ids are accounting-only (the state is
  addressed by slot, not by block).

Physical block 0 is reserved as the **null block**: free slots keep an
all-zero block table, so the decode step's unconditional per-slot cache
write lands in a garbage bin instead of a live request's block. Active
requests are never handed block 0 — this is what makes slot membership a
pure data change (mask/table contents) with no recompile.

``BlockPool`` and the bucketing helpers are pure Python (unit-testable
without jax); ``SlotStateCache`` owns the jitted slot join for the
recurrent families, discovering each cache leaf's batch axis automatically
by diffing ``init_decode_cache`` shapes across two batch sizes.
"""
from __future__ import annotations

import collections
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import init_decode_cache

NULL_BLOCK = 0


def is_recurrent(cfg: ArchConfig) -> bool:
    """Families whose decode state is O(1)-per-request (slot-indexed)."""
    return cfg.family == "hybrid" or (cfg.family == "ssm" and cfg.xlstm is not None)


def bucket_len(n: int, block_size: int) -> int:
    """Round a prompt length up to a whole number of blocks (the prefill
    shape buckets — bounds prefill compiles to one per bucket and wastes
    less than one block of pad per request)."""
    if n <= 0:
        raise ValueError(f"prompt length must be positive, got {n}")
    return -(-n // block_size) * block_size


def blocks_for_request(cfg: ArchConfig, prompt_len: int, max_new_tokens: int,
                       block_size: int) -> int:
    """Worst-case block need of one request, reserved in full at admission
    (no mid-decode allocation ⇒ an admitted request can never OOM the pool).

    Attention: the context grows to bucketed-prompt + generated tokens.
    Recurrent/hybrid: the degenerate fixed-footprint state, one block.
    """
    if is_recurrent(cfg):
        return 1
    total = bucket_len(prompt_len, block_size) + max_new_tokens
    return -(-total // block_size)


class BlockPool:
    """Free-list allocator over ``n_blocks`` fixed-size blocks.

    Pure Python bookkeeping (the device arrays live elsewhere). Block 0 is
    reserved as the null block and is never handed out. Because requests
    address blocks through tables, ANY free block satisfies any request —
    there is no contiguity requirement, so the pool cannot fragment:
    ``alloc(n)`` succeeds iff ``n <= num_free`` regardless of the
    alloc/free interleaving (pinned by tests/test_serving.py).
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the null block)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free: collections.deque = collections.deque(range(1, n_blocks))
        self._allocated: set = set()

    @property
    def capacity(self) -> int:
        """Usable blocks (the null block is not allocatable)."""
        return self.n_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._allocated)

    def occupancy(self) -> float:
        return self.num_allocated / self.capacity

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` blocks, or None (and no side effect) if unavailable."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            return None
        ids = [self._free.popleft() for _ in range(n)]
        self._allocated.update(ids)
        return ids

    def free(self, ids: Sequence[int]) -> None:
        for b in ids:
            if b == NULL_BLOCK:
                raise ValueError("cannot free the null block")
            if b not in self._allocated:
                raise ValueError(f"double free / foreign block {b}")
            self._allocated.remove(b)
            self._free.append(b)


# ---------------------------------------------------------------------------
# recurrent-family slot store: batch-axis discovery + jitted slot join
# ---------------------------------------------------------------------------

def cache_batch_axes(cfg: ArchConfig, seq_len: int) -> List[Optional[int]]:
    """Per-leaf batch-axis index of the family's decode cache, in
    tree_flatten order. Discovered mechanically: the axis where the leaf
    shapes of ``init_decode_cache`` at batch 2 vs batch 3 differ is the
    batch axis; leaves with identical shapes (e.g. the scalar ``length``)
    have no batch axis and return None."""
    s2 = jax.eval_shape(lambda: init_decode_cache(cfg, 2, seq_len))
    s3 = jax.eval_shape(lambda: init_decode_cache(cfg, 3, seq_len))
    axes: List[Optional[int]] = []
    for a, b in zip(jax.tree_util.tree_leaves(s2), jax.tree_util.tree_leaves(s3)):
        diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if len(diff) > 1:
            raise ValueError(f"ambiguous batch axis for leaf {a.shape} vs {b.shape}")
        axes.append(diff[0] if diff else None)
    return axes


def make_slot_join(axes: List[Optional[int]]) -> Callable:
    """Build the jitted join: write one request's (batch=1) prefilled cache
    into slot ``slot`` of the slot-indexed store. Leaves without a batch
    axis keep the store's value (per-slot lengths are tracked host-side by
    the engine)."""

    def join(store, req_cache, slot):
        ls, treedef = jax.tree_util.tree_flatten(store)
        lr = jax.tree_util.tree_leaves(req_cache)
        out = []
        for s, r, ax in zip(ls, lr, axes):
            if ax is None:
                out.append(s)
            else:
                out.append(jax.lax.dynamic_update_index_in_dim(s, r, slot, ax))
        return jax.tree_util.tree_unflatten(treedef, out)

    return jax.jit(join, donate_argnums=0)


class SlotStateCache:
    """Slot-indexed recurrent decode state behind the block-allocator API.

    ``store`` is the family's own ``init_decode_cache(cfg, num_slots, L)``
    pytree (so the sLSTM stabilizer floor, ring capacities etc. start at
    their true init values). ``join`` overwrites slot ``s`` with a freshly
    prefilled request state; eviction needs no device work — a stale slot's
    state keeps evolving on garbage until the next join overwrites it, and
    its sampled tokens are discarded (per-slot computation is independent,
    so garbage slots cannot perturb live ones)."""

    def __init__(self, cfg: ArchConfig, num_slots: int, max_total_len: int):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_total_len = max_total_len
        self.store = init_decode_cache(cfg, num_slots, max_total_len)
        self._join = make_slot_join(cache_batch_axes(cfg, max_total_len))

    def join(self, slot: int, req_cache) -> None:
        self.store = self._join(self.store, req_cache, slot)
