"""Batched serving engine: prefill + decode with KV/recurrent caches.

Serves a batch of requests with a shared-length cache (continuous batching is
approximated by padding to the batch's max prompt — the standard static-batch
TPU serving layout). Works for all decode-capable families:
attention archs take the fast parallel prefill; recurrent/hybrid archs
prefill by scanning decode steps (their O(1)-state architecture).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import decode_step, init_decode_cache, prefill


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 = greedy
    seed: int = 0
    attn_impl: str = "chunked"


class Engine:
    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig = ServeConfig()):
        if not cfg.has_decode:
            raise ValueError(f"{cfg.name} is encoder-only; nothing to decode")
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, self.cfg, t, c)
        )

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1
        ).astype(jnp.int32)

    def generate(self, prompts: jnp.ndarray) -> jnp.ndarray:
        """prompts: (B, Lp) int32 (left-padded with 0 allowed).
        Returns (B, max_new_tokens) generated ids."""
        cfg, scfg = self.cfg, self.scfg
        B, Lp = prompts.shape
        total = Lp + scfg.max_new_tokens
        key = jax.random.PRNGKey(scfg.seed)

        # all families use the parallel prefill (recurrent archs extract their
        # final states from the chunked scans — see models/{zamba2,xlstm}.py)
        logits, cache = prefill(
            self.params, cfg, {"tokens": prompts}, cache_len=total,
            attn_impl=scfg.attn_impl,
        )
        logits = logits[:, 0]

        outs = []
        tok = self._sample(logits, key)
        for i in range(scfg.max_new_tokens):
            outs.append(tok)
            if i == scfg.max_new_tokens - 1:
                break
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, tok[:, None], cache)
            tok = self._sample(logits, sub)
        return jnp.stack(outs, axis=1)
