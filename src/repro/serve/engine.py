"""Serving engines: static padded batches (reference) and continuous
batching over a paged KV / slot-state cache.

``StaticEngine`` is the original demo path: one batch, padded to the batch
max, all requests prefilled and decoded in lockstep. ``ContinuousEngine``
is the production path (SERVING.md): a FIFO scheduler admits requests into
``num_slots`` fixed batch slots between decode steps, attention context
lives in a shared block pool indexed by per-slot block tables
(``serve.kv_cache``), recurrent state is slot-indexed, and per-request
sampling params (temperature, seed, max_new_tokens) ride per-slot arrays.

No-recompile slot contract: the compiled decode step ``serve_decode`` is
shaped by (num_slots, table width, pool size) ONLY. Requests joining,
generating at different lengths, and leaving are pure data changes (tables,
lengths, temps, keys, tokens). After the first decode compile there are
zero further ``serve_decode`` compiles — pinned by
``analysis.recompile.CompileWatcher`` in tests/test_serving.py and the
benchmarks/serving.py smoke lane. Prefill compiles once per prompt-length
bucket (prompts round up to whole blocks) under its own function name, so
the decode audit is unaffected.

Per-request telemetry (queued / prefill / TTFT / finish / decode_step with
queue-depth and block-pool gauges) streams through the existing
``telemetry.TelemetrySink`` with the serving record schema
(``telemetry.serving``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.recompile import mark_step
from ..configs.base import ArchConfig
from ..models import (
    decode_step,
    init_kv_pool,
    paged_decode_step,
    prefill,
    slot_decode_step,
    write_prefill_blocks,
)
from ..telemetry.serving import serving_record
from .kv_cache import (
    BlockPool,
    SlotStateCache,
    blocks_for_request,
    bucket_len,
    is_recurrent,
)
from .scheduler import Request, RequestState, Scheduler

# The jitted decode entrypoint's compile-log name — audit recompiles with
# CompileWatcher(fn_name=SERVE_DECODE_FN).
SERVE_DECODE_FN = "serve_decode"


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 = greedy
    seed: int = 0
    attn_impl: str = "chunked"


def serving_kind(cfg: ArchConfig) -> str:
    """'paged' (attention families, block-table KV) or 'slot' (recurrent)."""
    if not cfg.has_decode:
        raise ValueError(f"{cfg.name} is encoder-only; nothing to decode")
    if is_recurrent(cfg):
        return "slot"
    if cfg.frontend != "none":
        raise ValueError(f"{cfg.name}: frontend-embedding archs are not "
                         "servable from token prompts")
    return "paged"


# Donation signatures of the compiled serve_decode entrypoints.  The KV /
# state pools are by far the largest decode buffers; donating them is what
# keeps exactly ONE copy resident — analysis/memory.py pins this with a
# donation-savings floor equal to the full pool bytes.
PAGED_DECODE_DONATE = (1, 2)    # k_pool, v_pool
SLOT_DECODE_DONATE = (1,)       # slot-state store


def paged_serve_decode_fn(cfg: ArchConfig):
    """Build the paged-attention ``serve_decode`` step for ``cfg``.

    Module-level (not a method closure) so the static-analysis driver can
    compile and audit the EXACT function the engine runs — same name for
    the recompile watcher, same donation signature, same HLO.
    """
    def serve_decode(params, k_pool, v_pool, tables, lengths, temps,
                     keys, token):
        logits, k_pool, v_pool = paged_decode_step(
            params, cfg, token, k_pool, v_pool, tables, lengths)
        tok, keys = _sample_slots(logits, temps, keys)
        return tok, k_pool, v_pool, keys

    return serve_decode


def slot_serve_decode_fn(cfg: ArchConfig):
    """Build the recurrent (slot-state) ``serve_decode`` step for ``cfg``."""
    def serve_decode(params, store, lengths, temps, keys, token):
        logits, store = slot_decode_step(
            params, cfg, token[:, None], store, lengths)
        tok, keys = _sample_slots(logits, temps, keys)
        return tok, store, keys

    return serve_decode


def serve_decode_audit_args(cfg: ArchConfig, ccfg, params):
    """Zero-valued arguments shaped exactly like ContinuousEngine's paged
    decode call — so ``jax.jit(paged_serve_decode_fn(cfg),
    donate_argnums=PAGED_DECODE_DONATE).lower(*args).compile()`` in the
    analysis driver produces the same executable the engine runs."""
    S = ccfg.num_slots
    bs = ccfg.block_size
    max_total = bucket_len(ccfg.max_prompt_len, bs) + ccfg.max_new_cap
    max_blocks = -(-max_total // bs)
    k_pool, v_pool = init_kv_pool(cfg, ccfg.n_blocks, bs)
    return (params, k_pool, v_pool,
            jnp.zeros((S, max_blocks), jnp.int32),
            jnp.zeros(S, jnp.int32),
            jnp.zeros(S, jnp.float32),
            jnp.zeros((S, 2), jnp.uint32),
            jnp.zeros(S, jnp.int32))


class StaticEngine:
    """Static padded-batch engine (the original demo path, kept as the
    baseline and parity reference for the continuous engine)."""

    def __init__(self, cfg: ArchConfig, params,
                 scfg: Optional[ServeConfig] = None):
        if not cfg.has_decode:
            raise ValueError(f"{cfg.name} is encoder-only; nothing to decode")
        self.cfg = cfg
        self.params = params
        # None + per-instance construction: a `scfg: ServeConfig = ServeConfig()`
        # default is evaluated ONCE at def time and shared across every
        # engine — mutating one engine's config would mutate them all.
        self.scfg = ServeConfig() if scfg is None else scfg
        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, self.cfg, t, c)
        )
        self._prefill = jax.jit(
            lambda p, t, L: prefill(p, self.cfg, {"tokens": t}, L,
                                    self.scfg.attn_impl),
            static_argnums=2)

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1
        ).astype(jnp.int32)

    def generate(self, prompts: jnp.ndarray,
                 on_token: Optional[Callable] = None,
                 stop_counts: Optional[Sequence[int]] = None) -> jnp.ndarray:
        """prompts: (B, Lp) int32 (left-padded with 0 allowed).
        Returns (B, n_steps) generated ids. ``on_token(i, tok)`` is called
        after each token batch is READY (blocks on the device), so
        benchmarks can timestamp static serving per token. ``stop_counts``
        gives per-row token budgets: the batch stops at ``max(stop_counts)``
        (the static head-of-line cost — every row rides until the slowest
        member finishes) without changing any compiled shape; rows past
        their own budget keep decoding garbage the caller truncates."""
        cfg, scfg = self.cfg, self.scfg
        B, Lp = prompts.shape
        total = Lp + scfg.max_new_tokens
        key = jax.random.PRNGKey(scfg.seed)
        n_steps = scfg.max_new_tokens
        if stop_counts is not None:
            n_steps = min(n_steps, max(int(c) for c in stop_counts))

        # all families use the parallel prefill (recurrent archs extract their
        # final states from the chunked scans — see models/{zamba2,xlstm}.py)
        logits, cache = self._prefill(self.params, prompts, total)
        logits = logits[:, 0]

        outs = []
        tok = self._sample(logits, key)
        for i in range(n_steps):
            outs.append(tok)
            if on_token is not None:
                jax.block_until_ready(tok)
                on_token(i, tok)
            if i == n_steps - 1:
                break
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, tok[:, None], cache)
            tok = self._sample(logits, sub)
        return jnp.stack(outs, axis=1)


# Backwards-compatible alias for the pre-continuous API.
Engine = StaticEngine


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ContinuousConfig:
    """Shapes and policy of the continuous engine. Everything here is a
    COMPILE-TIME shape parameter; per-request knobs live on Request."""
    num_slots: int = 4            # decode batch width (fixed jit shape)
    block_size: int = 8           # tokens per KV block
    n_blocks: int = 64            # physical pool blocks (incl. null block 0)
    max_prompt_len: int = 32      # longest admissible prompt
    max_new_cap: int = 32         # longest admissible per-request generation
    attn_impl: str = "chunked"
    seed: int = 0                 # mixed into per-request default seeds


class ContinuousEngine:
    """Continuous-batching engine: FIFO admission, paged/slot cache,
    per-request sampling, per-request telemetry."""

    def __init__(self, cfg: ArchConfig, params,
                 ccfg: Optional[ContinuousConfig] = None,
                 sink=None, clock: Callable[[], float] = time.perf_counter):
        self.cfg = cfg
        self.params = params
        self.ccfg = ccfg = ContinuousConfig() if ccfg is None else ccfg
        self.kind = serving_kind(cfg)
        self.sink = sink
        self._clock = clock

        bs = ccfg.block_size
        if ccfg.num_slots < 1 or bs < 1 or ccfg.n_blocks < 2:
            raise ValueError("num_slots >= 1, block_size >= 1, n_blocks >= 2")
        self._max_total = bucket_len(ccfg.max_prompt_len, bs) + ccfg.max_new_cap
        self._max_blocks = -(-self._max_total // bs)
        if (self.kind == "paged" and cfg.sliding_window is not None
                and bucket_len(ccfg.max_prompt_len, bs) > cfg.sliding_window):
            raise ValueError(
                f"{cfg.name}: paged prefill needs bucketed prompts within the "
                f"sliding window ({cfg.sliding_window}); shrink max_prompt_len")

        self.pool = BlockPool(ccfg.n_blocks, bs)
        self.scheduler = Scheduler(
            ccfg.num_slots, self.pool,
            lambda r: blocks_for_request(cfg, len(r.prompt),
                                         r.max_new_tokens, bs))

        S = ccfg.num_slots
        self._lengths = np.zeros(S, np.int32)
        self._temps = np.zeros(S, np.float32)
        self._cur_tok = np.zeros(S, np.int32)
        self._keys = jnp.zeros((S, 2), jnp.uint32)
        self._step_idx = 0
        self._next_rid = 0
        self.results: Dict[int, np.ndarray] = {}
        self.requests: Dict[int, Request] = {}

        if self.kind == "paged":
            self._k_pool, self._v_pool = init_kv_pool(cfg, ccfg.n_blocks, bs)
            self._tables = np.zeros((S, self._max_blocks), np.int32)
            self._scatter = jax.jit(write_prefill_blocks, donate_argnums=(0, 1))
            self._decode = jax.jit(paged_serve_decode_fn(cfg),
                                   donate_argnums=PAGED_DECODE_DONATE)
        else:
            self._slots = SlotStateCache(cfg, S, self._max_total)
            self._decode = jax.jit(slot_serve_decode_fn(cfg),
                                   donate_argnums=SLOT_DECODE_DONATE)

        self._prefill = jax.jit(
            lambda p, t, L: prefill(p, cfg, {"tokens": t}, L, ccfg.attn_impl),
            static_argnums=2)

    # -- submission ---------------------------------------------------------
    @property
    def busy(self) -> bool:
        return self.scheduler.has_work

    def submit(self, prompt, max_new_tokens: int = 16,
               temperature: float = 0.0, seed: Optional[int] = None,
               arrival: Optional[float] = None) -> int:
        """Queue one generation request; returns its request id.
        ``arrival`` (engine-clock seconds) lets open-loop drivers charge
        queueing delay from the TRACE arrival time rather than the moment
        the driver got around to calling submit."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not (1 <= prompt.shape[0] <= self.ccfg.max_prompt_len):
            raise ValueError(
                f"prompt length {prompt.shape[0]} outside "
                f"[1, {self.ccfg.max_prompt_len}]")
        if not (1 <= max_new_tokens <= self.ccfg.max_new_cap):
            raise ValueError(
                f"max_new_tokens {max_new_tokens} outside "
                f"[1, {self.ccfg.max_new_cap}]")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(
            rid=rid, prompt=prompt, max_new_tokens=int(max_new_tokens),
            temperature=float(temperature),
            seed=int(self.ccfg.seed * 1_000_003 + rid) if seed is None else int(seed),
            arrival=self._clock() if arrival is None else float(arrival))
        self.scheduler.submit(req)
        self.requests[rid] = req
        self._emit("queued", rid, float(self.scheduler.queue_depth))
        return rid

    # -- engine loop --------------------------------------------------------
    def step(self) -> bool:
        """Admit waiting requests, run ONE decode step over the slot batch,
        retire finished requests. Returns True while work remains."""
        for req in self.scheduler.admit():
            self._join(req)
        active = dict(self.scheduler.active)
        if not active:
            return self.scheduler.has_work

        mark_step(self._step_idx)
        t0 = self._clock()
        if self.kind == "paged":
            tok, self._k_pool, self._v_pool, self._keys = self._decode(
                self.params, self._k_pool, self._v_pool, self._tables,
                self._lengths, self._temps, self._keys, self._cur_tok)
        else:
            tok, store, self._keys = self._decode(
                self.params, self._slots.store, self._lengths, self._temps,
                self._keys, self._cur_tok)
            self._slots.store = store
        toks = np.asarray(tok)                       # host sync per step
        t1 = self._clock()
        self._step_idx += 1

        finished: List[Request] = []
        for slot, req in active.items():
            self._lengths[slot] += 1
            t = int(toks[slot])
            req.tokens.append(t)
            req.token_times.append(t1)
            self._cur_tok[slot] = t
            if len(req.tokens) >= req.max_new_tokens:
                finished.append(req)
        self._emit("decode_step", -1, t1 - t0)
        for req in finished:
            self._retire(req, t1)
        return self.scheduler.has_work

    def run(self) -> Dict[int, np.ndarray]:
        """Drive until idle; returns {rid: generated tokens}. (Open-loop
        drivers call step() themselves and submit between steps.)"""
        while self.step():
            pass
        return dict(self.results)

    # -- internals ----------------------------------------------------------
    def _join(self, req: Request) -> None:
        """Prefill an admitted request and install it into its slot."""
        t_start = self._clock()
        bs = self.ccfg.block_size
        slot = req.slot
        Lp = req.prompt.shape[0]
        Lb = bucket_len(Lp, bs)
        padded = np.zeros(Lb, np.int32)
        padded[Lb - Lp:] = req.prompt                # left-pad with token 0

        if self.kind == "paged":
            logits, cache = self._prefill(self.params, padded[None], Lb)
            ids = np.asarray(req.block_ids[: Lb // bs], np.int32)
            self._k_pool, self._v_pool = self._scatter(
                self._k_pool, self._v_pool, cache.k, cache.v, ids)
            row = np.zeros(self._max_blocks, np.int32)
            row[: len(req.block_ids)] = req.block_ids
            self._tables[slot] = row
        else:
            logits, cache = self._prefill(self.params, padded[None],
                                          self._max_total)
            self._slots.join(slot, cache)

        self._lengths[slot] = Lb
        self._temps[slot] = req.temperature

        # First token comes straight off the prefill logits; the slot's key
        # chain starts from the request's own seed.
        key = jax.random.PRNGKey(req.seed)
        carry, sub = jax.random.split(key)
        self._keys = self._keys.at[slot].set(carry)
        row_logits = np.asarray(logits[0, 0], np.float32)
        if req.temperature > 0:
            tok = int(jax.random.categorical(
                sub, jnp.asarray(row_logits) / max(req.temperature, 1e-6)))
        else:
            tok = int(row_logits.argmax())
        t_tok = self._clock()
        req.state = RequestState.DECODE
        req.tokens.append(tok)
        req.token_times.append(t_tok)
        req.first_token_time = t_tok
        self._cur_tok[slot] = tok
        self._emit("prefill", req.rid, t_tok - t_start)
        self._emit("ttft", req.rid, t_tok - req.arrival)
        if len(req.tokens) >= req.max_new_tokens:
            self._retire(req, t_tok)

    def _retire(self, req: Request, now: float) -> None:
        slot = req.slot
        self.scheduler.release(req)                  # frees blocks + slot
        if self.kind == "paged":
            self._tables[slot] = 0                   # back to the null block
        self._lengths[slot] = 0
        self._temps[slot] = 0.0
        self._cur_tok[slot] = 0
        req.finish_time = now
        self.results[req.rid] = np.asarray(req.tokens, np.int32)
        self._emit("finish", req.rid, now - req.arrival)

    def _emit(self, event: str, request_id: int, value: float) -> None:
        if self.sink is None:
            return
        rec = serving_record(
            step=self._step_idx, event=event, request_id=request_id,
            t=self._clock(), value=value,
            queue_depth=self.scheduler.queue_depth,
            active_slots=self.scheduler.num_active,
            free_blocks=self.pool.num_free)
        self.sink.emit(self._step_idx, [rec])


def _sample_slots(logits, temps, keys):
    """Per-slot sampling: greedy where temp==0, categorical with the slot's
    own key chain otherwise. Returns (tokens (S,) int32, advanced keys)."""
    splits = jax.vmap(lambda k: jax.random.split(k))(keys)     # (S, 2, 2)
    carry, sub = splits[:, 0], splits[:, 1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(sub, scaled).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy), carry
