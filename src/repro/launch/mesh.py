"""Production mesh builders.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  512 chips as (pod=2, data=16, model=16) — the `pod` axis is the
cross-DCN data-parallel axis (gradient all-reduce only; no model collectives
cross pods).

Functions, not module constants — importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """(data, model) mesh on whatever devices exist (tests / examples on CPU).

    ``model`` > 1 gives the 2D mesh the SUMO bucket update's tensor-parallel
    path runs under — B over `data`, each matrix's long dim over `model`
    (tier-1 pins (data=2, model=4) on 8 forced host devices, see
    tests/test_rsvd_sharded.py). A ``model`` that does not divide the device
    count is clamped to the largest divisor so the mesh always builds.
    """
    n = len(jax.devices())
    model = max(1, min(model, n))
    while n % model:
        model -= 1
    return jax.make_mesh((n // model, model), ("data", "model"))
