"""Production mesh builders.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  512 chips as (pod=2, data=16, model=16) — the `pod` axis is the
cross-DCN data-parallel axis (gradient all-reduce only; no model collectives
cross pods).

Functions, not module constants — importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import math
import warnings

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The fixed-shape pod mesh. Validates the device count up front: a
    mismatch used to surface as an opaque ``jax.make_mesh`` failure deep in
    launch; now it names the requested shape and what was found."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    have = len(jax.devices())
    if have < need:
        # jax.make_mesh tolerates extra devices (it truncates — the dry-run
        # forces 512 and builds the 256-chip mesh from the first half) but
        # too few only surfaces as an opaque reshape error deep inside it.
        raise ValueError(
            f"production mesh {dict(zip(axes, shape))} needs {need} devices, "
            f"found {have} — run on a "
            f"{'2-pod' if multi_pod else 'single-pod'} slice or use "
            "make_host_mesh() for ad-hoc device counts")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, strict: bool = False):
    """(data, model) mesh on whatever devices exist (tests / examples on CPU).

    ``model`` > 1 gives the 2D mesh the SUMO bucket update's tensor-parallel
    path runs under — B over `data`, each matrix's long dim over `model`
    (ragged long dims edge-pad; tier-1 pins (data=2, model=4) on 8 forced
    host devices, see tests/test_rsvd_sharded.py). A ``model`` that does not
    divide the device count is clamped to the largest divisor so the mesh
    always builds — with a warning, because a silently smaller model axis
    changes the memory/collective profile of the whole run. ``strict=True``
    raises instead (production launches should never train on a different
    mesh than the one they asked for).
    """
    n = len(jax.devices())
    requested = model
    model = max(1, min(model, n))
    while n % model:
        model -= 1
    if model != requested:
        msg = (f"make_host_mesh: requested model={requested} does not divide "
               f"the device count ({n}); largest usable divisor is {model}")
        if strict:
            raise ValueError(msg + " (strict=True)")
        warnings.warn(msg + " — clamping. Pass strict=True to fail instead.",
                      RuntimeWarning, stacklevel=2)
    return jax.make_mesh((n // model, model), ("data", "model"))
