"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract memory/cost/collective roofline terms. No real TPU needed — 512
placeholder host devices stand in for the production pods.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json
"""
# The XLA device-count override MUST precede any other import that could
# initialize jax (device count locks on first backend init).
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import (  # noqa: E402
    ARCH_IDS,
    SHAPE_BY_NAME,
    SHAPES,
    cell_supported,
    get_config,
)
from ..core import SumoConfig, sumo_optimizer  # noqa: E402
from ..models import (  # noqa: E402
    decode_cache_specs,
    decode_step,
    init_params,
    input_specs,
    prefill,
)
from ..parallel import (  # noqa: E402
    cache_specs,
    input_specs_sharding,
    opt_state_specs,
    tree_param_specs,
)
from ..roofline import (  # noqa: E402
    Roofline,
    extract_cost,
    model_flops_for,
)
from ..roofline.hlo_cost import analyze_hlo  # noqa: E402
from ..train.steps import make_train_step  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402


def _named(tree_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree_specs,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def _abstract_params(cfg):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def dryrun_cell(arch_id: str, shape_name: str, multi_pod: bool,
                optimizer: str = "sumo", rank: int = 128,
                verbose: bool = True, hints: bool = True) -> dict:
    cfg = get_config(arch_id)
    shape = SHAPE_BY_NAME[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    from ..models.layers import clear_sharding_hints, set_sharding_hints
    if hints:
        dp = ("pod", "data") if multi_pod else ("data",)
        set_sharding_hints(dp, "model", dict(mesh.shape))
    else:
        clear_sharding_hints()

    params_s = _abstract_params(cfg)
    param_specs = tree_param_specs(params_s, mesh, cfg)
    param_sh = _named(param_specs, mesh)
    batch_s = input_specs(cfg, shape)
    batch_sh = _named(input_specs_sharding(batch_s, mesh, shape.global_batch), mesh)

    with mesh:
        if shape.kind == "train":
            tx = sumo_optimizer(
                1e-3, params_s, SumoConfig(rank=rank, update_freq=200)
            ) if optimizer == "sumo" else None
            from ..train.steps import make_optimizer
            if tx is None:
                tx = make_optimizer(optimizer, 1e-3, params_s, rank=rank)
            opt_s = jax.eval_shape(tx.init, params_s)
            opt_sh = _named(opt_state_specs(opt_s, mesh, cfg), mesh)
            step = make_train_step(cfg, tx, attn_impl="flash")
            metric_sh = {k: NamedSharding(mesh, P())
                         for k in ("loss", "grad_norm", "update_norm")}
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, metric_sh),
            )
            lowered = jitted.lower(params_s, opt_s, batch_s)
        elif shape.kind == "prefill":
            def prefill_step(params, batch):
                return prefill(params, cfg, batch, cache_len=shape.seq_len)

            jitted = jax.jit(prefill_step, in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(params_s, batch_s)
        else:  # decode / long_decode: one token against a seq_len cache
            cache_s = decode_cache_specs(cfg, shape)
            cache_sh = _named(
                cache_specs(cache_s, mesh, cfg, shape.global_batch), mesh
            )

            def serve_step(params, token, cache):
                return decode_step(params, cfg, token, cache)

            jitted = jax.jit(
                serve_step,
                in_shardings=(param_sh, batch_sh["tokens"], cache_sh),
                out_shardings=(NamedSharding(mesh, P()), cache_sh),
            )
            lowered = jitted.lower(params_s, batch_s["tokens"], cache_s)

        compiled = lowered.compile()

    from ..analysis.memory import measure_compiled_memory

    mem = measure_compiled_memory(compiled)    # shared with analysis pass 5
    xla_flops, xla_bytes = extract_cost(compiled)       # XLA's own (no trip counts)
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)                             # trip-count-aware walker
    n_active = cfg.active_param_count()
    rl = Roofline(
        arch=arch_id, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=cost.flops, hlo_bytes=cost.bytes,
        collective_bytes=cost.collective_bytes,
        model_flops=model_flops_for(cfg, shape, n_active, shape.kind),
    )
    result = {
        "status": "ok",
        "compile_s": round(time.perf_counter() - t0, 1),
        "memory": {
            "argument_bytes": mem.argument_bytes,
            "output_bytes": mem.output_bytes,
            "temp_bytes": mem.temp_bytes,
            "alias_bytes": mem.alias_bytes,
            "code_bytes": mem.generated_code_bytes,
            "peak_bytes": mem.peak_bytes,
        },
        "xla_cost_analysis": {"flops": xla_flops, "bytes": xla_bytes},
        "collective_breakdown": {k: v for k, v in cost.collective_breakdown.items() if v},
        "unknown_trip_loops": cost.unknown_trip_loops,
        **rl.row(),
    }
    if verbose:
        print(f"[{arch_id} × {shape_name} × {mesh_name}] ok "
              f"compile={result['compile_s']}s "
              f"t_comp={rl.t_compute:.4f}s t_mem={rl.t_memory:.4f}s "
              f"t_coll={rl.t_collective:.4f}s -> {rl.bottleneck} "
              f"(useful {rl.useful_ratio:.2f}, roofline {rl.roofline_fraction:.2%})")
        print(f"  memory/device: args={result['memory']['argument_bytes']} "
              f"temp={result['memory']['temp_bytes']}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES], default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="all archs × shapes")
    ap.add_argument("--optimizer", default="sumo")
    ap.add_argument("--rank", type=int, default=128)
    ap.add_argument("--out", default=None, help="append results to this JSON file")
    ap.add_argument("--no-hints", action="store_true",
                    help="disable activation-sharding constraints (paper-faithful baseline)")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") in ("ok", "skipped")}

    n_fail = 0
    for arch_id in archs:
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch_id, shape_name, mesh_name) in done:
                    continue
                try:
                    r = dryrun_cell(arch_id, shape_name, mp,
                                    optimizer=args.optimizer, rank=args.rank,
                                    hints=not args.no_hints)
                except Exception as e:
                    traceback.print_exc()
                    r = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                         "status": "fail", "error": f"{type(e).__name__}: {e}"}
                    n_fail += 1
                results.append(r)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1, default=str)
    ok = sum(1 for r in results if r.get("status") == "ok")
    sk = sum(1 for r in results if r.get("status") == "skipped")
    print(f"\ndry-run: {ok} ok, {sk} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
