"""Serving launcher: continuous batching by default, static padded batches
with ``--static`` (the original demo path).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --requests 8 --max-new 32
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --static --batch 4 --prompt-len 16

With ``--telemetry OUT.jsonl`` the continuous engine streams per-request
records (queued / prefill / TTFT / finish / decode_step, with queue-depth
and block-pool gauges) through ``telemetry.TelemetrySink`` — see SERVING.md.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..models import init_params
from ..serve import ContinuousConfig, ContinuousEngine, ServeConfig, StaticEngine
from ..telemetry import JsonlWriter, TelemetrySink
from ..telemetry.serving import serving_stats_to_records, validate_serving_record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--static", action="store_true",
                    help="static padded-batch engine instead of continuous")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch rows (static) / decode slots (continuous)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8,
                    help="number of requests (continuous)")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--telemetry", default=None, metavar="OUT.jsonl",
                    help="stream serving records to this JSONL (continuous)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decode:
        print(f"{cfg.name} is encoder-only — no decode path")
        return 1
    params = init_params(cfg, jax.random.PRNGKey(0))

    if args.static:
        eng = StaticEngine(cfg, params, ServeConfig(
            max_new_tokens=args.max_new, temperature=args.temperature))
        key = jax.random.PRNGKey(1)
        prompts = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab)
        t0 = time.perf_counter()
        out = eng.generate(prompts)
        dt = time.perf_counter() - t0
        print(f"static: generated {out.shape} in {dt:.2f}s "
              f"({out.size / dt:.1f} tok/s incl. compile)")
        print("sample:", out[0][:16].tolist())
        return 0

    sink = None
    if args.telemetry:
        sink = TelemetrySink(writers=[JsonlWriter(args.telemetry)],
                             to_records=serving_stats_to_records,
                             validate_fn=validate_serving_record)
    max_blocks = -(-(args.prompt_len + args.max_new) // args.block_size) + 1
    ccfg = ContinuousConfig(
        num_slots=args.batch, block_size=args.block_size,
        n_blocks=1 + args.batch * max_blocks,
        max_prompt_len=args.prompt_len, max_new_cap=args.max_new)
    eng = ContinuousEngine(cfg, params, ccfg, sink=sink)
    rng = np.random.default_rng(1)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        eng.submit(rng.integers(1, cfg.vocab,
                                size=int(rng.integers(1, args.prompt_len + 1))),
                   max_new_tokens=args.max_new,
                   temperature=args.temperature)
    results = eng.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in results.values())
    print(f"continuous: served {len(results)} requests, {n_tok} tokens in "
          f"{dt:.2f}s ({n_tok / dt:.1f} tok/s incl. compile)")
    print("sample:", results[0][:16].tolist())
    if sink is not None:
        sink.close()
        print(f"telemetry: {sink.records_written} records -> {args.telemetry}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
