"""Serving launcher: batched generation with the KV/recurrent-cache engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..models import init_params
from ..serve import Engine, ServeConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decode:
        print(f"{cfg.name} is encoder-only — no decode path")
        return 1
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(
        max_new_tokens=args.max_new, temperature=args.temperature))
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.perf_counter()
    out = eng.generate(prompts)
    dt = time.perf_counter() - t0
    n_tok = out.size
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0][:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
