"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --optimizer sumo --steps 50 --batch 8 --seq 128

On a real cluster this process runs per host under the pod scheduler
(jax.distributed.initialize picks up the coordinator from env); on this
container it runs the same code single-host. --smoke selects the reduced
config so the full model zoo is trainable on CPU.
"""
from __future__ import annotations

import argparse
import dataclasses

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..configs.base import ShapeConfig
from ..train import FaultInjector, TrainConfig, train


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS) + ["llama-paper"],
                    default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--optimizer", default="sumo",
                    choices=["sumo", "sumo-svd", "sumo-ns5", "galore", "muon", "adamw"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--rank", type=int, default=32)
    ap.add_argument("--update-freq", type=int, default=50)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--preempt-at", type=int, nargs="*", default=None,
                    help="simulate preemptions at these steps (fault-tolerance demo)")
    ap.add_argument("--telemetry", action="store_true",
                    help="emit per-bucket spectral probes (SUMO only)")
    ap.add_argument("--telemetry-out", default=None,
                    help="JSONL path for the telemetry sink")
    ap.add_argument("--controller", action="store_true",
                    help="adaptive per-bucket rank/refresh controller "
                         "(implies --telemetry)")
    ap.add_argument("--controller-interval", type=int, default=0,
                    help="steps between controller checks (0 = update-freq)")
    ap.add_argument("--model-parallel", type=int, default=0,
                    help="model-axis size of the (data, model) host mesh the "
                         "whole run consumes: params placed by the Megatron "
                         "specs, opt state by opt_state_specs, batches over "
                         "data, and the SUMO bucket update under shard_map "
                         "(>1 = the 2D distributed-rSVD path; ragged long "
                         "dims edge-pad). 0 = no mesh")
    ap.add_argument("--strict-mesh", action="store_true",
                    help="fail instead of clamping when --model-parallel "
                         "does not divide the device count")
    ap.add_argument("--dp-compress", action="store_true",
                    help="compressed DP gradient exchange: compress -> pmean "
                         "of the r×short payload -> decompress inside the "
                         "step's shard_map over `data`, per-worker EF "
                         "residual in the train state (requires "
                         "--model-parallel >= 1; 1 = pure data parallelism)")
    ap.add_argument("--dp-compress-rank", type=int, default=32,
                    help="subspace rank r of the DP compression payload")
    ap.add_argument("--dp-compress-basis", default="sketch",
                    choices=["sketch", "sumo-q"],
                    help="sketch: zero-coordination seeded basis; sumo-q: "
                         "reuse the SUMO optimizer's resident rSVD Q "
                         "(one basis broadcast per refresh)")
    args = ap.parse_args(argv)

    arch = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch, kind="train")
    tcfg = TrainConfig(
        optimizer=args.optimizer, learning_rate=args.lr, rank=args.rank,
        update_freq=args.update_freq, total_steps=args.steps, accum=args.accum,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        telemetry=args.telemetry or bool(args.telemetry_out),
        telemetry_out=args.telemetry_out,
        controller=args.controller,
        controller_interval=args.controller_interval,
        model_parallel=args.model_parallel,
        strict_mesh=args.strict_mesh,
        dp_compress=args.dp_compress,
        dp_compress_rank=args.dp_compress_rank,
        dp_compress_basis=args.dp_compress_basis,
    )
    injector = FaultInjector(preempt_at=args.preempt_at) if args.preempt_at else None
    res = train(arch, shape, tcfg, fault_injector=injector)
    first = res.losses[0][1]
    last = res.losses[-1][1]
    print(f"\ndone: {res.final_step} steps, loss {first:.4f} -> {last:.4f}, "
          f"restarts {res.restarts}")
    if res.telemetry_records:
        dest = args.telemetry_out or "(in-memory)"
        print(f"telemetry: {res.telemetry_records} records -> {dest}, "
              f"{len(res.controller_events)} controller events")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
