"""Sharding rules for the (pod, data, model) production mesh.

Strategy (DESIGN.md §5):
  * 2D weight matrices — tensor-parallel over `model` on the dimension the
    Megatron layout prescribes (column-parallel up-projections, row-parallel
    down/out-projections), falling back to "largest divisible dim" for
    matrices outside the table.
  * 3D expert stacks — expert-parallel: E over `model`.
  * cfg.fsdp — additionally shard the other matrix dim over `data`
    (FSDP/ZeRO-3 style) so 22B+ archs fit 16 GB/chip.
  * optimizer states — same rule as the param they mirror; SUMO's Q basis
    shards its long dim over `model`, the r×short moment is replicated
    (negligible bytes — the point of the paper).
  * activations/batches — batch over (pod, data); KV caches shard batch
    over (pod, data) and heads over `model` when divisible, else sequence
    over `model`.

Everything returns jax.sharding.PartitionSpec; NamedSharding wrappers are
built by tree_shardings(mesh, ...).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..core.optimizer import BUCKET_KEY_RE, path_str

# path-pattern → (axis_to_shard_over_model) for 2D params: 0 = rows, 1 = cols
_MEGATRON_RULES: tuple[tuple[str, int], ...] = (
    (r"embed", 0),           # vocab/patch rows over model
    (r"lm_head", 1),         # vocab cols over model
    (r"wq$", 1), (r"wk$", 1), (r"wv$", 1),      # column-parallel
    (r"wo$", 0),                                  # row-parallel
    (r"w_gate$", 1), (r"w_up$", 1), (r"ff_up$", 1), (r"up_proj$", 1),
    (r"w_down$", 0), (r"ff_down$", 0), (r"down_proj$", 0),
    (r"in_proj$", 1), (r"out_proj$", 0),          # mamba
    (r"w_in$", 1), (r"w_gates$", 1),              # xlstm
    (r"router$", 1),
)

# Small per-step weights where ANY sharding costs a collective inside a
# sequential scan (e.g. the sLSTM recurrent blocks: 16 MB replicated vs a
# 2 MB all-reduce × seq_len steps = ~100 GB/step — measured, §Perf).
_REPLICATE_PATTERNS = (r"r_blocks$", r"conv1d", r"gate_bias$")


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _divisible(dim: int, mesh: Mesh, axis: str) -> bool:
    n = _axis_size(mesh, axis)
    return n > 1 and dim % n == 0


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Batch-sharding axes: ('pod', 'data') on the multi-pod mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def param_spec(path: str, shape: tuple[int, ...], mesh: Mesh,
               cfg: Optional[ArchConfig] = None) -> P:
    """PartitionSpec for one parameter leaf."""
    fsdp = bool(cfg and cfg.fsdp)
    if len(shape) <= 1:
        return P()
    for pat in _REPLICATE_PATTERNS:
        if re.search(pat, path):
            return P()
    # Small-expert MoE (granite: d_ff=512 ⇒ 32-wide sharded contractions):
    # replicate the expert weights and shard the CAPACITY dim of the dispatch
    # buffers over `model` instead (apply_moe mirrors this choice) — the
    # per-expert matrices are sub-MB, while f-sharding cost a 4 GB activation
    # all-reduce per layer (§Perf, granite iteration).
    if (cfg is not None and cfg.moe is not None and "experts" in path
            and cfg.d_ff // max(_axis_size(mesh, "model"), 1) < 128):
        return P()
    # Megatron TP dim for the trailing (m, n) matmul dims: 0 = rows, 1 = cols.
    tp_dim = None
    for pat, dim in _MEGATRON_RULES:
        if re.search(pat, path):
            tp_dim = dim
            break

    if len(shape) >= 3:
        # Stacked layers (scan) and expert stacks: the trailing 2 dims are the
        # matmul and MUST follow the Megatron rule (a stacked w_down sharded
        # on its output dim forces an activation all-gather + replicated
        # contraction — measured 16× FLOP waste in §Perf iteration 3).
        # Expert stacks additionally prefer expert-parallel on the E axis.
        spec = [None] * len(shape)
        nd = len(shape)
        if "experts" in path:
            for i in range(nd - 2):
                if _divisible(shape[i], mesh, "model"):
                    spec[i] = "model"
                    break
        if "model" not in spec:
            order = (tp_dim, 1 - tp_dim) if tp_dim is not None else (
                (0, 1) if shape[-2] >= shape[-1] else (1, 0))
            for d in order:
                if _divisible(shape[nd - 2 + d], mesh, "model"):
                    spec[nd - 2 + d] = "model"
                    break
        if fsdp:
            for j in (nd - 2, nd - 1):
                if spec[j] is None and _divisible(shape[j], mesh, "data"):
                    spec[j] = "data"
                    break
        return P(*spec)

    # 2D
    rows, cols = shape
    if tp_dim is None:
        tp_dim = 0 if rows >= cols else 1
    spec = [None, None]
    if _divisible(shape[tp_dim], mesh, "model"):
        spec[tp_dim] = "model"
    elif _divisible(shape[1 - tp_dim], mesh, "model"):
        spec[1 - tp_dim] = "model"
    if fsdp:
        other = 1 - spec.index("model") if "model" in spec else 0
        if spec[other] is None and _divisible(shape[other], mesh, "data"):
            spec[other] = "data"
    if all(s is None for s in spec):
        return P()
    return P(*spec)


def batch_spec(mesh: Mesh, ndim: int, batch_divisible: bool = True) -> P:
    """Inputs: batch over (pod,data); everything else replicated."""
    if ndim == 0 or not batch_divisible:
        return P()
    return P(data_axes(mesh))


def cache_spec(path: str, shape: tuple[int, ...], mesh: Mesh,
               cfg: Optional[ArchConfig] = None, batch: int = 0) -> P:
    """Decode-cache leaves. Transformer KV caches are (nL, B, S, KV, hd);
    recurrent states are (B, H, ...) or stacked (G, ..., B, ...)."""
    d_ax = data_axes(mesh)
    n_data = 1
    for a in d_ax:
        n_data *= _axis_size(mesh, a)
    spec = [None] * len(shape)
    # find the batch dim: the first dim equal to `batch`
    b_idx = next((i for i, d in enumerate(shape) if batch and d == batch), None)
    if b_idx is not None and batch % max(n_data, 1) == 0 and n_data > 1:
        spec[b_idx] = d_ax
    # shard a heads/seq-like dim over model: prefer KV-heads, else longest dim
    for i, d in enumerate(shape):
        if i == b_idx or len(shape) - i <= 1:
            continue
        if _divisible(d, mesh, "model") and d >= _axis_size(mesh, "model"):
            # pick the largest divisible non-batch dim
            pass
    cands = [
        (d, i) for i, d in enumerate(shape)
        if i != b_idx and spec[i] is None and _divisible(d, mesh, "model")
    ]
    if cands:
        _, i = max(cands)
        spec[i] = "model"
    return P(*spec)


# ---------------------------------------------------------------------------
# tree-level helpers
# ---------------------------------------------------------------------------

def tree_param_specs(params, mesh: Mesh, cfg: Optional[ArchConfig] = None):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path_str(path), leaf.shape, mesh, cfg), params
    )


def tree_shardings(tree_specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# Bucket-resident SUMO state: leaves live under Q/M/prev_norm keyed by the
# canonical "LONGxSHORT" bucket id (see core.optimizer.build_bucket_plan).
_BUCKET_FIELDS = ("Q", "M", "prev_norm")


def bucket_state_spec(path: str, shape: tuple[int, ...], mesh: Mesh,
                      bucket_axis: str = "data",
                      long_over_model: bool = True,
                      model_axis: str = "model") -> Optional[P]:
    """PartitionSpec for one bucket-resident SUMO state leaf, or None if the
    path is not a bucket-state leaf.

    The stacked B axis (dim 0) shards over ``bucket_axis`` — layer/expert
    parallelism across the bucket members, matching ``SumoConfig.bucket_axis``
    of the shard_map bucket-update path — and Q's long dim additionally
    shards over `model` (tensor parallel; the r-width moment stays replicated
    on that axis, negligible bytes). This is the DEFAULT wiring the 2D
    shard_map bucket update consumes in place: its in_specs are exactly
    ``P(bucket_axis, model, None)`` for Q, and the rSVD refresh runs the
    distributed range finder (core.rsvd ``axis_name``) on the model-sharded
    rows, so the state never re-gathers (see core.sumo "2D mesh").

    Ragged long dims: a state built by ``sumo(..., mesh=...)`` for a
    model>1 mesh stores Q with its long dim EDGE-PADDED to the next axis
    multiple (``core.sumo.padded_long`` — the path's last segment keeps the
    TRUE "LONGxSHORT" key), so the stored row count always divides and the
    padded Q places over `model` like any divisible bucket — the
    divisibility test below is then exact, not a fallback. A Q whose row
    count does NOT divide the model axis is a state that was not built
    (padded) for this mesh — it stays replicated on `model`, which keeps
    device_put correct while the checkpoint/convert machinery re-pads it.
    ``long_over_model=False`` remains only for meshes whose model axis is
    repurposed (no tensor parallelism in the update), where sharded Q WOULD
    be re-gathered at the shard_map boundary every step."""
    parts = path.split("/")
    if len(parts) < 2 or not BUCKET_KEY_RE.match(parts[-1]):
        return None
    if parts[-2] not in _BUCKET_FIELDS:
        return None
    spec = [None] * len(shape)
    if shape and _divisible(shape[0], mesh, bucket_axis):
        spec[0] = bucket_axis
    if (long_over_model and parts[-2] == "Q" and len(shape) == 3
            and _divisible(shape[1], mesh, model_axis)):
        spec[1] = model_axis
    return P(*spec)


def opt_state_specs(state, mesh: Mesh, cfg: Optional[ArchConfig] = None,
                    bucket_axis: str = "data",
                    bucket_long_over_model: bool = True,
                    model_axis: str = "model"):
    """Sharding for optimizer states: bucket-resident SUMO state gets
    per-bucket specs (B over ``bucket_axis``, Q's long dim — edge-padded for
    ragged buckets, see ``bucket_state_spec`` — over ``model_axis``;
    ``bucket_axis``/``model_axis`` must match the SumoConfig fields of the
    same names for the consume-in-place wiring to hold); everything else
    mirrors the generic param rule per leaf; scalars/keys replicated."""

    def leaf_spec(path, leaf):
        if leaf is None:
            return None
        shape = getattr(leaf, "shape", ())
        bspec = bucket_state_spec(path_str(path), shape, mesh,
                                  bucket_axis=bucket_axis,
                                  long_over_model=bucket_long_over_model,
                                  model_axis=model_axis)
        if bspec is not None:
            return bspec
        if len(shape) <= 1:
            return P()
        return param_spec(path_str(path), shape, mesh, cfg)

    return jax.tree_util.tree_map_with_path(
        leaf_spec, state, is_leaf=lambda x: x is None
    )


def update_audit_shardings(state, grads, mesh: Mesh,
                           cfg: Optional[ArchConfig] = None,
                           bucket_axis: str = "data",
                           model_axis: str = "model"):
    """Introspection hook for repro.analysis: the canonical placement for
    compiling ``tx.update`` in isolation — state resident exactly where
    ``opt_state_specs`` puts it, grads/params replicated (the update's
    contract: cotangents arrive replicated, every redistribution inside is
    the engine's own doing and is what the collective budgets audit).

    Returns ``(grads_shardings, state_shardings)`` NamedSharding trees for
    ``jax.jit(update, in_shardings=(g_sh, st_sh, g_sh))``. The sharded
    tests and ``analysis.driver`` share this one incantation so the lint
    audits the same program the tests pin.
    """
    st_specs = opt_state_specs(state, mesh, cfg, bucket_axis=bucket_axis,
                               model_axis=model_axis)
    st_sh = jax.tree_util.tree_map(
        lambda s: None if s is None else NamedSharding(mesh, s), st_specs,
        is_leaf=lambda x: x is None or isinstance(x, P))
    rep = NamedSharding(mesh, P())
    g_sh = jax.tree_util.tree_map(lambda _: rep, grads)
    return g_sh, st_sh


def comp_state_specs(comp_state, mesh: Mesh, data_axis: str = "data"):
    """Sharding for the DP-compression EF state
    (``parallel.compression.init_worker_state``): each error leaf's leading
    dim is the DP WORKER axis — placed over ``data_axis`` so the train
    step's shard_map body sees exactly its own worker's residual slice (the
    residual is purely local state; it never moves on the wire). The step
    counter is replicated; None leaves (exact/EF-off) stay None."""
    d_ax = data_axis if data_axis in mesh.shape else None

    def err_spec(leaf):
        if leaf is None:
            return None
        if d_ax is not None and getattr(leaf, "ndim", 0) >= 1 \
                and leaf.shape[0] % _axis_size(mesh, d_ax) == 0:
            return P(d_ax)
        return P()

    return type(comp_state)(
        step=P(),
        error=jax.tree_util.tree_map(err_spec, comp_state.error,
                                     is_leaf=lambda x: x is None),
    )


def cache_specs(cache, mesh: Mesh, cfg: Optional[ArchConfig], batch: int):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec(path_str(path), leaf.shape, mesh, cfg, batch),
        cache,
    )


def input_specs_sharding(specs: dict, mesh: Mesh, batch: int):
    """Shard every input leaf's batch (dim 0) over (pod, data) when divisible."""
    d_ax = data_axes(mesh)
    n_data = 1
    for a in d_ax:
        n_data *= _axis_size(mesh, a)

    def spec(leaf):
        shape = leaf.shape
        if len(shape) >= 1 and n_data > 1 and shape[0] % n_data == 0:
            return P(d_ax)
        return P()

    return {k: spec(v) for k, v in specs.items()}
