"""repro.parallel — mesh/sharding rules for pjit distribution."""
from .compression import (
    CompressionConfig,
    compress_grads,
    compression_ratio,
    finalize,
    init_state,
)
from .sharding import (
    batch_spec,
    bucket_state_spec,
    cache_specs,
    data_axes,
    input_specs_sharding,
    opt_state_specs,
    update_audit_shardings,
    param_spec,
    tree_param_specs,
    tree_shardings,
)

__all__ = [
    "param_spec", "tree_param_specs", "tree_shardings", "opt_state_specs",
    "bucket_state_spec", "update_audit_shardings",
    "cache_specs", "batch_spec", "data_axes", "input_specs_sharding",
]
