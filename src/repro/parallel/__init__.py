"""repro.parallel — mesh/sharding rules for pjit distribution and the
compressed DP gradient exchange."""
from .compression import (
    CompressionConfig,
    CompressionState,
    compress_grads,
    compression_ratio,
    dp_exchange_compiled_hlo,
    dp_wire_plan,
    eligible,
    exchange_shard,
    finalize,
    full_wire_bytes,
    hlo_wire_bytes,
    init_state,
    init_worker_state,
    make_dp_exchange_fn,
    step_bases,
    wire_bytes,
)
from .sharding import (
    batch_spec,
    bucket_state_spec,
    cache_specs,
    comp_state_specs,
    data_axes,
    input_specs_sharding,
    opt_state_specs,
    update_audit_shardings,
    param_spec,
    tree_param_specs,
    tree_shardings,
)

__all__ = [
    "param_spec", "tree_param_specs", "tree_shardings", "opt_state_specs",
    "bucket_state_spec", "update_audit_shardings", "comp_state_specs",
    "cache_specs", "batch_spec", "data_axes", "input_specs_sharding",
    "CompressionConfig", "CompressionState", "eligible", "init_state",
    "init_worker_state", "compress_grads", "finalize", "exchange_shard",
    "make_dp_exchange_fn", "step_bases", "dp_wire_plan", "wire_bytes", "full_wire_bytes",
    "hlo_wire_bytes", "compression_ratio", "dp_exchange_compiled_hlo",
]
