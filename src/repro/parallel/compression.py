"""Compressed data-parallel gradient exchange (SUMO-aligned).

The paper's subspace view gives a natural DP-communication compressor:
workers exchange the PROJECTED gradient Ĝ = QᵀG (r × short floats) instead
of the full G (long × short) — a (long/r)× wire reduction. This module is
the REAL training-path implementation consumed by ``train/steps.py``: the
exchange runs inside the step's shard_map over the ``data`` axis, where
``exchange_shard`` replaces the full-gradient mean with

    ĝ    = compress(g + e, basis)            # local, no collective
    ĝ̄   = jax.lax.pmean(ĝ, "data")          # r·short wire bytes
    g̃    = decompress(ĝ̄, basis)             # local
    e'   = (g + e) − decompress(ĝ, basis)    # per-worker EF residual

Two bases are supported, selected by ``CompressionConfig.use_sketch``:

  * **Zero-coordination seeded sketch** (default): Q is a seeded random
    orthonormal sketch regenerated from (seed, step, leaf) — every worker
    derives the same Q without any extra collective (Flora-style). The
    regeneration (``step_bases``) runs OUTSIDE the exchange's shard_map —
    it is deterministic replicated compute, still collective-free, and this
    jaxlib's partitioner cannot trace QR under a partially-manual shard_map.
  * **SUMO's resident rSVD basis** (``use_sketch=False``): the optimizer's
    own Q, already spectrally aligned with the gradient stream, is passed in
    as a ``bases`` tree (see ``core.sumo.sumo_dp_bases``). It changes only at
    refresh boundaries, so reuse costs ONE broadcast per refresh and no
    steady-state collective — machine-checked by
    ``analysis.collectives.steady_dp_compressed_budget`` on the compiled HLO
    (tests/test_compression_sharded.py, benchmarks/step_time.py). An
    all-zero basis leaf (a SUMO state before its first refresh, or a
    fallback-label leaf with no resident Q) falls back to the seeded sketch
    at the same rank, so the exchange never has a degenerate zero fixed
    point.

Error feedback (EF14/EF21): the per-worker residual e' above is purely
local, carried in ``CompressionState`` (one slot of the train state — the
loop donates and checkpoints it like any other state; the worker axis is
the leading dim of each error leaf, sharded over ``data``). EF restores
convergence to the uncompressed fixed point; verified on the real
collective in tests/test_compression_sharded.py.

Eligibility is ONE shared predicate, ``eligible(leaf, cfg)``: matrix leaves
(ndim >= 2) whose canonical long dim reaches ``cfg.min_dim`` compress;
everything else takes the exact full-size pmean. ``init_state`` /
``init_worker_state``, ``compress_grads``, ``decompress``/``finalize`` and
the wire accounting (``dp_wire_plan`` / ``compression_ratio`` — BYTES, not
elements) all consult it, and a grads tree that does not match the state's
init template fails loudly instead of silently mis-pairing leaves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    rank: int = 64
    seed: int = 0
    min_dim: int = 256     # leaves with canonical long dim below this go exact
    error_feedback: bool = True
    # True: seeded orthonormal sketch regenerated per (step, leaf) — zero
    # coordination. False: reuse resident bases passed via ``bases=`` (SUMO's
    # rSVD Q; sketch fallback per leaf where the basis is absent/all-zero).
    use_sketch: bool = True
    # Wire dtype of the compressed r×short payloads (the pmean buffers).
    # bf16 halves the exchange bytes; EF absorbs the quantization error
    # locally (it is computed from the round-tripped payload), so the
    # telescoping EF identity still holds. "float32" restores the exact
    # payload for algebra-exactness tests. Exact (ineligible) leaves always
    # ride their own dtype.
    payload_dtype: str = "bfloat16"


class CompressionState(NamedTuple):
    step: jnp.ndarray
    error: PyTree          # per-leaf EF residual; None for exact/EF-off leaves


def _orientation(shape) -> tuple[bool, int, int]:
    """(transpose, long, short) for a matrix leaf's trailing dims — the same
    canonical long-first convention as ``core.optimizer.canonical_dims``, so
    SUMO's resident (long, r) bases drop in without re-orientation."""
    m, n = int(shape[-2]), int(shape[-1])
    transpose = m < n
    return transpose, (n if transpose else m), (m if transpose else n)


def eligible(leaf, cfg: CompressionConfig) -> bool:
    """THE eligibility predicate (shared by state init, compression and the
    wire accounting): matrix leaves whose long dim reaches ``cfg.min_dim``.

    The old ``_eligible``'s ``max(leaf.shape) >= 1`` was vacuously true, so
    eligibility silently lived in ``init_state``'s error tree alone and any
    grads/state divergence mis-decided per leaf."""
    if leaf is None:
        return False
    shape = getattr(leaf, "shape", None)
    if shape is None or len(shape) < 2:
        return False
    _, long_d, _ = _orientation(shape)
    return long_d >= cfg.min_dim


def payload_rank(cfg: CompressionConfig, long_dim: int, basis=None) -> int:
    """r columns actually on the wire for one leaf: the basis's own width
    when a resident basis is used, else the sketch rank clamped to long."""
    if basis is not None:
        return int(basis.shape[-1])
    return min(cfg.rank, long_dim)


def _sketch(key, long_dim: int, r: int) -> jnp.ndarray:
    """Seeded orthonormal (long, r) basis — identical on every worker."""
    W = jax.random.normal(key, (long_dim, r), jnp.float32)
    Q, _ = jnp.linalg.qr(W)
    return Q


def _leaf_key(base_key, step, idx: int):
    return jax.random.fold_in(jax.random.fold_in(base_key, step), idx)


def _effective_basis(key, long_dim: int, r: int, Q=None) -> jnp.ndarray:
    """The basis compress/decompress actually use for one leaf.

    ``Q=None`` → the seeded sketch. A provided Q (batch dims allowed:
    per-expert bases of a 3D stack) is used as-is except where it is
    ALL-ZERO — a SUMO basis before its first rSVD refresh — which would make
    the exchange a zero fixed point (zero payload → zero decompressed grads
    → the optimizer never moves → the basis never refreshes); those matrices
    fall back to the sketch at the basis's own rank, and EF mops up the
    sketch's projection error until the real basis arrives.

    Call this (via ``step_bases``) OUTSIDE any partially-manual shard_map:
    the QR inside ``_sketch`` hard-crashes this jaxlib's SPMD partitioner
    when traced under a shard_map with auto axes of size > 1
    (``Check failed: sharding.IsManualSubgroup()``)."""
    if Q is None:
        return _sketch(key, long_dim, min(r, long_dim))
    Q = Q.astype(jnp.float32)
    sk = _sketch(key, long_dim, min(int(Q.shape[-1]), long_dim))
    if Q.ndim == 2:
        return jnp.where(jnp.linalg.norm(Q) > 0.0, Q, sk)
    flat = Q.reshape((-1,) + Q.shape[-2:])
    norms = jnp.sqrt(jnp.sum(flat * flat, axis=(1, 2)))
    return jnp.where((norms > 0.0)[:, None, None], flat, sk[None]).reshape(Q.shape)


def compress_leaf(G: jnp.ndarray, key, r: int, Q=None):
    """G (…, m, n) -> Ĝ (…, r_eff, short) in the canonical long-first view.

    ``Q``: optional (…, long, r) basis used VERBATIM (``step_bases`` output,
    or a resident ``core.sumo.sumo_dp_bases`` tree already effectivized);
    None regenerates the seeded sketch — never transmitted either way.
    Verbatim matters: inside a partially-manual shard_map body a provided
    basis is just matmul operands, while regenerating the sketch would trace
    QR where the partitioner can't handle it (see ``_effective_basis``)."""
    transpose, long_dim, _ = _orientation(G.shape)
    Gl = jnp.swapaxes(G, -1, -2) if transpose else G
    B = (Q.astype(jnp.float32) if Q is not None
         else _sketch(key, long_dim, min(r, long_dim)))
    if G.ndim == 2:
        return B.T @ Gl.astype(jnp.float32)
    flat = Gl.reshape((-1,) + Gl.shape[-2:]).astype(jnp.float32)
    if B.ndim == 2:
        out = jax.vmap(lambda g: B.T @ g)(flat)
    else:
        out = jax.vmap(lambda b, g: b.T @ g)(
            B.reshape((-1,) + B.shape[-2:]), flat)
    return out.reshape(Gl.shape[:-2] + out.shape[-2:])


def decompress_leaf(G_hat: jnp.ndarray, key, shape, Q=None) -> jnp.ndarray:
    transpose, long_dim, _ = _orientation(shape)
    r_eff = G_hat.shape[-2]
    B = (Q.astype(jnp.float32) if Q is not None
         else _sketch(key, long_dim, min(r_eff, long_dim)))
    if len(shape) == 2:
        out = B @ G_hat
    else:
        flat = G_hat.reshape((-1,) + G_hat.shape[-2:])
        if B.ndim == 2:
            out = jax.vmap(lambda g: B @ g)(flat)
        else:
            out = jax.vmap(lambda b, g: b @ g)(
                B.reshape((-1,) + B.shape[-2:]), flat)
        out = out.reshape(tuple(shape[:-2]) + out.shape[-2:])
    return jnp.swapaxes(out, -1, -2) if transpose else out


def _flatten_against_state(grads, state: CompressionState, cfg):
    """Flatten grads and align the state's error tree, failing LOUDLY when
    the state was initialised from a different template (tree mismatch, a
    leaf whose eligibility disagrees with its EF slot, or an error leaf of
    the wrong shape)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        grads, is_leaf=lambda x: x is None)
    try:
        err_leaves = treedef.flatten_up_to(state.error)
    except (ValueError, TypeError) as exc:
        raise ValueError(
            "CompressionState does not match the grads tree — it was "
            "initialised from a different template (e.g. params changed "
            "between init_state and compress_grads): "
            f"{exc}") from exc
    for i, (g, e) in enumerate(zip(leaves, err_leaves)):
        want_err = cfg.error_feedback and eligible(g, cfg)
        if want_err != (e is not None):
            raise ValueError(
                f"CompressionState leaf {i}: eligibility says EF residual "
                f"{'required' if want_err else 'absent'} but state has "
                f"{'one' if e is not None else 'none'} — state initialised "
                "from a different template or CompressionConfig")
        if e is not None and tuple(e.shape) != tuple(g.shape):
            raise ValueError(
                f"CompressionState leaf {i}: EF residual shape "
                f"{tuple(e.shape)} != grad shape {tuple(g.shape)}")
    return leaves, err_leaves, treedef


def _basis_leaves(bases, treedef, n: int, cfg: CompressionConfig):
    # A provided bases tree is honored regardless of use_sketch — the train
    # step precomputes even the SKETCH bases outside its shard_map (via
    # ``step_bases``) and passes them in. use_sketch only selects what the
    # caller feeds this: None/seeded sketches vs the resident SUMO Q tree.
    if bases is None:
        return [None] * n
    try:
        return treedef.flatten_up_to(bases)
    except (ValueError, TypeError) as exc:
        raise ValueError(
            "bases tree does not match the grads tree "
            f"(see core.sumo.sumo_dp_bases / step_bases): {exc}") from exc


def step_bases(grads_template: PyTree, step, cfg: CompressionConfig,
               bases: Optional[PyTree] = None) -> PyTree:
    """The per-leaf EFFECTIVE basis tree for one exchange step (None for
    ineligible leaves) — sketches generated, zero-Q resident bases
    bootstrapped, everything ready to use verbatim.

    Call this OUTSIDE the exchange's shard_map (ordinary jit: the QRs
    partition fine there) and hand the result to
    ``exchange_shard``/``compress_grads`` as ``bases``: inside a
    partially-manual shard_map body the basis must be a plain operand, not
    regenerated (see ``_effective_basis``). ``step`` may be traced
    (``CompressionState.step``); ``bases`` is the resident SUMO tree for
    ``use_sketch=False``, ignored (sketches win) when ``cfg.use_sketch``."""
    base = jax.random.PRNGKey(cfg.seed)
    leaves, treedef = jax.tree_util.tree_flatten(
        grads_template, is_leaf=lambda x: x is None)
    basis_leaves = _basis_leaves(
        bases if not cfg.use_sketch else None, treedef, len(leaves), cfg)
    out = []
    for i, (g, Q) in enumerate(zip(leaves, basis_leaves)):
        if not eligible(g, cfg):
            out.append(None)
            continue
        _, long_d, _ = _orientation(g.shape)
        r = payload_rank(cfg, long_d, Q)
        key = _leaf_key(base, step, i)
        out.append(_effective_basis(key, long_d, r, Q))
    return jax.tree_util.tree_unflatten(treedef, out)


def init_state(grads_template: PyTree, cfg: CompressionConfig
               ) -> CompressionState:
    """Single-worker EF state (tests / reference). The error tree keeps the
    SAME structure whether error feedback is on or off — EF-off just stores
    None everywhere instead of materialising full-size zero residuals."""
    error = jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32)
        if cfg.error_feedback and eligible(g, cfg) else None,
        grads_template,
        is_leaf=lambda x: x is None,
    )
    return CompressionState(step=jnp.zeros((), jnp.int32), error=error)


def init_worker_state(grads_template: PyTree, cfg: CompressionConfig,
                      n_workers: int) -> CompressionState:
    """EF state for the real sharded loop: each eligible leaf's residual is
    (n_workers, *grad_shape) — dim 0 is the DP worker axis, placed over the
    mesh's ``data`` axis (``parallel.sharding.comp_state_specs``) so the
    shard_map body sees exactly its own worker's slice."""
    error = jax.tree_util.tree_map(
        lambda g: jnp.zeros((n_workers,) + tuple(g.shape), jnp.float32)
        if cfg.error_feedback and eligible(g, cfg) else None,
        grads_template,
        is_leaf=lambda x: x is None,
    )
    return CompressionState(step=jnp.zeros((), jnp.int32), error=error)


def compress_grads(grads: PyTree, state: CompressionState,
                   cfg: CompressionConfig, bases: Optional[PyTree] = None):
    """Returns (payload tree to be MEANED across DP workers, meta, treedef).

    payload leaves: (…, r, short) compressed arrays for eligible leaves, raw
    arrays otherwise. Each meta entry for an eligible leaf is
    ``(shape, idx, new_error)`` — the NEXT EF residual, computed HERE from
    the local quantities (e' = (g+e) − QQᵀ(g+e) never needs the averaged
    payload), so ``finalize`` only decompresses the mean: one compression
    per leaf per step, and no second full-size gradient copy rides through
    the jitted step.
    """
    base = jax.random.PRNGKey(cfg.seed)
    leaves, err_leaves, treedef = _flatten_against_state(grads, state, cfg)
    basis_leaves = _basis_leaves(bases, treedef, len(leaves), cfg)

    payload, meta = [], []
    for i, (g, e, Q) in enumerate(zip(leaves, err_leaves, basis_leaves)):
        if not eligible(g, cfg):
            payload.append(g)
            meta.append(None)
            continue
        g32 = g.astype(jnp.float32)
        if cfg.error_feedback:
            g32 = g32 + e
        key = _leaf_key(base, state.step, i)
        p = compress_leaf(g32, key, cfg.rank, Q=Q).astype(cfg.payload_dtype)
        payload.append(p)
        if cfg.error_feedback:
            # round-trip through the WIRE dtype so EF absorbs quantization
            new_err = g32 - decompress_leaf(p.astype(jnp.float32), key,
                                            g.shape, Q=Q)
        else:
            new_err = None
        meta.append((g.shape, i, new_err))
    return jax.tree_util.tree_unflatten(treedef, payload), meta, treedef


def finalize(payload_mean: PyTree, meta, treedef, state: CompressionState,
             cfg: CompressionConfig, bases: Optional[PyTree] = None):
    """Decompress the averaged payload; install the EF residuals computed by
    ``compress_grads`` (no re-compression here)."""
    base = jax.random.PRNGKey(cfg.seed)
    p_leaves = treedef.flatten_up_to(payload_mean)
    basis_leaves = _basis_leaves(bases, treedef, len(p_leaves), cfg)
    out, new_err = [], []
    for p, m, Q in zip(p_leaves, meta, basis_leaves):
        if m is None:
            out.append(p)
            new_err.append(None)
            continue
        shape, i, err = m
        key = _leaf_key(base, state.step, i)
        out.append(decompress_leaf(p.astype(jnp.float32), key, shape,
                                   Q=Q).astype(jnp.float32))
        new_err.append(err)
    grads = jax.tree_util.tree_unflatten(treedef, out)
    new_state = CompressionState(
        step=state.step + 1,
        error=jax.tree_util.tree_unflatten(treedef, new_err),
    )
    return grads, new_state


def exchange_shard(grads: PyTree, state: CompressionState,
                   cfg: CompressionConfig, axis_name: str,
                   bases: Optional[PyTree] = None):
    """The per-worker DP exchange — call INSIDE a shard_map body that is
    manual over ``axis_name``: compress, ``lax.pmean`` the r×short payloads
    (exact full-size pmean for ineligible leaves), decompress the mean.
    Returns (mean grads, next per-worker CompressionState)."""
    payload, meta, treedef = compress_grads(grads, state, cfg, bases=bases)
    payload_mean = jax.tree_util.tree_map(
        lambda x: None if x is None else jax.lax.pmean(x, axis_name),
        payload, is_leaf=lambda x: x is None)
    return finalize(payload_mean, meta, treedef, state, cfg, bases=bases)


def make_dp_exchange_fn(mesh, cfg: CompressionConfig,
                        data_axis: str = "data"):
    """The standalone worker-stacked exchange program (tests + benchmarks
    compile and budget-audit exactly this; the train step inlines the same
    ``exchange_shard`` into its own shard_map body).

    Returns ``fn(grads_stacked, state, bases) -> (decoded_stacked, state')``
    where every grads leaf carries a leading (n_data,) worker dim sharded
    over ``data_axis`` (``state`` from ``init_worker_state``; ``bases``
    replicated or None). Isolating the exchange in its own program keeps
    the optimizer's collectives out of the DP wire budget's scope. The
    effective bases (sketches included) are prepared by ``step_bases``
    OUTSIDE the shard_map, so the manual body is pure matmuls + pmeans.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    auto = frozenset(a for a in mesh.axis_names if a != data_axis)
    none_leaf = lambda x: x is None
    squeeze = lambda t: jax.tree_util.tree_map(
        lambda x: None if x is None else x[0], t, is_leaf=none_leaf)
    expand = lambda t: jax.tree_util.tree_map(
        lambda x: None if x is None else x[None], t, is_leaf=none_leaf)

    def body(grads_stacked, state, eff_bases):
        grads = squeeze(grads_stacked)
        local = CompressionState(step=state.step, error=squeeze(state.error))
        decoded, new_local = exchange_shard(grads, local, cfg, data_axis,
                                            bases=eff_bases)
        new_state = CompressionState(step=new_local.step,
                                     error=expand(new_local.error))
        return expand(decoded), new_state

    sharded = P(data_axis)
    state_spec = CompressionState(step=P(), error=sharded)
    call = shard_map(
        body, mesh,
        in_specs=(sharded, state_spec, P()),
        out_specs=(sharded, state_spec),
        check_rep=False,
        **({"auto": auto} if auto else {}),
    )

    def fn(grads_stacked, state, bases):
        eff = step_bases(squeeze(grads_stacked), state.step, cfg,
                         bases=bases)
        return call(grads_stacked, state, eff)

    return fn


# ---------------------------------------------------------------------------
# Wire accounting (BYTES — the budget factories and CSV rows consume this)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WirePlanEntry:
    """One leaf's DP-exchange footprint. ``payload_dims`` is the pmean
    buffer's dims tuple (compressed or raw), directly comparable against
    ``roofline.hlo_cost.iter_collectives`` entries."""
    path: str
    shape: tuple
    eligible: bool
    rank: int                  # r on the wire (0 for exact leaves)
    payload_dims: tuple        # all-reduce buffer dims
    payload_bytes: int         # per-step wire bytes (cfg.payload_dtype)
    full_bytes: int            # uncompressed exchange bytes (leaf dtype)
    # Bytes of the same buffer in THIS backend's optimized HLO: XLA's
    # all-reduce promotion pass upcasts sub-f32 float collectives to f32 on
    # CPU/GPU (TPU reduces bf16 natively), so post-optimization audits see
    # 4 B/elem even for a bf16 wire. Budgets over compiled HLO must cap
    # against this; bandwidth/ratio claims use ``payload_bytes``.
    hlo_bytes: int = 0


def dp_wire_plan(grads_template: PyTree, cfg: CompressionConfig,
                 bases: Optional[PyTree] = None) -> list:
    """Per-leaf wire plan for one DP exchange — byte-accurate
    (``cfg.payload_dtype`` payloads for compressed leaves, the leaf's OWN
    dtype for exact ones, so bf16 grads are no longer counted as if they
    were fp32), sharing the ``eligible``/orientation/rank logic with the
    compression itself."""
    from ..core.optimizer import path_str

    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        grads_template, is_leaf=lambda x: x is None)
    basis_leaves = _basis_leaves(
        bases,
        jax.tree_util.tree_structure(grads_template,
                                     is_leaf=lambda x: x is None),
        len(leaves), cfg)
    plan = []
    for (path, g), Q in zip(leaves, basis_leaves):
        if g is None:
            continue
        shape = tuple(int(d) for d in g.shape)
        n = 1
        for d in shape:
            n *= d
        itemsize = int(jnp.dtype(g.dtype).itemsize)
        if not eligible(g, cfg):
            plan.append(WirePlanEntry(
                path=path_str(path), shape=shape, eligible=False, rank=0,
                payload_dims=shape, payload_bytes=n * itemsize,
                full_bytes=n * itemsize,
                hlo_bytes=n * _promoted_itemsize(g.dtype)))
            continue
        _, long_d, short_d = _orientation(shape)
        r = payload_rank(cfg, long_d, Q)
        batch = n // (shape[-2] * shape[-1])
        pdims = shape[:-2] + (r, short_d)
        p_elems = batch * r * short_d
        p_itemsize = int(jnp.dtype(cfg.payload_dtype).itemsize)
        plan.append(WirePlanEntry(
            path=path_str(path), shape=shape, eligible=True, rank=r,
            payload_dims=pdims, payload_bytes=p_elems * p_itemsize,
            full_bytes=n * itemsize,
            hlo_bytes=p_elems * _promoted_itemsize(cfg.payload_dtype)))
    return plan


def _promoted_itemsize(dtype) -> int:
    """Itemsize of one all-reduce element in this backend's optimized HLO:
    sub-f32 floats are promoted to f32 by XLA's all-reduce promotion pass."""
    dt = jnp.dtype(dtype)
    if jnp.issubdtype(dt, jnp.floating) and dt.itemsize < 4:
        return 4
    return int(dt.itemsize)


def wire_bytes(plan) -> int:
    return sum(e.payload_bytes for e in plan)


def hlo_wire_bytes(plan) -> int:
    """Wire bytes as this backend's optimized HLO reports them (bf16
    payloads promoted to f32 collectives) — audit compiled programs against
    THIS; quote bandwidth claims from ``wire_bytes``."""
    return sum(e.hlo_bytes for e in plan)


def full_wire_bytes(plan) -> int:
    return sum(e.full_bytes for e in plan)


def dp_exchange_compiled_hlo(mesh, cfg: CompressionConfig,
                             grads_template: PyTree,
                             data_axis: str = "data"):
    """Compile one real DP exchange over ``mesh`` and return
    ``(hlo_text, plan)`` — the artifact pair the precision lint's
    `bf16-wire-promoted` check audits: the plan's ``hlo_bytes`` dual view
    against the all-reduces actually in the compiled program. Uses the same
    stacked-grads placement incantation as tests/test_compression_sharded.py
    (worker rows over ``data_axis``, scalar state replicated)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    n = int(mesh.shape[data_axis])
    grads_stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + tuple(x.shape)),
        grads_template)
    state = init_worker_state(grads_template, cfg, n)
    stack = NamedSharding(mesh, P(data_axis))
    rep = NamedSharding(mesh, P())
    grads_d = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, stack), grads_stacked)
    state_d = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, stack if x.ndim > 0 else rep), state)
    fn = make_dp_exchange_fn(mesh, cfg, data_axis=data_axis)
    hlo_text = jax.jit(fn).lower(grads_d, state_d, None).compile().as_text()
    return hlo_text, dp_wire_plan(grads_template, cfg)


def compression_ratio(grads: PyTree, cfg: CompressionConfig,
                      bases: Optional[PyTree] = None) -> float:
    """Wire BYTES with compression / without (lower is better); the ≥8×
    reduction gate is ``1 / compression_ratio >= 8``. Cross-checked against
    the HLO-measured pmean bytes in tests/test_compression_sharded.py."""
    plan = dp_wire_plan(grads, cfg, bases=bases)
    return wire_bytes(plan) / max(full_wire_bytes(plan), 1)
