"""Gradient compression for the data-parallel axis (SUMO-aligned).

The paper's subspace view gives a natural DP-communication compressor:
workers exchange the PROJECTED gradient Ĝ = QᵀG (r × short floats) instead
of the full G (long × short) — an (long/r)× wire reduction. Two design
choices make this deployable:

  * **Zero-coordination basis.** Q is a seeded random orthonormal sketch
    regenerated from (seed, step) — every worker derives the same Q without
    any extra collective (Flora-style). SUMO's own rSVD basis could be reused
    instead (set ``use_sketch=False`` and pass the optimizer's Q), costing
    one broadcast per refresh.
  * **Error feedback (EF).** The per-worker residual e = G − Q Ĝ is carried
    and added to the next step's gradient before compression, which restores
    convergence to the uncompressed fixed point (standard EF14/EF21
    argument; verified empirically in tests/test_compression.py).

Integration point: wrap the per-shard gradients inside a shard_map over the
dp axis —
    ĝ   = compress(g + e, key)                  # local
    ĝ̄  = jax.lax.pmean(ĝ, "data")              # r·short wire bytes
    g̃, e = decompress(ĝ̄, key), (g + e) − decompress(ĝ, key)
On this container the collective itself is exercised via vmap-simulated
workers (tests); the compress/decompress path is the real production code.

Only 2D+ "matrix" leaves are compressed; small leaves go through exact.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    rank: int = 64
    seed: int = 0
    min_dim: int = 256     # leaves with long-dim below this go uncompressed
    error_feedback: bool = True


class CompressionState(NamedTuple):
    step: jnp.ndarray
    error: PyTree          # per-leaf EF residual (None for uncompressed leaves)


def _sketch(key, long_dim: int, r: int) -> jnp.ndarray:
    """Seeded orthonormal (long, r) basis — identical on every worker."""
    W = jax.random.normal(key, (long_dim, r), jnp.float32)
    Q, _ = jnp.linalg.qr(W)
    return Q


def _leaf_key(base_key, step, idx: int):
    return jax.random.fold_in(jax.random.fold_in(base_key, step), idx)


def _eligible(leaf) -> bool:
    return leaf is not None and leaf.ndim >= 2 and max(leaf.shape) >= 1


def init_state(grads_template: PyTree, cfg: CompressionConfig) -> CompressionState:
    error = jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32)
        if _eligible(g) and max(g.shape[-2:]) >= cfg.min_dim else None,
        grads_template,
        is_leaf=lambda x: x is None,
    )
    return CompressionState(step=jnp.zeros((), jnp.int32), error=error)


def compress_leaf(G: jnp.ndarray, key, r: int):
    """G (m, n) -> (Ĝ (r, short), basis is regenerated, not transmitted)."""
    m, n = G.shape[-2], G.shape[-1]
    transpose = m < n
    Gl = jnp.swapaxes(G, -1, -2) if transpose else G
    long_dim = Gl.shape[-2]
    r_eff = min(r, long_dim)
    Q = _sketch(key, long_dim, r_eff)
    if G.ndim == 2:
        return Q.T @ Gl.astype(jnp.float32)
    flat = Gl.reshape((-1,) + Gl.shape[-2:]).astype(jnp.float32)
    return jax.vmap(lambda g: Q.T @ g)(flat).reshape(
        Gl.shape[:-2] + (r_eff, Gl.shape[-1])
    )


def decompress_leaf(G_hat: jnp.ndarray, key, shape) -> jnp.ndarray:
    m, n = shape[-2], shape[-1]
    transpose = m < n
    long_dim = n if transpose else m
    r_eff = G_hat.shape[-2]
    Q = _sketch(key, long_dim, r_eff)
    if len(shape) == 2:
        out = Q @ G_hat
    else:
        flat = G_hat.reshape((-1,) + G_hat.shape[-2:])
        out = jax.vmap(lambda g: Q @ g)(flat).reshape(
            shape[:-2] + (long_dim, shape[-1] if not transpose else shape[-2])
        )
    return jnp.swapaxes(out, -1, -2) if transpose else out


def compress_grads(grads: PyTree, state: CompressionState,
                   cfg: CompressionConfig):
    """Returns (payload pytree to be summed across DP workers, new_state_fn).

    payload leaves: compressed (r, short) arrays for eligible leaves, raw
    arrays otherwise. Call ``finalize(payload_mean, state)`` after the
    cross-worker mean to obtain (decompressed grads, next state).
    """
    base = jax.random.PRNGKey(cfg.seed)
    leaves, treedef = jax.tree_util.tree_flatten(grads, is_leaf=lambda x: x is None)
    err_leaves = treedef.flatten_up_to(state.error)

    payload, meta = [], []
    for i, (g, e) in enumerate(zip(leaves, err_leaves)):
        if g is None or e is None:
            payload.append(g)
            meta.append(None)
            continue
        g32 = g.astype(jnp.float32)
        if cfg.error_feedback:
            g32 = g32 + e
        key = _leaf_key(base, state.step, i)
        payload.append(compress_leaf(g32, key, cfg.rank))
        meta.append((g.shape, i, g32))
    return jax.tree_util.tree_unflatten(treedef, payload), meta, treedef


def finalize(payload_mean: PyTree, meta, treedef, state: CompressionState,
             cfg: CompressionConfig):
    """Decompress the averaged payload; update EF residuals."""
    base = jax.random.PRNGKey(cfg.seed)
    p_leaves = treedef.flatten_up_to(payload_mean)
    out, new_err = [], []
    for p, m in zip(p_leaves, meta):
        if m is None:
            out.append(p)
            new_err.append(None)
            continue
        shape, i, g_with_err = m
        key = _leaf_key(base, state.step, i)
        decoded = decompress_leaf(p, key, shape)
        out.append(decoded.astype(jnp.float32))
        if cfg.error_feedback:
            # residual of the LOCAL contribution (what this worker failed to send)
            local_decoded = decompress_leaf(
                compress_leaf(g_with_err, key, cfg.rank), key, shape
            )
            new_err.append(g_with_err - local_decoded)
        else:
            new_err.append(jnp.zeros(shape, jnp.float32))
    grads = jax.tree_util.tree_unflatten(treedef, out)
    new_state = CompressionState(
        step=state.step + 1,
        error=jax.tree_util.tree_unflatten(treedef, new_err),
    )
    return grads, new_state


def compression_ratio(grads: PyTree, cfg: CompressionConfig) -> float:
    """Wire bytes with compression / without (lower is better)."""
    full = comp = 0
    for g in jax.tree_util.tree_leaves(grads):
        n = g.size
        full += n
        if g.ndim >= 2 and max(g.shape[-2:]) >= cfg.min_dim:
            short = min(g.shape[-2], g.shape[-1])
            batch = n // (g.shape[-2] * g.shape[-1])
            comp += batch * min(cfg.rank, max(g.shape[-2:])) * short
        else:
            comp += n
    return comp / full
