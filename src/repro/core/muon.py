"""Muon baseline (Jordan et al. 2024): full-space momentum + Newton-Schulz5
orthogonalization, with Moonlight's weight-decay + rms update scaling.

Paper role: the convergence-rate comparison of Lemma 3.3 — Muon pays the NS5
approximation error δ in full space; SUMO removes it by exact orthogonalization
in the subspace. State is the full-shape momentum (mn floats per matrix).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from . import optimizer as opt
from .orthogonalize import newton_schulz5, orthogonalize_polar


class MuonState(NamedTuple):
    step: jnp.ndarray
    momentum: opt.PyTree


def muon(
    learning_rate: Union[float, Callable],
    beta: float = 0.95,
    weight_decay: float = 0.0,
    ns_steps: int = 5,
    rms_scale: bool = True,
    nesterov: bool = True,
    exact: bool = False,   # exact=True -> SVD/polar orthogonalization (ablation)
) -> opt.Transform:
    lr_fn = learning_rate if callable(learning_rate) else (lambda s: jnp.asarray(learning_rate))

    def init(params):
        return MuonState(
            step=jnp.zeros((), jnp.int32),
            momentum=opt.tree_map_not_none(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
            ),
        )

    def _orth2d(M):
        return orthogonalize_polar(M) if exact else newton_schulz5(M, steps=ns_steps)

    def _leaf(g, m, p, lr):
        g32 = g.astype(jnp.float32)
        m_new = beta * m + g32
        direction = beta * m_new + g32 if nesterov else m_new
        if direction.ndim == 2:
            O = _orth2d(direction)
        else:
            flat = direction.reshape((-1,) + direction.shape[-2:])
            O = jax.vmap(_orth2d)(flat).reshape(direction.shape)
        rows, cols = g.shape[-2], g.shape[-1]
        scale = 0.2 * jnp.sqrt(float(max(rows, cols))) if rms_scale else 1.0
        d = -lr * scale * O
        if weight_decay > 0.0 and p is not None:
            d = d - lr * weight_decay * p.astype(jnp.float32)
        return d, m_new

    def update(grads, state: MuonState, params=None):
        lr = lr_fn(state.step).astype(jnp.float32)
        leaves_g, treedef = jax.tree_util.tree_flatten(grads, is_leaf=lambda x: x is None)
        leaves_m = treedef.flatten_up_to(state.momentum)
        leaves_p = (
            treedef.flatten_up_to(params) if params is not None else [None] * len(leaves_g)
        )
        out_u, out_m = [], []
        for g, m, p in zip(leaves_g, leaves_m, leaves_p):
            if g is None:
                out_u.append(None); out_m.append(None)
                continue
            d, m_new = _leaf(g, m, p, lr)
            out_u.append(d); out_m.append(m_new)
        unflat = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        return unflat(out_u), MuonState(step=state.step + 1, momentum=unflat(out_m))

    return opt.Transform(init, update)


def muon_optimizer(learning_rate, params, fallback_lr=None, **kw) -> opt.Transform:
    """Muon on matrices + AdamW on the rest (the standard Muon deployment)."""
    from .adamw import adamw

    labels = opt.partition_params(params)
    return opt.multi_transform(
        {
            "matrix": muon(learning_rate, **kw),
            "fallback": adamw(fallback_lr if fallback_lr is not None else learning_rate),
        },
        labels,
    )
