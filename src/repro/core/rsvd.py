"""Truncated randomized SVD (Halko, Martinsson, Tropp 2010) — jittable.

Used by SUMO / GaLore Block 1 to compute the rank-r orthonormal basis Q of the
gradient every K steps at O(mnr + mr^2) instead of full-SVD O(mn^2).

All functions are pure and jit/vmap/shard_map friendly. The only non-matmul
op is the QR factorization of the m×r (or n×r) sketch.

Distributed note: G may be sharded over its rows (model axis). ``G @ Omega``
and ``G.T @ Y`` are tall-skinny matmuls that pjit auto-partitions with a
single reduce-scatter/all-gather of an r-width panel — this is why the
subspace refresh costs O(r(m+n)) in collective bytes, not O(mn).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _orthonormalize(Y: jnp.ndarray) -> jnp.ndarray:
    """Thin-QR orthonormal basis of range(Y). Y: (m, r) -> Q: (m, r)."""
    Q, _ = jnp.linalg.qr(Y.astype(jnp.float32))
    return Q


@partial(jax.jit, static_argnames=("rank", "n_iter", "oversample"))
def randomized_range_finder(
    G: jnp.ndarray,
    key: jax.Array,
    rank: int,
    n_iter: int = 2,
    oversample: int = 4,
) -> jnp.ndarray:
    """Rank-`rank` orthonormal basis Q (m × rank) of the row space of G (m × n).

    Power iteration (n_iter) sharpens the spectrum separation; oversampling
    improves accuracy then truncates back to `rank`.
    """
    m, n = G.shape
    l = min(rank + oversample, min(m, n))
    G32 = G.astype(jnp.float32)
    Omega = jax.random.normal(key, (n, l), dtype=jnp.float32)
    Y = G32 @ Omega                       # (m, l)
    Q = _orthonormalize(Y)
    for _ in range(n_iter):
        # subspace/power iteration with re-orthonormalization for stability
        Z = G32.T @ Q                     # (n, l)
        Z = _orthonormalize(Z)
        Y = G32 @ Z                       # (m, l)
        Q = _orthonormalize(Y)
    return Q[:, :rank]


@partial(jax.jit, static_argnames=("rank", "n_iter", "oversample"))
def randomized_svd(
    G: jnp.ndarray,
    key: jax.Array,
    rank: int,
    n_iter: int = 2,
    oversample: int = 4,
):
    """Truncated rSVD: returns (U (m,r), s (r,), Vt (r,n))."""
    Q = randomized_range_finder(G, key, rank, n_iter, oversample)  # (m, r)
    B = Q.T @ G.astype(jnp.float32)       # (r, n) — small
    Ub, s, Vt = jnp.linalg.svd(B, full_matrices=False)
    U = Q @ Ub
    return U[:, :rank], s[:rank], Vt[:rank]


@partial(jax.jit, static_argnames=("rank",))
def truncated_svd(G: jnp.ndarray, rank: int):
    """Exact truncated SVD (reference / small matrices)."""
    U, s, Vt = jnp.linalg.svd(G.astype(jnp.float32), full_matrices=False)
    return U[:, :rank], s[:rank], Vt[:rank]


def subspace_overlap(Q1: jnp.ndarray, Q2: jnp.ndarray) -> jnp.ndarray:
    """‖Q1ᵀQ2‖_F² / r ∈ [0,1] — how aligned two orthonormal bases are."""
    r = Q1.shape[1]
    return jnp.sum(jnp.square(Q1.T @ Q2)) / r
