"""Truncated randomized SVD (Halko, Martinsson, Tropp 2010) — jittable,
single-device or row-sharded across a named mesh axis.

Used by SUMO / GaLore Block 1 to compute the rank-r orthonormal basis Q of the
gradient every K steps at O(mnr + mr^2) instead of full-SVD O(mn^2).

All functions are pure and jit/vmap/shard_map friendly.

Truncation: the oversampled sketch basis comes out of an orthogonalization
whose columns are NOT ordered by singular mass, so slicing ``Q[:, :rank]``
would throw the oversampling away (and can miss top directions outright when
the sketch mixes them into trailing columns). Both entry points therefore
truncate through the small factorization ``B = QᵀG``: ``svd(B) = Ub·s·Vt``
rotates the basis into singular order and ``Q @ Ub[:, :rank]`` keeps exactly
the top-rank directions of the oversampled subspace.
``randomized_range_finder`` and ``randomized_svd`` share this factorization
(``_halko_factor``), so the U they return is the same array computed by the
same ops — the range finder is simply the SVD with s/Vt discarded.

Distributed path (``axis_name``): G may arrive row-sharded over a shard_map
mesh axis — each shard holds a contiguous (m_loc, n) row block and the full
matrix is NEVER gathered. The collectives are all r-width panels:

  * ``G @ Omega`` and ``G @ Z`` are shard-local tall-skinny matmuls (Omega/Z
    are replicated (n, l) panels) — zero collectives;
  * ``Gᵀ @ Q`` and ``B = Qᵀ @ G`` produce per-shard partial (n, l)/(l, n)
    panels finished with one ``psum`` each — O(l·n) bytes, not O(m·n);
  * the thin-QR of the row-sharded (m, l) sketch is replaced by a
    CholeskyQR2-style Gram factorization: ``psum(YᵀY)`` (an l×l panel) +
    a small host-free Cholesky triangular solve, iterated twice for fp32
    stability (one pass loses ~κ(Y)² digits; the second restores
    orthonormality to fp32 roundoff).

So a refresh of a sharded (m, n) matrix costs O(l·(m/p + n)) local work and
O(l·(n + l)) collective bytes per power iteration — the r-width-collective
discipline GaLore-style methods rely on. With ``axis_name=None`` the code is
the plain single-device Halko pipeline (thin jnp QR, no collectives).

Orientation and the padded-rows regime (the distributed invariants)
-------------------------------------------------------------------
The distributed path assumes the canonical long-first orientation: the TRUE
(unpadded) global row count satisfies m ≥ n, so the sketch width l is clamped
by n alone — the local row count says nothing about the global shape and is
never consulted for the clamp.

Callers whose global long dim does not divide the mesh axis (SUMO's
edge-padded ragged buckets) append all-zero pad rows so every shard holds an
equal row block. Zero rows are INERT through this entire pipeline — a basis
refreshed from an edge-padded gradient has EXACTLY zero pad rows, and the
invariant is self-propagating across refreshes (zero in -> zero out). This
is no longer argued in prose here: it is a MACHINE-CHECKED theorem.
``repro.analysis.inertness.prove_refresh_inertness`` runs a structured-zeros
abstract interpreter over the jaxpr exported by ``refresh_closed_jaxpr``
below and proves the trailing-zero-rows claim op by op (see ANALYSIS.md for
the abstract domain and its axioms). The consumer (core.sumo) still applies
a defensive pad-row mask on entry so a hand-built or corrupted state cannot
silently break the invariant.

Rank clamping: the sketch can never deliver more than l = min(rank +
oversample, n) directions (n = min(m, n) single-device). ``rank > l`` is
therefore clamped EXPLICITLY — all three factors come back with
``rsvd_effective_rank(...)`` columns, never silently fewer than each other —
so a controller rank-grow on a small-short-dim bucket sees a consistent,
predictable shape instead of a mis-shaped Q.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


def _orthonormalize(Y: jnp.ndarray) -> jnp.ndarray:
    """Thin-QR orthonormal basis of range(Y). Y: (m, r) -> Q: (m, r)."""
    Q, _ = jnp.linalg.qr(Y.astype(jnp.float32))
    return Q


def _cholesky_qr2(Y: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Orthonormalize a row-sharded tall-skinny panel without gathering it.

    Y is the local (m_loc, l) row block of a global (m, l) panel sharded over
    ``axis_name``. Each pass forms the GLOBAL Gram matrix with an l×l psum,
    factors it (Cholesky) and applies the inverse triangular factor locally:
    Q = Y·L⁻ᵀ satisfies QᵀQ = L⁻¹(YᵀY)L⁻ᵀ = I. One pass is accurate to
    ~κ(Y)²·eps; the second pass (CholeskyQR2) runs on an already
    near-orthonormal panel (κ ≈ 1) and lands on fp32 roundoff.

    The Gram matrix carries a SHIFT before factoring (shifted CholeskyQR2,
    Fukaya et al.): fp32 Gram roundoff is O(eps·‖Y‖₂²) and an
    ill-conditioned panel's true λ_min sits below it, so an unshifted (or
    eps-scale-ignoring) shift lets ``cholesky`` meet a negative pivot and
    return NaNs — observed in practice when the sketch width hits the short
    dim (square Omega ⇒ κ(Y) = κ(G)·κ(Omega), a lottery) inside large fused
    train steps, where XLA's re-association moves the roundoff. Lifting the
    spectrum by 16·eps·trace ≥ 16·eps·λ_max keeps the factorization PD for
    ANY finite panel; the first pass then lands at κ ≲ 1/√(16·eps) and the
    second pass restores orthonormality to fp32 roundoff. The big lift is
    FIRST-pass only: shifting by s scales columns down by ~s/2, so reusing
    it in pass two would bias every norm by 16·eps·l (observable at 1e-5
    tolerances); the second pass sees a near-orthonormal panel (unit-scale
    diagonal, κ ≈ 1) where a mean-diagonal-scaled eps floor is already
    PD-safe and the bias is O(eps). Rank-deficient panels (zero gradients,
    the bucketed engine's masked pad slots) keep trace 0 ⇒ only the 1e-30
    floor, and come back as exact zero columns instead of NaNs.
    """
    l = Y.shape[-1]
    eye = jnp.eye(l, dtype=jnp.float32)
    eps = float(jnp.finfo(jnp.float32).eps)
    for i in range(2):
        gram = jax.lax.psum(Y.T @ Y, axis_name)          # (l, l) panel
        rel = 16.0 * eps if i == 0 else 2.0 * eps / l
        shift = rel * jnp.trace(gram) + 1e-30
        L = jnp.linalg.cholesky(gram + shift * eye)
        # Y <- Y L^-T, i.e. solve L X = Yᵀ and transpose back.
        Y = jax.scipy.linalg.solve_triangular(L, Y.T, lower=True).T
    return Y


def _sketch_basis(
    G32: jnp.ndarray,
    key: jax.Array,
    l: int,
    n_iter: int,
    axis_name: Optional[str],
) -> jnp.ndarray:
    """Orthonormal basis (m, l) of the oversampled range sketch, with power
    iteration. G32 is fp32, row-sharded over ``axis_name`` when given (the
    random Omega is generated identically on every shard from the shared
    key, so no broadcast is needed)."""
    n = G32.shape[1]
    ortho = (
        (lambda Y: _cholesky_qr2(Y, axis_name))
        if axis_name is not None
        else _orthonormalize
    )
    if l == n:
        # A square Omega cannot reduce dimension — range(G @ Omega) is
        # range(G) exactly — but it DOES multiply the panel's condition
        # number by κ(Omega), a lottery a square gaussian loses often
        # enough to break fp32 downstream (the l == n case is exactly
        # rank + oversample ≥ short dim, common for small-short buckets).
        # Use G itself as the panel: same subspace, κ(G) conditioning,
        # one matmul cheaper.
        Q = ortho(G32)
    else:
        Omega = jax.random.normal(key, (n, l), dtype=jnp.float32)
        Q = ortho(G32 @ Omega)                # (m, l), shard-local matmul
    for _ in range(n_iter):
        # subspace/power iteration with re-orthonormalization for stability
        Z = G32.T @ Q                         # (n, l) partial per shard
        if axis_name is not None:
            Z = jax.lax.psum(Z, axis_name)    # r-width panel reduce
        Z = _orthonormalize(Z)                # replicated: plain thin QR
        Q = ortho(G32 @ Z)                    # (m, l)
    return Q


def rsvd_effective_rank(rank: int, short_dim: int) -> int:
    """Number of columns the sketch pipeline actually delivers for a
    requested ``rank``: the sketch width l = min(rank + oversample,
    short_dim) bounds the subspace, so ``rank > l`` under-delivers — and
    since oversample ≥ 0, the binding clamp is always just the short dim.
    All rsvd entry points clamp to this value explicitly (never silently
    returning fewer columns than requested without the clamp being visible
    here). ``short_dim`` is min(m, n) single-device, or n on the
    distributed path (canonical long-first orientation — the true global
    long dim, pad rows included or not, never enters the clamp)."""
    return max(1, min(rank, short_dim))


def _halko_factor(
    G: jnp.ndarray,
    key: jax.Array,
    rank: int,
    n_iter: int,
    oversample: int,
    axis_name: Optional[str],
):
    """Shared core of both entry points: sketch basis + small factorization.

    Returns (U, s, Vt) with U = Q_sketch @ Ub — the properly truncated
    factors, all with exactly ``rsvd_effective_rank(rank, ...)`` columns
    (rank is CLAMPED by the sketch width — see module docstring). U is
    row-sharded like G under ``axis_name``."""
    m, n = G.shape
    # Sketch width: oversampled, clamped by the short dim. On the distributed
    # path m is the LOCAL row count, so the clamp uses n alone (the canonical
    # long-first orientation guarantees global TRUE rows >= n >= l; zero pad
    # rows on top of the true rows change nothing — see module docstring).
    short = n if axis_name is not None else min(m, n)
    l = min(rank + oversample, short)
    # The sketch spans at most l directions: rank > l cannot be delivered.
    # Clamp explicitly so U/s/Vt agree on their width instead of Ub[:, :rank]
    # silently under-delivering a mis-shaped Q to downstream code.
    rank = rsvd_effective_rank(rank, short)
    G32 = G.astype(jnp.float32)
    Q = _sketch_basis(G32, key, l, n_iter, axis_name)    # (m, l)
    B = Q.T @ G32                                        # (l, n) partial
    if axis_name is not None:
        B = jax.lax.psum(B, axis_name)                   # r-width panel
    Ub, s, Vt = jnp.linalg.svd(B, full_matrices=False)   # small: l x n
    U = Q @ Ub[:, :rank]                                 # spectral truncation
    return U, s[:rank], Vt[:rank]


@partial(jax.jit, static_argnames=("rank", "n_iter", "oversample", "axis_name"))
def randomized_range_finder(
    G: jnp.ndarray,
    key: jax.Array,
    rank: int,
    n_iter: int = 2,
    oversample: int = 4,
    axis_name: Optional[str] = None,
) -> jnp.ndarray:
    """Rank-`rank` orthonormal basis Q (m × rank) of the row space of G (m × n).

    Power iteration (n_iter) sharpens the spectrum separation; oversampling
    improves accuracy, and the truncation back to `rank` goes through the
    SVD of the small ``B = QᵀG`` (see module docstring) so the kept columns
    are the TOP singular directions of the oversampled subspace, in order.

    ``axis_name``: when set, G is the local row block of a matrix sharded
    over that shard_map mesh axis and Q comes back sharded the same way —
    only r-width panels cross shards. Requires the canonical long-first
    orientation (global TRUE rows ≥ n; all-zero edge-pad rows on top are
    inert — see module docstring).

    The returned basis has ``rsvd_effective_rank(rank, min(m, n))`` columns
    — `rank` is clamped by the sketch width, never silently under-delivered.
    """
    U, _, _ = _halko_factor(G, key, rank, n_iter, oversample, axis_name)
    return U


@partial(jax.jit, static_argnames=("rank", "n_iter", "oversample", "axis_name"))
def randomized_svd(
    G: jnp.ndarray,
    key: jax.Array,
    rank: int,
    n_iter: int = 2,
    oversample: int = 4,
    axis_name: Optional[str] = None,
):
    """Truncated rSVD: returns (U (m,r), s (r,), Vt (r,n)) with
    r = ``rsvd_effective_rank(rank, min(m, n))`` (the clamp that
    keeps all three factors consistently shaped when rank exceeds the
    sketch width).

    Reuses the range finder's factorization (same sketch, same small SVD):
    ``randomized_svd(G, ...)[0]`` and ``randomized_range_finder(G, ...)``
    are the same ops in the same order. Under ``axis_name`` U is row-sharded
    like G; s and Vt are replicated.
    """
    return _halko_factor(G, key, rank, n_iter, oversample, axis_name)


@partial(jax.jit, static_argnames=("rank",))
def truncated_svd(G: jnp.ndarray, rank: int):
    """Exact truncated SVD (reference / small matrices)."""
    U, s, Vt = jnp.linalg.svd(G.astype(jnp.float32), full_matrices=False)
    return U[:, :rank], s[:rank], Vt[:rank]


def refresh_closed_jaxpr(
    rows: int,
    short: int,
    rank: int,
    n_iter: int = 2,
    oversample: int = 4,
    axis_name: str = "model",
):
    """Named closed-jaxpr export of the DISTRIBUTED refresh body, for the
    pad-inertness prover (repro.analysis.inertness.prove_refresh_inertness).

    Traces ``randomized_range_finder`` through a size-1 single-axis
    shard_map so the jaxpr contains the real 2D-path refresh pipeline —
    CholeskyQR2 Gram psums + triangular solves, panel psums — rather than
    the single-device thin-QR path (whose LAPACK Q factor is NOT
    guaranteed zero-row-preserving for rank-deficient inputs; the
    distributed invariant is specifically a property of the triangular
    solve). Tracing needs no extra devices and runs abstractly.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), (axis_name,))

    def body(G, key):
        return randomized_range_finder(
            G, key, rank, n_iter=n_iter, oversample=oversample,
            axis_name=axis_name)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis_name, None), P()),
                   out_specs=P(axis_name, None), check_rep=False)
    return jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((rows, short), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )


def cholesky_qr2_closed_jaxpr(rows: int = 64, cols: int = 8,
                              axis_name: str = "model"):
    """Named closed-jaxpr export of the shifted-CholeskyQR2 kernel alone,
    for the precision guard lint (repro.analysis.precision): the jaxpr
    carries both Gram psums, both trace-scaled shifts and both Cholesky
    factorizations, so ``audit_jaxpr_guards`` can prove every shift sits on
    the eps·trace scale — the machine check for the PR 5 bug class (a bare
    constant shift has relative scale 0 and fails `under-scaled-shift`).
    Traced through a size-1 shard_map like ``refresh_closed_jaxpr``; needs
    no devices."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), (axis_name,))
    fn = shard_map(partial(_cholesky_qr2, axis_name=axis_name), mesh=mesh,
                   in_specs=P(axis_name, None), out_specs=P(axis_name, None),
                   check_rep=False)
    return jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((rows, cols), jnp.float32))


def subspace_overlap(Q1: jnp.ndarray, Q2: jnp.ndarray) -> jnp.ndarray:
    """‖Q1ᵀQ2‖_F² / min(r1, r2) ∈ [0,1] — how aligned two orthonormal bases
    are.

    Normalizing by min(r1, r2) keeps the score in [0, 1] and symmetric for
    bases of DIFFERENT ranks (exactly what a controller rank resize
    produces): ‖Q1ᵀQ2‖_F² sums min(r1, r2) squared principal cosines, so 1.0
    means the smaller subspace is contained in the larger one.
    """
    r = min(Q1.shape[1], Q2.shape[1])
    return jnp.sum(jnp.square(Q1.T @ Q2)) / r
