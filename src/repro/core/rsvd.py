"""Truncated randomized SVD (Halko, Martinsson, Tropp 2010) — jittable,
single-device or row-sharded across a named mesh axis.

Used by SUMO / GaLore Block 1 to compute the rank-r orthonormal basis Q of the
gradient every K steps at O(mnr + mr^2) instead of full-SVD O(mn^2).

All functions are pure and jit/vmap/shard_map friendly.

Truncation: the oversampled sketch basis comes out of an orthogonalization
whose columns are NOT ordered by singular mass, so slicing ``Q[:, :rank]``
would throw the oversampling away (and can miss top directions outright when
the sketch mixes them into trailing columns). Both entry points therefore
truncate through the small factorization ``B = QᵀG``: ``svd(B) = Ub·s·Vt``
rotates the basis into singular order and ``Q @ Ub[:, :rank]`` keeps exactly
the top-rank directions of the oversampled subspace.
``randomized_range_finder`` and ``randomized_svd`` share this factorization
(``_halko_factor``), so the U they return is the same array computed by the
same ops — the range finder is simply the SVD with s/Vt discarded.

Distributed path (``axis_name``): G may arrive row-sharded over a shard_map
mesh axis — each shard holds a contiguous (m_loc, n) row block and the full
matrix is NEVER gathered. The collectives are all r-width panels:

  * ``G @ Omega`` and ``G @ Z`` are shard-local tall-skinny matmuls (Omega/Z
    are replicated (n, l) panels) — zero collectives;
  * ``Gᵀ @ Q`` and ``B = Qᵀ @ G`` produce per-shard partial (n, l)/(l, n)
    panels finished with one ``psum`` each — O(l·n) bytes, not O(m·n);
  * the thin-QR of the row-sharded (m, l) sketch is replaced by a
    CholeskyQR2-style Gram factorization: ``psum(YᵀY)`` (an l×l panel) +
    a small host-free Cholesky triangular solve, iterated twice for fp32
    stability (one pass loses ~κ(Y)² digits; the second restores
    orthonormality to fp32 roundoff).

So a refresh of a sharded (m, n) matrix costs O(l·(m/p + n)) local work and
O(l·(n + l)) collective bytes per power iteration — the r-width-collective
discipline GaLore-style methods rely on. The distributed path assumes the
canonical long-first orientation (global m ≥ n, SUMO's convention), so the
sketch width l is clamped by n alone. With ``axis_name=None`` the code is the
plain single-device Halko pipeline (thin jnp QR, no collectives).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


def _orthonormalize(Y: jnp.ndarray) -> jnp.ndarray:
    """Thin-QR orthonormal basis of range(Y). Y: (m, r) -> Q: (m, r)."""
    Q, _ = jnp.linalg.qr(Y.astype(jnp.float32))
    return Q


def _cholesky_qr2(Y: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Orthonormalize a row-sharded tall-skinny panel without gathering it.

    Y is the local (m_loc, l) row block of a global (m, l) panel sharded over
    ``axis_name``. Each pass forms the GLOBAL Gram matrix with an l×l psum,
    factors it (Cholesky) and applies the inverse triangular factor locally:
    Q = Y·L⁻ᵀ satisfies QᵀQ = L⁻¹(YᵀY)L⁻ᵀ = I. One pass is accurate to
    ~κ(Y)²·eps; the second pass (CholeskyQR2) runs on an already
    near-orthonormal panel (κ ≈ 1) and lands on fp32 roundoff.

    The Gram matrix carries a tiny relative shift before factoring so
    rank-deficient panels (zero gradients, the bucketed engine's masked pad
    slots) stay finite — they come back as zero columns instead of NaNs, and
    for well-conditioned panels the second pass absorbs the perturbation.
    """
    l = Y.shape[-1]
    eye = jnp.eye(l, dtype=jnp.float32)
    for _ in range(2):
        gram = jax.lax.psum(Y.T @ Y, axis_name)          # (l, l) panel
        shift = 1e-12 * (jnp.trace(gram) / l) + 1e-30
        L = jnp.linalg.cholesky(gram + shift * eye)
        # Y <- Y L^-T, i.e. solve L X = Yᵀ and transpose back.
        Y = jax.scipy.linalg.solve_triangular(L, Y.T, lower=True).T
    return Y


def _sketch_basis(
    G32: jnp.ndarray,
    key: jax.Array,
    l: int,
    n_iter: int,
    axis_name: Optional[str],
) -> jnp.ndarray:
    """Orthonormal basis (m, l) of the oversampled range sketch, with power
    iteration. G32 is fp32, row-sharded over ``axis_name`` when given (the
    random Omega is generated identically on every shard from the shared
    key, so no broadcast is needed)."""
    n = G32.shape[1]
    ortho = (
        (lambda Y: _cholesky_qr2(Y, axis_name))
        if axis_name is not None
        else _orthonormalize
    )
    Omega = jax.random.normal(key, (n, l), dtype=jnp.float32)
    Q = ortho(G32 @ Omega)                    # (m, l), shard-local matmul
    for _ in range(n_iter):
        # subspace/power iteration with re-orthonormalization for stability
        Z = G32.T @ Q                         # (n, l) partial per shard
        if axis_name is not None:
            Z = jax.lax.psum(Z, axis_name)    # r-width panel reduce
        Z = _orthonormalize(Z)                # replicated: plain thin QR
        Q = ortho(G32 @ Z)                    # (m, l)
    return Q


def _halko_factor(
    G: jnp.ndarray,
    key: jax.Array,
    rank: int,
    n_iter: int,
    oversample: int,
    axis_name: Optional[str],
):
    """Shared core of both entry points: sketch basis + small factorization.

    Returns (U, s, Vt) with U = Q_sketch @ Ub — the properly truncated
    rank-`rank` factors. U is row-sharded like G under ``axis_name``."""
    m, n = G.shape
    # Sketch width: oversampled, clamped by the short dim. On the distributed
    # path m is the LOCAL row count, so the clamp uses n alone (the canonical
    # long-first orientation guarantees global m >= n >= l).
    l = min(rank + oversample, n if axis_name is not None else min(m, n))
    G32 = G.astype(jnp.float32)
    Q = _sketch_basis(G32, key, l, n_iter, axis_name)    # (m, l)
    B = Q.T @ G32                                        # (l, n) partial
    if axis_name is not None:
        B = jax.lax.psum(B, axis_name)                   # r-width panel
    Ub, s, Vt = jnp.linalg.svd(B, full_matrices=False)   # small: l x n
    U = Q @ Ub[:, :rank]                                 # spectral truncation
    return U, s[:rank], Vt[:rank]


@partial(jax.jit, static_argnames=("rank", "n_iter", "oversample", "axis_name"))
def randomized_range_finder(
    G: jnp.ndarray,
    key: jax.Array,
    rank: int,
    n_iter: int = 2,
    oversample: int = 4,
    axis_name: Optional[str] = None,
) -> jnp.ndarray:
    """Rank-`rank` orthonormal basis Q (m × rank) of the row space of G (m × n).

    Power iteration (n_iter) sharpens the spectrum separation; oversampling
    improves accuracy, and the truncation back to `rank` goes through the
    SVD of the small ``B = QᵀG`` (see module docstring) so the kept columns
    are the TOP singular directions of the oversampled subspace, in order.

    ``axis_name``: when set, G is the local row block of a matrix sharded
    over that shard_map mesh axis and Q comes back sharded the same way —
    only r-width panels cross shards. Requires the canonical long-first
    orientation (global rows ≥ n).
    """
    U, _, _ = _halko_factor(G, key, rank, n_iter, oversample, axis_name)
    return U


@partial(jax.jit, static_argnames=("rank", "n_iter", "oversample", "axis_name"))
def randomized_svd(
    G: jnp.ndarray,
    key: jax.Array,
    rank: int,
    n_iter: int = 2,
    oversample: int = 4,
    axis_name: Optional[str] = None,
):
    """Truncated rSVD: returns (U (m,r), s (r,), Vt (r,n)).

    Reuses the range finder's factorization (same sketch, same small SVD):
    ``randomized_svd(G, ...)[0]`` and ``randomized_range_finder(G, ...)``
    are the same ops in the same order. Under ``axis_name`` U is row-sharded
    like G; s and Vt are replicated.
    """
    return _halko_factor(G, key, rank, n_iter, oversample, axis_name)


@partial(jax.jit, static_argnames=("rank",))
def truncated_svd(G: jnp.ndarray, rank: int):
    """Exact truncated SVD (reference / small matrices)."""
    U, s, Vt = jnp.linalg.svd(G.astype(jnp.float32), full_matrices=False)
    return U[:, :rank], s[:rank], Vt[:rank]


def subspace_overlap(Q1: jnp.ndarray, Q2: jnp.ndarray) -> jnp.ndarray:
    """‖Q1ᵀQ2‖_F² / min(r1, r2) ∈ [0,1] — how aligned two orthonormal bases
    are.

    Normalizing by min(r1, r2) keeps the score in [0, 1] and symmetric for
    bases of DIFFERENT ranks (exactly what a controller rank resize
    produces): ‖Q1ᵀQ2‖_F² sums min(r1, r2) squared principal cosines, so 1.0
    means the smaller subspace is contained in the larger one.
    """
    r = min(Q1.shape[1], Q2.shape[1])
    return jnp.sum(jnp.square(Q1.T @ Q2)) / r
