"""Moment orthogonalization operators (paper Block 2).

Three implementations of orth(M) = U Vᵀ where M = U Σ Vᵀ:

  * ``orthogonalize_svd``   — exact, via jnp.linalg.svd (reference).
  * ``orthogonalize_polar`` — exact, via the Gram trick: the polar factor
        U Vᵀ = M (MᵀM)^{-1/2}; for the r×n SUMO moment (r ≪ n) MMᵀ is r×r,
        so one r×r eigh + two thin matmuls. Mathematically identical to SVD
        orthogonalization for full-rank M and MUCH cheaper on TPU (no QR
        iteration on an m×n operand). This is our TPU-native adaptation of
        the paper's Orthogonalization_SVD.
  * ``newton_schulz5``      — Muon's quintic Newton-Schulz (5 iterations,
        coefficients a,b,c = 3.4445, −4.7750, 2.0315). Used for the Muon
        baseline and the SUMO-NS5 ablation.
  * ``newton_schulz_cubic`` — the classical cubic iteration X ← ½X(3I−XᵀX·)
        analyzed in paper Lemma 3.2; used by the ortho-error benchmark.

Also: condition-number / effective-rank diagnostics used to reproduce
paper Fig. 1 and Lemma 3.1.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

_EPS = 1e-7

# Muon's tuned quintic coefficients.
_NS5_A, _NS5_B, _NS5_C = 3.4445, -4.7750, 2.0315


def orthogonalize_svd(M: jnp.ndarray) -> jnp.ndarray:
    """Exact U Vᵀ via full SVD (reference oracle)."""
    U, _, Vt = jnp.linalg.svd(M.astype(jnp.float32), full_matrices=False)
    return U @ Vt


def orthogonalize_svd_with_spectrum(M: jnp.ndarray):
    """One SVD, two outputs: (U Vᵀ, σ descending). The telemetry variant of
    ``orthogonalize_svd`` — same single factorization, the singular values
    are a free byproduct."""
    U, s, Vt = jnp.linalg.svd(M.astype(jnp.float32), full_matrices=False)
    return U @ Vt, s


def _polar_gram(M32: jnp.ndarray, eps: float):
    """Shared Gram-eigh polar core: returns (O, lam) where ``lam`` are the
    ASCENDING eigenvalues of the min-side Gram matrix (= σ(M)² ascending).
    Rank-deficient directions (λ≈0) are zeroed rather than amplified,
    matching the pseudo-polar factor that truncated SVD orthogonalization
    produces."""
    r, n = M32.shape
    if r <= n:
        Gm = M32 @ M32.T                      # (r, r) PSD
        lam, V = jnp.linalg.eigh(Gm)
        # inverse sqrt with rank guard relative to the largest eigenvalue
        lam_max = jnp.maximum(lam[-1], eps)
        good = lam > (eps * lam_max)
        inv_sqrt = jnp.where(good, 1.0 / jnp.sqrt(jnp.maximum(lam, eps * lam_max)), 0.0)
        P = (V * inv_sqrt[None, :]) @ V.T     # (MMᵀ)^{-1/2}
        O = P @ M32
        # one cubic Newton polish: kills the O(√κ·eps_f32) residual of eigh
        O = 1.5 * O - 0.5 * ((O @ O.T) @ O)
    else:
        Gm = M32.T @ M32
        lam, V = jnp.linalg.eigh(Gm)
        lam_max = jnp.maximum(lam[-1], eps)
        good = lam > (eps * lam_max)
        inv_sqrt = jnp.where(good, 1.0 / jnp.sqrt(jnp.maximum(lam, eps * lam_max)), 0.0)
        P = (V * inv_sqrt[None, :]) @ V.T
        O = M32 @ P
        O = 1.5 * O - 0.5 * (O @ (O.T @ O))
    return O, lam


def orthogonalize_polar(M: jnp.ndarray, eps: float = _EPS) -> jnp.ndarray:
    """Exact polar factor via Gram eigendecomposition.

    For M (r×n) with r <= n: UVᵀ = (MMᵀ)^{-1/2} M, computed with an r×r eigh.
    For r > n the mirrored identity M (MᵀM)^{-1/2} is used.
    """
    O, _ = _polar_gram(M.astype(jnp.float32), eps)
    return O.astype(M.dtype)


def orthogonalize_polar_with_spectrum(M: jnp.ndarray, eps: float = _EPS):
    """Polar factor + singular values from the SAME r×r eigh the polar
    orthogonalization already performs (λ(MMᵀ) = σ(M)²): returns
    (O, σ descending). O is bit-identical to ``orthogonalize_polar`` — the
    spectral-telemetry probes ride the existing factorization for free."""
    O, lam = _polar_gram(M.astype(jnp.float32), eps)
    sigma = jnp.sqrt(jnp.maximum(lam, 0.0))[::-1]
    return O.astype(M.dtype), sigma


def gram_spectrum(M: jnp.ndarray) -> jnp.ndarray:
    """σ(M) descending via an eigh of the min-side Gram matrix — the cheap
    (r×r, no large-matrix SVD) spectrum used when the orthogonalization
    method does not materialize one itself (NS5)."""
    M32 = M.astype(jnp.float32)
    Gm = M32 @ M32.T if M32.shape[0] <= M32.shape[1] else M32.T @ M32
    lam = jnp.linalg.eigvalsh(Gm)
    return jnp.sqrt(jnp.maximum(lam, 0.0))[::-1]


@partial(jax.jit, static_argnames=("steps",))
def newton_schulz5(M: jnp.ndarray, steps: int = 5) -> jnp.ndarray:
    """Muon's quintic Newton-Schulz orthogonalization (bf16-safe in fp32 here).

    X0 = M / ‖M‖_F, then X ← aX + (bA + cA²)X with A = XXᵀ.
    Operates on (r, n) with r <= n; transposes internally otherwise.
    """
    X = M.astype(jnp.float32)
    transposed = X.shape[0] > X.shape[1]
    if transposed:
        X = X.T
    X = X / (jnp.linalg.norm(X) + _EPS)

    def body(X, _):
        A = X @ X.T
        B = _NS5_B * A + _NS5_C * (A @ A)
        X = _NS5_A * X + B @ X
        return X, None

    X, _ = jax.lax.scan(body, X, None, length=steps)
    if transposed:
        X = X.T
    return X.astype(M.dtype)


@partial(jax.jit, static_argnames=("steps",))
def newton_schulz_cubic(M: jnp.ndarray, steps: int = 5) -> jnp.ndarray:
    """Classical cubic NS: X ← ½ X (3I − XᵀX) — quadratic convergence,
    contraction factor (1 − σ_min/σ_max)^{2^i} as in paper Lemma 3.2."""
    X = M.astype(jnp.float32)
    transposed = X.shape[0] > X.shape[1]
    if transposed:
        X = X.T
    # scale so all singular values are <= 1 (spectral-norm upper bound)
    X = X / (jnp.linalg.norm(X, ord=2) + _EPS) if min(X.shape) <= 512 else X / (
        jnp.linalg.norm(X) + _EPS
    )

    def body(X, _):
        A = X @ X.T
        X = 1.5 * X - 0.5 * (A @ X)
        return X, None

    X, _ = jax.lax.scan(body, X, None, length=steps)
    if transposed:
        X = X.T
    return X.astype(M.dtype)


#: method name -> implementation; the single dispatch table shared by
#: core.sumo, the precision lint and the ortho-error benchmark.
ORTH_METHODS = {
    "svd": orthogonalize_svd,
    "polar": orthogonalize_polar,
    "ns5": newton_schulz5,
    "cubic": newton_schulz_cubic,
}


def orth_closed_jaxpr(method: str, r: int = 16, n: int = 64,
                      ns_steps: int = 5):
    """Named closed-jaxpr export of one orthogonalization method on an
    (r, n) fp32 moment, for the precision guard lint
    (``repro.analysis.precision.audit_jaxpr_guards``): every division and
    rsqrt in these jaxprs must carry a provable eps floor. Tracing is
    abstract — no FLOPs run."""
    fn = ORTH_METHODS[method]
    if method in ("ns5", "cubic"):
        fn = partial(fn, steps=ns_steps)
    return jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((r, n), jnp.float32))


# ---------------------------------------------------------------------------
# Diagnostics (paper Fig. 1 / Lemma 3.1 reproduction)
# ---------------------------------------------------------------------------

def condition_number(M: jnp.ndarray) -> jnp.ndarray:
    """κ(MMᵀ) = (σ_max/σ_min)² of M, via singular values."""
    s = jnp.linalg.svd(M.astype(jnp.float32), compute_uv=False)
    return jnp.square(s[0] / jnp.maximum(s[-1], _EPS))


def effective_rank(M: jnp.ndarray, thresh: float = 0.01) -> jnp.ndarray:
    """# singular values above thresh·σ_max."""
    s = jnp.linalg.svd(M.astype(jnp.float32), compute_uv=False)
    return jnp.sum(s > thresh * s[0])


def rank_one_residual(M: jnp.ndarray) -> jnp.ndarray:
    """κ_M(t) of paper Eq. (1): ‖M − P(1)M‖_F² / ‖M‖_F² = 1 − σ1²/Σσ²."""
    s = jnp.linalg.svd(M.astype(jnp.float32), compute_uv=False)
    total = jnp.sum(jnp.square(s)) + _EPS
    return 1.0 - jnp.square(s[0]) / total


def orthogonality_error(O: jnp.ndarray) -> jnp.ndarray:
    """‖O Oᵀ − I‖_F / √r for O (r×n), r<=n — 0 for exactly orthogonal rows."""
    O32 = O.astype(jnp.float32)
    if O32.shape[0] > O32.shape[1]:
        O32 = O32.T
    r = O32.shape[0]
    return jnp.linalg.norm(O32 @ O32.T - jnp.eye(r)) / jnp.sqrt(r)
