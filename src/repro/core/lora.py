"""LoRA baseline: rank-r adapters W + (alpha/r)·B A on matrix params (leading
dims of stacked blocks / experts are treated as batch — one adapter pair per
slice), trained
with AdamW while base weights stay frozen. Also the post-hoc adapter
extraction of paper Appendix B (Δ = W_ft − W_pre factorized at rank(Δ)).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import optimizer as opt
from .rsvd import truncated_svd

PyTree = Any


@dataclasses.dataclass(frozen=True)
class LoraConfig:
    rank: int = 8
    alpha: float = 16.0
    seed: int = 0


def init_lora_params(params: PyTree, config: LoraConfig = LoraConfig()) -> PyTree:
    """Create {path: (A, B)} adapters for every matrix param. A is gaussian,
    B is zero (so the adapted model starts exactly at the base model)."""
    labels = opt.partition_params(params)
    key = jax.random.PRNGKey(config.seed)

    leaves, treedef = jax.tree_util.tree_flatten(params)
    lab_leaves = treedef.flatten_up_to(labels)
    keys = jax.random.split(key, len(leaves))

    adapters = []
    for leaf, lab, k in zip(leaves, lab_leaves, keys):
        if lab != "matrix" or leaf.ndim < 2:
            adapters.append(None)
            continue
        # leading dims (stacked blocks / experts) are batch: one adapter pair
        # per slice, so memory matches Table 1's per-matrix 3r(m+n) accounting
        bd = leaf.shape[:-2]
        m, n = leaf.shape[-2:]
        r = min(config.rank, min(m, n))
        A = jax.random.normal(k, (*bd, r, n), jnp.float32) / jnp.sqrt(n)
        B = jnp.zeros((*bd, m, r), jnp.float32)
        adapters.append({"A": A, "B": B})
    return jax.tree_util.tree_unflatten(treedef, adapters)


def _is_adapter(x) -> bool:
    return x is None or (isinstance(x, dict) and set(x.keys()) == {"A", "B"})


def apply_lora(params: PyTree, adapters: PyTree, config: LoraConfig = LoraConfig()) -> PyTree:
    """Effective weights W + (alpha/r)·B A."""

    def merge(ad, p):
        if ad is None:
            return p
        scale = config.alpha / ad["A"].shape[-2]   # rank dim (batched A is (..., r, n))
        return p + (scale * (ad["B"] @ ad["A"])).astype(p.dtype)

    # map over the ADAPTER tree (its {A,B} dicts / Nones are the leaves) and
    # zip the matching param subtrees in as the second argument
    return jax.tree_util.tree_map(merge, adapters, params, is_leaf=_is_adapter)


def extract_adapter(w_pre: jnp.ndarray, w_ft: jnp.ndarray, rank: int):
    """Post-hoc adapter extraction (paper App. B): factorize Δ = B A at rank r
    via truncated SVD (the global optimum of the Frobenius factorization)."""
    delta = (w_ft - w_pre).astype(jnp.float32)
    U, s, Vt = truncated_svd(delta, rank)
    B = U * jnp.sqrt(s)[None, :]
    A = jnp.sqrt(s)[:, None] * Vt
    return A, B


def lora_param_count(params: PyTree, config: LoraConfig = LoraConfig()) -> int:
    adapters = init_lora_params(params, config)
    return sum(
        int(l.size)
        for l in jax.tree_util.tree_leaves(adapters)
        if l is not None
    )
