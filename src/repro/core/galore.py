"""GaLore baseline (Zhao et al. 2024): low-rank gradient projection with Adam
moments kept IN the projected subspace. State per matrix: Q (long·r) plus two
r×short Adam moments (vs. SUMO's single moment) — paper Table 1's `2nr + mr`.

Differences from SUMO (deliberate, faithful to GaLore):
  * two Adam moments in the subspace, element-wise preconditioning
  * NO moment rotation on subspace refresh (moments silently live in the
    stale basis — the pathology SUMO's Block 1.1 fixes)
  * NO orthogonalization of the update
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from . import optimizer as opt
from .rsvd import randomized_range_finder

PyTree = opt.PyTree


class GaloreState(NamedTuple):
    step: jnp.ndarray
    key: jax.Array
    Q: PyTree
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class GaloreConfig:
    rank: int = 128
    update_freq: int = 200
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    alpha: float = 0.25        # GaLore's projection-back scale
    weight_decay: float = 0.0
    rsvd_iters: int = 2
    seed: int = 0


def galore(learning_rate: Union[float, Callable], config: GaloreConfig = GaloreConfig()) -> opt.Transform:
    cfg = config
    lr_fn = learning_rate if callable(learning_rate) else (lambda s: jnp.asarray(learning_rate))

    def _leaf_init(leaf):
        if leaf is None:
            return None, None, None
        m, n = leaf.shape[-2], leaf.shape[-1]
        long_d, short_d = (n, m) if m < n else (m, n)
        r = max(1, min(cfg.rank, min(m, n)))
        batch = leaf.shape[:-2]
        return (
            jnp.zeros(batch + (long_d, r), jnp.float32),
            jnp.zeros(batch + (r, short_d), jnp.float32),
            jnp.zeros(batch + (r, short_d), jnp.float32),
        )

    def init(params):
        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        triples = [_leaf_init(l) for l in leaves]
        unflat = lambda i: jax.tree_util.tree_unflatten(treedef, [t[i] for t in triples])
        return GaloreState(
            step=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(cfg.seed),
            Q=unflat(0),
            mu=unflat(1),
            nu=unflat(2),
        )

    def _matrix(G, Q, mu, nu, lr, c1, c2, do_refresh, key, W):
        m, n = G.shape
        transpose = m < n
        Gl = G.T if transpose else G
        r = Q.shape[1]

        Q = jax.lax.cond(
            do_refresh,
            lambda _: randomized_range_finder(Gl, key, r, n_iter=cfg.rsvd_iters),
            lambda _: Q,
            operand=None,
        )
        G_hat = Q.T @ Gl                                  # (r, short)
        mu = cfg.b1 * mu + (1 - cfg.b1) * G_hat
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(G_hat)
        step_hat = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        upd = Q @ step_hat                                # (long, short)
        if transpose:
            upd = upd.T
        d = -lr * cfg.alpha * upd
        if cfg.weight_decay > 0.0 and W is not None:
            d = d - lr * cfg.weight_decay * W.astype(jnp.float32)
        return d, Q, mu, nu

    def update(grads, state: GaloreState, params=None):
        step = state.step + 1
        lr = lr_fn(state.step).astype(jnp.float32)
        c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
        do_refresh = (state.step % cfg.update_freq) == 0

        leaves_g, treedef = jax.tree_util.tree_flatten(grads, is_leaf=lambda x: x is None)
        leaves_Q = treedef.flatten_up_to(state.Q)
        leaves_mu = treedef.flatten_up_to(state.mu)
        leaves_nu = treedef.flatten_up_to(state.nu)
        leaves_p = (
            treedef.flatten_up_to(params) if params is not None else [None] * len(leaves_g)
        )
        keys = jax.random.split(state.key, len(leaves_g) + 1)
        new_key, leaf_keys = keys[0], keys[1:]

        out = {"u": [], "Q": [], "mu": [], "nu": []}
        for g, Q, mu, nu, p, k in zip(
            leaves_g, leaves_Q, leaves_mu, leaves_nu, leaves_p, leaf_keys
        ):
            if g is None:
                for v in out.values():
                    v.append(None)
                continue
            g32 = g.astype(jnp.float32)
            if g.ndim == 2:
                d, Qn, mun, nun = _matrix(g32, Q, mu, nu, lr, c1, c2, do_refresh, k, p)
            else:
                bs = g.shape[:-2]
                fn = jax.vmap(
                    lambda G_, Q_, m_, v_, k_, W_: _matrix(
                        G_, Q_, m_, v_, lr, c1, c2, do_refresh, k_, W_
                    )
                )
                gb = g32.reshape((-1,) + g.shape[-2:])
                pb = (
                    p.astype(jnp.float32).reshape((-1,) + p.shape[-2:])
                    if p is not None else jnp.zeros_like(gb)
                )
                d, Qn, mun, nun = fn(
                    gb,
                    Q.reshape((-1,) + Q.shape[-2:]),
                    mu.reshape((-1,) + mu.shape[-2:]),
                    nu.reshape((-1,) + nu.shape[-2:]),
                    jax.random.split(k, gb.shape[0]),
                    pb,
                )
                d = d.reshape(g.shape)
                Qn = Qn.reshape(bs + Qn.shape[-2:])
                mun = mun.reshape(bs + mun.shape[-2:])
                nun = nun.reshape(bs + nun.shape[-2:])
            out["u"].append(d); out["Q"].append(Qn)
            out["mu"].append(mun); out["nu"].append(nun)

        unflat = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        return unflat(out["u"]), GaloreState(
            step=step, key=new_key, Q=unflat(out["Q"]),
            mu=unflat(out["mu"]), nu=unflat(out["nu"]),
        )

    return opt.Transform(init, update)


def galore_optimizer(learning_rate, params, config: GaloreConfig = GaloreConfig(),
                     fallback_lr=None) -> opt.Transform:
    from .adamw import adamw

    labels = opt.partition_params(params)
    return opt.multi_transform(
        {
            "matrix": galore(learning_rate, config),
            "fallback": adamw(fallback_lr if fallback_lr is not None else learning_rate),
        },
        labels,
    )
