"""Optimizer framework: a minimal, optax-like GradientTransformation protocol.

Everything is a pure-functional pair (init_fn, update_fn) over pytrees so it
composes with jit / shard_map / donate_argnums. We deliberately do NOT depend
on optax (not installed in the target container) — the protocol is a strict
subset, so swapping optax in later is trivial.

Parameter classification
------------------------
SUMO / Muon / GaLore apply only to 2D "reversible-layer" matrices (attention &
MLP projections, expert matrices). Embeddings, unembedding, norms, biases and
other <2D or excluded tensors fall back to AdamW — exactly the practice in the
Muon and GaLore papers. Classification is name+shape based and overridable
per-config via ``matrix_rules``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class Transform(NamedTuple):
    """A pure gradient transformation: state = init(params);
    updates, state = update(grads, state, params)."""

    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """W <- W + update (updates already carry their sign)."""
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params,
        updates,
        is_leaf=lambda x: x is None,
    )


def chain(*transforms: Transform) -> Transform:
    """Compose transforms left-to-right (like optax.chain)."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_states = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_states.append(s)
        return grads, tuple(new_states)

    return Transform(init, update)


# ---------------------------------------------------------------------------
# Parameter classification
# ---------------------------------------------------------------------------

# Path substrings that force the AdamW fallback even for 2D tensors.
_DEFAULT_FALLBACK_PATTERNS = (
    r"embed",        # token / position / patch embeddings
    r"lm_head",      # unembedding
    r"unembed",
    r"norm",         # rmsnorm / layernorm scales
    r"bias",
    r"A_log",        # mamba SSM params
    r"\bD\b",
    r"dt_",
    r"conv1d",       # short conv kernels
    r"router_bias",
)


def path_str(path) -> str:
    """Render a tree_util key path into 'a/b/c' form."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def is_matrix_param(path: str, leaf: jnp.ndarray,
                    fallback_patterns=_DEFAULT_FALLBACK_PATTERNS) -> bool:
    """True if this leaf should receive the matrix optimizer (SUMO/Muon/GaLore).

    Rules: ndim >= 2 (3D expert stacks count — they vmap over the leading
    axis), both trailing dims > 1, and no fallback pattern matches the path.
    """
    if leaf.ndim < 2:
        return False
    if leaf.shape[-1] <= 1 or leaf.shape[-2] <= 1:
        return False
    for pat in fallback_patterns:
        if re.search(pat, path):
            return False
    return True


def partition_params(params: PyTree, fallback_patterns=_DEFAULT_FALLBACK_PATTERNS):
    """Return a pytree of labels: 'matrix' | 'fallback' matching params."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: "matrix"
        if is_matrix_param(path_str(path), leaf, fallback_patterns)
        else "fallback",
        params,
    )


# ---------------------------------------------------------------------------
# Bucket plan: group same-shaped matrix leaves for stacked (vmapped) updates
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Bucket:
    """One stacked update group: every matrix in it shares the CANONICAL
    (long, short) trailing shape, long = max(m, n) ≥ short = min(m, n).

    Orientation is canonicalized so that an (m, n) leaf and its transpose
    partner (n, m) land in the SAME bucket (e.g. a transformer's w_up /
    w_down pair): the per-matrix update only ever operates on the long-first
    view, so merging them halves the refresh conds and — crucially — makes
    the bucket key a pure function of the optimizer-state shapes (Q is
    (long, r), M is (r, short) regardless of orientation), which is what lets
    bucket-resident state round-trip through checkpoints unambiguously.

    ``leaf_indices`` index into the *flattened* leaf list the plan was built
    from; ``counts[i]`` is how many matrices leaf i contributes (1 for a 2D
    leaf, prod(leading dims) for an (E, m, n) expert stack); ``transposed[i]``
    says whether that leaf's matrices must be transposed into the canonical
    long-first orientation (m < n). Stacking order is leaf order, experts in
    layout order — the scatter in the consumer must slice back with the same
    offsets (and transpose back where flagged).
    """

    shape: tuple[int, int]
    leaf_indices: tuple[int, ...]
    counts: tuple[int, ...]
    transposed: tuple[bool, ...]

    @property
    def size(self) -> int:
        return sum(self.counts)

    @property
    def key(self) -> str:
        """Stable string id — the bucket-resident state key."""
        return bucket_key(*self.shape)


def bucket_key(long_d: int, short_d: int) -> str:
    """Canonical bucket-state key ('LONGxSHORT'). The single encoder — used
    by Bucket.key and checkpoint layout migration; ``BUCKET_KEY_RE`` is the
    matching decoder side (layout detection in sumo/checkpoint/sharding)."""
    return f"{long_d}x{short_d}"


def canonical_dims(shape) -> tuple[int, int]:
    """Trailing (long, short) dims of a matrix leaf shape — the orientation
    used everywhere a bucket is identified (plan building, per-bucket
    rank/update_freq overrides, telemetry settings)."""
    m, n = int(shape[-2]), int(shape[-1])
    return (max(m, n), min(m, n))


# Matches bucket_key output — import this instead of re-encoding the format.
BUCKET_KEY_RE = re.compile(r"^\d+x\d+$")


def build_bucket_plan(shapes) -> tuple[Bucket, ...]:
    """Group flattened leaf shapes by canonical trailing (long, short) shape.

    ``shapes`` is a sequence of array shapes (or None for masked leaves, which
    are skipped). Purely static — safe to call at trace time; the same shapes
    always produce the same plan, so init, update and checkpoint restore agree
    without storing the plan anywhere. Buckets are ordered by first
    occurrence.
    """
    groups: dict[tuple[int, int], list[tuple[int, int, bool]]] = {}
    for i, s in enumerate(shapes):
        if s is None:
            continue
        if len(s) < 2:
            raise ValueError(f"bucket plan needs matrix leaves, got shape {s}")
        m, n = int(s[-2]), int(s[-1])
        key = canonical_dims(s)
        cnt = 1
        for d in s[:-2]:
            cnt *= int(d)
        groups.setdefault(key, []).append((i, cnt, m < n))
    return tuple(
        Bucket(
            shape=k,
            leaf_indices=tuple(i for i, _, _ in members),
            counts=tuple(c for _, c, _ in members),
            transposed=tuple(t for _, _, t in members),
        )
        for k, members in groups.items()
    )


def multi_transform(transforms: dict[str, Transform], labels: PyTree) -> Transform:
    """Route each leaf to the transform named by its label (optax.multi_transform).

    States are kept per-label as full pytrees with None at non-matching leaves,
    which keeps everything jit-compatible (structure is static).
    """

    labels_flat = jax.tree_util.tree_leaves(labels)
    names = sorted(set(labels_flat))
    for n in names:
        if n not in transforms:
            raise KeyError(f"label {n!r} has no transform (have {list(transforms)})")

    def _mask(tree, name):
        return jax.tree_util.tree_map(
            lambda leaf, lab: leaf if lab == name else None, tree, labels
        )

    def _merge(trees):
        """Merge per-label trees (None elsewhere) back into one tree."""
        def pick(*leaves):
            for l in leaves:
                if l is not None:
                    return l
            return None
        return jax.tree_util.tree_map(pick, *trees, is_leaf=lambda x: x is None)

    def init(params):
        return {n: transforms[n].init(_mask(params, n)) for n in names}

    def update(grads, state, params=None):
        outs, new_state = [], {}
        for n in names:
            g_n = _mask(grads, n)
            p_n = _mask(params, n) if params is not None else None
            u_n, s_n = transforms[n].update(g_n, state[n], p_n)
            outs.append(u_n)
            new_state[n] = s_n
        return _merge(outs), new_state

    return Transform(init, update)


# ---------------------------------------------------------------------------
# Generic helpers shared by optimizers
# ---------------------------------------------------------------------------

def tree_map_not_none(fn, *trees):
    """tree_map over trees that may contain None leaves (masked subsets)."""
    return jax.tree_util.tree_map(
        lambda *ls: None if ls[0] is None else fn(*ls),
        *trees,
        is_leaf=lambda x: x is None,
    )


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [l for l in jax.tree_util.tree_leaves(tree) if l is not None]
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(max_norm: float) -> Transform:
    def init(params):
        return ()

    def update(grads, state, params=None):
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
        return tree_map_not_none(lambda g: g * scale, grads), state

    return Transform(init, update)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Warmup-cosine LR schedule (the paper's training recipe default)."""

    peak_lr: float
    warmup_steps: int = 100
    total_steps: int = 10_000
    final_frac: float = 0.1

    def __call__(self, step: jnp.ndarray) -> jnp.ndarray:
        step = step.astype(jnp.float32)
        warm = self.peak_lr * step / jnp.maximum(1.0, self.warmup_steps)
        prog = jnp.clip(
            (step - self.warmup_steps)
            / jnp.maximum(1.0, self.total_steps - self.warmup_steps),
            0.0,
            1.0,
        )
        cos = self.final_frac + (1 - self.final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < self.warmup_steps, warm, self.peak_lr * cos)


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)
