"""SUMO: Subspace-Aware Moment-Orthogonalization (paper Algorithm 1).

Per 2D weight W (m×n) the optimizer keeps
  * Q  — rank-r orthonormal basis of the gradient's long dimension, refreshed
         every K steps with truncated randomized SVD          (Block 1)
  * M  — the single first-order moment in the projected space (r × short_dim)
  * prev_norm — ‖O_{t-1}‖_F for the norm-growth limiter       (Block 3)

Update (Def. C.1):
  refresh (t ≡ 0 mod K):  Q_new = rSVD_r(G);  M ← (Q_newᵀ Q_old) M   (Block 1.1)
  Ĝ = Qᵀ G                                                    (project)
  M ← β M + (1-β) Ĝ                                           (moment)
  O = orth(M)            exact polar/SVD, or NS5 for ablation (Block 2)
  O ← limiter(O)         if ‖O‖/‖O_prev‖ > γ, rescale         (Block 3)
  W ← W − η·(α·scale)·Q O − η·λ·W                             (Block 4)

Shape convention: we always project the LONGER side, so the moment is
(r × min(m,n)) and the subspace basis is (max(m,n) × r). For m < n this is
the paper's "projection from the right" remark. 3D expert stacks (E, m, n)
are handled by vmapping the per-matrix rule over the leading axis.

Everything is jit-safe: the K-step refresh runs under ``jax.lax.cond`` so the
rSVD cost is paid only on refresh steps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from . import optimizer as opt
from .orthogonalize import newton_schulz5, orthogonalize_polar, orthogonalize_svd
from .rsvd import randomized_range_finder

PyTree = opt.PyTree


class SumoState(NamedTuple):
    step: jnp.ndarray          # ()
    key: jax.Array             # rng for rSVD sketches
    Q: PyTree                  # per-leaf (long, r) bases (None on fallback leaves)
    M: PyTree                  # per-leaf (r, short) moments
    prev_norm: PyTree          # per-leaf () limiter memory


@dataclasses.dataclass(frozen=True)
class SumoConfig:
    rank: int = 128
    update_freq: int = 200          # K
    beta: float = 0.95              # moment decay (paper uses convex combination)
    alpha: float = 1.0              # projection-back scale factor
    weight_decay: float = 0.0
    gamma: float = 1.1              # norm-growth limiter threshold
    orth_method: str = "polar"      # polar | svd | ns5
    ns_steps: int = 5
    rsvd_iters: int = 2
    rsvd_oversample: int = 4
    rms_scale: bool = True          # multiply update by 0.2·√max(m,n) (Moonlight)
    seed: int = 0
    # Alg. 1's alternative refresh criterion ("‖Ĝ‖ ≤ ς", the T_ℓ times of
    # Theorem 3.8): ALSO refresh when the current basis captures less than
    # `refresh_quality` of the gradient's energy, ‖QᵀG‖_F < ς·‖G‖_F.
    # 0.0 disables (pure every-K refresh).
    refresh_quality: float = 0.0


def _orth(cfg: SumoConfig, M: jnp.ndarray) -> jnp.ndarray:
    if cfg.orth_method == "polar":
        return orthogonalize_polar(M)
    if cfg.orth_method == "svd":
        return orthogonalize_svd(M)
    if cfg.orth_method == "ns5":
        return newton_schulz5(M, steps=cfg.ns_steps)
    raise ValueError(f"unknown orth_method {cfg.orth_method!r}")


def _leaf_rank(cfg: SumoConfig, shape) -> int:
    """Effective rank for one matrix: never above the short dim."""
    m, n = shape[-2], shape[-1]
    return max(1, min(cfg.rank, min(m, n)))


def _matrix_update(
    cfg: SumoConfig,
    G: jnp.ndarray,           # (m, n) fp32
    Q: jnp.ndarray,           # (long, r)
    M: jnp.ndarray,           # (r, short)
    prev_norm: jnp.ndarray,   # ()
    lr: jnp.ndarray,
    do_refresh: jnp.ndarray,  # bool
    key: jax.Array,
    W: Optional[jnp.ndarray],
):
    """One SUMO step for a single 2D matrix. Returns (delta, Q, M, prev_norm)."""
    m, n = G.shape
    transpose = m < n            # static
    Gl = G.T if transpose else G      # (long, short)
    r = Q.shape[1]

    # Alg. 1 alternative criterion: refresh when the stale basis captures too
    # little of the current gradient (‖QᵀG‖ < ς‖G‖).
    if cfg.refresh_quality > 0.0:
        g_norm = jnp.linalg.norm(Gl) + 1e-12
        cap = jnp.linalg.norm(Q.T @ Gl) / g_norm
        do_refresh = jnp.logical_or(do_refresh, cap < cfg.refresh_quality)

    # ---- Block 1 + 1.1: subspace refresh & moment rotation -------------
    def refresh(_):
        Q_new = randomized_range_finder(
            Gl, key, r, n_iter=cfg.rsvd_iters, oversample=cfg.rsvd_oversample
        )
        R = Q_new.T @ Q            # (r, r) rotation old->new basis
        return Q_new, R @ M

    def keep(_):
        return Q, M

    Q, M = jax.lax.cond(do_refresh, refresh, keep, operand=None)

    # ---- project ---------------------------------------------------------
    G_hat = Q.T @ Gl               # (r, short)

    # ---- Block 2: moment + exact orthogonalization ------------------------
    M = cfg.beta * M + (1.0 - cfg.beta) * G_hat
    O = _orth(cfg, M)              # (r, short), orthonormal rows

    # ---- Block 3: norm-growth limiter -------------------------------------
    o_norm = jnp.linalg.norm(O)
    first = prev_norm <= 0.0
    cap = jnp.where(first, o_norm, cfg.gamma * prev_norm)
    scale_lim = jnp.minimum(1.0, cap / (o_norm + 1e-12))
    O = O * scale_lim
    new_prev = o_norm * scale_lim

    # ---- Block 4: back-project to the original space -----------------------
    upd = Q @ O                    # (long, short)
    if transpose:
        upd = upd.T                # (m, n)
    scale = cfg.alpha
    if cfg.rms_scale:
        scale = scale * 0.2 * jnp.sqrt(float(max(m, n)))
    delta = -lr * scale * upd
    if cfg.weight_decay > 0.0 and W is not None:
        delta = delta - lr * cfg.weight_decay * W.astype(jnp.float32)
    return delta, Q, M, new_prev


def sumo(
    learning_rate: Union[float, Callable],
    config: SumoConfig = SumoConfig(),
) -> opt.Transform:
    """Build the SUMO transform for a tree of MATRIX params (ndim >= 2).

    Leaves that are None are passed through (used under multi_transform).
    """
    lr_fn = learning_rate if callable(learning_rate) else (lambda s: jnp.asarray(learning_rate))
    cfg = config

    def _leaf_init(leaf):
        if leaf is None:
            return None, None, None
        shape = leaf.shape
        m, n = shape[-2], shape[-1]
        long_d, short_d = (n, m) if m < n else (m, n)
        r = _leaf_rank(cfg, shape)
        batch = shape[:-2]
        Q = jnp.zeros(batch + (long_d, r), jnp.float32)
        M = jnp.zeros(batch + (r, short_d), jnp.float32)
        pn = jnp.zeros(batch, jnp.float32) if batch else jnp.zeros((), jnp.float32)
        return Q, M, pn

    def init(params) -> SumoState:
        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        triples = [_leaf_init(l) for l in leaves]
        unflat = lambda i: jax.tree_util.tree_unflatten(treedef, [t[i] for t in triples])
        Qs, Ms, pns = unflat(0), unflat(1), unflat(2)
        return SumoState(
            step=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(cfg.seed),
            Q=Qs,
            M=Ms,
            prev_norm=pns,
        )

    def update(grads, state: SumoState, params=None):
        lr = lr_fn(state.step).astype(jnp.float32)
        do_refresh = (state.step % cfg.update_freq) == 0

        leaves_g, treedef = jax.tree_util.tree_flatten(
            grads, is_leaf=lambda x: x is None
        )
        leaves_Q = treedef.flatten_up_to(state.Q)
        leaves_M = treedef.flatten_up_to(state.M)
        leaves_pn = treedef.flatten_up_to(state.prev_norm)
        leaves_p = (
            treedef.flatten_up_to(params) if params is not None else [None] * len(leaves_g)
        )

        keys = jax.random.split(state.key, len(leaves_g) + 1)
        new_key, leaf_keys = keys[0], keys[1:]

        out_u, out_Q, out_M, out_pn = [], [], [], []
        for g, Q, M, pn, p, k in zip(
            leaves_g, leaves_Q, leaves_M, leaves_pn, leaves_p, leaf_keys
        ):
            if g is None:
                out_u.append(None); out_Q.append(None)
                out_M.append(None); out_pn.append(None)
                continue
            g32 = g.astype(jnp.float32)
            if g.ndim == 2:
                d, Qn, Mn, pnn = _matrix_update(
                    cfg, g32, Q, M, pn, lr, do_refresh, k, p
                )
            else:
                # batched expert stacks (E, m, n) (or deeper): vmap over batch
                batch_shape = g.shape[:-2]
                gb = g32.reshape((-1,) + g.shape[-2:])
                Qb = Q.reshape((-1,) + Q.shape[-2:])
                Mb = M.reshape((-1,) + M.shape[-2:])
                pnb = pn.reshape(-1)
                pb = (
                    p.astype(jnp.float32).reshape((-1,) + p.shape[-2:])
                    if p is not None
                    else None
                )
                kb = jax.random.split(k, gb.shape[0])
                fn = jax.vmap(
                    lambda G_, Q_, M_, pn_, k_, W_: _matrix_update(
                        cfg, G_, Q_, M_, pn_, lr, do_refresh, k_, W_
                    ),
                    in_axes=(0, 0, 0, 0, 0, 0 if pb is not None else None),
                )
                d, Qn, Mn, pnn = fn(gb, Qb, Mb, pnb, kb, pb)
                d = d.reshape(g.shape)
                Qn = Qn.reshape(batch_shape + Qn.shape[-2:])
                Mn = Mn.reshape(batch_shape + Mn.shape[-2:])
                pnn = pnn.reshape(batch_shape)
            out_u.append(d)
            out_Q.append(Qn)
            out_M.append(Mn)
            out_pn.append(pnn)

        unflat = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        new_state = SumoState(
            step=state.step + 1,
            key=new_key,
            Q=unflat(out_Q),
            M=unflat(out_M),
            prev_norm=unflat(out_pn),
        )
        return unflat(out_u), new_state

    return opt.Transform(init, update)


def sumo_optimizer(
    learning_rate,
    params: PyTree,
    config: SumoConfig = SumoConfig(),
    fallback_lr: Optional[Union[float, Callable]] = None,
    fallback_b1: float = 0.9,
    fallback_b2: float = 0.999,
    fallback_weight_decay: float = 0.0,
) -> opt.Transform:
    """SUMO on matrix params + AdamW fallback on everything else."""
    from .adamw import adamw

    labels = opt.partition_params(params)
    return opt.multi_transform(
        {
            "matrix": sumo(learning_rate, config),
            "fallback": adamw(
                fallback_lr if fallback_lr is not None else learning_rate,
                b1=fallback_b1,
                b2=fallback_b2,
                weight_decay=fallback_weight_decay,
            ),
        },
        labels,
    )
