"""SUMO: Subspace-Aware Moment-Orthogonalization (paper Algorithm 1).

Per 2D weight W (m×n) the optimizer keeps
  * Q  — rank-r orthonormal basis of the gradient's long dimension, refreshed
         every K steps with truncated randomized SVD          (Block 1)
  * M  — the single first-order moment in the projected space (r × short_dim)
  * prev_norm — ‖O_{t-1}‖_F for the norm-growth limiter       (Block 3)

Update (Def. C.1):
  refresh (t ≡ 0 mod K):  Q_new = rSVD_r(G);  M ← (Q_newᵀ Q_old) M   (Block 1.1)
  Ĝ = Qᵀ G                                                    (project)
  M ← β M + (1-β) Ĝ                                           (moment)
  O = orth(M)            exact polar/SVD, or NS5 for ablation (Block 2)
  O ← limiter(O)         if ‖O‖/‖O_prev‖ > γ, rescale         (Block 3)
  W ← W − η·(α·scale)·Q O − η·λ·W                             (Block 4)

Shape convention: we always project the LONGER side, so the moment is
(r × min(m,n)) and the subspace basis is (max(m,n) × r). For m < n this is
the paper's "projection from the right" remark. 3D expert stacks (E, m, n)
are handled by vmapping the per-matrix rule over the leading axis.

Everything is jit-safe: the K-step refresh runs under ``jax.lax.cond`` so the
rSVD cost is paid only on refresh steps.

Bucketed update engine
----------------------
With ``SumoConfig.bucketed=True`` (the default) the update groups every
matrix leaf with the same CANONICAL trailing (long, short) shape — an (m, n)
leaf and its transpose partner (n, m) share a bucket — into one stacked
(B, long, short) bucket (2D leaves contribute one matrix, (E, m, n) expert
stacks contribute E), then runs ONE ``jax.vmap``-ed ``_matrix_update`` per
bucket and scatters the results back to the original tree. A 24-layer
transformer therefore compiles ~3 bucketed updates instead of ~100 per-leaf
ones, and each bucket pays a single ``lax.cond``/rSVD for its refresh instead
of one per leaf (the refresh predicate is shared, so vmap keeps the cond a
cond). The projection Ĝ = QᵀG and back-projection U = QO route through
``kernels.ops`` — Pallas kernels on TPU, plain-matmul reference on CPU,
overridable with ``SumoConfig.projection``. The adaptive ``refresh_quality``
criterion is evaluated at bucket granularity (refresh the whole bucket when
ANY member's basis has gone stale) to keep the single-cond property; per-leaf
granularity is available via ``bucketed=False``, which also serves as the
bit-exact reference implementation in tests.

Bucket-resident optimizer state
-------------------------------
``SumoConfig.state_layout`` picks where Q/M/prev_norm live:

* ``"bucket"`` (the default under ``bucketed=True``) — state is stored in
  bucket layout: one stacked array per bucket, keyed by the canonical
  ``"LONGxSHORT"`` string of ``build_bucket_plan`` (Q: (B, long, r),
  M: (B, r, short), prev_norm: (B,)). The per-step state
  concatenate/scatter round-trip of the per-leaf layout disappears — the
  bucket array IS the storage — and each bucket is one shardable tensor:
  shard B over ``data`` (layer/expert parallel) and Q's long dim over
  ``model`` (see ``parallel.sharding.opt_state_specs``).
* ``"leaf"`` — Q/M/prev_norm mirror the param tree (the pre-bucket layout);
  kept for per-leaf introspection and as the migration source/target.

The plan is a pure function of the (static) leaf shapes, so init, update,
checkpoint save and restore all agree without storing the plan anywhere.
``convert_sumo_state`` converts between the two layouts bit-exactly (pure
data movement), and ``train.checkpoint`` migrates on restore when a
checkpoint's layout differs from the restore template's. Both engines run
under either layout (the per-leaf engine unstacks/restacks at the
boundary), so all four combinations are bit-identical — the equivalence
harness in tests/test_sumo_state_layout.py pins this.

Sharded bucket update
---------------------
Passing a ``jax.sharding.Mesh`` to ``sumo(..., mesh=...)`` runs each bucket
update under ``shard_map``, sharding the stacked B axis over
``SumoConfig.bucket_axis`` (default ``"data"``). Projection, moment update,
orthogonalization and the rSVD refresh are all per-matrix, so the
steady-state update runs entirely shard-local — zero collectives; only the
delta scatter back to (replicated) params gathers. Ragged buckets
(B % axis_size != 0) are padded with masked zero slots so odd layer counts
shard too; only singleton (B == 1) buckets keep the single-device vmap path.

2D mesh: when the mesh ALSO has a ``SumoConfig.model_axis`` (default
``"model"``) of size > 1, EVERY bucket runs the 2D path — each matrix's
long dim is sharded over `model` on top of B over `data`, so buckets whose
MATRICES are themselves model-sharded (embed/lm_head/MoE experts at 22B+
scale) refresh without ever re-gathering the (long, short) gradient.
Ragged long dims (long % model != 0) EDGE-PAD: the stored Q carries
all-zero pad rows up to ``padded_long(long, model)`` (the smallest multiple
of the axis size), G/W pad transiently at stack time, and deltas slice back
to true rows before the all-gather scatter. Zero pad rows are exactly inert
through the Gram/psum pipeline (see core.rsvd's module docstring for the
op-by-op invariant), so padded buckets run the identical code as divisible
ones — no bucket ever falls back to replicated-long full-matrix residency.
Q enters and leaves as ``opt_state_specs`` places it,
``P(data, model, None)`` on the PADDED long dim; G/W enter with their
(padded) long dim sliced over `model`; M/prev_norm/O stay replicated over
`model` (r-width bytes — the point of the paper). The refresh calls the
distributed range finder (``core.rsvd`` with ``axis_name``: CholeskyQR2
Gram orthogonalization, all collectives r-width panels), the projection
Ĝ = QᵀG finishes with one r-width psum over `model`, the back-projection QO
is collective-free, and the only full-size transfer remains the explicit
delta all-gather (`model` rows first, then the B-axis gather). Singleton
(B == 1) buckets — exactly the embed/lm_head shapes that need model
sharding most — run the 2D path with B replicated. The `model=1` mesh
keeps the paths above bit-identically: CholeskyQR2 differs from thin QR in
the last ulp, so it only runs when the matrices are actually sharded; with
`model>1` the 2D path is pinned to the gathered reference by subspace
overlap ≥ 1-1e-5, ragged long dims included (tests/test_rsvd_sharded.py).
Checkpoints restore across mesh shapes: ``train.checkpoint`` re-pads /
slices the bucket Q stacks against the restore template's mesh (the
bucket key records the TRUE long dim, so the migration is self-describing).

Spectral telemetry
------------------
``SumoConfig.telemetry=True`` makes the bucketed engine emit one
``SpectralStats`` per bucket in ``SumoState.stats``: the moment spectrum
σ(M) (read off the factorization the orthogonalization already performs —
no extra SVDs), κ(MMᵀ), the energy-capture ratio ‖QᵀG‖_F/‖G‖_F, the
orthogonality residual ‖OOᵀ−I‖_F/√r, moment/update/grad norms, and whether
the refresh cond fired. Probes never feed back into the update, so the
trajectory is bit-identical probes-on vs probes-off. The host-side sink,
JSONL/CSV schema and the rank/refresh feedback controller that consumes
these stats live in ``repro.telemetry``; per-bucket rank/cadence decisions
come back in via ``SumoConfig.bucket_overrides`` — a static config field, so
shape changes happen only at controlled recompile points.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..kernels.ops import subspace_backproject, subspace_project
from . import optimizer as opt
from .orthogonalize import (
    gram_spectrum,
    newton_schulz5,
    orthogonalize_polar,
    orthogonalize_polar_with_spectrum,
    orthogonalize_svd,
    orthogonalize_svd_with_spectrum,
)
from .rsvd import randomized_range_finder

PyTree = opt.PyTree

STATE_LAYOUTS = ("auto", "leaf", "bucket")


class MatrixStats(NamedTuple):
    """Per-matrix spectral probe values, emitted by ``_matrix_update`` when
    ``SumoConfig.telemetry`` is on. All fields are jit-safe device scalars
    (``sigma`` is the (r,) moment spectrum); under the bucketed engine they
    are vmapped to (B, ...) stacks and reduced to one ``SpectralStats`` per
    bucket. No extra SVDs: the spectrum rides the factorization the
    orthogonalization already performs (see orthogonalize.py)."""

    sigma: jnp.ndarray           # (r,) σ(M) descending
    energy: jnp.ndarray          # () ‖QᵀG‖_F / ‖G‖_F — subspace energy capture
    ortho_residual: jnp.ndarray  # () ‖OOᵀ − I‖_F / √r of the pre-limiter O
    moment_norm: jnp.ndarray     # () ‖M‖_F (= √Σσ², post moment update)
    update_norm: jnp.ndarray     # () ‖Δ‖_F of the main term lr·scale·QO
                                 #    (weight decay excluded)
    grad_norm: jnp.ndarray       # () ‖G‖_F


class SpectralStats(NamedTuple):
    """Per-bucket reduction of ``MatrixStats`` — the unit the telemetry sink
    serializes and the rank/refresh controller consumes. Worst-case fields
    (energy, κ, orthogonality residual) use min/max over the bucket because
    the controller re-tunes the WHOLE bucket; magnitude fields use means."""

    sigma: jnp.ndarray           # (r,) bucket-mean moment spectrum, descending
    kappa: jnp.ndarray           # () max over bucket of κ(MMᵀ) = (σ_max/σ_min)²
    energy: jnp.ndarray          # () min over bucket of ‖QᵀG‖_F/‖G‖_F
    ortho_residual: jnp.ndarray  # () max over bucket
    moment_norm: jnp.ndarray     # () mean
    update_norm: jnp.ndarray     # () mean
    grad_norm: jnp.ndarray       # () mean
    refresh_fired: jnp.ndarray   # () int32 — 1 iff the bucket refreshed this step


class SumoState(NamedTuple):
    step: jnp.ndarray          # ()
    key: jax.Array             # rng for rSVD sketches
    Q: PyTree                  # bases: per-leaf (long, r) arrays, or per-bucket
                               # (B, long, r) stacks keyed "LONGxSHORT"
    M: PyTree                  # moments: (r, short) per leaf / (B, r, short) per bucket
    prev_norm: PyTree          # limiter memory: () per leaf / (B,) per bucket
    stats: PyTree = None       # telemetry: {"LONGxSHORT": SpectralStats} when
                               # SumoConfig.telemetry, else None


@dataclasses.dataclass(frozen=True)
class SumoConfig:
    rank: int = 128
    update_freq: int = 200          # K
    beta: float = 0.95              # moment decay (paper uses convex combination)
    alpha: float = 1.0              # projection-back scale factor
    weight_decay: float = 0.0
    gamma: float = 1.1              # norm-growth limiter threshold
    orth_method: str = "polar"      # polar | svd | ns5
    ns_steps: int = 5
    rsvd_iters: int = 2
    rsvd_oversample: int = 4
    rms_scale: bool = True          # multiply update by 0.2·√max(m,n) (Moonlight)
    seed: int = 0
    # Alg. 1's alternative refresh criterion ("‖Ĝ‖ ≤ ς", the T_ℓ times of
    # Theorem 3.8): ALSO refresh when the current basis captures less than
    # `refresh_quality` of the gradient's energy, ‖QᵀG‖_F < ς·‖G‖_F.
    # 0.0 disables (pure every-K refresh).
    refresh_quality: float = 0.0
    # Bucketed update engine: stack same-(long, short) leaves and run one
    # vmapped update (one refresh cond + rSVD) per bucket. False = per-leaf
    # reference.
    bucketed: bool = True
    # Where Q/M/prev_norm live: "bucket" stores them as per-bucket stacked
    # arrays (no per-step state stack/scatter; the shardable layout), "leaf"
    # mirrors the param tree. "auto" = "bucket" when bucketed else "leaf".
    state_layout: str = "auto"
    # Mesh axis the shard_map path shards the stacked bucket (B) axis over,
    # when a mesh is passed to sumo(..., mesh=...).
    bucket_axis: str = "data"
    # Mesh axis the shard_map path shards each matrix's LONG dim over (tensor
    # parallel). When the mesh has this axis with size > 1, EVERY bucket runs
    # the 2D path: Q/G row-sharded over `model`, the rSVD refresh via the
    # distributed range finder, projection finished with an r-width psum —
    # no (long, short) collective ever. Ragged long dims edge-pad with
    # all-zero (bit-inert) rows to the next axis multiple instead of falling
    # back to the replicated-long path (see ``padded_long``).
    model_axis: str = "model"
    # Projection/back-projection impl: "auto" (Pallas on TPU, reference
    # matmul elsewhere), "pallas" (force the kernel; interpret mode on CPU),
    # or "reference".
    projection: str = "auto"
    # Spectral telemetry probes (repro.telemetry): emit per-bucket
    # SpectralStats as a jit-safe aux output in SumoState.stats. Probes never
    # feed back into the update, so the trajectory is bit-identical with them
    # on or off. Requires the bucketed engine.
    telemetry: bool = False
    # Per-bucket (rank, update_freq[, refresh_quality]) overrides keyed by
    # the canonical "LONGxSHORT" bucket id — the knob the
    # RankRefreshController turns. 0 for any field means "keep the global
    # default"; legacy 3-tuples (no quality entry) are accepted. Static
    # (part of the frozen config), so changing overrides is a controlled
    # recompile point.
    bucket_overrides: tuple[tuple, ...] = ()

    def resolved_state_layout(self) -> str:
        if self.state_layout == "auto":
            return "bucket" if self.bucketed else "leaf"
        if self.state_layout not in ("leaf", "bucket"):
            raise ValueError(
                f"unknown state_layout {self.state_layout!r} (have {STATE_LAYOUTS})")
        return self.state_layout

    def _override(self, long_d: int, short_d: int) -> tuple[int, int, float]:
        key = opt.bucket_key(long_d, short_d)
        for entry in self.bucket_overrides:
            if entry[0] == key:
                k, r, f = entry[:3]
                q = float(entry[3]) if len(entry) > 3 else 0.0
                return r, f, q
        return 0, 0, 0.0

    def bucket_rank(self, long_d: int, short_d: int) -> int:
        """Effective subspace rank for a (long, short) bucket: the per-bucket
        override when set, else the global default, never above short."""
        r, _, _ = self._override(long_d, short_d)
        base = r if r > 0 else self.rank
        return max(1, min(base, short_d))

    def bucket_update_freq(self, long_d: int, short_d: int) -> int:
        """Refresh cadence K for a (long, short) bucket (override or global)."""
        _, f, _ = self._override(long_d, short_d)
        return f if f > 0 else self.update_freq

    def bucket_refresh_quality(self, long_d: int, short_d: int) -> float:
        """Adaptive-refresh energy threshold ς for a (long, short) bucket
        (override or global; 0.0 = pure every-K refresh). Both engines
        evaluate the criterion from this one accessor, so a controller-set
        per-bucket ς is honored bit-identically by either."""
        _, _, q = self._override(long_d, short_d)
        return q if q > 0.0 else self.refresh_quality


def _orth(cfg: SumoConfig, M: jnp.ndarray) -> jnp.ndarray:
    if cfg.orth_method == "polar":
        return orthogonalize_polar(M)
    if cfg.orth_method == "svd":
        return orthogonalize_svd(M)
    if cfg.orth_method == "ns5":
        return newton_schulz5(M, steps=cfg.ns_steps)
    raise ValueError(f"unknown orth_method {cfg.orth_method!r}")


def _orth_with_spectrum(cfg: SumoConfig, M: jnp.ndarray):
    """(orth(M), σ(M) descending) at zero extra large-matrix factorizations:
    polar reuses its own r×r Gram eigh, svd reads σ off the one SVD it
    already runs. NS5 materializes no spectrum, so it pays one r×r Gram
    eigh — the documented exception (still no SVD of the full moment)."""
    if cfg.orth_method == "polar":
        return orthogonalize_polar_with_spectrum(M)
    if cfg.orth_method == "svd":
        return orthogonalize_svd_with_spectrum(M)
    if cfg.orth_method == "ns5":
        return newton_schulz5(M, steps=cfg.ns_steps), gram_spectrum(M)
    raise ValueError(f"unknown orth_method {cfg.orth_method!r}")


def _leaf_rank(cfg: SumoConfig, shape) -> int:
    """Effective rank for one matrix leaf (override-aware, never above the
    short dim)."""
    return cfg.bucket_rank(*opt.canonical_dims(shape))


def _matrix_update(
    cfg: SumoConfig,
    G: jnp.ndarray,           # (m, n) fp32
    Q: jnp.ndarray,           # (long, r)
    M: jnp.ndarray,           # (r, short)
    prev_norm: jnp.ndarray,   # ()
    lr: jnp.ndarray,
    do_refresh: jnp.ndarray,  # bool
    key: jax.Array,
    W: Optional[jnp.ndarray],
    quality: float = 0.0,
    with_stats: bool = False,
    axis_name: Optional[str] = None,
    full_long: Optional[int] = None,
):
    """One SUMO step for a single 2D matrix. Returns (delta, Q, M, prev_norm),
    plus a ``MatrixStats`` as a fifth element when ``with_stats``.

    ``quality`` is the RESOLVED per-bucket adaptive-refresh threshold ς for
    the in-function criterion (the per-leaf engine passes its bucket's
    value); the bucketed engine passes 0.0 and instead evaluates the
    criterion once per bucket, folding it into ``do_refresh`` so the
    predicate stays unbatched under vmap.

    ``with_stats`` only ADDS probe outputs (norm ratios and the spectrum that
    the orthogonalization's own factorization already materializes) — every
    value on the update path is computed by the same ops in the same order,
    so the trajectory is bit-identical with probes on or off.

    ``axis_name``: the 2D-mesh path. G/Q/W are the local row blocks of
    matrices whose LONG dim is sharded over that mesh axis, already in the
    canonical long-first orientation (the caller transposes before slicing,
    so no orientation inference happens on a row count that is local).
    M / prev_norm / O are replicated over the axis — every shard runs the
    identical small-matrix arithmetic on identical operands, and only
    r-width panels cross shards: the psum finishing Ĝ = QᵀG, the psum
    finishing the basis rotation R = Q_newᵀQ_old, and the distributed range
    finder's panels (see core.rsvd). ``full_long`` must then carry the
    GLOBAL long dim for the rms scale factor.
    """
    m, n = G.shape
    if axis_name is None:
        transpose = m < n        # static
        Gl = G.T if transpose else G      # (long, short)
        long_d = max(m, n)
    else:
        transpose = False        # caller guarantees canonical orientation
        Gl = G                   # (long_loc, short)
        long_d = full_long
    r = Q.shape[1]

    def _gnorm(A):
        """Global ‖A‖_F of a row-sharded matrix (plain norm when unsharded)."""
        if axis_name is None:
            return jnp.linalg.norm(A)
        return jnp.sqrt(jax.lax.psum(jnp.sum(jnp.square(A)), axis_name))

    # Alg. 1 alternative criterion: refresh when the stale basis captures too
    # little of the current gradient (‖QᵀG‖ < ς‖G‖).
    if quality > 0.0:
        g_norm = _gnorm(Gl) + 1e-12
        # the psum inside subspace_project already REPLICATES Ĝ across the
        # axis, so its norm is global as-is (a _gnorm here would double-psum
        # and inflate the capture by √axis_size)
        cap = jnp.linalg.norm(
            subspace_project(Q, Gl, impl="reference", axis_name=axis_name)
        ) / g_norm
        do_refresh = jnp.logical_or(do_refresh, cap < quality)

    # ---- Block 1 + 1.1: subspace refresh & moment rotation -------------
    def refresh(_):
        Q_new = randomized_range_finder(
            Gl, key, r, n_iter=cfg.rsvd_iters, oversample=cfg.rsvd_oversample,
            axis_name=axis_name,
        )
        R = Q_new.T @ Q            # (r, r) rotation old->new basis
        if axis_name is not None:
            R = jax.lax.psum(R, axis_name)   # finish the sharded contraction
        return Q_new, R @ M

    def keep(_):
        return Q, M

    Q, M = jax.lax.cond(do_refresh, refresh, keep, operand=None)

    # ---- project ---------------------------------------------------------
    G_hat = subspace_project(Q, Gl, impl=cfg.projection,
                             axis_name=axis_name)          # (r, short)

    # ---- Block 2: moment + exact orthogonalization ------------------------
    M = cfg.beta * M + (1.0 - cfg.beta) * G_hat
    if with_stats:
        O, sigma = _orth_with_spectrum(cfg, M)   # (r, short) + (r,) σ(M)
    else:
        O = _orth(cfg, M)          # (r, short), orthonormal rows
    if with_stats:
        g_norm = _gnorm(Gl)
        stats_energy = jnp.linalg.norm(G_hat) / (g_norm + 1e-12)
        # ‖M‖_F² = Σσ² (trace identity) — free from the spectrum, no pass
        # over M.
        stats_mnorm = jnp.sqrt(jnp.sum(jnp.square(sigma)))
        # pre-limiter O: the residual measures the orthogonalizer, not the cap
        OOt = O @ jnp.swapaxes(O, -1, -2)
        stats_ortho = jnp.linalg.norm(
            OOt - jnp.eye(O.shape[0], dtype=O.dtype)
        ) / jnp.sqrt(float(O.shape[0]))

    # ---- Block 3: norm-growth limiter -------------------------------------
    o_norm = jnp.linalg.norm(O)
    first = prev_norm <= 0.0
    cap = jnp.where(first, o_norm, cfg.gamma * prev_norm)
    scale_lim = jnp.minimum(1.0, cap / (o_norm + 1e-12))
    O = O * scale_lim
    new_prev = o_norm * scale_lim

    # ---- Block 4: back-project to the original space -----------------------
    upd = subspace_backproject(Q, O, impl=cfg.projection)  # (long, short)
    if transpose:
        upd = upd.T                # (m, n)
    scale = cfg.alpha
    if cfg.rms_scale:
        # long_d is the GLOBAL long dim (full_long under axis_name — the
        # local row count would mis-scale sharded matrices).
        scale = scale * 0.2 * jnp.sqrt(float(long_d))
    delta = -lr * scale * upd
    if cfg.weight_decay > 0.0 and W is not None:
        delta = delta - lr * cfg.weight_decay * W.astype(jnp.float32)
    if with_stats:
        # ‖QO‖_F = ‖O‖_F (Q has orthonormal columns) and the limiter already
        # computed ‖O_limited‖ = new_prev, so the main-term update norm is
        # free — no pass over the (long, short) delta. Weight decay is
        # excluded by construction (it is a separate, exactly-known term).
        mstats = MatrixStats(
            sigma=sigma,
            energy=stats_energy,
            ortho_residual=stats_ortho,
            moment_norm=stats_mnorm,
            update_norm=lr * scale * new_prev,
            grad_norm=g_norm,
        )
        return delta, Q, M, new_prev, mstats
    return delta, Q, M, new_prev


def _per_leaf_updates(cfg, leaves_g, leaves_Q, leaves_M, leaves_pn, leaves_p,
                      leaf_keys, lr, step):
    """Reference engine: one ``_matrix_update`` (and refresh cond) per leaf.

    3D expert stacks vmap over their leading axis; everything else is a
    straight Python loop, so a model with L same-shaped layers compiles L
    separate conds/rSVDs. Kept as the bit-exact oracle for the bucketed
    engine and for per-leaf adaptive-refresh granularity. The refresh cadence
    is evaluated per leaf from its bucket's (possibly overridden)
    ``update_freq`` — identical to the bucketed engine's per-bucket predicate
    since the cadence is a pure function of the canonical shape.
    """
    out_u, out_Q, out_M, out_pn = [], [], [], []
    for g, Q, M, pn, p, k in zip(
        leaves_g, leaves_Q, leaves_M, leaves_pn, leaves_p, leaf_keys
    ):
        if g is None:
            out_u.append(None); out_Q.append(None)
            out_M.append(None); out_pn.append(None)
            continue
        freq = cfg.bucket_update_freq(*opt.canonical_dims(g.shape))
        quality = cfg.bucket_refresh_quality(*opt.canonical_dims(g.shape))
        do_refresh = (step % freq) == 0
        g32 = g.astype(jnp.float32)
        if g.ndim == 2:
            d, Qn, Mn, pnn = _matrix_update(
                cfg, g32, Q, M, pn, lr, do_refresh, k, p, quality=quality
            )
        else:
            # batched expert stacks (E, m, n) (or deeper): vmap over batch
            batch_shape = g.shape[:-2]
            gb = g32.reshape((-1,) + g.shape[-2:])
            Qb = Q.reshape((-1,) + Q.shape[-2:])
            Mb = M.reshape((-1,) + M.shape[-2:])
            pnb = pn.reshape(-1)
            pb = (
                p.astype(jnp.float32).reshape((-1,) + p.shape[-2:])
                if p is not None
                else None
            )
            kb = jax.random.split(k, gb.shape[0])
            fn = jax.vmap(
                lambda G_, Q_, M_, pn_, k_, W_: _matrix_update(
                    cfg, G_, Q_, M_, pn_, lr, do_refresh, k_, W_,
                    quality=quality,
                ),
                in_axes=(0, 0, 0, 0, 0, 0 if pb is not None else None),
            )
            d, Qn, Mn, pnn = fn(gb, Qb, Mb, pnb, kb, pb)
            d = d.reshape(g.shape)
            Qn = Qn.reshape(batch_shape + Qn.shape[-2:])
            Mn = Mn.reshape(batch_shape + Mn.shape[-2:])
            pnn = pnn.reshape(batch_shape)
        out_u.append(d)
        out_Q.append(Qn)
        out_M.append(Mn)
        out_pn.append(pnn)
    return out_u, out_Q, out_M, out_pn


# ---------------------------------------------------------------------------
# State layout: per-leaf trees <-> per-bucket stacked arrays
# ---------------------------------------------------------------------------

def _leaf_state_shapes(cfg: SumoConfig, g_shape):
    """Leaf-layout (Q, M, prev_norm) shapes for one matrix leaf."""
    m, n = g_shape[-2], g_shape[-1]
    long_d, short_d = (n, m) if m < n else (m, n)
    r = _leaf_rank(cfg, g_shape)
    batch = tuple(g_shape[:-2])
    return batch + (long_d, r), batch + (r, short_d), batch


def _stack_leaf_state(plan, leaves_Q, leaves_M, leaves_pn):
    """Per-leaf state lists -> per-bucket stacked dicts (pure data movement).

    Q/M/prev_norm are orientation-free (always long-first), so no transposes
    are needed — only reshapes of the leading expert dims and concatenation
    in plan order.
    """
    Qd, Md, pnd = {}, {}, {}
    for b in plan:
        Qd[b.key] = jnp.concatenate(
            [leaves_Q[i].reshape((-1,) + leaves_Q[i].shape[-2:])
             for i in b.leaf_indices], axis=0)
        Md[b.key] = jnp.concatenate(
            [leaves_M[i].reshape((-1,) + leaves_M[i].shape[-2:])
             for i in b.leaf_indices], axis=0)
        pnd[b.key] = jnp.concatenate(
            [leaves_pn[i].reshape(-1) for i in b.leaf_indices], axis=0)
    return Qd, Md, pnd


def _check_bucket_slots(Qd, bucket):
    """The static-mask contract: the plan derived from the current tree must
    agree with the stored bucket stacks. A drift that changes a bucket's slot
    count fails here; one that merely permutes same-shaped leaves is
    undetectable without storing the plan (slots are positional) and stays
    the caller's responsibility."""
    if bucket.key not in Qd or Qd[bucket.key].shape[0] != bucket.size:
        have = (Qd[bucket.key].shape[0] if bucket.key in Qd else "no")
        raise ValueError(
            f"bucket {bucket.key}: state has {have} slots but the tree "
            f"contributes {bucket.size} — the None mask must match the tree "
            "the state was initialised from (state is keyed by the static "
            "bucket plan)"
        )


def _unstack_bucket_state(cfg, plan, leaf_shapes, Qd, Md, pnd):
    """Per-bucket stacked dicts -> per-leaf state lists (inverse of stack).

    Bucket Q stacks may carry the 2D mesh's edge-padded long dim (all-zero
    pad rows — see ``padded_long``); per-leaf state is always TRUE-shaped,
    so the pad rows are sliced off here."""
    n_leaves = len(leaf_shapes)
    lQ = [None] * n_leaves
    lM = [None] * n_leaves
    lpn = [None] * n_leaves
    for b in plan:
        _check_bucket_slots(Qd, b)
        Qb, Mb, pnb = Qd[b.key], Md[b.key], pnd[b.key]
        if Qb.shape[-2] > b.shape[0]:          # padded long -> true long
            Qb = Qb[:, : b.shape[0], :]
        off = 0
        for i, cnt in zip(b.leaf_indices, b.counts):
            sl = slice(off, off + cnt)
            off += cnt
            q_shape, m_shape, batch = _leaf_state_shapes(cfg, leaf_shapes[i])
            lQ[i] = Qb[sl].reshape(q_shape)
            lM[i] = Mb[sl].reshape(m_shape)
            lpn[i] = pnb[sl].reshape(batch)
    return lQ, lM, lpn


def sumo_state_layout(state: SumoState) -> str:
    """Detect a state's layout: 'bucket' iff Q is a dict of 'LONGxSHORT'
    stacked arrays (the ``build_bucket_plan`` keying), else 'leaf'."""
    if isinstance(state.Q, dict) and all(
        isinstance(k, str) and opt.BUCKET_KEY_RE.match(k) for k in state.Q
    ):
        return "bucket"
    return "leaf"


def bucket_spectral_stats(state) -> dict:
    """Telemetry claim hook for the precision lint: the per-bucket
    ``SpectralStats`` out of any optimizer state holding a SumoState
    (directly, or nested inside a chain/tuple), as a plain
    ``{"LONGxSHORT": SpectralStats}`` dict host-side.
    ``repro.analysis.precision.audit_ortho_bound`` checks each bucket's
    measured ortho residual against the paper's kappa-dependent bound.
    Returns {} when telemetry is off or no SumoState is present."""
    if isinstance(state, SumoState):
        return dict(state.stats) if isinstance(state.stats, dict) else {}
    if isinstance(state, (tuple, list)):
        for s in state:
            found = bucket_spectral_stats(s)
            if found:
                return found
    return {}


def convert_sumo_state(
    state: SumoState, params: PyTree, cfg: SumoConfig, target: str,
    long_pad_to: Optional[int] = None,
) -> SumoState:
    """Convert SUMO state between 'leaf' and 'bucket' layouts, bit-exactly.

    ``params`` (the masked matrix-param tree the state was initialised from —
    None leaves stay None) supplies the static leaf shapes/treedef the plan
    is derived from; no plan is ever stored in the state itself.

    ``long_pad_to``: the target mesh's model-axis size when converting TO
    the bucket layout of a 2D mesh — each bucket's Q stack comes back with
    its long dim edge-padded to exactly ``padded_long(long, long_pad_to)``
    (re-padding or slicing another mesh's zero pad rows as needed, both
    lossless; 1 = the unpadded single-device/model=1 layout). The default
    ``None`` leaves bucket padding untouched — a bucket → bucket conversion
    is then the identity. The bucket → leaf direction always slices pad
    rows off (per-leaf state is true-shaped), whatever this is set to.
    """
    if target not in ("leaf", "bucket"):
        raise ValueError(f"unknown target layout {target!r}")
    if sumo_state_layout(state) == target:
        if target == "bucket" and long_pad_to is not None:
            leaves, _ = jax.tree_util.tree_flatten(
                params, is_leaf=lambda x: x is None)
            plan = opt.build_bucket_plan(
                [None if l is None else l.shape for l in leaves])
            return state._replace(Q=_pad_bucket_q(state.Q, plan, long_pad_to))
        return state
    leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
    shapes = [None if l is None else l.shape for l in leaves]
    plan = opt.build_bucket_plan(shapes)
    if target == "bucket":
        Qd, Md, pnd = _stack_leaf_state(
            plan,
            treedef.flatten_up_to(state.Q),
            treedef.flatten_up_to(state.M),
            treedef.flatten_up_to(state.prev_norm),
        )
        return state._replace(
            Q=_pad_bucket_q(Qd, plan, long_pad_to or 1),
            M=Md, prev_norm=pnd)
    lQ, lM, lpn = _unstack_bucket_state(cfg, plan, shapes, state.Q, state.M,
                                        state.prev_norm)
    unflat = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
    return state._replace(Q=unflat(lQ), M=unflat(lM), prev_norm=unflat(lpn))


def sumo_dp_bases(state: SumoState, params_masked: PyTree) -> PyTree:
    """Per-leaf bases for DP-gradient compression reuse
    (``parallel.compression`` with ``use_sketch=False``).

    ``params_masked`` is the matrix-param tree the state was initialised
    from (the ``multi_transform`` "matrix" mask — None leaves stay None, and
    come back None here: the exchange falls back to the seeded sketch for
    them). Returns a matching tree whose leaves are the CURRENT Q in the
    canonical long-first orientation — ``batch + (long, r)`` float32, TRUE
    long rows (a 2D mesh's edge-pad rows are sliced off, they are zero by
    the engine's invariant and would only waste wire) — ready to pass as
    ``bases=`` to the compression path. cfg-free: every shape is read off
    the resident stacks themselves, so controller rank resizes are picked
    up automatically at the next extraction.

    Intentionally a separate tiny program from the train step: the loop
    jits and runs it once per refresh boundary and replicates the result
    (the advertised one broadcast per refresh) — extracting inside the
    step would re-gather the data-sharded bucket stacks EVERY step, which
    is exactly what ``steady_dp_compressed_budget`` forbids."""
    leaves, treedef = jax.tree_util.tree_flatten(
        params_masked, is_leaf=lambda x: x is None)
    shapes = [None if l is None else l.shape for l in leaves]
    if sumo_state_layout(state) != "bucket":
        return state.Q
    plan = opt.build_bucket_plan(shapes)
    lQ = [None] * len(leaves)
    for b in plan:
        _check_bucket_slots(state.Q, b)
        Qb = state.Q[b.key]
        true_long = b.shape[0]
        if Qb.shape[-2] > true_long:       # 2D-mesh edge pads -> true rows
            Qb = Qb[:, :true_long, :]
        r = int(Qb.shape[-1])
        off = 0
        for i, cnt in zip(b.leaf_indices, b.counts):
            batch = tuple(int(d) for d in shapes[i][:-2])
            lQ[i] = Qb[off:off + cnt].reshape(batch + (true_long, r))
            off += cnt
    return jax.tree_util.tree_unflatten(treedef, lQ)


# ---------------------------------------------------------------------------
# Bucketed engine
# ---------------------------------------------------------------------------

def _bucket_update_fn(cfg: SumoConfig, with_w: bool, with_stats: bool = False,
                      axis_name: Optional[str] = None,
                      full_long: Optional[int] = None):
    """The per-bucket batched update: vmap of ``_matrix_update`` over the
    stacked B axis with an UNBATCHED refresh predicate (one cond/rSVD per
    bucket). lr/do_refresh are explicit args so the same function body can be
    wrapped in ``shard_map`` without closing over traced values. With
    ``with_stats`` the vmapped update additionally returns a (B, ...)-stacked
    ``MatrixStats``. ``axis_name``/``full_long`` select the 2D-mesh
    per-matrix path (long dim sharded over ``axis_name`` — the collectives
    inside vmap batch over B, so the whole bucket's panels move in one psum
    per collective, not one per member)."""

    def run(lr, do_refresh, G, Q, M, pn, K, W):
        f = jax.vmap(
            lambda G_, Q_, M_, pn_, k_, W_: _matrix_update(
                cfg, G_, Q_, M_, pn_, lr, do_refresh, k_, W_,
                quality=0.0, with_stats=with_stats,
                axis_name=axis_name, full_long=full_long,
            ),
            in_axes=(0, 0, 0, 0, 0, 0 if with_w else None),
        )
        return f(G, Q, M, pn, K, W)

    if with_w:
        return run
    return lambda lr, do_refresh, G, Q, M, pn, K: run(
        lr, do_refresh, G, Q, M, pn, K, None)


def _reduce_bucket_stats(ms: MatrixStats, fired) -> SpectralStats:
    """(B, ...)-stacked per-matrix probes -> one per-bucket SpectralStats.

    κ is the EFFECTIVE condition number: an over-ranked moment (trailing
    σ ≈ 0 — the controller's SHRINK signal, visible in the tail mass) must
    not masquerade as the ill-conditioned regime (its TIGHTEN-refresh
    signal). Numerically-dead directions are cut at a spectral CLIFF — the
    first ≥100× drop between consecutive σ that lands below 1e-3·σ_max —
    rather than at a fixed magnitude: the spectrally-truncated rSVD basis
    tracks zero-mass directions at the fp32 moment noise floor (~1e-4·σ_max,
    rotation/projection roundoff accumulated across refreshes), while a
    genuinely ill-conditioned but LIVE spectrum decays geometrically with no
    cliff, so magnitude alone cannot separate the two."""
    sig = ms.sigma                        # (B, r) descending
    s0 = sig[:, :1]                       # (B, 1)
    cliff = (sig[:, :-1] > 100.0 * sig[:, 1:]) & (
        sig[:, 1:] < 1e-3 * s0)           # (B, r-1) drop into dead territory
    dead = jnp.cumsum(
        jnp.pad(cliff, ((0, 0), (1, 0))), axis=1) > 0   # dead from 1st cliff
    s_eff_min = jnp.min(jnp.where(dead, s0, sig), axis=1)
    kappa = jnp.max(jnp.square(sig[:, 0] / jnp.maximum(s_eff_min, 1e-30)))
    return SpectralStats(
        sigma=jnp.mean(sig, axis=0),
        kappa=kappa,
        energy=jnp.min(ms.energy),
        ortho_residual=jnp.max(ms.ortho_residual),
        moment_norm=jnp.mean(ms.moment_norm),
        update_norm=jnp.mean(ms.update_norm),
        grad_norm=jnp.mean(ms.grad_norm),
        refresh_fired=jnp.asarray(fired).astype(jnp.int32),
    )


def _pad_rows(a: jnp.ndarray, pad: int) -> jnp.ndarray:
    """Append `pad` zero slots along the stacked B axis."""
    return jnp.concatenate(
        [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0)


def padded_long(long_d: int, m_shards: int) -> int:
    """Edge-padded long dim: the smallest multiple of ``m_shards`` ≥
    ``long_d``. This is the stored/working row count of every long-dim array
    (Q, and transiently G/W/delta) on a mesh whose model axis has
    ``m_shards`` devices — ragged long dims (long % model != 0) shard by
    carrying all-zero pad rows at the END of the long dim (so the pads land
    contiguously on the last model shard). Identity when ``m_shards`` ≤ 1
    or the long dim already divides."""
    if m_shards <= 1:
        return long_d
    return -(-long_d // m_shards) * m_shards


def _model_shards(cfg: SumoConfig, mesh) -> int:
    """Size of the mesh's model axis as the bucket update sees it (1 when
    there is no mesh / no such axis — the no-padding 1D regime)."""
    if isinstance(mesh, Mesh) and cfg.model_axis in mesh.shape:
        return int(mesh.shape[cfg.model_axis])
    return 1


def _pad_long_rows(a: jnp.ndarray, pad: int) -> jnp.ndarray:
    """Append `pad` zero rows along the long (second-to-last) dim.

    jnp.pad (HLO Pad), NOT concatenate: when the result's long dim is
    sharded at the shard_map boundary, GSPMD partitions a Pad locally
    (iota/select against the scalar pad value) while a concatenate whose
    seam crosses a shard boundary lowers to dynamic-update-slice + a
    full-size all-reduce — exactly the (B, long, short) collective the 2D
    path promises never to move."""
    if pad <= 0:
        return a
    return jnp.pad(a, [(0, 0)] * (a.ndim - 2) + [(0, pad), (0, 0)])


def _normalize_long_rows(a: jnp.ndarray, true_long: int,
                         long_pad: int) -> jnp.ndarray:
    """Re-pad a long-dim array to exactly ``long_pad`` rows: rows beyond
    ``true_long`` (another mesh's zero pads — zeros by the engine
    invariant) are sliced off first, then zero rows are appended. Both
    directions are lossless; no-op when already at ``long_pad``."""
    if a.shape[-2] > true_long and a.shape[-2] != long_pad:
        a = a[..., :true_long, :]
    if long_pad > a.shape[-2]:
        a = _pad_long_rows(a, long_pad - a.shape[-2])
    return a


def _pad_bucket_q(Qd: dict, plan, m_shards: int) -> dict:
    """Normalize every bucket's Q stack to the mesh's edge-padded long dim:
    zero pad rows appended when the stack is narrower, and rows beyond the
    TRUE long dim sliced off first when the stack was padded for a LARGER
    model axis (those rows are zeros by the engine invariant, so both
    directions are lossless). Keeps bucket-layout state shapes
    mesh-consistent whichever engine — or previous mesh — produced them."""
    out = dict(Qd)
    for b in plan:
        if b.key in out:
            out[b.key] = _normalize_long_rows(
                out[b.key], b.shape[0], padded_long(b.shape[0], m_shards))
    return out


def _bucketed_updates(cfg, mesh, plan, leaves_g, Qd, Md, pnd, leaves_p,
                      leaf_keys, lr, step):
    """Bucketed engine over BUCKET-LAYOUT state: one vmapped
    ``_matrix_update`` per canonical (long, short) bucket.

    Gradients are stacked into the canonical long-first orientation (members
    with m < n transpose in; their deltas and decay params transpose with
    them — transposition commutes bit-exactly with every element-wise op in
    the update). State arrives and leaves as the per-bucket stacked dicts, so
    in bucket-resident mode there is NO per-step state copy at all. Per-matrix
    rSVD keys match the per-leaf engine exactly (same per-leaf key, same
    per-expert split), which is what makes all engine/layout combinations
    bit-comparable. The refresh cadence is evaluated per bucket from
    ``cfg.bucket_update_freq`` (the controller's per-bucket override knob).

    When ``mesh`` is given and ``mesh.shape[cfg.bucket_axis]`` > 1, every
    bucket with more than one matrix runs under ``shard_map`` with B sharded
    over that axis — ragged buckets (B % axis_size != 0) are padded with
    zero slots that are masked out of the adaptive-refresh predicate and
    sliced off the outputs, so odd layer counts shard too. Every block of the
    update (projection, moment, orthogonalization, rSVD refresh) is
    per-matrix, so the sharded update is collective-free in steady state.
    Singleton (B == 1) buckets keep the single-device vmap path — padding
    them buys no parallelism.

    Returns (out_updates, Qd, Md, pnd, stats) where ``stats`` is the
    per-bucket SpectralStats dict when ``cfg.telemetry`` else None.
    """
    n_leaves = len(leaves_g)
    out_u = [None] * n_leaves
    new_Qd, new_Md, new_pnd = {}, {}, {}
    tel = cfg.telemetry
    stats_d = {} if tel else None

    for bucket in plan:
        long_d, short_d = bucket.shape
        freq = cfg.bucket_update_freq(long_d, short_d)
        do_refresh = (step % freq) == 0
        # W only feeds the decoupled weight-decay term: skip the stacking
        # traffic entirely when decay is off or no member has a param. In a
        # mixed bucket, members without a param get zeros — a zero decay
        # term, matching the per-leaf engine's "no W, no decay" semantics.
        # W transposes into canonical orientation alongside G, so decay stays
        # bit-identical for m < n members sharing a bucket with their
        # transpose partners.
        stack_w = cfg.weight_decay > 0.0 and any(
            leaves_p[i] is not None for i in bucket.leaf_indices
        )
        Gs, Ws, Ks = [], [], []
        for i, cnt, tr in zip(bucket.leaf_indices, bucket.counts,
                              bucket.transposed):
            g = leaves_g[i]
            g32 = g.astype(jnp.float32).reshape((-1,) + g.shape[-2:])
            Gs.append(jnp.swapaxes(g32, -1, -2) if tr else g32)
            if stack_w:
                if leaves_p[i] is None:
                    Ws.append(jnp.zeros((cnt, long_d, short_d), jnp.float32))
                else:
                    w32 = leaves_p[i].astype(jnp.float32).reshape(
                        (-1,) + leaves_p[i].shape[-2:])
                    Ws.append(jnp.swapaxes(w32, -1, -2) if tr else w32)
            k = leaf_keys[i]
            Ks.append(k[None] if g.ndim == 2 else jax.random.split(k, cnt))
        G = jnp.concatenate(Gs, axis=0)          # (B, long, short)
        K = jnp.concatenate(Ks, axis=0)          # (B, key)
        W = jnp.concatenate(Ws, axis=0) if stack_w else None
        _check_bucket_slots(Qd, bucket)
        Q, M, pn = Qd[bucket.key], Md[bucket.key], pnd[bucket.key]

        fn = _bucket_update_fn(cfg, with_w=stack_w, with_stats=tel)
        axis = cfg.bucket_axis
        maxis = cfg.model_axis
        n_shards = (
            mesh.shape[axis]
            if isinstance(mesh, Mesh) and axis in mesh.shape else 1
        )
        m_shards = _model_shards(cfg, mesh)
        # 2D path: long dim over `model` (+ B over `data` when it pays) for
        # EVERY bucket — ragged long dims (long % model != 0) edge-pad with
        # all-zero rows up to ``padded_long`` so no bucket ever falls back to
        # the replicated-long 1D path on a model>1 mesh (the GaLore-style
        # full-matrix residency the memory claims argue against). Zero pad
        # rows are inert through the whole pipeline (core.rsvd module
        # docstring proves the invariant op by op), so padded and divisible
        # buckets run the same code. A model axis of size 1 (or no mesh)
        # keeps the 1D paths below bit-identically (the 2D body's
        # CholeskyQR2 refresh differs from thin QR in the last ulp, so it
        # only runs when the matrices are actually sharded).
        use_model = m_shards > 1
        q_thresh = cfg.bucket_refresh_quality(long_d, short_d)
        b_true = bucket.size
        ms = dr_out = None
        if use_model:
            # 2D-mesh sharded bucket update. Data-movement discipline: the
            # state enters exactly as ``opt_state_specs`` places it — Q
            # P(data, model, None) (B over `data`, long over `model`),
            # M/prev_norm P(data, None, None)/P(data) — and never moves; the
            # stacked G/W enter with their long dim sharded over `model`
            # (a local slice of the replicated grads, no collective) and
            # each data shard slices its own B-block by axis index. Every
            # cross-shard transfer is an r-width panel (projection psum,
            # rotation psum, the distributed range finder's Gram/panel
            # psums) except the one explicit delta all-gather (model axis
            # first — rows back to full — then the existing B-axis gather).
            # Singleton buckets (B == 1: embed/lm_head-shaped — the very
            # matrices that NEED model sharding) run with B replicated and
            # only the long dim sharded.
            #
            # Ragged long dims: G/W edge-pad with zero rows to ``long_pad``
            # (HLO Pad of the replicated stacks — no collective); the stored
            # Q is already padded (init/checkpoint restore/leaf restack all
            # agree on ``padded_long``). The authoritative pad-row mask
            # lives INSIDE body2d (shard-local jnp.where): it pins the pad
            # rows of G/Q/W to exact zeros at the point the Gram/psum
            # pipeline consumes them, which both defends the inertness
            # invariant against hand-built state AND against the fused-step
            # partitioner leaving unspecified values in the pad rows at the
            # shard_map boundary. ``full_long`` stays the TRUE long dim —
            # the rms scale and every stat must never see pad rows.
            long_pad = padded_long(long_d, m_shards)
            lpad = long_pad - long_d
            if lpad:
                G = _pad_long_rows(G, lpad)
                if stack_w:
                    W = _pad_long_rows(W, lpad)
            # leaf-layout restack delivers true-long stacks; a state migrated
            # in-process from a larger model axis arrives over-padded (zero
            # rows beyond the true long dim). No-op for the stored layout.
            Q = _normalize_long_rows(Q, long_d, long_pad)
            b_shard = n_shards > 1 and bucket.size > 1
            pad = (-bucket.size) % n_shards if b_shard else 0
            b_padded = bucket.size + pad
            if pad:
                G = _pad_rows(G, pad)
                K = _pad_rows(K, pad)
                Q = _pad_rows(Q, pad)
                M = _pad_rows(M, pad)
                pn = _pad_rows(pn, pad)
                if stack_w:
                    W = _pad_rows(W, pad)
            blk = b_padded // n_shards if b_shard else b_padded
            fn = _bucket_update_fn(cfg, with_w=stack_w, with_stats=tel,
                                   axis_name=maxis, full_long=long_d)

            # NOTE: body2d mirrors the 1D `body` below (B slicing, masked
            # staleness predicate, delta/stat gathers) plus the model-axis
            # psums/gather. They are kept separate because the 1D body is
            # pinned BIT-identical to the pre-2D engine — fold fixes to the
            # shared logic into both.
            def body2d(lr_, dr_, G_, Q_, M_, pn_, K_, *W_):
                if lpad:
                    # Shard-local pad-row mask on everything that feeds the
                    # Gram/psum pipeline. The global pads above are exact
                    # zeros SEMANTICALLY, but inside a fused train step the
                    # partitioner routes internally-padded layouts of the
                    # cotangents through the pad/stack assembly, and the
                    # values that land in the pad rows at this boundary are
                    # then unspecified — jnp.where (not multiply: 0·NaN =
                    # NaN) pins them to zero where the inertness invariant
                    # needs them. Only the LAST model shard holds pad rows;
                    # for well-formed inputs this is an exact identity.
                    rows_loc = G_.shape[-2]
                    g0 = jax.lax.axis_index(maxis) * rows_loc
                    live = ((g0 + jnp.arange(rows_loc)) < long_d)[None, :, None]
                    G_ = jnp.where(live, G_, 0.0)
                    Q_ = jnp.where(live, Q_, 0.0)
                    W_ = tuple(jnp.where(live, w, 0.0) for w in W_)
                if b_shard:
                    i0 = jax.lax.axis_index(axis) * blk
                    G_loc = jax.lax.dynamic_slice_in_dim(G_, i0, blk, axis=0)
                    K_loc = jax.lax.dynamic_slice_in_dim(K_, i0, blk, axis=0)
                    W_loc = tuple(
                        jax.lax.dynamic_slice_in_dim(w, i0, blk, axis=0)
                        for w in W_
                    )
                else:
                    i0 = 0
                    G_loc, K_loc, W_loc = G_, K_, W_
                if q_thresh > 0.0:
                    # bucket-wide staleness: the energy capture needs global
                    # norms — two r-width/scalar psums over `model`, then the
                    # scalar pmax over `data` (the documented exceptions).
                    g_sq = jax.lax.psum(
                        jnp.sum(jnp.square(G_loc), axis=(-2, -1)), maxis)
                    proj = jax.lax.psum(
                        jnp.matmul(jnp.swapaxes(Q_, -1, -2), G_loc), maxis)
                    caps = jnp.linalg.norm(proj, axis=(-2, -1)) / (
                        jnp.sqrt(g_sq) + 1e-12)
                    stale_mask = caps < q_thresh
                    if pad:
                        stale_mask = stale_mask & (
                            (i0 + jnp.arange(blk)) < b_true)
                    stale = jnp.any(stale_mask).astype(jnp.int32)
                    if b_shard:
                        stale = jax.lax.pmax(stale, axis)
                    dr_ = jnp.logical_or(dr_, stale > 0)
                out = fn(lr_, dr_, G_loc, Q_, M_, pn_, K_loc, *W_loc)
                d_loc, Qn, Mn, pnn = out[:4]
                d_full = jax.lax.all_gather(d_loc, maxis, axis=1, tiled=True)
                if b_shard:
                    d_full = jax.lax.all_gather(d_full, axis, axis=0,
                                                tiled=True)
                if tel:
                    # Stats ride out replicated (out_specs P()) — valid under
                    # long-dim padding because every long-reduced ingredient
                    # is a `model`-psum over rows in which the pad rows
                    # contribute EXACTLY zero (zero G rows, zero Q rows):
                    # energy capture ‖QᵀG‖/‖G‖, grad/update norms and the
                    # refresh predicate all reduce the same padded operands
                    # the update itself consumes, and full_long (not the
                    # padded row count) feeds the rms scale — so pad rows can
                    # never dilute a stat. σ/κ/ortho-residual live in the
                    # r×short space pads never enter. Pinned against the 1D
                    # engine's probes on a ragged-long bucket in
                    # tests/test_rsvd_sharded.py.
                    ms_full = out[4]
                    if b_shard:
                        ms_full = jax.tree_util.tree_map(
                            lambda a: jax.lax.all_gather(
                                a, axis, axis=0, tiled=True), ms_full)
                    return d_full, Qn, Mn, pnn, ms_full, dr_
                return d_full, Qn, Mn, pnn

            bax = axis if b_shard else None
            gspec = P(None, maxis, None)
            in_specs = (P(), P(), gspec, P(bax, maxis, None),
                        P(bax, None, None), P(bax), P(None, None))
            if stack_w:
                in_specs = in_specs + (gspec,)
            out_specs = (P(None, None, None), P(bax, maxis, None),
                         P(bax, None, None), P(bax))
            if tel:
                out_specs = out_specs + (MatrixStats(*([P()] * 6)), P())
            call = shard_map(
                body2d, mesh=mesh, in_specs=in_specs,
                out_specs=out_specs, check_rep=False,
            )
            args = (lr, do_refresh, G, Q, M, pn, K) + ((W,) if stack_w else ())
            out = call(*args)
            d, Qn, Mn, pnn = out[:4]
            if tel:
                ms, dr_out = out[4], out[5]
            if pad:
                d, Qn, Mn, pnn = (a[:b_true] for a in (d, Qn, Mn, pnn))
                if tel:
                    ms = jax.tree_util.tree_map(lambda a: a[:b_true], ms)
            if lpad:
                # deltas slice back to TRUE rows before the scatter to the
                # (true-shaped) params; Qn keeps the padded long dim — the
                # stored bucket-resident layout on this mesh.
                d = d[:, :long_d]
        elif n_shards > 1 and bucket.size > 1:
            # Sharded bucket update. Data-movement discipline: the stacked
            # G/W/keys enter REPLICATED (they are assembled locally from the
            # replicated grads — no resharding collective at the shard_map
            # boundary) and each shard slices its own B-block by axis index;
            # the state stacks enter and leave SHARDED over B and never move;
            # the only steady-state collective is ONE explicit all_gather of
            # the delta stack (the updates must reach the replicated params).
            # With refresh_quality > 0 the bucket-wide staleness OR adds a
            # scalar pmax per bucket — the documented exception; telemetry
            # adds one tiny all_gather of the per-matrix stat scalars.
            # Ragged buckets are padded with zero slots up to the axis size:
            # a zero gradient + zero state produces a zero delta (the polar
            # rank guard zeroes O), pad slots are masked out of the staleness
            # predicate, and outputs are sliced back to the true size.
            pad = (-bucket.size) % n_shards
            b_padded = bucket.size + pad
            if pad:
                G = _pad_rows(G, pad)
                K = _pad_rows(K, pad)
                Q = _pad_rows(Q, pad)
                M = _pad_rows(M, pad)
                pn = _pad_rows(pn, pad)
                if stack_w:
                    W = _pad_rows(W, pad)
            blk = b_padded // n_shards

            # NOTE: twin of body2d above (which adds the model-axis
            # collectives) — fold fixes to the shared logic into both.
            def body(lr_, dr_, G_, Q_, M_, pn_, K_, *W_):
                i0 = jax.lax.axis_index(axis) * blk
                G_loc = jax.lax.dynamic_slice_in_dim(G_, i0, blk, axis=0)
                K_loc = jax.lax.dynamic_slice_in_dim(K_, i0, blk, axis=0)
                W_loc = tuple(
                    jax.lax.dynamic_slice_in_dim(w, i0, blk, axis=0)
                    for w in W_
                )
                if q_thresh > 0.0:
                    g_norms = jnp.linalg.norm(G_loc, axis=(-2, -1)) + 1e-12
                    caps = jnp.linalg.norm(
                        jnp.matmul(jnp.swapaxes(Q_, -1, -2), G_loc),
                        axis=(-2, -1),
                    ) / g_norms
                    stale_mask = caps < q_thresh
                    if pad:
                        stale_mask = stale_mask & ((i0 + jnp.arange(blk)) < b_true)
                    stale = jnp.any(stale_mask).astype(jnp.int32)
                    dr_ = jnp.logical_or(dr_, jax.lax.pmax(stale, axis) > 0)
                out = fn(lr_, dr_, G_loc, Q_, M_, pn_, K_loc, *W_loc)
                d_loc, Qn, Mn, pnn = out[:4]
                d_full = jax.lax.all_gather(d_loc, axis, axis=0, tiled=True)
                if tel:
                    ms_full = jax.tree_util.tree_map(
                        lambda a: jax.lax.all_gather(a, axis, axis=0, tiled=True),
                        out[4])
                    return d_full, Qn, Mn, pnn, ms_full, dr_
                return d_full, Qn, Mn, pnn

            s3 = P(axis, None, None)
            rep3, rep2 = P(None, None, None), P(None, None)
            in_specs = (P(), P(), rep3, s3, s3, P(axis), rep2)
            if stack_w:
                in_specs = in_specs + (rep3,)
            out_specs = (rep3, s3, s3, P(axis))
            if tel:
                out_specs = out_specs + (MatrixStats(*([P()] * 6)), P())
            call = shard_map(
                body, mesh=mesh, in_specs=in_specs,
                out_specs=out_specs, check_rep=False,
            )
            args = (lr, do_refresh, G, Q, M, pn, K) + ((W,) if stack_w else ())
            out = call(*args)
            d, Qn, Mn, pnn = out[:4]
            if tel:
                ms, dr_out = out[4], out[5]
            if pad:
                d, Qn, Mn, pnn = (a[:b_true] for a in (d, Qn, Mn, pnn))
                if tel:
                    ms = jax.tree_util.tree_map(lambda a: a[:b_true], ms)
        else:
            # Bucket-level adaptive refresh: refresh the whole bucket when
            # ANY member's basis has gone stale. Keeping the predicate
            # unbatched is what lets vmap preserve the cond (a batched pred
            # would lower to a select that always pays the rSVD).
            do_refresh_b = do_refresh
            if q_thresh > 0.0:
                g_norms = jnp.linalg.norm(G, axis=(-2, -1)) + 1e-12
                caps = jnp.linalg.norm(
                    jnp.matmul(jnp.swapaxes(Q, -1, -2), G), axis=(-2, -1)
                ) / g_norms
                do_refresh_b = jnp.logical_or(
                    do_refresh, jnp.any(caps < q_thresh)
                )
            args = (lr, do_refresh_b, G, Q, M, pn, K) + ((W,) if stack_w else ())
            out = fn(*args)
            d, Qn, Mn, pnn = out[:4]
            if tel:
                ms, dr_out = out[4], do_refresh_b

        if tel:
            stats_d[bucket.key] = _reduce_bucket_stats(ms, dr_out)
        new_Qd[bucket.key] = Qn
        new_Md[bucket.key] = Mn
        new_pnd[bucket.key] = pnn
        off = 0
        for i, cnt, tr in zip(bucket.leaf_indices, bucket.counts,
                              bucket.transposed):
            sl = slice(off, off + cnt)
            off += cnt
            di = jnp.swapaxes(d[sl], -1, -2) if tr else d[sl]
            out_u[i] = di.reshape(leaves_g[i].shape)
    return out_u, new_Qd, new_Md, new_pnd, stats_d


def sumo(
    learning_rate: Union[float, Callable],
    config: SumoConfig = SumoConfig(),
    mesh: Optional[Mesh] = None,
) -> opt.Transform:
    """Build the SUMO transform for a tree of MATRIX params (ndim >= 2).

    Leaves that are None are passed through (used under multi_transform).
    ``mesh`` enables the shard_map bucket-update path (B sharded over
    ``config.bucket_axis``); without it everything runs single-device.
    """
    lr_fn = learning_rate if callable(learning_rate) else (lambda s: jnp.asarray(learning_rate))
    cfg = config
    layout = cfg.resolved_state_layout()
    if cfg.telemetry and not cfg.bucketed:
        raise ValueError(
            "SumoConfig.telemetry requires the bucketed engine "
            "(spectral probes are emitted per bucket)")

    def _leaf_init(leaf):
        if leaf is None:
            return None, None, None
        q_shape, m_shape, batch = _leaf_state_shapes(cfg, leaf.shape)
        return (
            jnp.zeros(q_shape, jnp.float32),
            jnp.zeros(m_shape, jnp.float32),
            jnp.zeros(batch, jnp.float32),
        )

    def _init_stats(plan):
        """Zero-filled SpectralStats per bucket — gives SumoState a stable
        tree structure from init onward (no recompile after the first step)."""
        out = {}
        for b in plan:
            r = cfg.bucket_rank(*b.shape)
            out[b.key] = SpectralStats(
                sigma=jnp.zeros((r,), jnp.float32),
                kappa=jnp.zeros((), jnp.float32),
                energy=jnp.zeros((), jnp.float32),
                ortho_residual=jnp.zeros((), jnp.float32),
                moment_norm=jnp.zeros((), jnp.float32),
                update_norm=jnp.zeros((), jnp.float32),
                grad_norm=jnp.zeros((), jnp.float32),
                refresh_fired=jnp.zeros((), jnp.int32),
            )
        return out

    def init(params) -> SumoState:
        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        plan = opt.build_bucket_plan(
            [None if l is None else l.shape for l in leaves])
        if layout == "bucket":
            # On a 2D mesh the stored Q carries the edge-padded long dim
            # (zero pad rows) so ragged buckets shard P(data, model, None)
            # in place like divisible ones — opt_state_specs and the update
            # consume exactly this shape, checkpoints re-pad/slice it
            # across meshes.
            m_shards = _model_shards(cfg, mesh)
            Qs, Ms, pns = {}, {}, {}
            for b in plan:
                long_d, short_d = b.shape
                r = cfg.bucket_rank(long_d, short_d)
                Qs[b.key] = jnp.zeros(
                    (b.size, padded_long(long_d, m_shards), r), jnp.float32)
                Ms[b.key] = jnp.zeros((b.size, r, short_d), jnp.float32)
                pns[b.key] = jnp.zeros((b.size,), jnp.float32)
        else:
            triples = [_leaf_init(l) for l in leaves]
            unflat = lambda i: jax.tree_util.tree_unflatten(
                treedef, [t[i] for t in triples])
            Qs, Ms, pns = unflat(0), unflat(1), unflat(2)
        return SumoState(
            step=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(cfg.seed),
            Q=Qs,
            M=Ms,
            prev_norm=pns,
            stats=_init_stats(plan) if cfg.telemetry else None,
        )

    def update(grads, state: SumoState, params=None):
        lr = lr_fn(state.step).astype(jnp.float32)

        leaves_g, treedef = jax.tree_util.tree_flatten(
            grads, is_leaf=lambda x: x is None
        )
        shapes = [None if g is None else g.shape for g in leaves_g]
        plan = opt.build_bucket_plan(shapes)
        leaves_p = (
            treedef.flatten_up_to(params) if params is not None else [None] * len(leaves_g)
        )

        keys = jax.random.split(state.key, len(leaves_g) + 1)
        new_key, leaf_keys = keys[0], keys[1:]
        unflat = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)

        if cfg.bucketed:
            if layout == "bucket":
                # Bucket-resident fast path: the stacked state arrays are the
                # storage — no per-step state stack/scatter at all.
                Qd, Md, pnd = state.Q, state.M, state.prev_norm
            else:
                Qd, Md, pnd = _stack_leaf_state(
                    plan,
                    treedef.flatten_up_to(state.Q),
                    treedef.flatten_up_to(state.M),
                    treedef.flatten_up_to(state.prev_norm),
                )
            out_u, Qd2, Md2, pnd2, stats_d = _bucketed_updates(
                cfg, mesh, plan, leaves_g, Qd, Md, pnd, leaves_p,
                leaf_keys, lr, state.step,
            )
            if layout == "bucket":
                new_Q, new_M, new_pn = Qd2, Md2, pnd2
            else:
                lQ, lM, lpn = _unstack_bucket_state(cfg, plan, shapes, Qd2,
                                                    Md2, pnd2)
                new_Q, new_M, new_pn = unflat(lQ), unflat(lM), unflat(lpn)
        else:
            if layout == "bucket":
                leaves_Q, leaves_M, leaves_pn = _unstack_bucket_state(
                    cfg, plan, shapes, state.Q, state.M, state.prev_norm)
            else:
                leaves_Q = treedef.flatten_up_to(state.Q)
                leaves_M = treedef.flatten_up_to(state.M)
                leaves_pn = treedef.flatten_up_to(state.prev_norm)
            stats_d = None
            out_u, out_Q, out_M, out_pn = _per_leaf_updates(
                cfg, leaves_g, leaves_Q, leaves_M, leaves_pn, leaves_p,
                leaf_keys, lr, state.step,
            )
            if layout == "bucket":
                new_Q, new_M, new_pn = _stack_leaf_state(
                    plan, out_Q, out_M, out_pn)
                # keep the stored layout mesh-consistent: the per-leaf
                # engine computes on true-long state, but bucket-resident Q
                # stays edge-padded on a 2D mesh (zero rows — bit-inert)
                new_Q = _pad_bucket_q(new_Q, plan, _model_shards(cfg, mesh))
            else:
                new_Q, new_M, new_pn = unflat(out_Q), unflat(out_M), unflat(out_pn)

        new_state = SumoState(
            step=state.step + 1,
            key=new_key,
            Q=new_Q,
            M=new_M,
            prev_norm=new_pn,
            stats=stats_d,
        )
        return unflat(out_u), new_state

    return opt.Transform(init, update)


class UpdateTrace(NamedTuple):
    """Introspection export for repro.analysis (see update_closed_jaxpr)."""
    closed_jaxpr: object   # ClosedJaxpr of (grads, state, params) -> (u, s')
    arg_claims: list       # per-flat-invar {dim: trailing_zeros} or None
    plan: list             # per-bucket pad expectations (dicts)
    out_shapes: object     # shape pytree of the traced outputs


def update_closed_jaxpr(
    params,
    cfg: Optional[SumoConfig] = None,
    mesh: Optional[Mesh] = None,
    lr: float = 0.01,
) -> UpdateTrace:
    """Named closed-jaxpr export of the bucketed update, for static analysis.

    Traces ``sumo(lr, cfg, mesh).update`` on abstract values only (no
    device computation, but shard_map tracing does require the mesh's
    devices to exist) and returns, alongside the jaxpr:

      * ``arg_claims`` — the inductive hypothesis for the pad-inertness
        prover: the flat input positions of the state Q stacks, each
        claiming its edge-pad rows (beyond the bucket's TRUE long dim) are
        zero — true at init and re-established by every proved update;
      * ``plan`` — per bucket: true/padded B and long dims, whether it runs
        under shard_map, and the flat OUTPUT index of its new-state Q stack
        (the prover's proof obligation).

    Requires the bucket-resident engine (the only layout with padded
    stacks to reason about).
    """
    cfg = cfg if cfg is not None else SumoConfig()
    if not cfg.bucketed or cfg.resolved_state_layout() != "bucket":
        raise ValueError(
            "update_closed_jaxpr requires the bucketed engine with "
            "bucket-resident state layout")
    tx = sumo(lr, cfg, mesh=mesh)
    as_sds = lambda x: (x if x is None or isinstance(x, jax.ShapeDtypeStruct)
                        else jax.ShapeDtypeStruct(jnp.shape(x),
                                                  jnp.asarray(x).dtype))
    p_sds = jax.tree_util.tree_map(as_sds, params,
                                   is_leaf=lambda x: x is None)
    state_sds = jax.eval_shape(tx.init, p_sds)
    closed, out_shapes = jax.make_jaxpr(
        lambda g, s, p: tx.update(g, s, p), return_shape=True
    )(p_sds, state_sds, p_sds)

    leaves = jax.tree_util.tree_flatten(
        params, is_leaf=lambda x: x is None)[0]
    bplan = opt.build_bucket_plan(
        [None if l is None else jnp.shape(l) for l in leaves])
    n_shards = (int(mesh.shape[cfg.bucket_axis])
                if isinstance(mesh, Mesh) and cfg.bucket_axis in mesh.shape
                else 1)
    m_shards = _model_shards(cfg, mesh)

    # Flat layouts. Inputs: leaves(g) + leaves(state) + leaves(p); outputs:
    # leaves(updates) + leaves(new_state). SumoState flattens in field order
    # (step, key, Q, M, prev_norm, stats) and dicts flatten by sorted key.
    n_g = len(jax.tree_util.tree_leaves(p_sds))
    q_keys = sorted(state_sds.Q)
    q_in_base = n_g + 2          # after state.step, state.key
    q_out_base = n_g + 2         # after updates tree, new step/key

    arg_claims: list = [None] * len(closed.jaxpr.invars)
    plan_out = []
    for b in bplan:
        long_d, short_d = b.shape
        long_pad = padded_long(long_d, m_shards)
        b_shard = n_shards > 1 and b.size > 1
        b_padded = b.size + ((-b.size) % n_shards if b_shard else 0)
        qi = q_in_base + q_keys.index(b.key)
        if long_pad > long_d:
            arg_claims[qi] = {1: long_pad - long_d}
        plan_out.append({
            "key": b.key, "b_true": b.size, "b_padded": b_padded,
            "long": long_d, "long_padded": long_pad, "short": short_d,
            "sharded": m_shards > 1 or b_shard,
            "data_shards": n_shards if b_shard else 1,
            "model_shards": m_shards,
            "q_out_index": q_out_base + q_keys.index(b.key),
        })
    return UpdateTrace(closed_jaxpr=closed, arg_claims=arg_claims,
                       plan=plan_out, out_shapes=out_shapes)


def sumo_optimizer(
    learning_rate,
    params: PyTree,
    config: SumoConfig = SumoConfig(),
    fallback_lr: Optional[Union[float, Callable]] = None,
    fallback_b1: float = 0.9,
    fallback_b2: float = 0.999,
    fallback_weight_decay: float = 0.0,
    mesh: Optional[Mesh] = None,
) -> opt.Transform:
    """SUMO on matrix params + AdamW fallback on everything else."""
    from .adamw import adamw

    labels = opt.partition_params(params)
    return opt.multi_transform(
        {
            "matrix": sumo(learning_rate, config, mesh=mesh),
            "fallback": adamw(
                fallback_lr if fallback_lr is not None else learning_rate,
                b1=fallback_b1,
                b2=fallback_b2,
                weight_decay=fallback_weight_decay,
            ),
        },
        labels,
    )
