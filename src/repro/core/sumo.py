"""SUMO: Subspace-Aware Moment-Orthogonalization (paper Algorithm 1).

Per 2D weight W (m×n) the optimizer keeps
  * Q  — rank-r orthonormal basis of the gradient's long dimension, refreshed
         every K steps with truncated randomized SVD          (Block 1)
  * M  — the single first-order moment in the projected space (r × short_dim)
  * prev_norm — ‖O_{t-1}‖_F for the norm-growth limiter       (Block 3)

Update (Def. C.1):
  refresh (t ≡ 0 mod K):  Q_new = rSVD_r(G);  M ← (Q_newᵀ Q_old) M   (Block 1.1)
  Ĝ = Qᵀ G                                                    (project)
  M ← β M + (1-β) Ĝ                                           (moment)
  O = orth(M)            exact polar/SVD, or NS5 for ablation (Block 2)
  O ← limiter(O)         if ‖O‖/‖O_prev‖ > γ, rescale         (Block 3)
  W ← W − η·(α·scale)·Q O − η·λ·W                             (Block 4)

Shape convention: we always project the LONGER side, so the moment is
(r × min(m,n)) and the subspace basis is (max(m,n) × r). For m < n this is
the paper's "projection from the right" remark. 3D expert stacks (E, m, n)
are handled by vmapping the per-matrix rule over the leading axis.

Everything is jit-safe: the K-step refresh runs under ``jax.lax.cond`` so the
rSVD cost is paid only on refresh steps.

Bucketed update engine
----------------------
With ``SumoConfig.bucketed=True`` (the default) the update groups every
matrix leaf with the same trailing (m, n) shape into one stacked (B, m, n)
bucket (2D leaves contribute one matrix, (E, m, n) expert stacks contribute
E), then runs ONE ``jax.vmap``-ed ``_matrix_update`` per bucket and scatters
the results back to the original tree. A 24-layer transformer therefore
compiles ~4 bucketed updates instead of ~100 per-leaf ones, and each bucket
pays a single ``lax.cond``/rSVD for its refresh instead of one per leaf (the
refresh predicate is shared, so vmap keeps the cond a cond). The projection
Ĝ = QᵀG and back-projection U = QO route through ``kernels.ops`` —
Pallas kernels on TPU, plain-matmul reference on CPU, overridable with
``SumoConfig.projection``. The adaptive ``refresh_quality`` criterion is
evaluated at bucket granularity (refresh the whole bucket when ANY member's
basis has gone stale) to keep the single-cond property; per-leaf granularity
is available via ``bucketed=False``, which also serves as the bit-exact
reference implementation in tests. Optimizer *state* stays per-leaf either
way, so checkpointing and sharding specs are unaffected. One bucket is one
shardable (B, m, n) tensor — the unit for multi-device SUMO later.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from ..kernels.ops import subspace_backproject, subspace_project
from . import optimizer as opt
from .orthogonalize import newton_schulz5, orthogonalize_polar, orthogonalize_svd
from .rsvd import randomized_range_finder

PyTree = opt.PyTree


class SumoState(NamedTuple):
    step: jnp.ndarray          # ()
    key: jax.Array             # rng for rSVD sketches
    Q: PyTree                  # per-leaf (long, r) bases (None on fallback leaves)
    M: PyTree                  # per-leaf (r, short) moments
    prev_norm: PyTree          # per-leaf () limiter memory


@dataclasses.dataclass(frozen=True)
class SumoConfig:
    rank: int = 128
    update_freq: int = 200          # K
    beta: float = 0.95              # moment decay (paper uses convex combination)
    alpha: float = 1.0              # projection-back scale factor
    weight_decay: float = 0.0
    gamma: float = 1.1              # norm-growth limiter threshold
    orth_method: str = "polar"      # polar | svd | ns5
    ns_steps: int = 5
    rsvd_iters: int = 2
    rsvd_oversample: int = 4
    rms_scale: bool = True          # multiply update by 0.2·√max(m,n) (Moonlight)
    seed: int = 0
    # Alg. 1's alternative refresh criterion ("‖Ĝ‖ ≤ ς", the T_ℓ times of
    # Theorem 3.8): ALSO refresh when the current basis captures less than
    # `refresh_quality` of the gradient's energy, ‖QᵀG‖_F < ς·‖G‖_F.
    # 0.0 disables (pure every-K refresh).
    refresh_quality: float = 0.0
    # Bucketed update engine: stack same-(m, n) leaves and run one vmapped
    # update (one refresh cond + rSVD) per bucket. False = per-leaf reference.
    bucketed: bool = True
    # Projection/back-projection impl: "auto" (Pallas on TPU, reference
    # matmul elsewhere), "pallas" (force the kernel; interpret mode on CPU),
    # or "reference".
    projection: str = "auto"


def _orth(cfg: SumoConfig, M: jnp.ndarray) -> jnp.ndarray:
    if cfg.orth_method == "polar":
        return orthogonalize_polar(M)
    if cfg.orth_method == "svd":
        return orthogonalize_svd(M)
    if cfg.orth_method == "ns5":
        return newton_schulz5(M, steps=cfg.ns_steps)
    raise ValueError(f"unknown orth_method {cfg.orth_method!r}")


def _leaf_rank(cfg: SumoConfig, shape) -> int:
    """Effective rank for one matrix: never above the short dim."""
    m, n = shape[-2], shape[-1]
    return max(1, min(cfg.rank, min(m, n)))


def _matrix_update(
    cfg: SumoConfig,
    G: jnp.ndarray,           # (m, n) fp32
    Q: jnp.ndarray,           # (long, r)
    M: jnp.ndarray,           # (r, short)
    prev_norm: jnp.ndarray,   # ()
    lr: jnp.ndarray,
    do_refresh: jnp.ndarray,  # bool
    key: jax.Array,
    W: Optional[jnp.ndarray],
    check_quality: bool = True,
):
    """One SUMO step for a single 2D matrix. Returns (delta, Q, M, prev_norm).

    ``check_quality=False`` skips the in-function adaptive-refresh test; the
    bucketed engine evaluates it once per bucket and folds it into
    ``do_refresh`` so the predicate stays unbatched under vmap.
    """
    m, n = G.shape
    transpose = m < n            # static
    Gl = G.T if transpose else G      # (long, short)
    r = Q.shape[1]

    # Alg. 1 alternative criterion: refresh when the stale basis captures too
    # little of the current gradient (‖QᵀG‖ < ς‖G‖).
    if check_quality and cfg.refresh_quality > 0.0:
        g_norm = jnp.linalg.norm(Gl) + 1e-12
        cap = jnp.linalg.norm(Q.T @ Gl) / g_norm
        do_refresh = jnp.logical_or(do_refresh, cap < cfg.refresh_quality)

    # ---- Block 1 + 1.1: subspace refresh & moment rotation -------------
    def refresh(_):
        Q_new = randomized_range_finder(
            Gl, key, r, n_iter=cfg.rsvd_iters, oversample=cfg.rsvd_oversample
        )
        R = Q_new.T @ Q            # (r, r) rotation old->new basis
        return Q_new, R @ M

    def keep(_):
        return Q, M

    Q, M = jax.lax.cond(do_refresh, refresh, keep, operand=None)

    # ---- project ---------------------------------------------------------
    G_hat = subspace_project(Q, Gl, impl=cfg.projection)   # (r, short)

    # ---- Block 2: moment + exact orthogonalization ------------------------
    M = cfg.beta * M + (1.0 - cfg.beta) * G_hat
    O = _orth(cfg, M)              # (r, short), orthonormal rows

    # ---- Block 3: norm-growth limiter -------------------------------------
    o_norm = jnp.linalg.norm(O)
    first = prev_norm <= 0.0
    cap = jnp.where(first, o_norm, cfg.gamma * prev_norm)
    scale_lim = jnp.minimum(1.0, cap / (o_norm + 1e-12))
    O = O * scale_lim
    new_prev = o_norm * scale_lim

    # ---- Block 4: back-project to the original space -----------------------
    upd = subspace_backproject(Q, O, impl=cfg.projection)  # (long, short)
    if transpose:
        upd = upd.T                # (m, n)
    scale = cfg.alpha
    if cfg.rms_scale:
        scale = scale * 0.2 * jnp.sqrt(float(max(m, n)))
    delta = -lr * scale * upd
    if cfg.weight_decay > 0.0 and W is not None:
        delta = delta - lr * cfg.weight_decay * W.astype(jnp.float32)
    return delta, Q, M, new_prev


def _per_leaf_updates(cfg, leaves_g, leaves_Q, leaves_M, leaves_pn, leaves_p,
                      leaf_keys, lr, do_refresh):
    """Reference engine: one ``_matrix_update`` (and refresh cond) per leaf.

    3D expert stacks vmap over their leading axis; everything else is a
    straight Python loop, so a model with L same-shaped layers compiles L
    separate conds/rSVDs. Kept as the bit-exact oracle for the bucketed
    engine and for per-leaf adaptive-refresh granularity.
    """
    out_u, out_Q, out_M, out_pn = [], [], [], []
    for g, Q, M, pn, p, k in zip(
        leaves_g, leaves_Q, leaves_M, leaves_pn, leaves_p, leaf_keys
    ):
        if g is None:
            out_u.append(None); out_Q.append(None)
            out_M.append(None); out_pn.append(None)
            continue
        g32 = g.astype(jnp.float32)
        if g.ndim == 2:
            d, Qn, Mn, pnn = _matrix_update(
                cfg, g32, Q, M, pn, lr, do_refresh, k, p
            )
        else:
            # batched expert stacks (E, m, n) (or deeper): vmap over batch
            batch_shape = g.shape[:-2]
            gb = g32.reshape((-1,) + g.shape[-2:])
            Qb = Q.reshape((-1,) + Q.shape[-2:])
            Mb = M.reshape((-1,) + M.shape[-2:])
            pnb = pn.reshape(-1)
            pb = (
                p.astype(jnp.float32).reshape((-1,) + p.shape[-2:])
                if p is not None
                else None
            )
            kb = jax.random.split(k, gb.shape[0])
            fn = jax.vmap(
                lambda G_, Q_, M_, pn_, k_, W_: _matrix_update(
                    cfg, G_, Q_, M_, pn_, lr, do_refresh, k_, W_
                ),
                in_axes=(0, 0, 0, 0, 0, 0 if pb is not None else None),
            )
            d, Qn, Mn, pnn = fn(gb, Qb, Mb, pnb, kb, pb)
            d = d.reshape(g.shape)
            Qn = Qn.reshape(batch_shape + Qn.shape[-2:])
            Mn = Mn.reshape(batch_shape + Mn.shape[-2:])
            pnn = pnn.reshape(batch_shape)
        out_u.append(d)
        out_Q.append(Qn)
        out_M.append(Mn)
        out_pn.append(pnn)
    return out_u, out_Q, out_M, out_pn


def _bucketed_updates(cfg, leaves_g, leaves_Q, leaves_M, leaves_pn, leaves_p,
                      leaf_keys, lr, do_refresh):
    """Bucketed engine: one vmapped ``_matrix_update`` per (m, n) bucket.

    Leaves sharing a trailing matrix shape are stacked into a (B, m, n)
    bucket (expert stacks flatten their leading dims in), updated with a
    single vmap whose refresh predicate is unbatched — so the whole bucket
    pays ONE ``lax.cond``/rSVD — and sliced back to the original leaves.
    Per-matrix rSVD keys match the per-leaf engine exactly (same per-leaf
    key, same per-expert split), which is what makes the two engines
    bit-comparable.
    """
    shapes = [None if g is None else g.shape for g in leaves_g]
    plan = opt.build_bucket_plan(shapes)
    n_leaves = len(leaves_g)
    out_u = [None] * n_leaves
    out_Q = [None] * n_leaves
    out_M = [None] * n_leaves
    out_pn = [None] * n_leaves

    for bucket in plan:
        m, n = bucket.shape
        # W only feeds the decoupled weight-decay term: skip the stacking
        # traffic entirely when decay is off or no member has a param. In a
        # mixed bucket, members without a param get zeros — a zero decay
        # term, matching the per-leaf engine's "no W, no decay" semantics.
        stack_w = cfg.weight_decay > 0.0 and any(
            leaves_p[i] is not None for i in bucket.leaf_indices
        )
        Gs, Qs, Ms, pns, Ws, Ks = [], [], [], [], [], []
        for i, cnt in zip(bucket.leaf_indices, bucket.counts):
            g = leaves_g[i]
            Gs.append(g.astype(jnp.float32).reshape((-1, m, n)))
            Qs.append(leaves_Q[i].reshape((-1,) + leaves_Q[i].shape[-2:]))
            Ms.append(leaves_M[i].reshape((-1,) + leaves_M[i].shape[-2:]))
            pns.append(leaves_pn[i].reshape(-1))
            if stack_w:
                Ws.append(
                    leaves_p[i].astype(jnp.float32).reshape((-1, m, n))
                    if leaves_p[i] is not None
                    else jnp.zeros((cnt, m, n), jnp.float32)
                )
            k = leaf_keys[i]
            Ks.append(k[None] if g.ndim == 2 else jax.random.split(k, cnt))
        G = jnp.concatenate(Gs, axis=0)          # (B, m, n)
        Q = jnp.concatenate(Qs, axis=0)          # (B, long, r)
        M = jnp.concatenate(Ms, axis=0)          # (B, r, short)
        pn = jnp.concatenate(pns, axis=0)        # (B,)
        K = jnp.concatenate(Ks, axis=0)          # (B, key)
        W = jnp.concatenate(Ws, axis=0) if stack_w else None

        # Bucket-level adaptive refresh: refresh the whole bucket when ANY
        # member's basis has gone stale. Keeping the predicate unbatched is
        # what lets vmap preserve the cond (a batched pred would lower to a
        # select that always pays the rSVD).
        do_refresh_b = do_refresh
        if cfg.refresh_quality > 0.0:
            Gl = jnp.swapaxes(G, -1, -2) if m < n else G
            g_norms = jnp.linalg.norm(Gl, axis=(-2, -1)) + 1e-12
            caps = jnp.linalg.norm(
                jnp.matmul(jnp.swapaxes(Q, -1, -2), Gl), axis=(-2, -1)
            ) / g_norms
            do_refresh_b = jnp.logical_or(
                do_refresh, jnp.any(caps < cfg.refresh_quality)
            )

        fn = jax.vmap(
            lambda G_, Q_, M_, pn_, k_, W_: _matrix_update(
                cfg, G_, Q_, M_, pn_, lr, do_refresh_b, k_, W_,
                check_quality=False,
            ),
            in_axes=(0, 0, 0, 0, 0, 0 if W is not None else None),
        )
        d, Qn, Mn, pnn = fn(G, Q, M, pn, K, W)

        off = 0
        for i, cnt in zip(bucket.leaf_indices, bucket.counts):
            sl = slice(off, off + cnt)
            off += cnt
            out_u[i] = d[sl].reshape(leaves_g[i].shape)
            out_Q[i] = Qn[sl].reshape(leaves_Q[i].shape)
            out_M[i] = Mn[sl].reshape(leaves_M[i].shape)
            out_pn[i] = pnn[sl].reshape(leaves_pn[i].shape)
    return out_u, out_Q, out_M, out_pn


def sumo(
    learning_rate: Union[float, Callable],
    config: SumoConfig = SumoConfig(),
) -> opt.Transform:
    """Build the SUMO transform for a tree of MATRIX params (ndim >= 2).

    Leaves that are None are passed through (used under multi_transform).
    """
    lr_fn = learning_rate if callable(learning_rate) else (lambda s: jnp.asarray(learning_rate))
    cfg = config

    def _leaf_init(leaf):
        if leaf is None:
            return None, None, None
        shape = leaf.shape
        m, n = shape[-2], shape[-1]
        long_d, short_d = (n, m) if m < n else (m, n)
        r = _leaf_rank(cfg, shape)
        batch = shape[:-2]
        Q = jnp.zeros(batch + (long_d, r), jnp.float32)
        M = jnp.zeros(batch + (r, short_d), jnp.float32)
        pn = jnp.zeros(batch, jnp.float32) if batch else jnp.zeros((), jnp.float32)
        return Q, M, pn

    def init(params) -> SumoState:
        leaves, treedef = jax.tree_util.tree_flatten(params, is_leaf=lambda x: x is None)
        triples = [_leaf_init(l) for l in leaves]
        unflat = lambda i: jax.tree_util.tree_unflatten(treedef, [t[i] for t in triples])
        Qs, Ms, pns = unflat(0), unflat(1), unflat(2)
        return SumoState(
            step=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(cfg.seed),
            Q=Qs,
            M=Ms,
            prev_norm=pns,
        )

    def update(grads, state: SumoState, params=None):
        lr = lr_fn(state.step).astype(jnp.float32)
        do_refresh = (state.step % cfg.update_freq) == 0

        leaves_g, treedef = jax.tree_util.tree_flatten(
            grads, is_leaf=lambda x: x is None
        )
        leaves_Q = treedef.flatten_up_to(state.Q)
        leaves_M = treedef.flatten_up_to(state.M)
        leaves_pn = treedef.flatten_up_to(state.prev_norm)
        leaves_p = (
            treedef.flatten_up_to(params) if params is not None else [None] * len(leaves_g)
        )

        keys = jax.random.split(state.key, len(leaves_g) + 1)
        new_key, leaf_keys = keys[0], keys[1:]

        engine = _bucketed_updates if cfg.bucketed else _per_leaf_updates
        out_u, out_Q, out_M, out_pn = engine(
            cfg, leaves_g, leaves_Q, leaves_M, leaves_pn, leaves_p,
            leaf_keys, lr, do_refresh,
        )

        unflat = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        new_state = SumoState(
            step=state.step + 1,
            key=new_key,
            Q=unflat(out_Q),
            M=unflat(out_M),
            prev_norm=unflat(out_pn),
        )
        return unflat(out_u), new_state

    return opt.Transform(init, update)


def sumo_optimizer(
    learning_rate,
    params: PyTree,
    config: SumoConfig = SumoConfig(),
    fallback_lr: Optional[Union[float, Callable]] = None,
    fallback_b1: float = 0.9,
    fallback_b2: float = 0.999,
    fallback_weight_decay: float = 0.0,
) -> opt.Transform:
    """SUMO on matrix params + AdamW fallback on everything else."""
    from .adamw import adamw

    labels = opt.partition_params(params)
    return opt.multi_transform(
        {
            "matrix": sumo(learning_rate, config),
            "fallback": adamw(
                fallback_lr if fallback_lr is not None else learning_rate,
                b1=fallback_b1,
                b2=fallback_b2,
                weight_decay=fallback_weight_decay,
            ),
        },
        labels,
    )
