"""repro.core — SUMO and baseline optimizers (the paper's contribution)."""
from .adamw import adamw, adamw_optimizer
from .galore import GaloreConfig, galore, galore_optimizer
from .lora import LoraConfig, apply_lora, extract_adapter, init_lora_params
from .memory import analytic_state_floats, model_memory_report, tree_state_bytes
from .muon import muon, muon_optimizer
from .optimizer import (
    Bucket,
    Schedule,
    Transform,
    apply_updates,
    build_bucket_plan,
    canonical_dims,
    chain,
    clip_by_global_norm,
    constant_schedule,
    global_norm,
    multi_transform,
    partition_params,
)
from .orthogonalize import (
    ORTH_METHODS,
    condition_number,
    effective_rank,
    gram_spectrum,
    newton_schulz5,
    newton_schulz_cubic,
    orth_closed_jaxpr,
    orthogonality_error,
    orthogonalize_polar,
    orthogonalize_polar_with_spectrum,
    orthogonalize_svd,
    orthogonalize_svd_with_spectrum,
    rank_one_residual,
)
from .rsvd import (
    cholesky_qr2_closed_jaxpr,
    randomized_range_finder,
    randomized_svd,
    rsvd_effective_rank,
    subspace_overlap,
    truncated_svd,
)
from .sumo import (
    MatrixStats,
    SpectralStats,
    SumoConfig,
    SumoState,
    bucket_spectral_stats,
    convert_sumo_state,
    padded_long,
    sumo,
    sumo_dp_bases,
    sumo_optimizer,
    sumo_state_layout,
)

__all__ = [
    "SumoConfig", "SumoState", "sumo", "sumo_optimizer",
    "convert_sumo_state", "sumo_state_layout", "padded_long",
    "sumo_dp_bases", "bucket_spectral_stats",
    "MatrixStats", "SpectralStats",
    "GaloreConfig", "galore", "galore_optimizer",
    "muon", "muon_optimizer",
    "adamw", "adamw_optimizer",
    "LoraConfig", "init_lora_params", "apply_lora", "extract_adapter",
    "Transform", "chain", "multi_transform", "partition_params",
    "Bucket", "build_bucket_plan", "canonical_dims",
    "apply_updates", "clip_by_global_norm", "global_norm",
    "Schedule", "constant_schedule",
    "orthogonalize_svd", "orthogonalize_polar", "newton_schulz5",
    "newton_schulz_cubic", "condition_number", "effective_rank",
    "rank_one_residual", "orthogonality_error", "gram_spectrum",
    "orthogonalize_polar_with_spectrum", "orthogonalize_svd_with_spectrum",
    "ORTH_METHODS", "orth_closed_jaxpr",
    "randomized_range_finder", "randomized_svd", "truncated_svd",
    "rsvd_effective_rank", "subspace_overlap", "cholesky_qr2_closed_jaxpr",
    "analytic_state_floats", "model_memory_report", "tree_state_bytes",
]
