"""AdamW (decoupled weight decay) — the full-state baseline and the fallback
optimizer for non-matrix params under SUMO / Muon / GaLore."""
from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from . import optimizer as opt


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: opt.PyTree       # 1st moment
    nu: opt.PyTree       # 2nd moment


def adamw(
    learning_rate: Union[float, Callable],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> opt.Transform:
    lr_fn = learning_rate if callable(learning_rate) else (lambda s: jnp.asarray(learning_rate))

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=opt.tree_map_not_none(zeros, params),
            nu=opt.tree_map_not_none(zeros, params),
        )

    def update(grads, state: AdamWState, params=None):
        step = state.step + 1
        lr = lr_fn(state.step).astype(jnp.float32)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        mu = opt.tree_map_not_none(
            lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32), grads, state.mu
        )
        nu = opt.tree_map_not_none(
            lambda g, v: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            grads,
            state.nu,
        )

        def _upd(m, v, p):
            d = -lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay > 0.0 and p is not None:
                d = d - lr * weight_decay * p.astype(jnp.float32)
            return d

        if params is not None:
            updates = jax.tree_util.tree_map(
                lambda m, v, p: None if m is None else _upd(m, v, p),
                mu, nu, params, is_leaf=lambda x: x is None,
            )
        else:
            updates = opt.tree_map_not_none(lambda m, v: _upd(m, v, None), mu, nu)
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return opt.Transform(init, update)


def adamw_optimizer(learning_rate, params, **kw) -> opt.Transform:
    """Plain AdamW over the whole tree (the 'Full Fine-Tuning' baseline)."""
    del params
    return adamw(learning_rate, **kw)
