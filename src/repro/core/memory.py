"""Analytic optimizer-state memory accounting — reproduces paper Table 1.

For W ∈ R^{m×n} with m >= n, rank r, subspace refresh period K:

  method   | optim-state floats        | compute / step (amortized)
  ---------+---------------------------+---------------------------
  SUMO     | m·r + r·n (+1 scalar)     | O(mnr + mn²/K)   (rSVD amortized)
  Adam     | 2·m·n                     | O(mn)
  Shampoo  | m² + n²                   | O(m³ + n³)
  SOAP     | 2mn + 2m² + 2n²           | O(m³ + n³)
  GaLore   | m·r + 2·r·n               | O(mnr + mn²/K)
  Muon     | m·n                       | O(mn·ns_steps·min(m,n)/max(m,n)) ~ NS matmuls

These functions count REAL states from the live optimizer pytrees too, so the
benchmark can assert analytic == measured.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def _mn(shape) -> tuple[int, int]:
    m, n = shape[-2], shape[-1]
    batch = 1
    for d in shape[:-2]:
        batch *= d
    return batch * max(m, n), min(m, n)  # fold expert batch into the long dim


def analytic_state_floats(method: str, shape, rank: int = 128) -> int:
    """Optimizer state floats for one matrix param of `shape`."""
    m, n = _mn(shape)
    r = min(rank, n)
    method = method.lower()
    if method == "sumo":
        return m * r + r * n + 1
    if method == "adam" or method == "adamw":
        return 2 * m * n
    if method == "galore":
        return m * r + 2 * r * n
    if method == "muon":
        return m * n
    if method == "shampoo":
        return m * m + n * n
    if method == "soap":
        return 2 * m * n + 2 * m * m + 2 * n * n
    if method == "lora":  # adapter params + their Adam states
        return 3 * r * (m + n)
    raise ValueError(method)


def analytic_flops_per_step(method: str, shape, rank: int = 128, K: int = 200,
                            ns_steps: int = 5) -> float:
    """Amortized optimizer FLOPs per step for one matrix param (paper Table 1)."""
    m, n = _mn(shape)
    r = min(rank, n)
    method = method.lower()
    if method in ("sumo", "galore"):
        project = 2 * m * n * r                    # QᵀG + back-projection
        refresh = (2 * m * n * r + 4 * m * r * r) / K
        if method == "sumo":
            # polar orth on (r, n): Gram 2nr² + eigh ~ 10r³ + back 2nr² + rotate 2r²n
            orth = 4 * n * r * r + 10 * r ** 3 + 2 * r * r * n / K
        else:
            orth = 4 * r * n                       # element-wise adam in subspace
        return project + refresh + orth
    if method in ("adam", "adamw"):
        return 8.0 * m * n
    if method == "muon":
        # NS5: per iter 2 matmuls (n²m) + (n³): ~ ns_steps * (2mn² + 2n³) + norm
        return ns_steps * (2 * m * n * n + 2 * n ** 3) + 2 * m * n
    if method == "shampoo" or method == "soap":
        return float(m ** 3 + n ** 3)
    raise ValueError(method)


def tree_state_bytes(state: PyTree) -> int:
    """Measured bytes of a live optimizer state pytree (Nones skipped)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(state):
        if isinstance(leaf, (jnp.ndarray, jax.Array)):
            total += leaf.size * leaf.dtype.itemsize
    return total


def tree_param_bytes(params: PyTree) -> int:
    return sum(
        l.size * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(params)
        if hasattr(l, "dtype")
    )


def analytic_activation_bytes(cfg, batch: int, seq: int) -> int:
    """Upper bound on a train step's fwd+bwd activation transients, in
    bytes, from the arch config — the activation term of the pass-5 memory
    budget (``analysis.memory.steady_memory_budget``).

    Per token, in f32 floats: 6×vocab for the logits family (logits,
    d-logits, softmax workspace, log-normalizer broadcast, target one-hot /
    gather, loss mask), and per layer 24×d_model of saved d-wide
    activations (qkvo, norms, residual streams, their cotangents), 8×d_ff
    for the MLP hidden pair, and 4×n_heads×seq for the attention score /
    softmax matrices (the O(seq²) term — scores are (batch, heads, seq,
    seq), i.e. heads×seq floats per token, ×2 fwd/bwd ×2 score+softmax).
    Coefficients are calibrated as an upper bound (~1.5–2× the measured
    temp bytes on the smoke model at seq 16–64) — headroom for XLA's
    fusion/layout choices, tight enough that a duplicated activation tree
    (e.g. a dropped donation re-materializing the backward) still trips
    ``transient-exceeds-plan``.
    """
    tokens = batch * seq
    floats_per_token = (
        6 * cfg.vocab
        + cfg.n_layers * (24 * cfg.d_model + 8 * cfg.d_ff
                          + 4 * cfg.n_heads * seq))
    return 4 * tokens * floats_per_token


def predict_state_bytes(method: str, params: PyTree, rank: int = 128) -> int:
    """EXACT optimizer-state bytes for the live engines, from params+config.

    ``analytic_state_floats`` is the paper's Table-1 model (batch dims folded
    into the long dim — the right analytic simplification, but it undercounts
    the real per-slice engines on stacked leaves). This predictor instead
    replays the engines' own layout decisions — ``partition_params`` labels,
    ``build_bucket_plan`` bucket stacking, per-slice factors — WITHOUT looking
    at a live state tree, so ``predict_state_bytes(m, params, r) ==
    tree_state_bytes(make_optimizer(m, ...).init(params))`` is a real
    cross-check (asserted for all five optimizers in benchmarks/memory_table.py
    and the analysis driver), not a tautology.

    Byte accounting per method (fp32 states, int32 step, uint32[2] key):

      adamw   step + mu/nu on every leaf
      sumo    fallback AdamW + per bucket Q(B,long,r) M(B,r,short) prev_norm(B)
              + step + refresh key
      galore  fallback AdamW + per matrix leaf Q(b,long,r), mu/nu(b,r,short)
              + step + refresh key
      muon    fallback AdamW + full-shape momentum on matrix leaves + step
      lora    frozen base: adapters A(b,r,n)+B(b,m,r) and AdamW over them
    """
    from . import optimizer as opt

    method = method.lower()
    if method == "adamw" or method == "adam":
        return 4 + 2 * tree_param_bytes(params)

    labels = opt.partition_params(params)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    lab_leaves = treedef.flatten_up_to(labels)
    matrix = [l for l, lab in zip(leaves, lab_leaves) if lab == "matrix"]
    fb = [l for l, lab in zip(leaves, lab_leaves) if lab != "matrix"]
    fb_bytes = 4 + 2 * sum(l.size * l.dtype.itemsize for l in fb)

    def slices(leaf):
        b = 1
        for d in leaf.shape[:-2]:
            b *= int(d)
        long_d, short_d = opt.canonical_dims(leaf.shape)
        return b, long_d, short_d

    if method == "lora":
        ab = 0
        for leaf in matrix:
            b, long_d, short_d = slices(leaf)
            m, n = int(leaf.shape[-2]), int(leaf.shape[-1])
            r = min(rank, short_d)
            ab += 4 * b * (r * n + m * r)       # A + B adapters
        return 3 * ab + 4                       # adapters + AdamW mu/nu + step

    if method == "muon":
        mb = 4 + sum(l.size * l.dtype.itemsize for l in matrix)
        return mb + fb_bytes

    if method == "galore":
        mb = 4 + 8                              # step + refresh key
        for leaf in matrix:
            b, long_d, short_d = slices(leaf)
            r = min(rank, short_d)
            mb += 4 * b * (long_d * r + 2 * r * short_d)
        return mb + fb_bytes

    if method in ("sumo", "sumo-svd", "sumo-ns5"):
        plan = opt.build_bucket_plan([l.shape for l in matrix])
        mb = 4 + 8                              # step + refresh key
        for bucket in plan:
            long_d, short_d = bucket.shape
            r = min(rank, short_d)
            mb += 4 * bucket.size * (long_d * r + r * short_d + 1)
        return mb + fb_bytes

    raise ValueError(method)


def model_memory_report(params: PyTree, rank: int = 128) -> dict[str, int]:
    """Analytic per-method optimizer state bytes for a whole model (fp32 states).

    Matrix params get the method's state; fallback params are charged 2 floats
    (AdamW) under every method, matching real deployments.
    """
    from . import optimizer as opt

    labels = opt.partition_params(params)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    lab_leaves = treedef.flatten_up_to(labels)

    report = {}
    for method in ("sumo", "galore", "muon", "adamw", "shampoo", "soap"):
        floats = 0
        for leaf, lab in zip(leaves, lab_leaves):
            if lab == "matrix":
                floats += analytic_state_floats(method, leaf.shape, rank)
            else:
                floats += 2 * leaf.size          # AdamW fallback
        report[method] = floats * 4              # fp32 bytes
    return report
