"""Analytic optimizer-state memory accounting — reproduces paper Table 1.

For W ∈ R^{m×n} with m >= n, rank r, subspace refresh period K:

  method   | optim-state floats        | compute / step (amortized)
  ---------+---------------------------+---------------------------
  SUMO     | m·r + r·n (+1 scalar)     | O(mnr + mn²/K)   (rSVD amortized)
  Adam     | 2·m·n                     | O(mn)
  Shampoo  | m² + n²                   | O(m³ + n³)
  SOAP     | 2mn + 2m² + 2n²           | O(m³ + n³)
  GaLore   | m·r + 2·r·n               | O(mnr + mn²/K)
  Muon     | m·n                       | O(mn·ns_steps·min(m,n)/max(m,n)) ~ NS matmuls

These functions count REAL states from the live optimizer pytrees too, so the
benchmark can assert analytic == measured.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def _mn(shape) -> tuple[int, int]:
    m, n = shape[-2], shape[-1]
    batch = 1
    for d in shape[:-2]:
        batch *= d
    return batch * max(m, n), min(m, n)  # fold expert batch into the long dim


def analytic_state_floats(method: str, shape, rank: int = 128) -> int:
    """Optimizer state floats for one matrix param of `shape`."""
    m, n = _mn(shape)
    r = min(rank, n)
    method = method.lower()
    if method == "sumo":
        return m * r + r * n + 1
    if method == "adam" or method == "adamw":
        return 2 * m * n
    if method == "galore":
        return m * r + 2 * r * n
    if method == "muon":
        return m * n
    if method == "shampoo":
        return m * m + n * n
    if method == "soap":
        return 2 * m * n + 2 * m * m + 2 * n * n
    if method == "lora":  # adapter params + their Adam states
        return 3 * r * (m + n)
    raise ValueError(method)


def analytic_flops_per_step(method: str, shape, rank: int = 128, K: int = 200,
                            ns_steps: int = 5) -> float:
    """Amortized optimizer FLOPs per step for one matrix param (paper Table 1)."""
    m, n = _mn(shape)
    r = min(rank, n)
    method = method.lower()
    if method in ("sumo", "galore"):
        project = 2 * m * n * r                    # QᵀG + back-projection
        refresh = (2 * m * n * r + 4 * m * r * r) / K
        if method == "sumo":
            # polar orth on (r, n): Gram 2nr² + eigh ~ 10r³ + back 2nr² + rotate 2r²n
            orth = 4 * n * r * r + 10 * r ** 3 + 2 * r * r * n / K
        else:
            orth = 4 * r * n                       # element-wise adam in subspace
        return project + refresh + orth
    if method in ("adam", "adamw"):
        return 8.0 * m * n
    if method == "muon":
        # NS5: per iter 2 matmuls (n²m) + (n³): ~ ns_steps * (2mn² + 2n³) + norm
        return ns_steps * (2 * m * n * n + 2 * n ** 3) + 2 * m * n
    if method == "shampoo" or method == "soap":
        return float(m ** 3 + n ** 3)
    raise ValueError(method)


def tree_state_bytes(state: PyTree) -> int:
    """Measured bytes of a live optimizer state pytree (Nones skipped)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(state):
        if isinstance(leaf, (jnp.ndarray, jax.Array)):
            total += leaf.size * leaf.dtype.itemsize
    return total


def tree_param_bytes(params: PyTree) -> int:
    return sum(
        l.size * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(params)
        if hasattr(l, "dtype")
    )


def model_memory_report(params: PyTree, rank: int = 128) -> dict[str, int]:
    """Analytic per-method optimizer state bytes for a whole model (fp32 states).

    Matrix params get the method's state; fallback params are charged 2 floats
    (AdamW) under every method, matching real deployments.
    """
    from . import optimizer as opt

    labels = opt.partition_params(params)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    lab_leaves = treedef.flatten_up_to(labels)

    report = {}
    for method in ("sumo", "galore", "muon", "adamw", "shampoo", "soap"):
        floats = 0
        for leaf, lab in zip(leaves, lab_leaves):
            if lab == "matrix":
                floats += analytic_state_floats(method, leaf.shape, rank)
            else:
                floats += 2 * leaf.size          # AdamW fallback
        report[method] = floats * 4              # fp32 bytes
    return report
