"""hubert-xlarge [audio] — 48L d_model=1280 16H d_ff=5120 vocab=504.
Encoder-only (bidirectional), same arch as wav2vec2; the CNN feature
extractor is a STUB (input_specs() provides precomputed frame embeddings).
No autoregressive decode — decode shapes are n/a (DESIGN.md §4).
[arXiv:2106.07447; unverified]
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    rotary_pct=0.0,          # hubert uses (stubbed) conv positional embeddings
    norm="layernorm",
    mlp="gelu",
    frontend="audio_stub",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=32, remat=False, dtype="float32",
    )
