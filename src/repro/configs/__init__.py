"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib

from .base import SHAPE_BY_NAME, SHAPES, ArchConfig, ShapeConfig, cell_supported

_MODULES = {
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen3-4b": "qwen3_4b",
    "smollm-360m": "smollm_360m",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "mixtral-8x22b": "mixtral_8x22b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "zamba2-7b": "zamba2_7b",
    "hubert-xlarge": "hubert_xlarge",
    "xlstm-1.3b": "xlstm_1_3b",
    "llama-paper": "llama_paper",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "llama-paper")


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch_id]}", __package__)


def get_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).smoke_config()


__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "SHAPE_BY_NAME",
    "ARCH_IDS", "get_config", "get_smoke_config", "cell_supported",
]
