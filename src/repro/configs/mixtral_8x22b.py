"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]
"""
import dataclasses

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2),
    fsdp=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, sliding_window=32,
        moe=MoEConfig(num_experts=4, top_k=2),
        remat=False, dtype="float32",
    )
