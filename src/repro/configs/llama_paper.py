"""The paper's own pre-training LLaMA configs (Table 3: 60M/130M/350M/1B on
C4) with the paper's r/d_model rank pairings — used by the pre-training
benchmark and the end-to-end example drivers."""
import dataclasses

from .base import ArchConfig

_BASE = dict(
    family="dense",
    n_kv_heads=None,   # filled per-size (MHA in the paper)
    vocab=32000,
    rope_theta=10000.0,
)


def _llama(name, n_layers, d_model, n_heads, d_ff, rank) -> tuple[ArchConfig, int]:
    cfg = ArchConfig(
        name=name, family="dense", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_heads, d_ff=d_ff, vocab=32000,
    )
    return cfg, rank


LLAMA_60M, RANK_60M = _llama("llama-60m", 8, 512, 8, 1376, 128)
LLAMA_130M, RANK_130M = _llama("llama-130m", 12, 768, 12, 2048, 256)
LLAMA_350M, RANK_350M = _llama("llama-350m", 24, 1024, 16, 2736, 256)
LLAMA_1B, RANK_1B = _llama("llama-1b", 24, 2048, 32, 5461, 512)

CONFIG = LLAMA_130M   # registry default for --arch llama-paper


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        LLAMA_60M, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, remat=False, dtype="float32",
    )
