"""stablelm-1.6b [dense] — 24L d_model=2048 32H (GQA kv=32) d_ff=5632
vocab=100352. LayerNorm, partial rotary (25%).
[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    norm="layernorm",
    rotary_pct=0.25,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, remat=False, dtype="float32",
    )
