"""ArchConfig — one schema covering all 10 assigned architecture families.

Every src/repro/configs/<id>.py exposes
    CONFIG: ArchConfig            the full published configuration
    smoke_config() -> ArchConfig  a reduced same-family config for CPU tests
and the registry in configs/__init__.py maps --arch <id> to them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64           # N
    head_dim: int = 64            # P
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128              # SSD chunk length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8          # xLSTM[7:1] layout: every 8th block is sLSTM
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333
    # mLSTM chunk length. The matrix memory is (hd × hd) per head, so the
    # stacked inter-chunk states cost L/chunk · H · hd² bytes while the
    # intra-chunk panels cost L · chunk · H bytes — chunk ≈ hd balances them
    # (§Perf hillclimb: 128 → 512 cut per-device HBM traffic ~5× at hd=1024).
    chunk: int = 512


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # attention details
    head_dim: Optional[int] = None    # default d_model // n_heads
    qk_norm: bool = False
    rotary_pct: float = 1.0
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # SWA width; None = full attention
    causal: bool = True                    # False for encoder-only (hubert)
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    mlp: str = "swiglu"                    # swiglu | gelu
    tie_embeddings: bool = False
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    attn_every: int = 0               # hybrid: every k-th layer is (shared) attention
    shared_attn: bool = False         # zamba2: attention block weights are shared
    # modality frontend stubs
    frontend: str = "none"            # none | vision_stub | audio_stub
    n_frontend_tokens: int = 0        # patches / frames provided by input_specs()
    # numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True                # activation checkpointing per block
    max_seq_len: int = 32768
    # distribution hints
    fsdp: bool = False                # shard params over the data axis too

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k tokens? (SSM/recurrent/SWA only.)"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    @property
    def has_decode(self) -> bool:
        return self.causal  # encoder-only archs have no autoregressive decode

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, f, V = self.d_model, self.d_ff, self.vocab
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        attn = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        if self.mlp == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.moe is not None:
            mlp = self.moe.num_experts * (3 * d * f) + d * self.moe.num_experts
        per_layer = attn + mlp + 2 * d
        if self.family == "ssm" and self.xlstm is not None:
            # rough: mLSTM block ~ 2*(d*2d qkv/proj) + gates
            per_layer = 8 * d * d
        if self.family in ("ssm", "hybrid") and self.ssm is not None:
            d_in = self.ssm.expand * d
            per_layer_ssm = 2 * d * d_in + d_in * d + d_in * (2 * self.ssm.state_dim)
            if self.family == "hybrid":
                pass  # mixture handled approximately
            else:
                per_layer = per_layer_ssm
        total = self.n_layers * per_layer + V * d
        if not self.tie_embeddings:
            total += V * d
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_experts = self.moe.num_experts * 3 * d * f
        active_experts = self.moe.top_k * 3 * d * f
        return self.param_count() - self.n_layers * (dense_experts - active_experts)


# The four LM shapes assigned to every architecture.
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode | long_decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "long_decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_supported(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is (arch × shape) a runnable dry-run cell? Returns (ok, reason)."""
    if shape.kind in ("decode", "long_decode") and not cfg.has_decode:
        return False, "n/a-encoder (no autoregressive decode)"
    if shape.kind == "long_decode" and not cfg.subquadratic:
        return False, "skip-quadratic (full attention at 500k context)"
    return True, "ok"
