"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64. Mamba2 backbone + SHARED attention block applied
every 6 mamba blocks. [arXiv:2411.15242; unverified]

The shared attention block uses a 4096 sliding window at long context so
long_500k decode is O(window) — this is one of the designated sub-quadratic
long-context cells (DESIGN.md §4).
"""
import dataclasses

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    sliding_window=4096,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk=128),
    attn_every=6,
    shared_attn=True,
    fsdp=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, sliding_window=32,
        ssm=SSMConfig(state_dim=8, head_dim=16, expand=2, conv_width=4, chunk=16),
        attn_every=2,
        remat=False, dtype="float32",
    )
