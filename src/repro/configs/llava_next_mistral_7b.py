"""llava-next-mistral-7b [vlm] — Mistral-7B backbone + anyres vision stub.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The vision tower is a STUB per the assignment: input_specs() provides
precomputed anyres patch embeddings (n_frontend_tokens of them) already at
d_model width; the backbone (the part that trains/serves) is full-fidelity.
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    rope_theta=1_000_000.0,
    frontend="vision_stub",
    n_frontend_tokens=576,        # one anyres base tile (24×24 patches)
    fsdp=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, n_frontend_tokens=8, remat=False, dtype="float32",
    )
