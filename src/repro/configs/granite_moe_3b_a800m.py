"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512 (per
expert) vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
import dataclasses

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    moe=MoEConfig(num_experts=40, top_k=8),
    tie_embeddings=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab=256, moe=MoEConfig(num_experts=8, top_k=2),
        remat=False, dtype="float32",
    )
