"""xlstm-1.3b [ssm] — 48 blocks d_model=2048 4H vocab=50304, xLSTM[7:1]
layout (every 8th block sLSTM, rest mLSTM). d_ff=0: blocks carry their own
internal up/down projections (proj factor 2 mLSTM, 4/3 sLSTM).
[arXiv:2405.04517; unverified]

Recurrent — O(1) decode state; designated long_500k cell.
"""
import dataclasses

from .base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    xlstm=XLSTMConfig(slstm_every=8),
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        vocab=256, xlstm=XLSTMConfig(slstm_every=2),
        remat=False, dtype="float32",
    )
