"""deepseek-coder-33b [dense] — 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256. llama-arch. [arXiv:2401.14196; hf]
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
    rope_theta=100_000.0,
    fsdp=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2, d_model=56, n_heads=4, n_kv_heads=2, d_ff=112,
        vocab=256, remat=False, dtype="float32",
    )
