"""Roofline-term extraction from compiled dry-run artifacts.

Terms (per EXPERIMENTS.md §Roofline, TPU v5e constants):
    t_compute    = HLO_FLOPs       / (chips × 197e12  bf16 FLOP/s)
    t_memory     = HLO_bytes       / (chips × 819e9   B/s HBM)
    t_collective = collective_bytes/ (chips × 50e9    B/s per ICI link)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). collective_bytes is
NOT in cost_analysis — we parse the optimized HLO text and sum the RESULT
buffer sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (result size ≈ bytes crossing the interconnect per device
for these ops; all-reduce is counted twice for the reduce+broadcast phases).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e per-chip constants
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# matches e.g.  %ag = bf16[16,2048,512]{2,1,0} all-gather(...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\w+\[[\d,]*\]\S*))\s+(" + "|".join(_COLLECTIVE_OPS) + r")[\s(.]"
)


def _shape_bytes(shape_str: str) -> int:
    """Sum byte sizes over all shapes in a result string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    totals: dict            # op -> bytes
    count: dict             # op -> #ops

    @property
    def total_bytes(self) -> int:
        return sum(self.totals.values())


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveStats:
    totals = {op: 0 for op in _COLLECTIVE_OPS}
    counts = {op: 0 for op in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if op + "-start" in line and op in line:
            pass  # the start op carries the shape; done ops counted via start
        b = _shape_bytes(shape_str)
        # all-reduce moves ~2× the buffer (reduce-scatter + all-gather phases)
        if op == "all-reduce":
            b *= 2
        totals[op] += b
        counts[op] += 1
    return CollectiveStats(totals=totals, count=counts)


@dataclasses.dataclass
class Roofline:
    """All byte/flop quantities are PER DEVICE (the compiled HLO module is the
    per-device SPMD program); model_flops is global and divided by chips."""

    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # per device
    hlo_bytes: float             # per device
    collective_bytes: float      # per device link traffic
    model_flops: float           # GLOBAL 6·N·D useful flops
    collectives: Optional[CollectiveStats] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops) — remat/redundancy waste."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the dominant term sets
        step time: MODEL_FLOPS/(chips·peak) / max(term)."""
        t_star = self.model_flops / (self.chips * PEAK_FLOPS)
        t_dom = max(self.t_compute, self.t_memory, self.t_collective)
        return (t_star / t_dom) if t_dom > 0 else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape, n_params_active: int, kind: str) -> float:
    """Useful FLOPs: 6·N·D (train) / 2·N·D (inference) plus the attention
    score/value matmuls (2·L²·H·hd per layer per sequence fwd, causal-halved;
    windowed archs pay 2·L·W instead of L²). For small-d long-L cells the
    attention term dominates — omitting it (pure 6ND) would misread those
    rooflines."""
    B, L = shape.global_batch, shape.seq_len
    fwd_bwd = 3.0 if kind == "train" else 1.0
    tokens = B * L if kind in ("train", "prefill") else B
    flops = (6.0 if kind == "train" else 2.0) * n_params_active * tokens

    # attention context flops (only attention-bearing layers)
    n_attn_layers = cfg.n_layers
    if cfg.family == "hybrid" and cfg.attn_every:
        n_attn_layers = cfg.n_layers // cfg.attn_every
    if cfg.family == "ssm":
        n_attn_layers = 0   # recurrent: context flops are in the params term
    if n_attn_layers and kind in ("train", "prefill"):
        eff = min(L, cfg.sliding_window) if cfg.sliding_window else L
        ctx = L * eff if cfg.sliding_window else L * L / 2.0  # causal half
        if not cfg.causal:
            ctx = L * L
        flops += fwd_bwd * n_attn_layers * B * 4.0 * ctx * cfg.n_heads * cfg.hd
    elif n_attn_layers:  # decode: one token attends to the whole cache
        eff = min(L, cfg.sliding_window) if cfg.sliding_window else L
        flops += n_attn_layers * B * 4.0 * eff * cfg.n_heads * cfg.hd
    return flops


def extract_cost(compiled) -> tuple[float, float]:
    """(flops, bytes) from compiled.cost_analysis(), robust to key variants."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return 0.0, 0.0
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return flops, byts
