"""repro.roofline — roofline-term extraction from compiled artifacts."""
from .analysis import (
    CollectiveStats,
    Roofline,
    collective_bytes_from_hlo,
    extract_cost,
    model_flops_for,
)

__all__ = [
    "Roofline", "CollectiveStats", "collective_bytes_from_hlo",
    "extract_cost", "model_flops_for",
]
