"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so for
scan-over-layers models (everything here) it under-reports FLOPs/bytes by
~n_layers× and misses every collective inside the loop. This walker parses
the optimized HLO text, builds the computation call graph, extracts static
trip counts from loop-condition constants (jax scans lower to
``while (i < N)`` with N inline), and accumulates:

  * flops        — 2·prod(result)·prod(contract) for dots; |result| for
                   element-wise/fusion ops (dots dominate);
  * bytes        — operands + result per top-level (post-fusion) op — the
                   same HBM-traffic convention XLA's own model uses;
  * collective_bytes — result-buffer sizes of all-gather / reduce-scatter /
                   all-to-all / collective-permute / collective-broadcast
                   (+2× for all-reduce), trip-multiplied. Async pairs
                   (``all-reduce-start``/``-done`` etc.) charge once, on the
                   ``-start`` op, using the destination buffer of its tuple
                   result type — not the whole (operand, result) tuple.

Conditionals charge their worst-case branch (field-wise max): SUMO's K-step
rSVD refresh — and on the 2D mesh its r-width panel collectives — lives in a
``lax.cond`` branch, which a pick-one-branch walk would hide entirely.


Validated against analytic 6·N·D model FLOPs in tests (agrees within the
attention/remat overhead margin).
"""
from __future__ import annotations

import dataclasses
import re
from functools import lru_cache
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\("
)
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _last_shape_info(shape_str: str) -> tuple[int, int, tuple[int, ...]]:
    """(elements, bytes, dims) of the LAST array shape in the string.

    Async collectives (``all-gather-start`` …) return a ``(operand, result)``
    tuple; the destination buffer — the wire payload — is the last element.
    For plain single-shape result types this is just that shape.
    """
    last = None
    for m in _SHAPE_RE.finditer(shape_str):
        if m.group(1) in _DTYPE_BYTES:
            last = m
    if last is None:
        return 0, 0, ()
    dims = tuple(int(d) for d in last.group(2).split(",")) if last.group(2) \
        else ()
    n = 1
    for d in dims:
        n *= d
    return n, n * _DTYPE_BYTES[last.group(1)], dims


def _collective_payload(op: "Op") -> tuple[int, tuple[int, ...]]:
    """(bytes, dims) a collective op moves, charging async pairs once.

    ``-done`` ops are free (the ``-start`` already paid). ``-start`` ops use
    the last shape of their tuple result type; synchronous ops have a single
    result shape so the same rule applies.
    """
    if op.opcode.endswith("-done"):
        return 0, ()
    _, b, dims = _last_shape_info(op.result_type)
    return b, dims


def _shape_info(shape_str: str) -> tuple[int, int]:
    """(total elements, total bytes) over all array shapes in the string."""
    elems_total, bytes_total = 0, 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems_total += n
        bytes_total += n * _DTYPE_BYTES[dt]
    return elems_total, bytes_total


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    operands: list
    attrs: str
    raw: str = ""


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: dict = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.collective_breakdown.items():
            self.collective_breakdown[k] = self.collective_breakdown.get(k, 0) + v
        self.unknown_trip_loops += o.unknown_trip_loops
        return self

    def scaled(self, mult: float) -> "Cost":
        return Cost(
            flops=self.flops * mult,
            bytes=self.bytes * mult,
            collective_bytes=self.collective_bytes * mult,
            collective_breakdown={
                k: v * mult for k, v in self.collective_breakdown.items()
            },
            unknown_trip_loops=self.unknown_trip_loops,
        )


def _split_operands(args: str) -> list[str]:
    """Operand %names at depth 0 of the op's argument list."""
    out, depth, cur = [], 0, []
    for ch in args:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    names = []
    for tok in out:
        # Operands print either bare ("%name") or type-prefixed
        # ("f32[64,64]{1,0} %name", "(s32[], f32[8]) %name") depending on the
        # HLO printer options; the %name is always the last token.
        m = re.search(r"%([\w.\-]+)$", tok.strip())
        names.append(m.group(1) if m else None)
    return names


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[Op]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: dict[str, Cost] = {}

    # -- parsing ------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.computations[cur] = []
                    if line.strip().startswith("ENTRY"):
                        self.entry = cur
                continue
            if line.strip() == "}":
                continue
            if cur is None:
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            name, rtype, opcode = m.group(1), m.group(2), m.group(3)
            # operand segment: text between opcode '(' and its matching ')'
            start = m.end()
            depth, i = 1, start
            while i < len(line) and depth:
                if line[i] in "([{":
                    depth += 1
                elif line[i] in ")]}":
                    depth -= 1
                i += 1
            operands = _split_operands(line[start : i - 1])
            attrs = line[i:]
            self.computations[cur].append(
                Op(name=name, result_type=rtype, opcode=opcode,
                   operands=operands, attrs=attrs, raw=line)
            )

    # -- shape table -----------------------------------------------------------
    @lru_cache(maxsize=None)
    def _shapes(self, comp: str) -> dict[str, str]:
        return {op.name: op.result_type for op in self.computations.get(comp, [])}

    def _trip_count(self, cond_comp: str) -> Optional[int]:
        """Largest s32 constant in the loop condition ≈ trip count (jax scans
        lower to `while (i < N)` with i0=0, step 1)."""
        consts = []
        for op in self.computations.get(cond_comp, []):
            if op.opcode == "constant" and "s32[]" in op.result_type:
                m = re.search(r"constant\((\d+)\)", op.raw)
                if m:
                    consts.append(int(m.group(1)))
        if not consts:  # constants may be inlined elsewhere in the condition
            for op in self.computations.get(cond_comp, []):
                for m in _CONST_RE.finditer(op.raw):
                    consts.append(int(m.group(1)))
        return max(consts) if consts else None

    def _called(self, attrs: str, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w.\-]+)", attrs)
        return m.group(1) if m else None

    def _branch_targets(self, op: Op) -> list[str]:
        """Branch computations of a conditional: the predicated
        true/false pair or the indexed branch_computations list."""
        m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
        if m:
            return [t for t in re.findall(r"%?([\w.\-]+)", m.group(1))
                    if t in self.computations]
        out = []
        for key in ("true_computation", "false_computation"):
            t = self._called(op.attrs, key)
            if t:
                out.append(t)
        return out

    def _while_trip(self, op: Op) -> Optional[int]:
        """Trip count of a while op: XLA's own loop analysis when present
        (``backend_config={"known_trip_count":{"n":"10"}}``), else the
        largest constant in the loop condition."""
        m = re.search(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"', op.raw)
        if m:
            return int(m.group(1))
        cond = self._called(op.attrs, "condition")
        return self._trip_count(cond) if cond else None

    _SLICE_OPS = ("dynamic-slice", "slice", "gather", "dynamic-update-slice")

    @lru_cache(maxsize=None)
    def _fusion_root(self, target: str) -> Optional[Op]:
        for iop in self.computations.get(target, []):
            if "ROOT" in iop.raw:
                return iop
        return None

    def _fusion_operand_bytes(self, op: Op, target: str, shapes: dict) -> int:
        """Bytes actually read from each fusion operand: slice-sized when the
        matching parameter only feeds slice/gather ops inside the fusion."""
        inner_ops = self.computations.get(target, [])
        # parameter name -> parameter index
        param_idx: dict[str, int] = {}
        for iop in inner_ops:
            if iop.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", iop.raw)
                if m:
                    param_idx[iop.name] = int(m.group(1))
        # consumers per parameter
        touched_by_param: dict[int, int] = {}
        sliced_only: dict[int, bool] = {i: True for i in param_idx.values()}
        for iop in inner_ops:
            for nm in iop.operands:
                if nm in param_idx:
                    pi = param_idx[nm]
                    if iop.opcode in self._SLICE_OPS:
                        sb = _shape_info(iop.result_type)[1]
                        if iop.opcode == "dynamic-update-slice" and len(iop.operands) > 1:
                            upd = iop.operands[1]
                            ishapes = self._shapes(target)
                            if upd in ishapes:
                                sb = _shape_info(ishapes[upd])[1]
                        touched_by_param[pi] = touched_by_param.get(pi, 0) + sb
                    else:
                        sliced_only[pi] = False
        total = 0
        for j, nm in enumerate(op.operands):
            if nm is None or nm not in shapes:
                continue
            full = _shape_info(shapes[nm])[1]
            if sliced_only.get(j, False) and j in touched_by_param:
                total += min(full, touched_by_param[j])
            else:
                total += full
        return total

    # -- cost ----------------------------------------------------------------
    def computation_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        shapes = self._shapes(comp)
        for op in self.computations.get(comp, []):
            total += self._op_cost(op, comp, shapes)
        self._memo[comp] = total
        return total

    def _op_cost(self, op: Op, comp: str, shapes: dict[str, str]) -> Cost:
        oc = op.opcode
        res_elems, res_bytes = _shape_info(op.result_type)

        if oc in ("parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "after-all", "partition-id", "replica-id",
                  "iota"):
            return Cost()

        if oc == "while":
            body = self._called(op.attrs, "body")
            cond = self._called(op.attrs, "condition")
            inner = Cost()
            if body:
                inner += self.computation_cost(body)
            if cond:
                inner += self.computation_cost(cond)
            trip = self._while_trip(op)
            if trip is None:
                c = inner.scaled(1.0)
                c.unknown_trip_loops += 1
                return c
            return inner.scaled(trip)

        if oc == "conditional":
            # One branch executes per call; charge the WORST-CASE branch per
            # field (a steady-state/refresh pair would otherwise hide the
            # refresh collectives entirely — SUMO's K-step rSVD lives in a
            # cond branch). Field-wise max is an upper bound for any single
            # execution and keeps ≤-style budget asserts sound.
            worst = Cost()
            for branch in self._branch_targets(op):
                c = self.computation_cost(branch)
                worst.flops = max(worst.flops, c.flops)
                worst.bytes = max(worst.bytes, c.bytes)
                worst.collective_bytes = max(worst.collective_bytes,
                                             c.collective_bytes)
                for k, v in c.collective_breakdown.items():
                    worst.collective_breakdown[k] = max(
                        worst.collective_breakdown.get(k, 0), v)
                worst.unknown_trip_loops = max(worst.unknown_trip_loops,
                                               c.unknown_trip_loops)
            return worst

        if oc in ("call", "async-start"):
            target = self._called(op.attrs, "calls") or self._called(
                op.attrs, "to_apply"
            )
            if target:
                return self.computation_cost(target)
            return Cost(flops=res_elems, bytes=res_bytes)

        # operand bytes
        opnd_bytes = 0
        for name in op.operands:
            if name and name in shapes:
                opnd_bytes += _shape_info(shapes[name])[1]
        io_bytes = opnd_bytes + res_bytes

        base = oc.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES:
            if oc.endswith("-done"):
                return Cost()
            payload, _ = _collective_payload(op)
            cb = payload * (2 if base == "all-reduce" else 1)
            return Cost(
                bytes=io_bytes, collective_bytes=cb,
                collective_breakdown={base: cb},
            )

        if oc in ("dot", "dot-general"):
            contract = 1
            mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
            lhs = op.operands[0] if op.operands else None
            if mm and lhs and lhs in shapes:
                dims_m = _SHAPE_RE.search(shapes[lhs])
                if dims_m and dims_m.group(2):
                    lhs_dims = [int(d) for d in dims_m.group(2).split(",")]
                    for ci in mm.group(1).split(","):
                        if ci != "":
                            contract *= lhs_dims[int(ci)]
            return Cost(flops=2.0 * res_elems * contract, bytes=io_bytes)

        if oc == "convolution":
            # not used by our models; approximate as elementwise
            return Cost(flops=res_elems, bytes=io_bytes)

        if oc == "fusion":
            target = self._called(op.attrs, "calls")
            inner = self.computation_cost(target) if target else Cost()
            # Bytes: operands that are only dynamic-sliced/gathered inside the
            # fusion contribute their SLICE bytes, not the whole buffer —
            # otherwise a scan backward that slices its 500 MB residual stack
            # per timestep books 24576× the buffer (measured 300+ TB phantom
            # traffic on the xlstm cell).
            touched = self._fusion_operand_bytes(op, target, shapes) if target \
                else opnd_bytes
            # a DUS-rooted fusion writes only the update slice (in-place)
            out_bytes = res_bytes
            root = self._fusion_root(target)
            if root is not None and root.opcode == "dynamic-update-slice":
                ishapes = self._shapes(target)
                upd = root.operands[1] if len(root.operands) > 1 else None
                if upd in ishapes:
                    out_bytes = _shape_info(ishapes[upd])[1]
                flops_est = inner.flops
            else:
                flops_est = max(inner.flops, float(res_elems))
            return Cost(
                flops=flops_est,
                bytes=touched + out_bytes,
                collective_bytes=inner.collective_bytes,
                collective_breakdown=dict(inner.collective_breakdown),
            )

        if oc in ("custom-call",):
            return Cost(flops=res_elems, bytes=io_bytes)

        if oc == "dynamic-update-slice":
            # in-place update: traffic = read + write of the UPDATE slice only
            # (XLA aliases the target buffer; counting the full operand would
            # overcount scan-carry updates by the buffer/slice ratio)
            upd = op.operands[1] if len(op.operands) > 1 else None
            upd_bytes = _shape_info(shapes[upd])[1] if upd in shapes else res_bytes
            return Cost(flops=0.0, bytes=2.0 * upd_bytes)

        if oc in ("dynamic-slice", "slice", "gather"):
            # indexed read + write of the slice; the source buffer is not
            # streamed in full
            return Cost(flops=0.0, bytes=2.0 * res_bytes)

        if oc == "scatter":
            upd = op.operands[2] if len(op.operands) > 2 else None
            upd_bytes = _shape_info(shapes[upd])[1] if upd in shapes else res_bytes
            return Cost(flops=float(res_elems), bytes=3.0 * upd_bytes)

        if oc in ("copy", "copy-start", "transpose", "reshape", "broadcast",
                  "pad", "concatenate", "reverse", "select",
                  "compare", "convert", "reduce", "sort", "map", "clamp"):
            return Cost(flops=float(res_elems), bytes=io_bytes)

        if oc.endswith("-done"):
            return Cost()

        # default element-wise
        return Cost(flops=float(res_elems), bytes=io_bytes)

    def total(self) -> Cost:
        if self.entry is None:
            # fall back: sum all computations not called by others (rare)
            raise ValueError("no ENTRY computation found in HLO")
        return self.computation_cost(self.entry)


def analyze_hlo(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()


def top_dots(hlo_text: str, k: int = 20) -> list[dict]:
    """Top-k dot ops by trip-multiplied FLOPs, with source attribution."""
    model = HloCostModel(hlo_text)
    entries: list[dict] = []

    def walk(comp: str, mult: float, seen: tuple):
        if comp in seen:
            return
        shapes = model._shapes(comp)
        for op in model.computations.get(comp, []):
            if op.opcode in ("dot", "dot-general"):
                c = model._op_cost(op, comp, shapes)
                m = re.search(r'op_name="([^"]*)"', op.raw)
                entries.append({
                    "flops": c.flops * mult, "mult": mult,
                    "shape": op.result_type.strip(),
                    "source": m.group(1) if m else "?",
                })
            elif op.opcode == "while":
                body = model._called(op.attrs, "body")
                cond = model._called(op.attrs, "condition")
                trip = model._while_trip(op)
                for c2 in (body, cond):
                    if c2:
                        walk(c2, mult * (trip or 1), seen + (comp,))
            elif op.opcode in ("call", "conditional", "fusion"):
                tgt = model._called(op.attrs, "calls") or model._called(
                    op.attrs, "to_apply")
                if tgt:
                    walk(tgt, mult, seen + (comp,))

    walk(model.entry, 1.0, ())
    entries.sort(key=lambda e: -e["flops"])
    return entries[:k]


def top_bytes(hlo_text: str, k: int = 20) -> list[dict]:
    """Top-k ops by trip-multiplied HBM traffic, with source attribution."""
    model = HloCostModel(hlo_text)
    entries: list[dict] = []

    def walk(comp: str, mult: float, seen: tuple):
        if comp in seen:
            return
        shapes = model._shapes(comp)
        for op in model.computations.get(comp, []):
            if op.opcode == "while":
                body = model._called(op.attrs, "body")
                cond = model._called(op.attrs, "condition")
                trip = model._while_trip(op)
                for c2 in (body, cond):
                    if c2:
                        walk(c2, mult * (trip or 1), seen + (comp,))
                continue
            if op.opcode in ("call", "conditional"):
                tgt = model._called(op.attrs, "calls") or model._called(
                    op.attrs, "to_apply")
                if tgt:
                    walk(tgt, mult, seen + (comp,))
                continue
            c = model._op_cost(op, comp, shapes)
            if c.bytes <= 0:
                continue
            m = re.search(r'op_name="([^"]*)"', op.raw)
            entries.append({
                "bytes": c.bytes * mult, "mult": mult, "opcode": op.opcode,
                "shape": op.result_type.strip(),
                "source": m.group(1) if m else "?",
            })

    walk(model.entry, 1.0, ())
    entries.sort(key=lambda e: -e["bytes"])
    return entries[:k]


def iter_collectives(hlo_text) -> list[dict]:
    """Every collective instance in the program, trip-multiplied.

    Walks the call graph (while bodies × trip count, call/fusion targets, and
    EVERY branch of a conditional — nested conditionals included), charging
    async ``-start``/``-done`` pairs once on the ``-start`` op. Each entry:

      op          collective kind ("all-gather", "all-reduce", ...)
      bytes       payload bytes × trip multiplier (×2 for all-reduce)
      payload     un-multiplied single-execution payload bytes (no ×2)
      dims        destination-buffer dims tuple, e.g. (4, 104, 16)
      mult        trip multiplier
      shape       raw HLO result-type string
      source      jax op_name metadata ("?" when absent)
      branch_depth  0 at top level, ≥1 inside a lax.cond branch
      computation   HLO computation the op lives in

    This is the single collective walker: ``top_collectives`` and the
    ``repro.analysis.collectives`` budget lint are both built on it.

    Accepts HLO text or an existing HloCostModel.
    """
    model = hlo_text if isinstance(hlo_text, HloCostModel) \
        else HloCostModel(hlo_text)
    entries: list[dict] = []

    def walk(comp: str, mult: float, seen: tuple, branch_depth: int):
        if comp in seen:
            return
        for op in model.computations.get(comp, []):
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                payload, dims = _collective_payload(op)
                b = payload * (2 if base == "all-reduce" else 1)
                m = re.search(r'op_name="([^"]*)"', op.raw)
                entries.append({
                    "op": base, "bytes": b * mult, "payload": payload,
                    "dims": dims, "mult": mult,
                    "shape": op.result_type.strip(),
                    "source": m.group(1) if m else "?",
                    "branch_depth": branch_depth, "computation": comp,
                })
            elif op.opcode == "while":
                body = model._called(op.attrs, "body")
                cond = model._called(op.attrs, "condition")
                trip = model._while_trip(op)
                for c in (body, cond):
                    if c:
                        walk(c, mult * (trip or 1), seen + (comp,),
                             branch_depth)
            elif op.opcode == "conditional":
                for tgt in model._branch_targets(op):
                    walk(tgt, mult, seen + (comp,), branch_depth + 1)
            elif op.opcode in ("call", "fusion", "async-start"):
                tgt = model._called(op.attrs, "calls") or model._called(
                    op.attrs, "to_apply")
                if tgt:
                    walk(tgt, mult, seen + (comp,), branch_depth)

    if model.entry is not None:
        walk(model.entry, 1.0, (), 0)
    return entries


_REDUCTION_OPS = {
    # ops that ACCUMULATE: the element type they run in is the precision the
    # whole reduction happens at, regardless of what the operands were.
    "reduce", "reduce-window", "dot", "all-reduce", "reduce-scatter",
}

_FLOAT_DTYPES = {"f8e4m3fn", "f8e5m2", "f16", "bf16", "f32", "f64"}


def _result_dtypes(shape_str: str) -> tuple:
    """All known array element types in an HLO result-type string, in order
    (singleton for plain results, several for tuple results)."""
    return tuple(m.group(1) for m in _SHAPE_RE.finditer(shape_str)
                 if m.group(1) in _DTYPE_BYTES)


def iter_reductions(hlo_text) -> list[dict]:
    """Every accumulating op in the program — the precision lint's walk.

    Same call-graph traversal as ``iter_collectives`` (while bodies × trip,
    every conditional branch, call/fusion/async targets, ``-done`` free) but
    emitting the ops whose RESULT element type is an accumulation precision:
    ``reduce`` / ``reduce-window`` (with their ``to_apply`` computation),
    ``dot``, ``all-reduce`` and ``reduce-scatter``. Each entry:

      op              base opcode ("reduce", "dot", "all-reduce", ...)
      accum_dtypes    result element types (tuple; singleton for plain ops)
      operand_dtypes  element type of each operand (None when unresolvable)
      to_apply        reduce computation name, or None (dots)
      comp_root       ROOT opcode of the reduce computation ("add", "maximum",
                      "or", ...) — additive roots are the precision-sensitive
                      ones; None when there is no to_apply
      comp_dtype      ROOT result element type of the reduce computation
      mult            trip multiplier
      shape           raw HLO result-type string
      source          jax op_name metadata ("?" when absent)
      branch_depth    0 at top level, >=1 inside a lax.cond branch
      computation     HLO computation the op lives in

    ``repro.analysis.precision.audit_accumulation_hlo`` is built on this.
    Accepts HLO text or an existing HloCostModel.
    """
    model = hlo_text if isinstance(hlo_text, HloCostModel) \
        else HloCostModel(hlo_text)
    entries: list[dict] = []

    def walk(comp: str, mult: float, seen: tuple, branch_depth: int):
        if comp in seen:
            return
        shapes = model._shapes(comp)
        for op in model.computations.get(comp, []):
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in _REDUCTION_OPS and not op.opcode.endswith("-done"):
                to_apply = model._called(op.attrs, "to_apply")
                comp_root = comp_dtype = None
                if to_apply:
                    root = model._fusion_root(to_apply)
                    if root is not None:
                        comp_root = root.opcode
                        rdts = _result_dtypes(root.result_type)
                        comp_dtype = rdts[0] if rdts else None
                operand_dtypes = []
                for nm in op.operands:
                    dts = _result_dtypes(shapes.get(nm, "")) if nm else ()
                    operand_dtypes.append(dts[0] if dts else None)
                m = re.search(r'op_name="([^"]*)"', op.raw)
                entries.append({
                    "op": base,
                    "accum_dtypes": _result_dtypes(op.result_type),
                    "operand_dtypes": tuple(operand_dtypes),
                    "to_apply": to_apply,
                    "comp_root": comp_root, "comp_dtype": comp_dtype,
                    "mult": mult, "shape": op.result_type.strip(),
                    "source": m.group(1) if m else "?",
                    "branch_depth": branch_depth, "computation": comp,
                })
                continue
            if op.opcode == "while":
                body = model._called(op.attrs, "body")
                cond = model._called(op.attrs, "condition")
                trip = model._while_trip(op)
                for c in (body, cond):
                    if c:
                        walk(c, mult * (trip or 1), seen + (comp,),
                             branch_depth)
            elif op.opcode == "conditional":
                for tgt in model._branch_targets(op):
                    walk(tgt, mult, seen + (comp,), branch_depth + 1)
            elif op.opcode in ("call", "fusion", "async-start"):
                tgt = model._called(op.attrs, "calls") or model._called(
                    op.attrs, "to_apply")
                if tgt:
                    walk(tgt, mult, seen + (comp,), branch_depth)

    if model.entry is not None:
        walk(model.entry, 1.0, (), 0)
    return entries


def top_collectives(hlo_text: str, k: int = 20) -> list[dict]:
    """Attribute collective bytes to jax source ops: walks the call graph with
    trip-count multipliers and returns the top-k collectives by total bytes,
    each with its HLO shape and the jax op_name metadata (source attribution).
    """
    entries = iter_collectives(hlo_text)
    entries.sort(key=lambda e: -e["bytes"])
    return entries[:k]
