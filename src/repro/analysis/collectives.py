"""Collective-budget lint: declarative budgets over compiled HLO.

The steady-state collective discipline is SUMO's distributed contract
(ANALYSIS.md):

  * 1D (data-only) steady path: the ONLY collective is the all-gather of
    each sharded bucket's delta stack. No all-reduce, ever.
  * 2D (data, model) steady path: delta all-gathers (model axis then data
    axis), plus r-width panel all-reduces (Gram matrices, projections,
    staleness scalars) whose minor dimensions never exceed l = rank +
    oversample. Nothing ever moves a full (B, long, short) buffer through
    an all-reduce — that is exactly the PR 5 concatenate-seam failure.
  * checkpoint restore (cross-mesh resharding): pure data movement —
    permutes/gathers bounded by the state size, no reductions.

A :class:`CollectiveBudget` states which collective kinds may appear and,
per kind, an :class:`OpBudget` of shape/width/count/byte caps.  Kinds not
named in the budget are forbidden outright.  :func:`audit_hlo` checks a
compiled program's optimized HLO against a budget using the single shared
walker ``repro.roofline.hlo_cost.iter_collectives`` (trip-multiplied,
async-pair-aware, conditional branches included) and returns a
:class:`BudgetReport` whose violations carry stable machine-readable codes:

  forbidden-collective     a kind the budget does not allow at all
  shape-not-allowed        op's buffer dims outside the allowed-shapes set
  panel-width-exceeded     min/second-minor dim above the r-panel caps
  op-bytes-exceeded        a single instance above max_op_bytes
  op-count-exceeded        more instances of a kind than max_count
  kind-total-bytes-exceeded   per-kind trip-multiplied total above cap
  total-bytes-exceeded     whole-program collective bytes above cap
  cond-branch-required     op required to live inside a lax.cond branch
                           (refresh-only collectives) found on the
                           every-step path

Branch accounting: totals SUM over all conditional branches, an upper bound
on any single execution — sound for <=-style budgets (and strictly tighter
than nothing: a forbidden op in an untaken branch still fails, which is the
point of a static lint).

Tests (tests/test_sumo_sharded.py, tests/test_rsvd_sharded.py),
benchmarks/step_time.py and the tier-1 static lint (tools/lint_static.py)
all consume the named budget factories below instead of private regex
audits.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable, Optional

from ..roofline.hlo_cost import HloCostModel, iter_collectives

__all__ = [
    "OpBudget", "CollectiveBudget", "BudgetViolation", "BudgetReport",
    "BudgetError", "audit_hlo", "assert_budget",
    "bucket_collective_plan", "padded_delta_bytes", "delta_bytes",
    "pad_overhead_frac", "steady_1d_budget", "steady_2d_budget",
    "refresh_2d_budget", "restore_budget", "steady_dp_compressed_budget",
]


@dataclasses.dataclass(frozen=True)
class OpBudget:
    """Caps for one collective kind. ``None`` means unconstrained."""
    max_count: Optional[int] = None          # instances (un-multiplied)
    max_op_bytes: Optional[int] = None       # single-instance payload bytes
    max_total_bytes: Optional[float] = None  # trip-multiplied kind total
    allowed_shapes: Optional[frozenset] = None  # exact dims tuples
    max_min_dim: Optional[int] = None        # smallest buffer dim (r-panel)
    max_second_dim: Optional[int] = None     # second-smallest buffer dim
    max_elems: Optional[int] = None          # buffer element count
    cond_only: bool = False                  # must sit inside a lax.cond


@dataclasses.dataclass(frozen=True)
class CollectiveBudget:
    """Named set of per-kind OpBudgets; unlisted kinds are forbidden."""
    name: str
    rules: dict  # kind -> OpBudget
    max_total_bytes: Optional[float] = None  # across all kinds
    note: str = ""


@dataclasses.dataclass(frozen=True)
class BudgetViolation:
    code: str        # stable machine-readable code (see module docstring)
    kind: str        # collective kind ("all-reduce", ...)
    detail: str      # human-readable specifics
    shape: str = ""  # raw HLO result type of the offending op
    source: str = "" # jax op_name metadata

    def __str__(self):
        loc = f" [{self.source}]" if self.source and self.source != "?" else ""
        return f"{self.code}: {self.kind} {self.shape}{loc} — {self.detail}"


@dataclasses.dataclass
class BudgetReport:
    budget: str
    ok: bool
    violations: list
    collectives: list    # the raw iter_collectives entries audited
    total_bytes: float

    def summary(self) -> str:
        head = (f"budget '{self.budget}': "
                f"{'OK' if self.ok else 'FAIL'} — "
                f"{len(self.collectives)} collective op(s), "
                f"{self.total_bytes:.0f} trip-multiplied bytes")
        if self.violations:
            head += "\n" + "\n".join(f"  ✗ {v}" for v in self.violations)
        return head


class BudgetError(AssertionError):
    def __init__(self, report: BudgetReport):
        self.report = report
        super().__init__(report.summary())


def audit_hlo(hlo_text, budget: CollectiveBudget) -> BudgetReport:
    """Check compiled HLO (text or HloCostModel) against a budget."""
    entries = iter_collectives(hlo_text)
    violations: list[BudgetViolation] = []
    counts: dict[str, int] = {}
    kind_bytes: dict[str, float] = {}
    total = 0.0

    for e in entries:
        kind, dims = e["op"], e["dims"]
        counts[kind] = counts.get(kind, 0) + 1
        kind_bytes[kind] = kind_bytes.get(kind, 0.0) + e["bytes"]
        total += e["bytes"]
        rule = budget.rules.get(kind)
        if rule is None:
            violations.append(BudgetViolation(
                "forbidden-collective", kind,
                f"kind not allowed by budget '{budget.name}'",
                e["shape"], e["source"]))
            continue
        if rule.allowed_shapes is not None and dims not in rule.allowed_shapes:
            violations.append(BudgetViolation(
                "shape-not-allowed", kind,
                f"dims {dims} not in allowed set "
                f"{sorted(rule.allowed_shapes)}", e["shape"], e["source"]))
        if dims:
            sdims = sorted(dims)
            if rule.max_min_dim is not None and sdims[0] > rule.max_min_dim:
                violations.append(BudgetViolation(
                    "panel-width-exceeded", kind,
                    f"min dim {sdims[0]} > {rule.max_min_dim} "
                    "(not an r-width panel)", e["shape"], e["source"]))
            if (rule.max_second_dim is not None and len(sdims) > 1
                    and sdims[1] > rule.max_second_dim):
                violations.append(BudgetViolation(
                    "panel-width-exceeded", kind,
                    f"second-minor dim {sdims[1]} > {rule.max_second_dim}",
                    e["shape"], e["source"]))
        if rule.max_elems is not None:
            n = 1
            for d in dims:
                n *= d
            if n > rule.max_elems:
                violations.append(BudgetViolation(
                    "panel-width-exceeded", kind,
                    f"{n} elements > {rule.max_elems}",
                    e["shape"], e["source"]))
        if rule.max_op_bytes is not None and e["payload"] > rule.max_op_bytes:
            violations.append(BudgetViolation(
                "op-bytes-exceeded", kind,
                f"payload {e['payload']} B > {rule.max_op_bytes} B",
                e["shape"], e["source"]))
        if rule.cond_only and e["branch_depth"] == 0:
            violations.append(BudgetViolation(
                "cond-branch-required", kind,
                "refresh-only collective found on the every-step path",
                e["shape"], e["source"]))

    for kind, rule in budget.rules.items():
        if rule.max_count is not None and counts.get(kind, 0) > rule.max_count:
            violations.append(BudgetViolation(
                "op-count-exceeded", kind,
                f"{counts[kind]} instances > {rule.max_count}"))
        if (rule.max_total_bytes is not None
                and kind_bytes.get(kind, 0.0) > rule.max_total_bytes):
            violations.append(BudgetViolation(
                "kind-total-bytes-exceeded", kind,
                f"{kind_bytes[kind]:.0f} B > {rule.max_total_bytes:.0f} B"))
    if budget.max_total_bytes is not None and total > budget.max_total_bytes:
        violations.append(BudgetViolation(
            "total-bytes-exceeded", "*",
            f"{total:.0f} B > {budget.max_total_bytes:.0f} B"))

    return BudgetReport(budget=budget.name, ok=not violations,
                        violations=violations, collectives=entries,
                        total_bytes=total)


def assert_budget(hlo_text, budget: CollectiveBudget) -> BudgetReport:
    """audit_hlo, raising BudgetError on any violation."""
    report = audit_hlo(hlo_text, budget)
    if not report.ok:
        raise BudgetError(report)
    return report


# -- bucket plans: the shapes a budget should expect ------------------------

_KEY_RE = re.compile(r"^(\d+)x(\d+)$")


@dataclasses.dataclass(frozen=True)
class BucketPlanEntry:
    key: str          # "LONGxSHORT"
    b_true: int       # true stacked matrix count
    b_padded: int     # after zero-slot padding to a multiple of data shards
    long: int         # true long dim
    long_padded: int  # after edge-row padding to a multiple of model shards
    short: int
    rank: int         # r columns held in Q
    sharded: bool     # runs under shard_map (vs the vmap fallback)
    b_gathered: bool  # B is sharded too => a second, data-axis delta gather

    @property
    def delta_bytes(self) -> int:
        """fp32 bytes of the TRUE delta stack (no padding)."""
        return self.b_true * self.long * self.short * 4

    @property
    def padded_delta_bytes(self) -> int:
        """fp32 bytes of the padded delta stack actually gathered."""
        return self.b_padded * self.long_padded * self.short * 4


def bucket_collective_plan(state, mesh, *, data_axis: str = "data",
                           model_axis: str = "model") -> list:
    """Per-bucket gather footprint, derived from a sumo state's Q/M stacks.

    ``state`` is a SumoState (or anything with ``.Q``/``.M`` dicts keyed
    "LONGxSHORT"); Q stacks are (B, long_padded, r) and M stacks are
    (B, r, short). The bucket key carries the TRUE long dim, so padding is
    recoverable without re-tracing.

    Sharding mirrors core.sumo._bucketed_updates exactly: with a model
    axis > 1 EVERY bucket runs the 2D shard_map path (B additionally
    sharded when it pays, i.e. B > 1 on a data axis > 1); on a 1D mesh
    only B > 1 buckets shard and singletons keep the vmap fallback.
    """
    axes = dict(getattr(mesh, "shape", {}) or {})
    data_sz = int(axes.get(data_axis, 1))
    model_sz = int(axes.get(model_axis, 1))
    entries = []
    for key, q in state.Q.items():
        m = _KEY_RE.match(key)
        if not m:
            continue
        long_d = int(m.group(1))
        short_d = int(m.group(2))
        b_true, long_padded, r = int(q.shape[0]), int(q.shape[1]), \
            int(q.shape[2])
        b_gathered = data_sz > 1 and b_true > 1
        sharded = model_sz > 1 or b_gathered
        b_padded = b_true
        if b_gathered and b_true % data_sz:
            b_padded = -(-b_true // data_sz) * data_sz
        entries.append(BucketPlanEntry(
            key=key, b_true=b_true, b_padded=b_padded, long=long_d,
            long_padded=long_padded if model_sz > 1 else long_d,
            short=short_d, rank=r, sharded=sharded, b_gathered=b_gathered))
    return entries


def delta_bytes(plan: Iterable) -> int:
    return sum(e.delta_bytes for e in plan if e.sharded)


def padded_delta_bytes(plan: Iterable) -> int:
    return sum(e.padded_delta_bytes for e in plan if e.sharded)


def pad_overhead_frac(plan: Iterable) -> float:
    """(padded - true) / true delta bytes over the sharded buckets."""
    d = delta_bytes(plan)
    return (padded_delta_bytes(plan) - d) / d if d else 0.0


# -- named budgets ----------------------------------------------------------

def _gather_shapes(plan, data_shards: int) -> frozenset:
    """Delta all-gather buffer shapes the 1D/2D paths legitimately emit:
    the full padded stack (data-axis gather result, and the model-axis
    result for B-replicated buckets) plus the per-data-shard block stack
    (model-axis gather result when B is sharded too)."""
    shapes = set()
    for e in plan:
        if not e.sharded:
            continue
        shapes.add((e.b_padded, e.long_padded, e.short))
        if e.b_gathered and data_shards > 1:
            shapes.add((max(1, e.b_padded // data_shards), e.long_padded,
                        e.short))
    return frozenset(shapes)


def _state_regather_shapes(plan, data_shards: int) -> frozenset:
    """State re-gather shapes for RAGGED-B buckets (b_padded != b_true).

    Such a bucket's resident state cannot be data-sharded (B does not
    divide), so the engine pads and shards internally and XLA gathers the
    padded Q/M/prev_norm stacks back to the replicated-B layout on the way
    out. Divisible buckets keep their state sharded end to end and emit
    none of these."""
    shapes = set()
    for e in plan:
        if not e.sharded or e.b_padded == e.b_true:
            continue
        shapes.add((e.b_padded, e.long_padded, e.rank))
        if data_shards > 1:
            shapes.add((max(1, e.b_padded // data_shards), e.long_padded,
                        e.rank))
        shapes.add((e.b_padded, e.rank, e.short))
        shapes.add((e.b_padded,))
    return frozenset(shapes)


def _state_regather_bytes(plan, data_shards: int) -> int:
    total = 0
    for dims in _state_regather_shapes(plan, data_shards):
        n = 1
        for d in dims:
            n *= d
        total += n * 4
    return total


def steady_1d_budget(plan: Iterable, *, name: str = "steady-1d"
                     ) -> CollectiveBudget:
    """Data-only mesh, steady state: delta all-gathers and NOTHING else.

    Q/M/prev_norm are resident; no all-reduce may appear anywhere in the
    compiled update (refresh is per-matrix on a 1D mesh, so even the cond
    branch is collective-free beyond the gathers).
    """
    plan = list(plan)
    pdb = padded_delta_bytes(plan)
    return CollectiveBudget(
        name=name,
        rules={
            "all-gather": OpBudget(
                allowed_shapes=_gather_shapes(plan, 1),
                max_total_bytes=float(pdb) if pdb else None,
            ),
        },
        max_total_bytes=float(pdb) if pdb else None,
        note="1D steady path: only the delta all-gather, bounded by the "
             "padded delta bytes.",
    )


def _panel_rules(plan, rank_plus_over: int, data_shards: int) -> dict:
    plan = list(plan)
    l = rank_plus_over
    short_max = max((e.short for e in plan if e.sharded), default=0)
    b_max = max((e.b_padded for e in plan if e.sharded), default=0)
    pdb = padded_delta_bytes(plan)
    # Two gathers per bucket (model axis then data axis), each bounded by
    # the padded delta stack, plus the ragged-B state re-gathers.
    gather_total = 2.0 * pdb + _state_regather_bytes(plan, data_shards)
    panel_elems = b_max * l * short_max
    return {
        "all-gather": OpBudget(
            allowed_shapes=_gather_shapes(plan, data_shards)
            | _state_regather_shapes(plan, data_shards),
            max_total_bytes=gather_total if pdb else None,
        ),
        "all-reduce": OpBudget(
            # r-width panels only: Grams (blk,l,l), sketch panels
            # (blk,short,l), projections (blk,r,short), staleness scalars.
            # The per-instance caps are the machine check that catches a
            # full (B, long, short) all-reduce (the PR 5 seam failure) —
            # panel elems are smaller by a factor of long/l.
            max_min_dim=l,
            max_second_dim=max(l, short_max),
            max_elems=panel_elems if b_max else None,
            max_op_bytes=panel_elems * 4 if b_max else None,
        ),
    }


def steady_2d_budget(plan: Iterable, rank_plus_over: int, data_shards: int, *,
                     name: str = "steady-2d") -> CollectiveBudget:
    """2D (data, model) mesh: delta all-gathers + r-width panel all-reduces.

    ``rank_plus_over`` is l = rank + oversample, the widest legitimate panel
    minor dim. The compiled update contains the refresh cond branch, so the
    budget admits its panel all-reduces — but never a full-matrix one: the
    elems/width caps reject anything (B, long, short)-sized, which is how
    this budget catches the PR 5 concatenate->all-reduce seam.
    """
    plan = list(plan)
    pdb = padded_delta_bytes(plan)
    rules = _panel_rules(plan, rank_plus_over, data_shards)
    # Aggregate cap: gathers + state re-gathers + panel all-reduce traffic.
    # The panel term is bounded per instance by the width caps; a generous
    # 1x pdb covers the refresh branch's repeated rounds (summed worst-case
    # over cond branches) while a single full-matrix all-reduce of the
    # largest bucket would alone blow the per-instance caps above.
    total = 3.0 * pdb + _state_regather_bytes(plan, data_shards)
    return CollectiveBudget(
        name=name, rules=rules,
        max_total_bytes=total if pdb else None,
        note="2D steady path: two delta gathers per bucket plus r-width "
             "panel all-reduces; full-matrix all-reduce forbidden by the "
             "width caps.",
    )


def refresh_2d_budget(plan: Iterable, rank_plus_over: int, data_shards: int, *,
                      name: str = "refresh-2d") -> CollectiveBudget:
    """Refresh-every-step regime (update_freq=1 benchmarks): same shape
    discipline as steady-2d but with the per-kind aggregate caps lifted —
    the rSVD rounds repeat the panel all-reduces, so only the width caps
    and the gather-shape set are meaningful."""
    plan = list(plan)
    pdb = padded_delta_bytes(plan)
    rules = _panel_rules(plan, rank_plus_over, data_shards)
    return CollectiveBudget(
        name=name, rules=rules,
        max_total_bytes=None,
        note="Refresh branch: panel-width discipline only; totals scale "
             f"with rSVD rounds (padded delta bytes = {pdb}).",
    )


def steady_dp_compressed_budget(wire_plan: Iterable, *,
                                name: str = "steady-dp-compressed",
                                with_loss_scalar: bool = True
                                ) -> CollectiveBudget:
    """Compressed DP gradient exchange, steady state: r×short pmeans ONLY.

    ``wire_plan`` is ``parallel.compression.dp_wire_plan(grads, cfg,
    bases=...)`` — one entry per leaf with the pmean buffer's dims and
    byte-accurate payload sizes. With ``bases`` from the resident SUMO
    state (``core.sumo.sumo_dp_bases``), the per-leaf ranks are read off
    the same Q stacks ``bucket_collective_plan`` describes, so this budget
    composes with the optimizer-side budgets: together they pin the WHOLE
    sharded step's collective story (the optimizer's gathers/panels by
    ``steady_{1d,2d}_budget`` on ``tx.update``'s program, the DP wire by
    this one on the exchange program).

    The caps are the machine check of ROADMAP item 1's bandwidth claim:

      * the only collective kind allowed is ``all-reduce`` (the pmean);
        a basis gather or broadcast appearing on the steady path — e.g.
        extracting the sumo-q bases INSIDE the step instead of once per
        refresh — fails as ``forbidden-collective``;
      * every buffer must be one of the plan's payload shapes (compressed
        (…, r, short) for eligible leaves, the raw shape for exact ones, a
        scalar for the loss when ``with_loss_scalar``) — a full long×short
        pmean of an eligible leaf fails as ``shape-not-allowed`` AND
        ``op-bytes-exceeded`` (its payload exceeds the largest legitimate
        one, since any full-size leaf that large would have been eligible);
      * the kind/global totals cap the trip-multiplied bytes at the plan's
        wire total (×2: ``iter_collectives`` charges all-reduce both ways),
        so even many small illegitimate ops cannot hide.
    """
    plan = list(wire_plan)
    shapes = {tuple(e.payload_dims) for e in plan}
    if with_loss_scalar:
        shapes.add(())
    # Caps are over COMPILED HLO, where XLA promotes sub-f32 float
    # all-reduces to f32 — so a bf16 wire audits at its promoted (hlo)
    # bytes; the true-wire ``payload_bytes`` back the bandwidth claims.
    wire = sum(e.hlo_bytes for e in plan)
    max_payload = max((e.hlo_bytes for e in plan), default=0)
    # the loss scalar rides the same budget: 8 B of slack (f32, ×2)
    slack = 8.0 if with_loss_scalar else 0.0
    total = 2.0 * wire + slack
    return CollectiveBudget(
        name=name,
        rules={
            "all-reduce": OpBudget(
                allowed_shapes=frozenset(shapes),
                max_op_bytes=max_payload if max_payload else None,
                max_count=len(plan) + (1 if with_loss_scalar else 0),
                max_total_bytes=total if wire else None,
            ),
        },
        max_total_bytes=total if wire else None,
        note="Compressed DP exchange: one r-width pmean per eligible leaf "
             "(exact pmean below min_dim), bounded by the wire plan's "
             "bytes; any full long×short DP collective is rejected by "
             "shape, per-op bytes and totals at once.",
    )


def restore_budget(state_bytes: int, *, name: str = "checkpoint-restore"
                   ) -> CollectiveBudget:
    """Cross-mesh checkpoint restore: resharding is pure data movement.

    XLA lowers a sharding change to all-gather / all-to-all /
    collective-permute (possibly with dynamic-slices); a reduction appearing
    here means state is being ARITHMETICALLY combined across devices — a
    restore bug, never resharding.
    """
    cap = 2.0 * float(state_bytes)
    return CollectiveBudget(
        name=name,
        rules={
            "all-gather": OpBudget(max_total_bytes=cap),
            "all-to-all": OpBudget(max_total_bytes=cap),
            "collective-permute": OpBudget(max_total_bytes=cap),
            "collective-broadcast": OpBudget(max_total_bytes=cap),
        },
        max_total_bytes=cap,
        note="Restore/resharding: moves, never reduces.",
    )
