"""Recompile-boundary audit (static pass 4).

The training loop promises that, after warmup, the jitted step recompiles
ONLY at controller-announced boundaries (rank/refresh rebuilds recorded in
``TrainResult.controller_events``) and at fault restarts.  An off-boundary
recompile means a silently unstable jit cache — a shape or static-arg leak
— and shows up as an unexplained step-time spike in production.

Mechanism: ``jax_log_compiles`` emits a "Compiling <name> ..." log record
on the ``jax`` logger for every cache-miss compilation.  ``CompileWatcher``
captures those records (filtered by function name) while the loop runs and
tags each with the loop's current step, reported via ``mark_step``.
``audit_recompiles`` then checks every observed compile step against the
allowed set.

Violation code (stable string): ``off-boundary-recompile``.
"""
from __future__ import annotations

import dataclasses
import logging
import re
from typing import Optional

__all__ = [
    "CompileEvent", "CompileWatcher", "RecompileReport", "RecompileError",
    "mark_step", "current_step", "audit_recompiles",
]

_COMPILING_RE = re.compile(r"Compiling ([\w<>.-]+) ")

# The loop calls mark_step(step) before invoking the jitted step so the
# watcher can attribute a compile log record to a training step. A plain
# module global: the loop and the watcher live in the same process, and
# nested watchers see a consistent value.
_CURRENT_STEP: list = [None]


def mark_step(step: Optional[int]) -> None:
    """Record the training step about to execute (loop-side hook)."""
    _CURRENT_STEP[0] = step


def current_step() -> Optional[int]:
    return _CURRENT_STEP[0]


@dataclasses.dataclass(frozen=True)
class CompileEvent:
    fn_name: str
    step: Optional[int]   # None = compiled outside any marked step
    message: str


class RecompileError(AssertionError):
    pass


class CompileWatcher(logging.Handler):
    """Context manager capturing jax compilation log records.

    with CompileWatcher() as w:
        train(...)
    events = w.events   # every CompileEvent, step-tagged via mark_step()
    """

    def __init__(self, fn_name: Optional[str] = None):
        super().__init__(level=logging.DEBUG)
        self.fn_name = fn_name
        self.events: list = []
        self._logger = logging.getLogger("jax")
        self._prev_enabled = None
        self._prev_level = None
        self._prev_propagate = None
        self._detached: list = []

    def emit(self, record):
        msg = record.getMessage()
        m = _COMPILING_RE.search(msg)
        if not m:
            return
        name = m.group(1)
        if self.fn_name is not None and self.fn_name not in name:
            return
        self.events.append(CompileEvent(
            fn_name=name, step=current_step(), message=msg.split("\n")[0]))

    def __enter__(self):
        import jax
        self._prev_enabled = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        self._prev_level = self._logger.level
        if self._logger.level > logging.WARNING or self._logger.level == 0:
            self._logger.setLevel(logging.WARNING)
        # keep the compile chatter out of the user's stderr while we watch:
        # stop propagation AND park jax's own stderr handler (propagate only
        # governs ancestors, not sibling handlers on the same logger)
        self._prev_propagate = self._logger.propagate
        self._logger.propagate = False
        self._detached = list(self._logger.handlers)
        for h in self._detached:
            self._logger.removeHandler(h)
        self._logger.addHandler(self)
        mark_step(None)
        return self

    def __exit__(self, *exc):
        import jax
        self._logger.removeHandler(self)
        for h in self._detached:
            self._logger.addHandler(h)
        self._detached = []
        jax.config.update("jax_log_compiles", self._prev_enabled)
        self._logger.setLevel(self._prev_level)
        self._logger.propagate = self._prev_propagate
        mark_step(None)
        return False


@dataclasses.dataclass
class RecompileReport:
    ok: bool
    violations: list        # off-boundary CompileEvents
    compiles: list          # all audited CompileEvents
    allowed_steps: frozenset
    warmup_through: int

    def summary(self) -> str:
        head = "recompile audit: " + ("OK" if self.ok else "FAILED")
        lines = [head,
                 f"  compiles observed : {len(self.compiles)}",
                 f"  warmup through    : step {self.warmup_through}",
                 f"  allowed boundaries: {sorted(self.allowed_steps)}"]
        for e in self.violations:
            lines.append(f"  off-boundary-recompile: {e.fn_name} at step "
                         f"{e.step}")
        return "\n".join(lines)


def audit_recompiles(events, fn_name: Optional[str] = None,
                     warmup_through: int = 0,
                     allowed_steps=()) -> RecompileReport:
    """Check captured compile events against the allowed boundaries.

    ``warmup_through``: steps <= this (and None-tagged compiles, which
    happen during tracing/placement before the loop starts stepping) are
    warmup and always allowed.  ``allowed_steps``: controller-announced
    rebuild boundaries — a rebuild at step s recompiles when step s+1 runs,
    so both s and s+1 are accepted.
    """
    allowed = frozenset(allowed_steps)
    audited = [e for e in events
               if fn_name is None or fn_name in e.fn_name]
    violations = []
    for e in audited:
        if e.step is None or e.step <= warmup_through:
            continue
        if e.step in allowed or (e.step - 1) in allowed:
            continue
        violations.append(e)
    return RecompileReport(ok=not violations, violations=violations,
                           compiles=audited, allowed_steps=allowed,
                           warmup_through=warmup_through)
