"""Pass 5 — memory-budget lint over compiled artifacts (ANALYSIS.md).

The paper's headline systems claim — SUMO cuts optimizer-state memory vs
AdamW and the low-rank SOTA (Table 1) — and the serving path's "the KV pool
lives on device ONCE" donation story used to be analytic prose. This pass
makes them machine checks against what XLA actually produced:

  * ``measure_compiled_memory(compiled)`` reads the executable's
    ``memory_analysis()`` stats (argument/output/temp/alias bytes) and
    cross-checks them with an HLO buffer-table walk built on the same
    parser as ``roofline/hlo_cost`` (ENTRY parameters, ROOT result, and the
    ``input_output_alias`` donation table), so the pass still works — and
    can't be lied to by one source — when either side is unavailable.
  * a declarative ``MemoryBudget`` (peak cap, per-category caps for
    params / opt state / transients, donation-savings floor, an exact
    opt-state plan) audited by ``audit_memory``; violations carry stable
    codes::

        peak-bytes-exceeded       donation-not-realized
        transient-exceeds-plan    state-bytes-mismatch

  * analytic factories — ``steady_memory_budget`` / ``refresh_memory_budget``
    / ``dp_compress_memory_budget`` for the training path, derived from
    ``bucket_memory_plan(state, mesh)`` (the resident SumoState stacks), and
    ``serve_decode_memory_budget`` for serving, derived from the KV
    ``BlockPool`` geometry — so every cap is a sum of Table-1 / pool terms,
    not a magic constant.

The donation-savings floor is exact where it matters: a train step that
donates (params, opt_state) must realize ``param_bytes + state_bytes`` of
aliasing, and the paged ``serve_decode`` must realize both pools' bytes —
an un-donated KV pool is precisely a 2× peak-memory bug and fails with
``donation-not-realized`` (and, at the cap, ``peak-bytes-exceeded``).
Falsifiability for all codes is pinned in tests/test_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

from ..roofline.hlo_cost import HloCostModel, _shape_info
from .donation import _ALIAS_PAIR_RE

PyTree = Any

MEMORY_VIOLATION_CODES = (
    "peak-bytes-exceeded",
    "donation-not-realized",
    "transient-exceeds-plan",
    "state-bytes-mismatch",
)

_PARAM_NUM_RE = re.compile(r"parameter\((\d+)\)")


class MemoryBudgetError(AssertionError):
    """A compiled program exceeded its declared memory budget."""


@dataclasses.dataclass(frozen=True)
class MemoryViolation:
    code: str          # one of MEMORY_VIOLATION_CODES
    detail: str
    measured: float    # bytes (or ratio) observed
    limit: float       # the budget's cap / floor it broke

    def __str__(self):
        return f"[{self.code}] {self.detail}"


# ---------------------------------------------------------------------------
# measured side: memory_analysis() + the HLO buffer-table walk
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BufferTable:
    """Entry-computation buffers of one compiled program, from HLO text.

    ``param_bytes`` is indexed by HLO parameter number; ``aliased_params``
    are the parameter numbers the ``input_output_alias`` table donates into
    outputs. Parsed with the same HloCostModel the roofline/collective
    passes use — one parser, no drift.
    """
    param_bytes: tuple
    output_bytes: float
    aliased_params: tuple

    @property
    def argument_bytes(self) -> float:
        return float(sum(self.param_bytes))

    @property
    def alias_bytes(self) -> float:
        return float(sum(self.param_bytes[i] for i in self.aliased_params
                         if i < len(self.param_bytes)))


def hlo_buffer_table(hlo_text: str) -> BufferTable:
    """Walk one program's ENTRY buffers: per-parameter bytes, ROOT output
    bytes, and which parameters the donation table aliases into outputs."""
    model = hlo_text if isinstance(hlo_text, HloCostModel) \
        else HloCostModel(hlo_text)
    params: dict = {}
    out_bytes = 0.0
    for op in model.computations.get(model.entry, []):
        if op.opcode == "parameter":
            m = _PARAM_NUM_RE.search(op.raw)
            if m:
                params[int(m.group(1))] = float(_shape_info(op.result_type)[1])
        if "ROOT" in op.raw:
            out_bytes = float(_shape_info(op.result_type)[1])
    raw = hlo_text if isinstance(hlo_text, str) else ""
    aliased = []
    m = re.search(r"input_output_alias=\{(.*?)\}\s*$",
                  raw, re.MULTILINE | re.DOTALL)
    if m is None:
        m = re.search(r"input_output_alias=\{([^\n]*)", raw)
    if m is not None:
        aliased = sorted({int(g) for g in _ALIAS_PAIR_RE.findall(m.group(1))})
    n = 1 + max(params) if params else 0
    return BufferTable(
        param_bytes=tuple(params.get(i, 0.0) for i in range(n)),
        output_bytes=out_bytes,
        aliased_params=tuple(aliased))


@dataclasses.dataclass(frozen=True)
class MemoryMeasurement:
    """What one compiled executable holds in HBM, by category (bytes)."""
    argument_bytes: float
    output_bytes: float
    temp_bytes: float
    alias_bytes: float             # donated input bytes realized as aliases
    generated_code_bytes: float = 0.0
    table: Optional[BufferTable] = None
    from_stats: bool = True        # memory_analysis() was available

    @property
    def peak_bytes(self) -> float:
        """Live-set upper bound: arguments + outputs + temps + code, with
        donated (aliased) bytes — counted in both arguments and outputs —
        subtracted once. This is what donation buys: an un-donated buffer
        shows up twice here."""
        return (self.argument_bytes + self.output_bytes + self.temp_bytes
                + self.generated_code_bytes - self.alias_bytes)


def measure_compiled_memory(compiled, hlo_text: Optional[str] = None
                            ) -> MemoryMeasurement:
    """Measure a ``jax.jit(...).lower(...).compile()`` executable.

    Primary source is ``compiled.memory_analysis()`` (the dryrun idiom:
    attributes read defensively — backends differ); the HLO buffer table is
    always walked as the cross-check and the fallback when stats are
    missing. Alias bytes take the MINIMUM of the two sources: a donation the
    stats report but the alias table dropped (or vice versa) must not be
    credited to the peak.
    """
    text = hlo_text if hlo_text is not None else compiled.as_text()
    table = hlo_buffer_table(text)
    try:
        stats = compiled.memory_analysis()
    except Exception:
        stats = None
    arg = getattr(stats, "argument_size_in_bytes", None)
    out = getattr(stats, "output_size_in_bytes", None)
    temp = getattr(stats, "temp_size_in_bytes", None)
    alias = getattr(stats, "alias_size_in_bytes", None)
    code = getattr(stats, "generated_code_size_in_bytes", None)
    from_stats = arg is not None
    if alias is None:
        alias = table.alias_bytes
    else:
        alias = min(float(alias), table.alias_bytes) \
            if table.aliased_params or alias == 0 else float(alias)
    return MemoryMeasurement(
        argument_bytes=float(arg) if arg is not None else table.argument_bytes,
        output_bytes=float(out) if out is not None else table.output_bytes,
        temp_bytes=float(temp) if temp is not None else 0.0,
        alias_bytes=float(alias),
        generated_code_bytes=float(code or 0.0),
        table=table, from_stats=from_stats)


# ---------------------------------------------------------------------------
# the budget
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MemoryBudget:
    """Declarative peak-HBM budget for ONE compiled program.

    Caps are bytes; ``None`` disables a check. ``state_plan_bytes`` is the
    EXACT analytic opt-state size (Table 1 applied to the live layout, see
    ``core.memory.predict_state_bytes``) — the measured state tree must
    match it within ``state_tol_frac`` or the audit fails with
    ``state-bytes-mismatch``.
    """
    name: str
    max_peak_bytes: Optional[float] = None
    max_transient_bytes: Optional[float] = None
    min_alias_bytes: Optional[float] = None       # donation-savings floor
    max_param_bytes: Optional[float] = None       # per-category caps,
    max_state_bytes: Optional[float] = None       # checked vs the live trees
    state_plan_bytes: Optional[float] = None
    state_tol_frac: float = 0.0
    note: str = ""


@dataclasses.dataclass
class MemoryReport:
    budget_name: str
    violations: list
    measurement: Optional[MemoryMeasurement] = None
    ok: bool = True

    def summary(self) -> str:
        head = f"memory budget '{self.budget_name}': " + \
            ("OK" if self.ok else f"{len(self.violations)} violation(s)")
        if self.measurement is not None:
            m = self.measurement
            head += (f" (peak={m.peak_bytes:.0f} args={m.argument_bytes:.0f}"
                     f" out={m.output_bytes:.0f} temp={m.temp_bytes:.0f}"
                     f" alias={m.alias_bytes:.0f})")
        return "\n".join([head] + [f"  {v}" for v in self.violations])


def audit_memory(measurement: MemoryMeasurement, budget: MemoryBudget, *,
                 param_bytes: Optional[float] = None,
                 state_bytes: Optional[float] = None) -> MemoryReport:
    """Audit one measured executable against a budget.

    ``param_bytes`` / ``state_bytes`` are the live input trees' sizes
    (``core.memory.tree_param_bytes`` / ``tree_state_bytes``) — the compiled
    artifact can't label which argument is which category, the caller can.
    """
    v: list = []

    def add(code, detail, measured, limit):
        v.append(MemoryViolation(code=code, detail=detail,
                                 measured=float(measured), limit=float(limit)))

    m = measurement
    if budget.max_peak_bytes is not None and m.peak_bytes > budget.max_peak_bytes:
        add("peak-bytes-exceeded",
            f"live-set peak {m.peak_bytes:.0f} B exceeds the plan's "
            f"{budget.max_peak_bytes:.0f} B "
            f"(args={m.argument_bytes:.0f} out={m.output_bytes:.0f} "
            f"temp={m.temp_bytes:.0f} alias={m.alias_bytes:.0f})",
            m.peak_bytes, budget.max_peak_bytes)
    if budget.max_transient_bytes is not None \
            and m.temp_bytes > budget.max_transient_bytes:
        add("transient-exceeds-plan",
            f"temp buffers {m.temp_bytes:.0f} B exceed the transient "
            f"allowance {budget.max_transient_bytes:.0f} B",
            m.temp_bytes, budget.max_transient_bytes)
    if budget.min_alias_bytes is not None \
            and m.alias_bytes < budget.min_alias_bytes:
        add("donation-not-realized",
            f"only {m.alias_bytes:.0f} B of donated inputs alias outputs; "
            f"the budget's donation floor is {budget.min_alias_bytes:.0f} B "
            "(an un-donated buffer is resident TWICE at peak)",
            m.alias_bytes, budget.min_alias_bytes)
    if budget.max_param_bytes is not None and param_bytes is not None \
            and param_bytes > budget.max_param_bytes:
        add("state-bytes-mismatch",
            f"category params: {param_bytes:.0f} B exceeds the cap "
            f"{budget.max_param_bytes:.0f} B",
            param_bytes, budget.max_param_bytes)
    if budget.max_state_bytes is not None and state_bytes is not None \
            and state_bytes > budget.max_state_bytes:
        add("state-bytes-mismatch",
            f"category opt-state: {state_bytes:.0f} B exceeds the cap "
            f"{budget.max_state_bytes:.0f} B",
            state_bytes, budget.max_state_bytes)
    if budget.state_plan_bytes is not None and state_bytes is not None:
        tol = budget.state_tol_frac * budget.state_plan_bytes
        if abs(state_bytes - budget.state_plan_bytes) > tol:
            add("state-bytes-mismatch",
                f"measured opt-state {state_bytes:.0f} B != analytic plan "
                f"{budget.state_plan_bytes:.0f} B "
                f"(tol {tol:.0f} B) — Table 1 and the live engine drifted",
                state_bytes, budget.state_plan_bytes)
    return MemoryReport(budget_name=budget.name, violations=v,
                        measurement=measurement, ok=not v)


def assert_memory_budget(measurement, budget, **kw) -> MemoryReport:
    """``audit_memory`` that raises MemoryBudgetError on violations."""
    report = audit_memory(measurement, budget, **kw)
    if not report.ok:
        raise MemoryBudgetError(report.summary())
    return report


def audit_state_ratio(name: str, measured_bytes: float, baseline_bytes: float,
                      max_ratio: float) -> MemoryReport:
    """The Table-1 ratio claim as a lint: ``measured / baseline`` must not
    exceed ``max_ratio`` (e.g. SUMO state vs AdamW state at the paper's
    >= 20% reduction → max_ratio 0.8). Fails ``state-bytes-mismatch``."""
    ratio = measured_bytes / max(baseline_bytes, 1.0)
    v = []
    if ratio > max_ratio:
        v.append(MemoryViolation(
            code="state-bytes-mismatch",
            detail=f"state-bytes ratio {ratio:.3f} exceeds the analytic "
                   f"plan's {max_ratio:.3f} "
                   f"({measured_bytes:.0f} B vs {baseline_bytes:.0f} B "
                   "baseline) — the paper's memory-reduction claim does "
                   "not hold on the live trees",
            measured=ratio, limit=max_ratio))
    return MemoryReport(budget_name=name, violations=v, ok=not v)


def audit_table1_state(rank: int = 8, arch_id: str = "smollm-360m", *,
                       ratios=(("adamw", 0.80), ("galore", 1.00)),
                       methods=("sumo", "muon", "galore", "adamw", "lora")
                       ) -> tuple:
    """The paper's Table-1 memory claim as a lint, on LIVE optimizer trees.

    For every method, the measured state bytes of the real engine must equal
    ``core.memory.predict_state_bytes`` exactly (code ``state-bytes-mismatch``
    on drift); then the measured SUMO bytes must not exceed each baseline's
    measured bytes × the claimed ratio cap. Returns
    ({method: (measured, predicted)}, [MemoryViolation...]) — shared by
    benchmarks/memory_table.py and the analysis driver, so the CSV rows and
    the PASS/FAIL line cannot diverge.
    """
    import jax

    from ..configs import get_smoke_config
    from ..core.lora import LoraConfig, init_lora_params
    from ..core.memory import (predict_state_bytes, tree_param_bytes,
                               tree_state_bytes)
    from ..models import init_params
    from ..train.steps import make_optimizer

    cfg = get_smoke_config(arch_id)
    params = init_params(cfg, jax.random.PRNGKey(0))
    results = {}
    violations = []
    for method in methods:
        if method == "lora":
            adapters = init_lora_params(params, LoraConfig(rank=rank))
            tx = make_optimizer("adamw", 1e-3, adapters)
            measured = tree_param_bytes(adapters) \
                + tree_state_bytes(tx.init(adapters))
        else:
            tx = make_optimizer(method, 1e-3, params, rank=rank,
                                update_freq=8)
            measured = tree_state_bytes(tx.init(params))
        predicted = predict_state_bytes(method, params, rank)
        results[method] = (measured, predicted)
        if measured != predicted:
            violations.append(MemoryViolation(
                code="state-bytes-mismatch",
                detail=f"{method}: live engine state {measured} B != exact "
                       f"layout predictor {predicted} B — Table 1 and the "
                       "engine drifted",
                measured=float(measured), limit=float(predicted)))
    for base, cap in ratios:
        if base in results and "sumo" in results:
            rep = audit_state_ratio(
                f"table1/sumo-vs-{base}", float(results["sumo"][0]),
                float(results[base][0]), max_ratio=cap)
            violations.extend(rep.violations)
    return results, violations


# ---------------------------------------------------------------------------
# analytic plans: resident SumoState decomposition
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketMemoryEntry:
    """Resident bytes of one bucket's optimizer state (padded, as stored)."""
    key: str            # "LONGxSHORT"
    b_padded: int
    long_padded: int
    short: int
    rank: int
    q_bytes: int
    m_bytes: int
    norm_bytes: int
    sharded: bool
    data_shards: int
    model_shards: int

    @property
    def state_bytes(self) -> int:
        return self.q_bytes + self.m_bytes + self.norm_bytes

    @property
    def per_shard_bytes(self) -> float:
        """Bytes resident per device: Q is (B/data, long/model, r); M and
        prev_norm shard over data only (they are replicated over model)."""
        d = max(1, self.data_shards)
        mshards = max(1, self.model_shards) if self.sharded else 1
        return (self.q_bytes / (d * mshards)
                + (self.m_bytes + self.norm_bytes) / d)


@dataclasses.dataclass(frozen=True)
class BucketMemoryPlan:
    entries: tuple
    fallback_bytes: int     # AdamW mu/nu on non-matrix leaves
    scalar_bytes: int       # step counters, refresh keys

    @property
    def bucket_bytes(self) -> int:
        return sum(e.state_bytes for e in self.entries)

    @property
    def total_bytes(self) -> int:
        return self.bucket_bytes + self.fallback_bytes + self.scalar_bytes


_KEY_RE = re.compile(r"^(\d+)x(\d+)$")


def bucket_memory_plan(state: PyTree, mesh=None) -> BucketMemoryPlan:
    """Decompose a live optimizer state's resident bytes by bucket/category.

    Mirrors ``bucket_collective_plan``'s reading of the SumoState Q/M stacks
    (bucket layout: Q "LONGxSHORT" -> (B, long_padded, r)), plus the
    fallback AdamW states and scalar bookkeeping, so
    ``plan.total_bytes == tree_state_bytes(state)`` exactly — the budget
    factories below derive their caps from this decomposition, and
    ``core.memory.predict_state_bytes`` (params + config only) pins it
    against the paper's Table-1 model.
    """
    import jax

    from ..core.sumo import SumoState

    data_shards = model_shards = 1
    if mesh is not None:
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        data_shards = int(axis_sizes.get("data", 1))
        model_shards = int(axis_sizes.get("model", 1))

    entries = []
    fallback = 0
    scalars = 0

    def _bytes(leaf) -> int:
        return int(leaf.size) * leaf.dtype.itemsize if hasattr(leaf, "dtype") \
            else 0

    def visit(node):
        nonlocal fallback, scalars
        if isinstance(node, SumoState):
            qd = node.Q if isinstance(node.Q, dict) else {}
            for key in sorted(qd):
                m = _KEY_RE.match(str(key))
                q = qd[key]
                if m is None or getattr(q, "ndim", 0) != 3:
                    fallback += _bytes(q)
                    continue
                mm = node.M[key]
                pn = node.prev_norm[key]
                b, lp, r = (int(d) for d in q.shape)
                short = int(mm.shape[-1])
                sharded = b > 1 and (b % data_shards == 0
                                     or data_shards > 1)
                entries.append(BucketMemoryEntry(
                    key=str(key), b_padded=b, long_padded=lp, short=short,
                    rank=r, q_bytes=_bytes(q), m_bytes=_bytes(mm),
                    norm_bytes=_bytes(pn), sharded=sharded,
                    data_shards=data_shards, model_shards=model_shards))
            for other in jax.tree_util.tree_leaves(
                    (node.step, getattr(node, "key", None))):
                scalars += _bytes(other)
            if not isinstance(node.Q, dict):      # leaf layout: charge as-is
                for leaf in jax.tree_util.tree_leaves(
                        (node.Q, node.M, node.prev_norm)):
                    fallback += _bytes(leaf)
            return
        if isinstance(node, dict):
            for k in node:
                visit(node[k])
            return
        if isinstance(node, (list, tuple)) and not hasattr(node, "dtype"):
            # NamedTuples and plain containers: recurse fields
            for item in node:
                visit(item)
            return
        b = _bytes(node)
        if b <= 4 and getattr(node, "ndim", 1) == 0:
            scalars += b
        else:
            fallback += b

    visit(state)
    return BucketMemoryPlan(entries=tuple(entries),
                            fallback_bytes=fallback, scalar_bytes=scalars)


# ---------------------------------------------------------------------------
# budget factories
# ---------------------------------------------------------------------------

def steady_memory_budget(params: PyTree, state: PyTree, mesh=None, *,
                         batch_bytes: float = 0.0,
                         activation_bytes: float = 0.0,
                         transient_mult: float = 3.0,
                         out_slack_bytes: float = 4096.0,
                         state_plan_bytes: Optional[float] = None,
                         name: str = "memory-steady-train") -> MemoryBudget:
    """Budget for the compiled train/update step with (params, opt_state)
    donated. Every term is derived from the live trees:

      * donation floor = param + state bytes EXACTLY (both trees are
        donated and every leaf keeps its shape — anything less means the
        partitioner dropped an alias and the buffer is resident twice);
      * transient allowance = ``transient_mult`` × (param + state) +
        ``batch_bytes`` + ``activation_bytes`` — gradients and the
        refresh-cond workspace are O(params); the fwd/bwd activation live
        set scales with batch tokens instead, so callers auditing a real
        train step pass ``core.memory.analytic_activation_bytes(cfg,
        batch, seq)`` for it;
      * peak = the aliased resident set (params + state counted ONCE) +
        batch + metrics slack + the transient allowance.
    """
    from ..core.memory import tree_param_bytes, tree_state_bytes

    pb = float(tree_param_bytes(params))
    sb = float(tree_state_bytes(state))
    resident = pb + sb
    transient_cap = transient_mult * resident + float(batch_bytes) \
        + float(activation_bytes)
    return MemoryBudget(
        name=name,
        max_peak_bytes=resident + float(batch_bytes) + out_slack_bytes
        + transient_cap,
        max_transient_bytes=transient_cap,
        min_alias_bytes=resident,
        max_param_bytes=pb,
        max_state_bytes=sb,
        state_plan_bytes=state_plan_bytes,
        note="steady train step: donated params+state alias in full; "
             "transients bounded by a params-proportional allowance plus "
             "the analytic activation live set")


def refresh_memory_budget(params: PyTree, state: PyTree, mesh=None, *,
                          rank_plus_over: int,
                          batch_bytes: float = 0.0,
                          activation_bytes: float = 0.0,
                          transient_mult: float = 3.0,
                          name: str = "memory-refresh-train") -> MemoryBudget:
    """Like ``steady_memory_budget`` plus the rSVD refresh workspace: per
    bucket, the sketch panel (B, long_padded, l), its Gram/CholeskyQR2
    factors (B, l, l) and the projected moment (B, l, short), l = rank +
    oversample. The compiled step materializes the refresh as a cond
    branch, so its workspace belongs in the transient allowance even for
    update_freq > 1 programs."""
    plan = bucket_memory_plan(state, mesh)
    l = int(rank_plus_over)
    workspace = 0.0
    for e in plan.entries:
        workspace += 4.0 * e.b_padded * (
            e.long_padded * l           # sketch / basis panel
            + 2 * l * l                 # Gram + triangular factor
            + l * e.short)              # projected moment
    base = steady_memory_budget(params, state, mesh,
                                batch_bytes=batch_bytes,
                                activation_bytes=activation_bytes,
                                transient_mult=transient_mult, name=name)
    return dataclasses.replace(
        base,
        max_transient_bytes=base.max_transient_bytes + workspace,
        max_peak_bytes=base.max_peak_bytes + workspace,
        note="refresh-boundary train step: steady budget + per-bucket rSVD "
             f"workspace (l={l})")


def dp_compress_memory_budget(params: PyTree, state: PyTree, wire_plan,
                              n_workers: int, mesh=None, *,
                              batch_bytes: float = 0.0,
                              activation_bytes: float = 0.0,
                              transient_mult: float = 3.0,
                              name: str = "memory-dp-compress") -> MemoryBudget:
    """The --dp-compress step's budget: the steady budget widened by the
    per-worker error-feedback residuals (one full-gradient-shaped tree per
    local worker, donated with the comp state) and the r×short exchange
    payloads (bf16 on the wire, fp32 in the factors)."""
    from ..core.memory import tree_param_bytes
    from ..parallel.compression import wire_bytes

    pb = float(tree_param_bytes(params))
    ef_bytes = float(n_workers) * pb                 # fp32 EF residual tree
    payload = 2.0 * float(wire_bytes(wire_plan))     # compress + decompress
    base = steady_memory_budget(params, state, mesh,
                                batch_bytes=batch_bytes,
                                activation_bytes=activation_bytes,
                                transient_mult=transient_mult, name=name)
    return dataclasses.replace(
        base,
        min_alias_bytes=base.min_alias_bytes + ef_bytes,
        max_transient_bytes=base.max_transient_bytes
        + float(n_workers) * pb + payload,
        max_peak_bytes=base.max_peak_bytes + 2.0 * ef_bytes + payload,
        note=f"dp-compress step: steady budget + {n_workers} per-worker EF "
             "residuals (donated) + exchange payloads")


def serve_decode_memory_budget(cfg, ccfg, params: PyTree, *,
                               transient_mult: float = 2.5,
                               name: str = "memory-serve-decode"
                               ) -> MemoryBudget:
    """Budget for the compiled paged ``serve_decode``, derived from the KV
    ``BlockPool`` geometry: both pools are
    (n_layers, n_blocks, block_size, n_kv_heads, hd) in the model's compute
    dtype, donated, and must alias in full — the pool is the dominant
    buffer, and failing to donate it is exactly a 2× peak bug. The decode
    transients (per-slot context gathers, scatter staging, one slot-batch of
    logits, hidden activations) track the pool, not the params — so the
    allowance is ``transient_mult`` × pool bytes plus the logits batch and a
    small params fraction. A pool compiled at 2× the plan geometry blows
    BOTH the transient allowance and the peak cap."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..core.memory import tree_param_bytes
    from ..models import init_kv_pool

    pools = jax.eval_shape(lambda: init_kv_pool(
        cfg, ccfg.n_blocks, ccfg.block_size))
    pool_bytes = float(sum(
        int(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize for p in pools))
    pb = float(tree_param_bytes(params))
    S = int(ccfg.num_slots)
    logits_bytes = 4.0 * S * int(cfg.vocab)
    small_io = 4.0 * S * (8 + ccfg.n_blocks)         # tables/lengths/temps/keys
    transient_cap = transient_mult * pool_bytes + 4.0 * logits_bytes + pb / 8.0
    return MemoryBudget(
        name=name,
        max_peak_bytes=pb + pool_bytes + logits_bytes + small_io
        + transient_cap,
        max_transient_bytes=transient_cap,
        min_alias_bytes=pool_bytes,
        max_param_bytes=pb,
        note=f"paged serve_decode: pools ({pool_bytes:.0f} B) donated and "
             "aliased in full; peak = params + ONE copy of the pools + "
             "pool-proportional decode transients")
