"""Pad-inertness prover: a structured-zeros abstract interpreter over jaxprs.

The bucketed SUMO update runs on PADDED stacks: ragged long dims gain
edge-pad rows (zero rows appended so the model axis divides evenly) and
ragged B dims gain pad slots (zero matrices appended so the data axis
divides evenly). Correctness of the whole 2D engine rests on one invariant:

    pad rows and pad slots are INERT — exactly zero into every op,
    exactly zero out of every op, so slicing them off at the end
    recovers bit-identical unpadded results.

This module proves that mechanically. It interprets the jaxpr of the
update under an abstract domain that tracks *structured zeros*:

  ``Zeros``  per-dimension trailing-zero slabs ``(count, deps)`` — the
             trailing ``count`` slices along a dim are exactly zero;
             ``deps`` is the set of mesh axis names the structure may vary
             across (empty = shard-uniform).
  ``Conc``   a concrete scalar (e.g. ``axis_index`` under the last-shard
             assignment, literals, small integer arithmetic).
  ``Aff``    an affine integer array ``off + sum_d stride_d * i_d`` (iotas
             and index arithmetic — the live-row index ramps).
  ``Mask``   a boolean array that is True everywhere except trailing bands
             (``i_d < n_d - tfalse_d`` AND-ed over dims) — the live-row
             masks produced by comparing an ``Aff`` ramp against a bound.
  ``TOP``    no information.

Shard-local code (inside ``shard_map``) is evaluated under the LAST-shard
assignment: ``axis_index(a) = size(a) - 1``. A slab with ``deps = {a}``
therefore reads "on the last ``a``-shard, trailing ``count`` slices are
zero"; entering ``shard_map`` adds the mapped axes to ``deps``, and a
zero claim may only be exported back to the global view when its deps are
covered by the axes that shard that dimension (the trailing global block
belongs to the last shard).

Soundness caveats — the same explicit axioms the superseded prose proof in
``core/rsvd.py`` relied on, now stated once, in code:

  * FINITE ARITHMETIC: ``0 * x = 0`` assumes no Inf/NaN operand. The
    engine masks with ``jnp.where`` (not multiplication) precisely so pad
    lanes never see non-finite values; the prover inherits the assumption
    for ``mul``.
  * NONSINGULAR TRIANGULAR FACTORS: ``triangular_solve`` propagates zero
    columns/rows of the RHS assuming the triangular factor is invertible —
    guaranteed by the shifted CholeskyQR2 (the Gram matrix is made
    strictly SPD before factoring).
  * EPS-GUARDED DIVISION: ``div`` propagates the numerator's zeros
    assuming a finite nonzero denominator (all engine denominators are
    ``+ eps``-guarded).

Decompositions (``qr``/``svd``/``eigh``/``cholesky``) are TOP: the Q
factor of a zero block is NOT zero (it is an arbitrary orthonormal
basis), and the prover does not pretend otherwise — the end-to-end claims
survive because every decomposition output is subsequently multiplied by
a structured-zero operand, which the ``dot_general`` rule tracks.

Unknown primitives are TOP. Everything here is conservative: the prover
can fail on a correct program (and then the program should be made more
obviously correct), but a proved claim holds.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

try:
    from jax.core import Literal as _Literal
except ImportError:  # pragma: no cover - jax internal layout drift
    from jax._src.core import Literal as _Literal

__all__ = [
    "Slab", "Zeros", "Conc", "Aff", "Mask", "TOP",
    "ShardMapRecord", "InertnessResult", "InertnessError",
    "analyze_jaxpr", "Claim", "check_claims", "prove_update_inertness",
    "prove_refresh_inertness",
]

EMPTY = frozenset()


@dataclasses.dataclass(frozen=True)
class Slab:
    count: int
    deps: frozenset = EMPTY


class _Top:
    def __repr__(self):
        return "TOP"


TOP = _Top()


@dataclasses.dataclass(frozen=True)
class Zeros:
    """Trailing-zero slabs, one per dimension (aligned with the aval)."""
    slabs: tuple  # tuple[Slab, ...]

    def __repr__(self):
        return "Zeros(" + ",".join(
            f"{s.count}{sorted(s.deps) if s.deps else ''}"
            for s in self.slabs) + ")"


@dataclasses.dataclass(frozen=True)
class Conc:
    v: object
    deps: frozenset = EMPTY


@dataclasses.dataclass(frozen=True)
class Aff:
    """off + sum_d strides[d] * i_d (integer array)."""
    off: int
    strides: tuple
    deps: frozenset = EMPTY


@dataclasses.dataclass(frozen=True)
class Mask:
    """bool array: True iff i_d < n_d - tfalse[d] for every dim d."""
    tfalse: tuple
    deps: frozenset = EMPTY


def _shape(v):
    return tuple(v.aval.shape)


def _no_zeros(ndim):
    return Zeros(tuple(Slab(0) for _ in range(ndim)))


def _all_zeros(shape):
    if not shape:
        return Conc(0.0)
    return Zeros(tuple(Slab(n) for n in shape))


def as_zeros(av, shape) -> Zeros:
    """Collapse any abstract value to its zero-slab content."""
    if isinstance(av, Zeros):
        return av
    if isinstance(av, Conc) and not shape and _is_zero_scalar(av.v):
        return Zeros(())
    return _no_zeros(len(shape))


def _is_zero_scalar(v) -> bool:
    try:
        return float(v) == 0.0
    except (TypeError, ValueError):
        return False


def is_all_zero(av, shape) -> bool:
    if isinstance(av, Conc):
        return not shape and _is_zero_scalar(av.v)
    if not isinstance(av, Zeros):
        return False
    if not shape:
        return False
    return any(s.count >= n and n > 0 for s, n in zip(av.slabs, shape))


def _union_deps(av) -> frozenset:
    if isinstance(av, Zeros):
        out = EMPTY
        for s in av.slabs:
            out |= s.deps
        return out
    return getattr(av, "deps", EMPTY)


def _add_deps(av, deps, shape):
    """Taint an abstract value with extra axis deps (keeps its refinement)."""
    if not deps:
        if isinstance(av, (Zeros, Conc, Aff, Mask)):
            return av
        return _no_zeros(len(shape))
    if isinstance(av, Conc):
        return Conc(av.v, av.deps | deps)
    if isinstance(av, Aff):
        return Aff(av.off, av.strides, av.deps | deps)
    if isinstance(av, Mask):
        return Mask(av.tfalse, av.deps | deps)
    z = as_zeros(av, shape)
    return Zeros(tuple(
        Slab(s.count, (s.deps | deps) if s.count else EMPTY)
        for s in z.slabs))


def _meet_zeros(a: Zeros, b: Zeros) -> Zeros:
    return Zeros(tuple(
        Slab(min(sa.count, sb.count), sa.deps | sb.deps)
        for sa, sb in zip(a.slabs, b.slabs)))


# -- shard_map records and results ------------------------------------------

@dataclasses.dataclass
class ShardMapRecord:
    out_shapes: list   # global shapes of the shard_map eqn's outputs
    out_slabs: list    # globalized Zeros per output


@dataclasses.dataclass
class InertnessResult:
    out_slabs: list           # Zeros per flat jaxpr output
    out_shapes: list
    records: list             # ShardMapRecord per shard_map eqn encountered


class InertnessError(AssertionError):
    pass


class _Ctx:
    def __init__(self):
        self.axis_sizes: dict[str, int] = {}
        self.records: list[ShardMapRecord] = []


# -- the interpreter --------------------------------------------------------

_ZERO_PRESERVING_UNARY = {
    "neg", "abs", "sign", "sqrt", "cbrt", "sin", "tan", "sinh", "tanh",
    "asin", "atan", "asinh", "atanh", "erf", "erf_inv", "expm1", "log1p",
    "floor", "ceil", "round", "real", "imag", "conj",
    "convert_element_type", "copy", "stop_gradient", "reduce_precision",
    "square",
}

_CALL_PRIMS = {"pjit", "closed_call", "core_call", "xla_call", "remat",
               "checkpoint", "custom_jvp_call", "custom_vjp_call",
               "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr"}


def analyze_jaxpr(closed_jaxpr, arg_claims: Optional[list] = None,
                  axis_sizes: Optional[dict] = None) -> InertnessResult:
    """Run the prover over a ClosedJaxpr.

    ``arg_claims``: optional list aligned with the flat invars; each entry
    is None or a dict ``{dim: trailing_zero_count}`` asserting structured
    zeros of that input (e.g. the inductive hypothesis that a state Q
    stack's edge-pad rows are zero coming in).
    """
    ctx = _Ctx()
    ctx.axis_sizes.update(axis_sizes or {})
    jaxpr = closed_jaxpr.jaxpr
    env: dict = {}

    for var, const in zip(jaxpr.constvars, closed_jaxpr.consts):
        env[var] = _classify_const(const)
    for i, var in enumerate(jaxpr.invars):
        claim = (arg_claims[i] if arg_claims and i < len(arg_claims)
                 else None)
        shape = _shape(var)
        if claim:
            slabs = [Slab(0)] * len(shape)
            for d, c in claim.items():
                slabs[d] = Slab(min(int(c), shape[d]))
            env[var] = Zeros(tuple(slabs))
        else:
            env[var] = _no_zeros(len(shape))
    _interp(jaxpr, env, ctx)
    outs = [as_zeros(_read(env, v), _shape(v)) for v in jaxpr.outvars]
    return InertnessResult(
        out_slabs=outs, out_shapes=[_shape(v) for v in jaxpr.outvars],
        records=ctx.records)


def _classify_const(c):
    try:
        arr = np.asarray(c)
    except Exception:
        return TOP
    if arr.ndim == 0:
        return Conc(arr.item())
    if arr.size and not np.any(arr):
        return _all_zeros(arr.shape)
    return _no_zeros(arr.ndim)


def _read(env, atom):
    if isinstance(atom, _Literal):
        return _classify_const(atom.val)
    return env.get(atom, TOP)


def _interp(jaxpr, env, ctx):
    for eqn in jaxpr.eqns:
        ins = [_read(env, a) for a in eqn.invars]
        outs = _eqn(eqn, ins, env, ctx)
        for var, av in zip(eqn.outvars, outs):
            env[var] = av


def _top_outs(eqn):
    return [TOP for _ in eqn.outvars]


def _eqn(eqn, ins, env, ctx):
    name = eqn.primitive.name
    h = _HANDLERS.get(name)
    if h is not None:
        return h(eqn, ins, ctx)
    if name in _ZERO_PRESERVING_UNARY:
        av = ins[0]
        if isinstance(av, (Zeros, Conc, Aff, Mask)):
            if name == "convert_element_type" and isinstance(av, Mask):
                # bool mask -> numeric: trailing-false bands become zeros
                return [Zeros(tuple(Slab(t, av.deps) for t in av.tfalse))]
            return [av if not isinstance(av, Conc) else
                    Conc(av.v if name != "neg" else _neg(av.v), av.deps)]
        return _top_outs(eqn)
    if name in _CALL_PRIMS:
        return _call(eqn, ins, ctx)
    return _top_outs(eqn)


def _neg(v):
    try:
        return -v
    except TypeError:
        return v


def _call(eqn, ins, ctx):
    inner = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
             or eqn.params.get("fun_jaxpr"))
    if inner is None:
        return _top_outs(eqn)
    closed = inner
    jaxpr = getattr(closed, "jaxpr", closed)
    consts = getattr(closed, "consts", ())
    # custom_jvp/vjp pass extra tracing args before the real operands
    args = ins[-len(jaxpr.invars):] if len(ins) >= len(jaxpr.invars) else ins
    sub = {}
    for var, const in zip(jaxpr.constvars, consts):
        sub[var] = _classify_const(const)
    for var, av in zip(jaxpr.invars, args):
        sub[var] = av
    _interp(jaxpr, sub, ctx)
    return [as_zeros(_read(sub, v), _shape(v)) if not isinstance(
        _read(sub, v), (Conc, Aff, Mask)) else _read(sub, v)
        for v in jaxpr.outvars][: len(eqn.outvars)] + \
        [TOP] * max(0, len(eqn.outvars) - len(jaxpr.outvars))


# -- elementwise ------------------------------------------------------------

def _bin_zero_sets(a, b, eqn):
    sa = as_zeros(a, _shape(eqn.invars[0])).slabs
    sb = as_zeros(b, _shape(eqn.invars[1])).slabs
    shape = _shape(eqn.outvars[0])
    # scalar operand against array: treat scalar zeros as nothing /
    # everything per its concrete value at the call sites below
    return sa, sb, shape


def _h_add(eqn, ins, ctx):
    a, b = ins
    out_shape = _shape(eqn.outvars[0])
    for x, y in ((a, b), (b, a)):
        if isinstance(x, Conc) and isinstance(y, Aff) and _is_int(x.v):
            return [Aff(y.off + int(x.v), y.strides, y.deps | x.deps)]
    if isinstance(a, Aff) and isinstance(b, Aff) and a.strides == b.strides:
        pass  # adding two ramps doubles strides; rare — fall through
    if isinstance(a, Conc) and isinstance(b, Conc):
        try:
            v = a.v + b.v if eqn.primitive.name == "add" else a.v - b.v
            return [Conc(v, a.deps | b.deps)]
        except TypeError:
            return _top_outs(eqn)
    for x, y in ((a, b), (b, a)):
        if isinstance(x, Conc) and _is_zero_scalar(x.v):
            if eqn.primitive.name == "add" or x is b:
                return [as_zeros(y, out_shape)]
    za = as_zeros(a, _shape_of(eqn.invars[0], out_shape))
    zb = as_zeros(b, _shape_of(eqn.invars[1], out_shape))
    if len(za.slabs) != len(out_shape) or len(zb.slabs) != len(out_shape):
        return _top_outs(eqn)
    return [_meet_zeros(za, zb)]


def _shape_of(atom, fallback):
    s = tuple(atom.aval.shape)
    return s if s else fallback


def _h_mul(eqn, ins, ctx):
    a, b = ins
    out_shape = _shape(eqn.outvars[0])
    if isinstance(a, Conc) and isinstance(b, Conc):
        try:
            return [Conc(a.v * b.v, a.deps | b.deps)]
        except TypeError:
            return _top_outs(eqn)
    for x, y in ((a, b), (b, a)):
        if isinstance(x, Conc) and isinstance(y, Aff) and _is_int(x.v):
            k = int(x.v)
            return [Aff(y.off * k, tuple(s * k for s in y.strides),
                        y.deps | x.deps)]
        if isinstance(x, Conc) and _is_zero_scalar(x.v):
            return [_all_zeros(out_shape)]
        if isinstance(x, Conc):
            # finite nonzero scalar: preserves the array's zeros
            return [as_zeros(y, out_shape)]
    for xi, yv in ((0, b), (1, a)):
        if not _shape(eqn.invars[xi]) and _shape(eqn.invars[1 - xi]):
            # unknown scalar times array: zeros survive regardless of the
            # scalar's value (0 * s = 0, finite-arithmetic axiom)
            return [as_zeros(yv, out_shape)]
    za = as_zeros(a, out_shape)
    zb = as_zeros(b, out_shape)
    if len(za.slabs) != len(out_shape) or len(zb.slabs) != len(out_shape):
        return _top_outs(eqn)
    # 0 * x = 0 (finite-arithmetic axiom): union of zero regions
    return [Zeros(tuple(
        Slab(max(sa.count, sb.count),
             (sa.deps | sb.deps) if max(sa.count, sb.count) else EMPTY)
        for sa, sb in zip(za.slabs, zb.slabs)))]


def _h_div(eqn, ins, ctx):
    a, _b = ins
    out_shape = _shape(eqn.outvars[0])
    if isinstance(a, Conc) and _is_zero_scalar(a.v):
        return [_all_zeros(out_shape)]
    za = as_zeros(a, out_shape)
    if len(za.slabs) != len(out_shape):
        return _top_outs(eqn)
    # eps-guarded-denominator axiom: numerator zeros survive
    return [za]


def _is_int(v):
    try:
        return float(v) == int(v)
    except (TypeError, ValueError):
        return False


def _h_minmax(eqn, ins, ctx):
    a, b = ins
    out_shape = _shape(eqn.outvars[0])
    is_min = eqn.primitive.name == "min"
    for x, y in ((a, b), (b, a)):
        if isinstance(x, Conc):
            try:
                c = float(x.v)
            except (TypeError, ValueError):
                return _top_outs(eqn)
            # min(0, c>=0) = 0 ; max(0, c<=0) = 0
            if (is_min and c >= 0.0) or (not is_min and c <= 0.0):
                return [as_zeros(y, out_shape)]
            return _top_outs(eqn)
    za, zb = as_zeros(a, out_shape), as_zeros(b, out_shape)
    if len(za.slabs) == len(zb.slabs) == len(out_shape):
        # min(0,0)=max(0,0)=0: intersection survives
        return [_meet_zeros(za, zb)]
    return _top_outs(eqn)


def _h_integer_pow(eqn, ins, ctx):
    y = eqn.params.get("y", 0)
    if isinstance(y, (int, float)) and y > 0:
        return [as_zeros(ins[0], _shape(eqn.outvars[0]))]
    return _top_outs(eqn)


def _h_compare(eqn, ins, ctx):
    a, b = ins
    name = eqn.primitive.name
    if isinstance(a, Conc) and isinstance(b, Conc):
        try:
            av, bv = float(a.v), float(b.v)
            v = {"lt": av < bv, "le": av <= bv, "gt": av > bv,
                 "ge": av >= bv, "eq": av == bv, "ne": av != bv}[name]
            return [Conc(v, a.deps | b.deps)]
        except (TypeError, ValueError):
            return _top_outs(eqn)
    # ramp < bound: prefix-true mask (the live-row masks)
    if name in ("lt", "le") and isinstance(a, Aff) and isinstance(b, Conc):
        shape = _shape(eqn.outvars[0])
        nz = [d for d, s in enumerate(a.strides) if s]
        if len(nz) == 1 and a.strides[nz[0]] > 0 and _is_int(b.v):
            d, stride = nz[0], a.strides[nz[0]]
            bound = int(b.v) + (1 if name == "le" else 0)
            # true while off + stride*i < bound
            t = (bound - a.off + stride - 1) // stride
            t = max(0, min(shape[d], t))
            tfalse = [0] * len(shape)
            tfalse[d] = shape[d] - t
            return [Mask(tuple(tfalse), a.deps | b.deps)]
    return _top_outs(eqn)


def _h_and_or(eqn, ins, ctx):
    a, b = ins
    if isinstance(a, Mask) and isinstance(b, Mask) \
            and len(a.tfalse) == len(b.tfalse):
        f = max if eqn.primitive.name == "and" else min
        return [Mask(tuple(f(x, y) for x, y in zip(a.tfalse, b.tfalse)),
                     a.deps | b.deps)]
    if isinstance(a, Conc) and isinstance(b, Conc):
        try:
            v = (bool(a.v) and bool(b.v)) if eqn.primitive.name == "and" \
                else (bool(a.v) or bool(b.v))
            return [Conc(v, a.deps | b.deps)]
        except (TypeError, ValueError):
            pass
    return _top_outs(eqn)


def _h_select_n(eqn, ins, ctx):
    pred, *cases = ins
    out_shape = _shape(eqn.outvars[0])
    if isinstance(pred, Conc):
        try:
            idx = int(pred.v)
        except (TypeError, ValueError):
            return _top_outs(eqn)
        if 0 <= idx < len(cases):
            # the choice is exact under the last-shard interpretation, but
            # it depended on pred — taint the result with pred's axis deps
            return [_add_deps(cases[idx], pred.deps, out_shape)]
    zs = [as_zeros(c, out_shape) for c in cases]
    if any(len(z.slabs) != len(out_shape) for z in zs):
        return _top_outs(eqn)
    both = zs[0]
    for z in zs[1:]:
        both = _meet_zeros(both, z)
    if isinstance(pred, Mask) and len(cases) == 2 \
            and len(pred.tfalse) == len(out_shape):
        # case 0 is selected where pred is False (the trailing bands)
        c0, c1 = cases[0], cases[1]
        if is_all_zero(c0, _shape_of(eqn.invars[1], out_shape)) or (
                isinstance(c0, Conc) and _is_zero_scalar(c0.v)):
            # rows in the mask's trailing-false band select case 0 (zero);
            # rows outside it may still be zero via case 1's own slab. Per
            # dim, deps come only from the source that provides the count.
            slabs = []
            for d in range(len(out_shape)):
                s1 = zs[1].slabs[d]
                if s1.count >= pred.tfalse[d]:
                    c, deps = s1.count, s1.deps
                else:
                    c, deps = pred.tfalse[d], pred.deps
                slabs.append(Slab(c, deps if c else EMPTY))
            return [Zeros(tuple(slabs))]
    return [both]


# -- structural -------------------------------------------------------------

def _h_broadcast_in_dim(eqn, ins, ctx):
    av = ins[0]
    out_shape = tuple(eqn.params["shape"])
    bdims = tuple(eqn.params["broadcast_dimensions"])
    in_shape = _shape(eqn.invars[0])
    if isinstance(av, Conc):
        if _is_zero_scalar(av.v):
            return [_all_zeros(out_shape)]
        return [_no_zeros(len(out_shape))]
    if isinstance(av, Aff):
        strides = [0] * len(out_shape)
        ok = True
        for i, d in enumerate(bdims):
            if in_shape[i] == out_shape[d]:
                strides[d] = av.strides[i]
            elif av.strides[i]:
                ok = False
        if ok:
            return [Aff(av.off, tuple(strides), av.deps)]
        return _top_outs(eqn)
    if isinstance(av, Mask):
        tf = [0] * len(out_shape)
        ok = True
        for i, d in enumerate(bdims):
            if in_shape[i] == out_shape[d]:
                tf[d] = av.tfalse[i]
            elif av.tfalse[i]:
                ok = False  # a size-1 false band replicated: all-false dim
        if ok:
            return [Mask(tuple(tf), av.deps)]
        return _top_outs(eqn)
    z = as_zeros(av, in_shape)
    if is_all_zero(av, in_shape):
        return [_all_zeros(out_shape)]
    slabs = [Slab(0)] * len(out_shape)
    for i, d in enumerate(bdims):
        s = z.slabs[i]
        if in_shape[i] == out_shape[d]:
            slabs[d] = s
        elif s.count >= in_shape[i] and in_shape[i] > 0:
            slabs[d] = Slab(out_shape[d], s.deps)
    return [Zeros(tuple(slabs))]


def _h_iota(eqn, ins, ctx):
    d = eqn.params.get("dimension", 0)
    shape = _shape(eqn.outvars[0])
    strides = tuple(1 if i == d else 0 for i in range(len(shape)))
    return [Aff(0, strides)]


def _h_axis_index(eqn, ins, ctx):
    a = eqn.params["axis_name"]
    size = ctx.axis_sizes.get(a)
    if size is None:
        return _top_outs(eqn)
    return [Conc(size - 1, frozenset({a}))]


def _h_concatenate(eqn, ins, ctx):
    d = eqn.params["dimension"]
    out_shape = _shape(eqn.outvars[0])
    shapes = [_shape(v) for v in eqn.invars]
    zs = [as_zeros(av, s) for av, s in zip(ins, shapes)]
    if any(len(z.slabs) != len(s) for z, s in zip(zs, shapes)):
        return _top_outs(eqn)
    # trailing zeros along d: whole all-zero suffix operands, then the last
    # non-all-zero operand's own trailing slab
    count, deps = 0, EMPTY
    for av, z, s in zip(reversed(ins), reversed(zs), reversed(shapes)):
        if is_all_zero(av, s):
            count += s[d]
            deps |= _union_deps(av)
            continue
        count += z.slabs[d].count
        deps |= z.slabs[d].deps
        break
    slabs = []
    for i in range(len(out_shape)):
        if i == d:
            slabs.append(Slab(min(count, out_shape[d]),
                              deps if count else EMPTY))
        else:
            c = min(z.slabs[i].count for z in zs)
            dd = EMPTY
            for z in zs:
                dd |= z.slabs[i].deps
            slabs.append(Slab(c, dd if c else EMPTY))
    return [Zeros(tuple(slabs))]


def _h_pad(eqn, ins, ctx):
    av, padval = ins
    out_shape = _shape(eqn.outvars[0])
    in_shape = _shape(eqn.invars[0])
    cfg = eqn.params["padding_config"]
    pad_is_zero = (isinstance(padval, Conc) and _is_zero_scalar(padval.v)) \
        or is_all_zero(padval, _shape(eqn.invars[1]))
    z = as_zeros(av, in_shape)
    if len(z.slabs) != len(out_shape):
        return _top_outs(eqn)
    slabs = []
    for d, (lo, hi, interior) in enumerate(cfg):
        s = z.slabs[d]
        if pad_is_zero:
            c = hi + (s.count if interior == 0 else 0)
            slabs.append(Slab(min(c, out_shape[d]), s.deps if c else EMPTY))
        else:
            c = s.count if (hi == 0 and interior == 0) else 0
            slabs.append(Slab(c, s.deps if c else EMPTY))
    return [Zeros(tuple(slabs))]


def _h_transpose(eqn, ins, ctx):
    perm = eqn.params["permutation"]
    z = as_zeros(ins[0], _shape(eqn.invars[0]))
    if len(z.slabs) != len(perm):
        return _top_outs(eqn)
    return [Zeros(tuple(z.slabs[p] for p in perm))]


def _h_squeeze(eqn, ins, ctx):
    dims = set(eqn.params["dimensions"])
    z = as_zeros(ins[0], _shape(eqn.invars[0]))
    return [Zeros(tuple(s for d, s in enumerate(z.slabs) if d not in dims))]


def _h_reshape(eqn, ins, ctx):
    av = ins[0]
    in_shape = _shape(eqn.invars[0])
    out_shape = _shape(eqn.outvars[0])
    if is_all_zero(av, in_shape):
        return [_all_zeros(out_shape)]
    z = as_zeros(av, in_shape)
    # only unit-dim insertion/removal keeps slab geometry intact
    in_nonunit = [(d, n) for d, n in enumerate(in_shape) if n != 1]
    out_nonunit = [(d, n) for d, n in enumerate(out_shape) if n != 1]
    if [n for _, n in in_nonunit] != [n for _, n in out_nonunit]:
        return [_no_zeros(len(out_shape))]
    slabs = [Slab(0)] * len(out_shape)
    for (di, _), (do, _) in zip(in_nonunit, out_nonunit):
        slabs[do] = z.slabs[di]
    return [Zeros(tuple(slabs))]


def _h_slice(eqn, ins, ctx):
    starts = eqn.params["start_indices"]
    limits = eqn.params["limit_indices"]
    strides = eqn.params.get("strides") or [1] * len(starts)
    in_shape = _shape(eqn.invars[0])
    z = as_zeros(ins[0], in_shape)
    if len(z.slabs) != len(in_shape):
        return _top_outs(eqn)
    slabs = []
    for d, (s0, lim, st) in enumerate(zip(starts, limits, strides)):
        sl = z.slabs[d]
        if st != 1:
            slabs.append(Slab(0))
            continue
        first_zero = in_shape[d] - sl.count
        c = max(0, min(lim - s0, lim - max(s0, first_zero)))
        slabs.append(Slab(c, sl.deps if c else EMPTY))
    return [Zeros(tuple(slabs))]


def _h_dynamic_slice(eqn, ins, ctx):
    av = ins[0]
    starts = ins[1:]
    in_shape = _shape(eqn.invars[0])
    sizes = eqn.params["slice_sizes"]
    z = as_zeros(av, in_shape)
    if len(z.slabs) != len(in_shape):
        return _top_outs(eqn)
    slabs = []
    for d, w in enumerate(sizes):
        sl = z.slabs[d]
        st = starts[d] if d < len(starts) else TOP
        if isinstance(st, Conc) and _is_int(st.v):
            # XLA clamps the start so the window fits
            s0 = max(0, min(int(st.v), in_shape[d] - w))
            first_zero = in_shape[d] - sl.count
            c = max(0, min(w, (s0 + w) - max(s0, first_zero)))
            slabs.append(Slab(c, (sl.deps | st.deps) if c else EMPTY))
        elif sl.count >= in_shape[d]:
            slabs.append(Slab(w, EMPTY))  # slicing an all-zero dim
        else:
            slabs.append(Slab(0))
    return [Zeros(tuple(slabs))]


def _h_dynamic_update_slice(eqn, ins, ctx):
    operand, update = ins[0], ins[1]
    out_shape = _shape(eqn.outvars[0])
    if is_all_zero(operand, _shape(eqn.invars[0])) and \
            is_all_zero(update, _shape(eqn.invars[1])):
        return [_all_zeros(out_shape)]
    return _top_outs(eqn)


def _h_reduce(eqn, ins, ctx):
    axes = set(eqn.params["axes"])
    in_shape = _shape(eqn.invars[0])
    z = as_zeros(ins[0], in_shape)
    if len(z.slabs) != len(in_shape):
        return _top_outs(eqn)
    # sum/max/min/prod of an all-zero slice is zero; reduced dims vanish
    return [Zeros(tuple(s for d, s in enumerate(z.slabs) if d not in axes))]


def _h_dot_general(eqn, ins, ctx):
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lshape, rshape = _shape(eqn.invars[0]), _shape(eqn.invars[1])
    out_shape = _shape(eqn.outvars[0])
    la, ra = ins
    if is_all_zero(la, lshape) or is_all_zero(ra, rshape):
        return [_all_zeros(out_shape)]
    zl, zr = as_zeros(la, lshape), as_zeros(ra, rshape)
    if len(zl.slabs) != len(lshape) or len(zr.slabs) != len(rshape):
        return _top_outs(eqn)
    lfree = [d for d in range(len(lshape)) if d not in lc and d not in lb]
    rfree = [d for d in range(len(rshape)) if d not in rc and d not in rb]
    slabs = []
    for j in range(len(lb)):
        a, b = zl.slabs[lb[j]], zr.slabs[rb[j]]
        c = max(a.count, b.count)
        slabs.append(Slab(c, (a.deps | b.deps) if c else EMPTY))
    for d in lfree:
        slabs.append(zl.slabs[d])
    for d in rfree:
        slabs.append(zr.slabs[d])
    if len(slabs) != len(out_shape):
        return _top_outs(eqn)
    return [Zeros(tuple(slabs))]


def _h_triangular_solve(eqn, ins, ctx):
    # Solves with the triangular factor a: result has b's shape. Zero batch
    # slices and zero slices along the NON-solved matrix dim of b stay zero,
    # ASSUMING a is nonsingular (shifted-CholeskyQR2 axiom, see module doc).
    b_shape = _shape(eqn.invars[1])
    zb = as_zeros(ins[1], b_shape)
    if len(zb.slabs) != len(b_shape):
        return _top_outs(eqn)
    left = eqn.params.get("left_side", True)
    nd = len(b_shape)
    slabs = list(zb.slabs)
    solved_dim = nd - 2 if left else nd - 1
    slabs[solved_dim] = Slab(0)
    return [Zeros(tuple(slabs))]


# -- collectives and control flow ------------------------------------------

def _axes_set(eqn):
    axes = eqn.params.get("axes") or eqn.params.get("axis_name")
    if not isinstance(axes, (tuple, list)):
        axes = (axes,)
    out = set()
    for a in axes:  # axis_name may itself be a tuple of names
        if isinstance(a, (tuple, list)):
            out.update(x for x in a if x is not None)
        elif a is not None:
            out.add(a)
    return frozenset(out)


def _h_psum(eqn, ins, ctx):
    axes = _axes_set(eqn)
    outs = []
    for av, ov in zip(ins, eqn.outvars):
        shape = _shape(ov)
        if isinstance(av, Conc) and av.deps.isdisjoint(axes):
            outs.append(av)  # uniform scalar across the reduced axes
            continue
        z = as_zeros(av, shape)
        if len(z.slabs) != len(shape):
            outs.append(TOP)
            continue
        # a zero slab survives a cross-shard reduction only if it does not
        # vary across the reduced axes (sum of per-shard zeros is zero)
        outs.append(Zeros(tuple(
            s if s.deps.isdisjoint(axes) else Slab(0) for s in z.slabs)))
    return outs


def _h_all_gather(eqn, ins, ctx):
    axes = _axes_set(eqn)
    d = eqn.params.get("all_gather_dimension", 0)
    tiled = eqn.params.get("tiled", False)
    av = ins[0]
    out_shape = _shape(eqn.outvars[0])
    in_shape = _shape(eqn.invars[0])
    z = as_zeros(av, in_shape)
    if len(z.slabs) != len(in_shape):
        return _top_outs(eqn)
    slabs_in = list(z.slabs)
    if not tiled:
        slabs_in.insert(d, Slab(0))
    slabs = []
    for i, s in enumerate(slabs_in):
        if i == d:
            # the last shard's block lands at the trailing position, so its
            # trailing zeros survive; gathering removes the axis dependence
            slabs.append(Slab(s.count, s.deps - axes))
        elif s.deps.isdisjoint(axes):
            slabs.append(s)
        else:
            slabs.append(Slab(0))
    if len(slabs) != len(out_shape):
        return _top_outs(eqn)
    return [Zeros(tuple(slabs))]


def _h_cond(eqn, ins, ctx):
    branches = eqn.params["branches"]
    pred, args = ins[0], ins[1:]

    def run(branch):
        jaxpr = branch.jaxpr
        sub = {}
        for var, const in zip(jaxpr.constvars, branch.consts):
            sub[var] = _classify_const(const)
        for var, av in zip(jaxpr.invars, args):
            sub[var] = av
        _interp(jaxpr, sub, ctx)
        return [_read(sub, v) for v in jaxpr.outvars]

    if isinstance(pred, Conc) and _is_int(pred.v):
        idx = max(0, min(len(branches) - 1, int(pred.v)))
        outs = run(branches[idx])
        return [o for o in outs]
    results = [run(b) for b in branches]
    outs = []
    for i, ov in enumerate(eqn.outvars):
        shape = _shape(ov)
        z = as_zeros(results[0][i], shape)
        for r in results[1:]:
            z2 = as_zeros(r[i], shape)
            if len(z.slabs) == len(z2.slabs):
                z = _meet_zeros(z, z2)
            else:
                z = _no_zeros(len(shape))
        outs.append(z)
    return outs


def _h_shard_map(eqn, ins, ctx):
    mesh = eqn.params.get("mesh")
    sizes = dict(getattr(mesh, "shape", {}) or {})
    in_names = eqn.params.get("in_names", ())
    out_names = eqn.params.get("out_names", ())
    jaxpr = eqn.params.get("jaxpr")
    if jaxpr is None:
        return _top_outs(eqn)
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    consts = getattr(jaxpr, "consts", ())
    saved = dict(ctx.axis_sizes)
    ctx.axis_sizes.update({k: int(v) for k, v in sizes.items()})
    try:
        sub = {}
        for var, const in zip(inner.constvars, consts):
            sub[var] = _classify_const(const)
        for var, av, names in zip(inner.invars, ins, in_names):
            sub[var] = _localize(av, _shape(var), names, sizes)
        _interp(inner, sub, ctx)
        glob = []
        for var, ov, names in zip(inner.outvars, eqn.outvars, out_names):
            local = as_zeros(_read(sub, var), _shape(var))
            glob.append(_globalize(local, _shape(ov), names))
    finally:
        ctx.axis_sizes = saved
    ctx.records.append(ShardMapRecord(
        out_shapes=[_shape(ov) for ov in eqn.outvars],
        out_slabs=list(glob)))
    return glob


def _localize(av, local_shape, names, sizes):
    """Global abstract value -> shard-local view under in_names."""
    if not isinstance(av, Zeros):
        return _no_zeros(len(local_shape))
    if len(av.slabs) != len(local_shape):
        return _no_zeros(len(local_shape))
    slabs = []
    for d, s in enumerate(av.slabs):
        axes = frozenset(names.get(d, ()))
        if not axes:
            slabs.append(s)
            continue
        factor = 1
        for a in axes:
            factor *= int(sizes.get(a, 1))
        block = local_shape[d]
        global_n = block * factor
        if s.count >= global_n:
            slabs.append(Slab(block, s.deps))
        else:
            c = min(s.count, block)
            slabs.append(Slab(c, (s.deps | axes) if c else EMPTY))
    return Zeros(tuple(slabs))


def _globalize(local: Zeros, global_shape, names) -> Zeros:
    """Shard-local zeros -> global claims under out_names.

    A local trailing slab becomes a global one only when every axis it
    depends on shards THAT dimension — then the trailing global block is
    the last shard's block, where the slab holds. A slab depending on an
    axis that shards a different dim (or none) must be dropped: the
    assembled trailing block comes from other shards of that axis.
    """
    if len(local.slabs) != len(global_shape):
        return _no_zeros(len(global_shape))
    slabs = []
    for d, s in enumerate(local.slabs):
        axes = frozenset(names.get(d, ()))
        if s.count and s.deps <= axes:
            slabs.append(Slab(min(s.count, global_shape[d]), EMPTY))
        else:
            slabs.append(Slab(0))
    return Zeros(tuple(slabs))


def _h_clamp(eqn, ins, ctx):
    lo, x, hi = ins
    out_shape = _shape(eqn.outvars[0])
    try:
        lo_ok = isinstance(lo, Conc) and float(lo.v) <= 0.0
        hi_ok = isinstance(hi, Conc) and float(hi.v) >= 0.0
    except (TypeError, ValueError):
        return _top_outs(eqn)
    if lo_ok and hi_ok:
        return [as_zeros(x, out_shape)]
    return _top_outs(eqn)


_HANDLERS = {
    "add": _h_add, "sub": _h_add,
    "mul": _h_mul, "div": _h_div,
    "min": _h_minmax, "max": _h_minmax,
    "integer_pow": _h_integer_pow,
    "lt": _h_compare, "le": _h_compare, "gt": _h_compare,
    "ge": _h_compare, "eq": _h_compare, "ne": _h_compare,
    "and": _h_and_or, "or": _h_and_or,
    "select_n": _h_select_n,
    "broadcast_in_dim": _h_broadcast_in_dim,
    "iota": _h_iota,
    "axis_index": _h_axis_index,
    "concatenate": _h_concatenate,
    "pad": _h_pad,
    "transpose": _h_transpose,
    "squeeze": _h_squeeze,
    "reshape": _h_reshape,
    "slice": _h_slice,
    "dynamic_slice": _h_dynamic_slice,
    "dynamic_update_slice": _h_dynamic_update_slice,
    "reduce_sum": _h_reduce, "reduce_max": _h_reduce,
    "reduce_min": _h_reduce, "reduce_prod": _h_reduce,
    "dot_general": _h_dot_general,
    "triangular_solve": _h_triangular_solve,
    "psum": _h_psum, "pmax": _h_psum, "pmin": _h_psum,
    "all_gather": _h_all_gather,
    "cond": _h_cond,
    "shard_map": _h_shard_map,
    "clamp": _h_clamp,
}


# -- claims -----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Claim:
    """"Output (or shard_map output) has >= count trailing zeros on dim."""
    what: str          # human-readable target, e.g. "state.Q['102x16']"
    dim: int
    count: int
    # where to look: an output index into out_slabs, or a shard_map record
    # selector (record output shape, output position)
    out_index: Optional[int] = None
    record_shape: Optional[tuple] = None
    record_pos: Optional[int] = None


def check_claims(result: InertnessResult, claims: list) -> list:
    """Returns a list of failure strings (empty = all claims proven)."""
    failures = []
    for c in claims:
        if c.count <= 0:
            continue
        z = None
        where = c.what
        if c.out_index is not None:
            if c.out_index >= len(result.out_slabs):
                failures.append(f"{where}: output index {c.out_index} "
                                "out of range")
                continue
            z = result.out_slabs[c.out_index]
        else:
            for rec in result.records:
                if (c.record_pos is not None
                        and c.record_pos < len(rec.out_shapes)
                        and rec.out_shapes[c.record_pos] == c.record_shape):
                    z = rec.out_slabs[c.record_pos]
                    break
            if z is None:
                failures.append(
                    f"{where}: no shard_map output of shape "
                    f"{c.record_shape} at position {c.record_pos}")
                continue
        got = z.slabs[c.dim].count if c.dim < len(z.slabs) else 0
        if got < c.count:
            failures.append(
                f"{where}: needs >= {c.count} trailing zeros on dim "
                f"{c.dim}, proved only {got} ({z})")
    return failures


# -- SUMO-specific proof drivers -------------------------------------------

def prove_update_inertness(params, cfg=None, mesh=None, lr: float = 0.01,
                           ) -> InertnessResult:
    """Prove pad inertness of the full bucketed update (the tentpole claim).

    Inductive step: ASSUMING the incoming state Q stacks' edge-pad rows are
    zero (true at init, where Q is zeros), prove that (a) the new state Q
    stacks' pad rows are exactly zero, and (b) inside every shard_map, the
    gathered delta stack's pad rows AND pad B-slots are exactly zero — so
    the final slice-off recovers the unpadded result bit-exactly.

    Raises InertnessError listing every claim the prover could not
    establish.
    """
    from ..core.sumo import update_closed_jaxpr

    traced = update_closed_jaxpr(params, cfg=cfg, mesh=mesh, lr=lr)
    result = analyze_jaxpr(traced.closed_jaxpr, traced.arg_claims)
    claims = []
    for e in traced.plan:
        lpad = e["long_padded"] - e["long"]
        bpad = e["b_padded"] - e["b_true"]
        if not e["sharded"] or (lpad == 0 and bpad == 0):
            continue
        # The interpreter reasons about the LAST shard of each mesh axis,
        # so a pad band spanning several trailing shards is provable only
        # up to one shard-block's worth (the pad slots on earlier shards
        # are still inert — sliced off at unstack — but outside what the
        # last-shard abstraction can state). Cap the claims accordingly.
        lprov = min(lpad, e["long_padded"] // max(1, e["model_shards"]))
        bprov = min(bpad, e["b_padded"] // max(1, e["data_shards"]))
        delta_shape = (e["b_padded"], e["long_padded"], e["short"])
        claims.append(Claim(
            what=f"delta[{e['key']}] pad rows", dim=1, count=lprov,
            record_shape=delta_shape, record_pos=0))
        claims.append(Claim(
            what=f"delta[{e['key']}] pad B-slots", dim=0, count=bprov,
            record_shape=delta_shape, record_pos=0))
        if lprov and e["q_out_index"] is not None:
            claims.append(Claim(
                what=f"state.Q[{e['key']}] pad rows", dim=1, count=lprov,
                out_index=e["q_out_index"]))
    failures = check_claims(result, claims)
    if failures:
        raise InertnessError(
            "pad-inertness proof FAILED:\n  " + "\n  ".join(failures))
    if not claims:
        raise InertnessError(
            "pad-inertness proof is vacuous: no padded sharded bucket in "
            "the traced configuration")
    return result


def prove_null_block_inertness(num_slots: int = 4, max_blocks: int = 8,
                               block_size: int = 8, free_slots: int = 2,
                               ) -> InertnessResult:
    """Serving null-block proof: free slots' unconditional decode writes
    provably land only in physical block 0.

    The continuous engine decodes ALL ``num_slots`` slots every step — free
    slots included (fixed jit shape, SERVING.md). The safety convention is
    that a free slot's table row is all zeros and its length is zero, so its
    per-layer K/V scatter targets the reserved null block and can never
    corrupt a live request's blocks. This proves that mechanically over the
    jaxpr of ``models.transformer.paged_write_targets`` — the exact
    computation ``paged_decode_step`` uses to pick its scatter targets:
    assuming the trailing ``free_slots`` table rows and lengths are zero
    (the canonical layout; slots are symmetric), both the physical block
    index and the in-block offset of those slots are exactly zero.

    Raises InertnessError if the proof does not go through (e.g. someone
    reintroduces a gather-based lookup, which is TOP to this interpreter).
    """
    import jax
    import jax.numpy as jnp

    from ..models.transformer import paged_write_targets

    closed = jax.make_jaxpr(
        lambda t, ln: paged_write_targets(t, ln, block_size))(
        jnp.zeros((num_slots, max_blocks), jnp.int32),
        jnp.zeros((num_slots,), jnp.int32))
    result = analyze_jaxpr(
        closed, arg_claims=[{0: free_slots}, {0: free_slots}])
    failures = check_claims(result, [
        Claim(what=f"free slots' write block ({free_slots} trailing slots)",
              dim=0, count=free_slots, out_index=0),
        Claim(what=f"free slots' write offset ({free_slots} trailing slots)",
              dim=0, count=free_slots, out_index=1),
    ])
    if failures:
        raise InertnessError(
            "null-block inertness proof FAILED:\n  " + "\n  ".join(failures))
    return result


def prove_refresh_inertness(rows: int = 102, pad: int = 2, short: int = 16,
                            l: int = 8) -> InertnessResult:
    """Standalone single-device proof over the rSVD refresh body: a sketch
    input with trailing zero rows yields a basis Q with the same trailing
    zero rows (this replaces the op-by-op prose proof that used to live in
    core/rsvd.py's docstring)."""
    from ..core.rsvd import refresh_closed_jaxpr

    closed = refresh_closed_jaxpr(rows + pad, short, l)
    result = analyze_jaxpr(closed, arg_claims=[{0: pad}, None])
    failures = check_claims(result, [Claim(
        what=f"range_finder(G[{rows}+{pad} rows]) pad rows",
        dim=0, count=pad, out_index=0)])
    if failures:
        raise InertnessError(
            "refresh-inertness proof FAILED:\n  " + "\n  ".join(failures))
    return result
