"""repro.analysis — machine-checked static guarantees.

Four passes over jaxprs and optimized HLO (see ANALYSIS.md):

  collectives  declarative collective-budget lint over compiled HLO
  inertness    abstract-interpretation proof that edge-pad rows/slots of
               the bucketed SUMO update stay exactly zero
  donation     jit donation markers vs compiled input-output aliasing,
               plus a source lint for donated-buffer reuse
  recompile    post-warmup recompiles only at controller boundaries

Run all of them: ``python -m repro.analysis`` (or tools/lint_static.py).

Submodule attributes are re-exported lazily so ``import repro.analysis``
stays cheap (no jax import) — the training loop imports
``analysis.recompile`` on its hot import path.
"""
from __future__ import annotations

_EXPORTS = {
    # collectives
    "OpBudget": "collectives", "CollectiveBudget": "collectives",
    "BudgetViolation": "collectives", "BudgetReport": "collectives",
    "BudgetError": "collectives", "audit_hlo": "collectives",
    "assert_budget": "collectives", "BucketPlanEntry": "collectives",
    "bucket_collective_plan": "collectives", "delta_bytes": "collectives",
    "padded_delta_bytes": "collectives", "pad_overhead_frac": "collectives",
    "steady_1d_budget": "collectives", "steady_2d_budget": "collectives",
    "refresh_2d_budget": "collectives", "restore_budget": "collectives",
    # inertness
    "analyze_jaxpr": "inertness", "check_claims": "inertness",
    "Claim": "inertness", "InertnessError": "inertness",
    "InertnessResult": "inertness", "prove_update_inertness": "inertness",
    "prove_refresh_inertness": "inertness",
    # donation
    "DonationReport": "donation", "DonationViolation": "donation",
    "DonationError": "donation", "audit_donation": "donation",
    "lint_donation_source": "donation", "lint_donation_file": "donation",
    "audit_train_step_donation": "donation",
    # recompile
    "CompileWatcher": "recompile", "CompileEvent": "recompile",
    "RecompileReport": "recompile", "RecompileError": "recompile",
    "mark_step": "recompile", "audit_recompiles": "recompile",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.analysis' has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
