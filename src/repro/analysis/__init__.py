"""repro.analysis — machine-checked static guarantees.

Six passes over jaxprs and optimized HLO (see ANALYSIS.md):

  collectives  declarative collective-budget lint over compiled HLO
  inertness    abstract-interpretation proof that edge-pad rows/slots of
               the bucketed SUMO update stay exactly zero (and that free
               serving slots write only the null KV block)
  donation     jit donation markers vs compiled input-output aliasing,
               plus source lints for donated-buffer reuse and implicit
               host-buffer dtypes on the serve/train/telemetry paths
  recompile    post-warmup recompiles only at controller boundaries
  memory       declarative peak-HBM budgets over compiled artifacts
               (train step, Table-1 state claim, paged serve_decode)
  precision    fp32/bf16 discipline: accumulation dtypes over compiled
               HLO and traced jaxprs, the DP payload's true-wire dtype,
               an eps-guard lint over the refresh/orth jaxprs, and the
               paper's kappa-dependent ortho error bound per bucket

Run all of them: ``python -m repro.analysis`` (or tools/lint_static.py);
``--json`` emits the machine-readable static-analysis-v2 report and
``--list`` the required check names per lane (the single source
tools/run_tier1.sh and tools/analysis_diff.py read).

Submodule attributes are re-exported lazily so ``import repro.analysis``
stays cheap (no jax import) — the training loop imports
``analysis.recompile`` on its hot import path.
"""
from __future__ import annotations

_EXPORTS = {
    # collectives
    "OpBudget": "collectives", "CollectiveBudget": "collectives",
    "BudgetViolation": "collectives", "BudgetReport": "collectives",
    "BudgetError": "collectives", "audit_hlo": "collectives",
    "assert_budget": "collectives", "BucketPlanEntry": "collectives",
    "bucket_collective_plan": "collectives", "delta_bytes": "collectives",
    "padded_delta_bytes": "collectives", "pad_overhead_frac": "collectives",
    "steady_1d_budget": "collectives", "steady_2d_budget": "collectives",
    "refresh_2d_budget": "collectives", "restore_budget": "collectives",
    # inertness
    "analyze_jaxpr": "inertness", "check_claims": "inertness",
    "Claim": "inertness", "InertnessError": "inertness",
    "InertnessResult": "inertness", "prove_update_inertness": "inertness",
    "prove_refresh_inertness": "inertness",
    "prove_null_block_inertness": "inertness",
    # donation
    "DonationReport": "donation", "DonationViolation": "donation",
    "DonationError": "donation", "audit_donation": "donation",
    "lint_donation_source": "donation", "lint_donation_file": "donation",
    "audit_train_step_donation": "donation",
    "lint_host_dtype_source": "donation", "lint_host_dtype_file": "donation",
    "audit_host_dtypes": "donation",
    # recompile
    "CompileWatcher": "recompile", "CompileEvent": "recompile",
    "RecompileReport": "recompile", "RecompileError": "recompile",
    "mark_step": "recompile", "audit_recompiles": "recompile",
    # memory
    "MemoryBudget": "memory", "MemoryBudgetError": "memory",
    "MemoryViolation": "memory", "MemoryReport": "memory",
    "MemoryMeasurement": "memory", "MEMORY_VIOLATION_CODES": "memory",
    "BufferTable": "memory", "hlo_buffer_table": "memory",
    "measure_compiled_memory": "memory", "audit_memory": "memory",
    "assert_memory_budget": "memory", "audit_state_ratio": "memory",
    "audit_table1_state": "memory", "BucketMemoryEntry": "memory",
    "BucketMemoryPlan": "memory", "bucket_memory_plan": "memory",
    "steady_memory_budget": "memory", "refresh_memory_budget": "memory",
    "dp_compress_memory_budget": "memory",
    "serve_decode_memory_budget": "memory",
    # precision
    "PrecisionBudget": "precision", "PrecisionViolation": "precision",
    "PrecisionReport": "precision", "PrecisionError": "precision",
    "PRECISION_VIOLATION_CODES": "precision",
    "assert_precision": "precision", "merge_reports": "precision",
    "audit_accumulation_hlo": "precision", "audit_wire_dtype": "precision",
    "audit_jaxpr_guards": "precision", "audit_ortho_bound": "precision",
    "ns_error_bound": "precision", "svd_tier_bound": "precision",
    "method_bound": "precision", "NS5_PLATEAU": "precision",
    "F32_EPS": "precision",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.analysis' has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
