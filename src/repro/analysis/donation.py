"""Donation / aliasing audit (static pass 3).

Two independent checks, both static:

1. **HLO cross-check** (`audit_donation`): jit a function with
   ``donate_argnums``, lower it, and verify the donation survived all the
   way down — every donated leaf must carry a ``tf.aliasing_output``
   marker in the StableHLO entry signature, and the compiled executable's
   ``input_output_alias`` table must alias exactly the marked parameters.
   XLA silently *drops* an alias when shapes/dtypes/layouts prevent reuse;
   this audit turns that silent memory regression into a named violation.

2. **Source lint** (`lint_donation_source`): donation invalidates the
   caller's buffer, so Python code must not keep using a reference it
   passed into a donating jit.  The lint finds ``X = jax.jit(...,
   donate_argnums=...)`` bindings, then checks every call site of ``X``:
   a donated positional argument that is a bare name must either be
   rebound by the same assignment (``params, ... = step_fn(params, ...)``)
   or never read again in the enclosing function.

Violation codes (stable strings, asserted by tests):
  ``donation-dropped``       declared donated leaf with no StableHLO marker
  ``alias-mismatch``         compiled alias table disagrees with markers
  ``donated-arg-not-rebound``  Python reuse of a donated reference
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Optional

__all__ = [
    "DonationViolation", "DonationReport", "DonationError",
    "audit_donation", "lint_donation_source", "lint_donation_file",
    "lint_host_dtype_source", "lint_host_dtype_file", "audit_host_dtypes",
    "audit_train_step_donation",
]


@dataclasses.dataclass(frozen=True)
class DonationViolation:
    code: str
    detail: str
    where: str = ""

    def __str__(self):
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.code}: {self.detail}{loc}"


@dataclasses.dataclass
class DonationReport:
    ok: bool
    violations: list
    declared_leaves: int = 0
    marked_args: tuple = ()
    compiled_aliases: tuple = ()

    def summary(self) -> str:
        head = "donation audit: " + ("OK" if self.ok else "FAILED")
        lines = [head,
                 f"  declared donated leaves : {self.declared_leaves}",
                 f"  stablehlo-marked args   : {len(self.marked_args)}",
                 f"  compiled aliases        : {len(self.compiled_aliases)}"]
        lines += [f"  {v}" for v in self.violations]
        return "\n".join(lines)


class DonationError(AssertionError):
    pass


# -- HLO-level audit --------------------------------------------------------

_MARKER_RE = re.compile(
    r"%arg(\d+)[^{%]*\{[^{}]*tf\.aliasing_output\s*=\s*(\d+)")
_ALIAS_TABLE_RE = re.compile(r"input_output_alias=\{(.*?)\}\s*(?:,|$)",
                             re.DOTALL)
_ALIAS_PAIR_RE = re.compile(r"\{[\d,\s]*\}:\s*\((\d+)")


def _stablehlo_markers(stablehlo_text: str):
    """(arg_index, output_index) pairs carrying tf.aliasing_output."""
    return tuple((int(a), int(o))
                 for a, o in _MARKER_RE.findall(stablehlo_text))


def _compiled_aliases(compiled_text: str):
    """Parameter numbers aliased in the executable's alias table."""
    m = re.search(r"input_output_alias=\{((?:[^{}]|\{[^{}]*\})*)\}",
                  compiled_text)
    if not m:
        return ()
    return tuple(int(p) for p in _ALIAS_PAIR_RE.findall(m.group(1)))


def audit_donation(fn, args, donate_argnums) -> DonationReport:
    """Lower ``jit(fn, donate_argnums=...)`` on ``args`` and cross-check
    the donation markers against the compiled aliasing table."""
    import jax

    if isinstance(donate_argnums, int):
        donate_argnums = (donate_argnums,)
    jitted = jax.jit(fn, donate_argnums=tuple(donate_argnums))
    lowered = jitted.lower(*args)
    marked = _stablehlo_markers(lowered.as_text())
    declared = sum(len(jax.tree_util.tree_leaves(args[i]))
                   for i in donate_argnums)
    violations = []
    if len(marked) < declared:
        violations.append(DonationViolation(
            "donation-dropped",
            f"declared {declared} donated leaves but only {len(marked)} "
            "carry tf.aliasing_output in the lowered StableHLO"))
    compiled = ()
    try:
        compiled_text = lowered.compile().as_text()
    except Exception:
        compiled_text = None  # backend may not expose executable text
    if compiled_text:
        compiled = _compiled_aliases(compiled_text)
        marked_params = {a for a, _ in marked}
        if set(compiled) - marked_params:
            violations.append(DonationViolation(
                "alias-mismatch",
                f"compiled aliases params {sorted(set(compiled) - marked_params)} "
                "that carry no StableHLO donation marker"))
        if marked_params and not compiled:
            violations.append(DonationViolation(
                "alias-mismatch",
                "donation markers present but the executable aliases "
                "nothing — XLA dropped every alias"))
    return DonationReport(ok=not violations, violations=violations,
                          declared_leaves=declared, marked_args=marked,
                          compiled_aliases=compiled)


# -- Python-source lint -----------------------------------------------------

def _donating_jit_bindings(tree: ast.AST) -> dict:
    """name -> set of donated positional indices, for every
    ``name = jax.jit(..., donate_argnums=...)`` binding in the module."""
    out = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt = node.targets[0]
        names = []
        if isinstance(tgt, ast.Name):
            names = [tgt.id]
        elif isinstance(tgt, ast.Tuple):
            names = [e.id for e in tgt.elts if isinstance(e, ast.Name)]
        call = node.value
        if isinstance(call, ast.Tuple) and len(call.elts) == len(names):
            pairs = list(zip(names, call.elts))
        else:
            pairs = [(n, call) for n in names[:1]]
        for name, val in pairs:
            if not isinstance(val, ast.Call):
                continue
            fnode = val.func
            is_jit = (isinstance(fnode, ast.Attribute) and fnode.attr == "jit") \
                or (isinstance(fnode, ast.Name) and fnode.id == "jit")
            if not is_jit:
                continue
            for kw in val.keywords:
                if kw.arg == "donate_argnums":
                    try:
                        donated = ast.literal_eval(kw.value)
                    except (ValueError, SyntaxError):
                        continue
                    if isinstance(donated, int):
                        donated = (donated,)
                    out[name] = set(int(d) for d in donated)
    return out


def _rebound_names(stmt) -> set:
    """Names (re)bound by the statement containing a call."""
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    out = set()
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                out.add(n.id)
    return out


def _enclosing_function(tree, node):
    best = None
    for f in ast.walk(tree):
        if isinstance(f, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and f.lineno <= node.lineno <= max(
                    getattr(f, "end_lineno", f.lineno), f.lineno):
            if best is None or f.lineno > best.lineno:
                best = f
    return best


def lint_donation_source(source: str, filename: str = "<string>") -> list:
    """Lint one module's source; returns DonationViolation list."""
    tree = ast.parse(source, filename=filename)
    bindings = _donating_jit_bindings(tree)
    if not bindings:
        return []
    # map statements for "is the call's result an assignment" lookup
    parent = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parent[child] = node
    violations = []
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call) or not isinstance(call.func, ast.Name):
            continue
        donated = bindings.get(call.func.id)
        if donated is None:
            continue
        stmt = call
        while stmt in parent and not isinstance(stmt, ast.stmt):
            stmt = parent[stmt]
        rebound = _rebound_names(stmt)
        fn = _enclosing_function(tree, call)
        for pos in sorted(donated):
            if pos >= len(call.args):
                continue
            arg = call.args[pos]
            if not isinstance(arg, ast.Name):
                continue  # fresh expression: nothing retained to misuse
            if arg.id in rebound:
                continue
            # donated name not rebound: flag any later read in the function
            used_later = False
            scope = fn if fn is not None else tree
            for n in ast.walk(scope):
                if isinstance(n, ast.Name) and n.id == arg.id \
                        and isinstance(n.ctx, ast.Load) \
                        and n.lineno > call.lineno:
                    used_later = True
                    break
            if used_later:
                violations.append(DonationViolation(
                    "donated-arg-not-rebound",
                    f"'{arg.id}' is donated into {call.func.id}() at line "
                    f"{call.lineno} but read again afterwards without being "
                    "rebound", where=f"{filename}:{call.lineno}"))
    return violations


def lint_donation_file(path) -> list:
    with open(path) as f:
        return lint_donation_source(f.read(), filename=str(path))


# -- host-buffer dtype lint -------------------------------------------------

# numpy constructors whose default dtype is PLATFORM-DERIVED (int64/float64
# on this host) → the jitted step sees a different aval than the int32/f32
# the shapes were designed for, and every call recompiles (the PR 8 serving
# footgun: an int64 lengths array re-tracing serve_decode per step).
# Positional index where each signature accepts dtype; np.asarray is exempt
# — it preserves an existing array's dtype, which is the common hot-path use
# (np.asarray(device_array) host syncs without changing the aval).
_NP_DTYPE_POS = {"array": 1, "zeros": 1, "ones": 1, "empty": 1,
                 "full": 2, "arange": 3}


def lint_host_dtype_source(source: str, filename: str = "<string>") -> list:
    """Flag ``np.array/zeros/ones/empty/full/arange`` calls without an
    explicit dtype in host-side code; returns DonationViolation list with
    code ``host-buffer-no-dtype``."""
    tree = ast.parse(source, filename=filename)
    violations = []
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call) \
                or not isinstance(call.func, ast.Attribute):
            continue
        base = call.func.value
        if not (isinstance(base, ast.Name) and base.id in ("np", "numpy")):
            continue
        pos = _NP_DTYPE_POS.get(call.func.attr)
        if pos is None:
            continue
        if any(kw.arg == "dtype" for kw in call.keywords):
            continue
        if len(call.args) > pos:        # positional dtype (np.zeros(S, np.int32))
            continue
        violations.append(DonationViolation(
            "host-buffer-no-dtype",
            f"np.{call.func.attr}(...) at line {call.lineno} has no explicit "
            "dtype — the platform default (int64/float64) changes the jitted "
            "aval and recompiles the step on every call",
            where=f"{filename}:{call.lineno}"))
    return violations


def lint_host_dtype_file(path) -> list:
    with open(path) as f:
        return lint_host_dtype_source(f.read(), filename=str(path))


def audit_host_dtypes() -> DonationReport:
    """Run the host-buffer dtype lint over the serving/training hot paths
    (the modules whose host arrays feed jitted per-step functions) plus the
    telemetry sinks and the serving benchmark — their host buffers feed
    aggregates and jitted-step arguments, so a platform-default int64
    either recompiles a step or silently double-widths a metric."""
    import os

    from ..serve import engine as _engine
    from ..serve import kv_cache as _kv
    from ..serve import scheduler as _sched
    from ..telemetry import serving as _tserv
    from ..telemetry import sink as _tsink
    from ..train import loop as _loop
    from ..train import steps as _steps

    violations = []
    for mod in (_engine, _kv, _sched, _loop, _steps, _tsink, _tserv):
        violations.extend(lint_host_dtype_file(mod.__file__))
    # benchmarks/ lives outside the package: lint by repo-relative path.
    repo = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    bench = os.path.join(repo, "benchmarks", "serving.py")
    if os.path.exists(bench):
        violations.extend(lint_host_dtype_file(bench))
    return DonationReport(ok=not violations, violations=violations)


# -- repo-specific driver ---------------------------------------------------

def audit_train_step_donation(steps: int = 1) -> DonationReport:
    """Audit the real training step's donation on a smoke config.

    Builds the same ``make_train_step`` + ``jax.jit(...,
    donate_argnums=(0, 1))`` pairing the loop uses and checks the lowered
    aliasing end to end.
    """
    import jax
    from ..configs import get_smoke_config
    from ..configs.base import ShapeConfig
    from ..data import DataConfig, make_batch
    from ..models import init_params
    from ..train import loop as _loop
    from ..train.steps import make_optimizer, make_train_step

    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("lint", seq_len=16, global_batch=2, kind="train")
    params = init_params(arch, jax.random.PRNGKey(0))
    tx = make_optimizer("sumo", 3e-3, params, rank=4, update_freq=8)
    opt_state = tx.init(params)
    batch = make_batch(0, shape, arch, DataConfig(seed=0))
    fn = make_train_step(arch, tx)
    report = audit_donation(fn, (params, opt_state, batch),
                            donate_argnums=(0, 1))
    report.violations.extend(lint_donation_file(_loop.__file__))
    from ..train import steps as _steps
    report.violations.extend(lint_donation_file(_steps.__file__))
    report.ok = not report.violations
    return report
