"""Shared driver for the six static-analysis passes.

``python -m repro.analysis [--mode 1d|2d|all] [--json|--list]`` (or
tools/lint_static.py) runs every pass that the current device count
supports and prints one PASS/FAIL/SKIP line per check — or, with
``--json``, a machine-readable report (schema ``static-analysis-v2``:
stable check names, PASS/FAIL/SKIP status, first detail line) consumed by
tools/run_tier1.sh, or, with ``--list``, just the check names/lanes the
mode requires (no jax import, no work) so report consumers
(tools/analysis_diff.py) read the required set from one source.  Exit
code 0 iff nothing FAILed — SKIPs (missing devices) are not failures, so
the same entry point works on a laptop and in the 8-device tier-1 lane.

Train-stack imports stay inside the pass functions: importing this module
must not pull jax (the ``repro.analysis`` package promises a cheap import
for the training loop's ``mark_step`` hook).
"""
from __future__ import annotations

import dataclasses

__all__ = ["run", "run_checks", "list_checks", "json_report", "main",
           "CheckResult", "REPORT_SCHEMA"]


@dataclasses.dataclass
class CheckResult:
    name: str
    status: str   # "PASS" | "FAIL" | "SKIP"
    detail: str = ""


def _devices():
    import jax
    return len(jax.devices())


def _mesh_1d():
    import jax
    n = _devices()
    return jax.make_mesh((n,), ("data",))


def _mesh_2d():
    import jax
    return jax.make_mesh((_devices() // 4, 4), ("data", "model"))


def _smoke_params(key, ragged: bool):
    import jax
    shapes = [("l%d" % i, (64, 32)) for i in range(6)]
    if ragged:
        shapes += [("r%d" % i, (102, 16)) for i in range(3)]
    return {name: jax.random.normal(jax.random.fold_in(key, i), s)
            for i, (name, s) in enumerate(shapes)}


def _compiled_update_hlo(params, cfg, mesh):
    """Compile the sharded bucketed update with resident state placement
    (the same incantation the sharded tests use — see
    parallel.sharding.update_audit_shardings) and return (hlo_text,
    state)."""
    import jax
    from ..core import sumo
    from ..parallel import update_audit_shardings

    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    tx = sumo(0.01, cfg, mesh=mesh)
    state = tx.init(params)
    g_sh, st_sh = update_audit_shardings(state, grads, mesh)
    compiled = jax.jit(
        lambda g, s, p: tx.update(g, s, p),
        in_shardings=(g_sh, st_sh, g_sh),
    ).lower(grads, state, params).compile()
    return compiled.as_text(), state


# -- pass 1: collective budgets ---------------------------------------------

def check_collectives_1d() -> CheckResult:
    import jax
    from ..core import SumoConfig
    from .collectives import (assert_budget, bucket_collective_plan,
                              steady_1d_budget, BudgetError)

    if _devices() < 2:
        return CheckResult("collectives/steady-1d", "SKIP",
                           f"needs >=2 devices, have {_devices()}")
    mesh = _mesh_1d()
    params = _smoke_params(jax.random.PRNGKey(0), ragged=False)
    cfg = SumoConfig(rank=8, update_freq=4, weight_decay=0.05)
    hlo, state = _compiled_update_hlo(params, cfg, mesh)
    plan = bucket_collective_plan(state, mesh)
    try:
        rep = assert_budget(hlo, steady_1d_budget(plan))
    except BudgetError as e:
        return CheckResult("collectives/steady-1d", "FAIL",
                           e.report.summary())
    return CheckResult("collectives/steady-1d", "PASS", rep.summary())


def check_collectives_2d() -> CheckResult:
    import jax
    from ..core import SumoConfig
    from .collectives import (assert_budget, bucket_collective_plan,
                              steady_2d_budget, BudgetError)

    if _devices() < 8:
        return CheckResult("collectives/steady-2d", "SKIP",
                           f"needs >=8 devices, have {_devices()}")
    mesh = _mesh_2d()
    params = _smoke_params(jax.random.PRNGKey(0), ragged=True)
    cfg = SumoConfig(rank=4, update_freq=4, rsvd_oversample=4,
                     weight_decay=0.05)
    hlo, state = _compiled_update_hlo(params, cfg, mesh)
    plan = bucket_collective_plan(state, mesh)
    budget = steady_2d_budget(
        plan, rank_plus_over=cfg.rank + cfg.rsvd_oversample,
        data_shards=int(mesh.shape["data"]))
    try:
        rep = assert_budget(hlo, budget)
    except BudgetError as e:
        return CheckResult("collectives/steady-2d", "FAIL",
                           e.report.summary())
    return CheckResult("collectives/steady-2d", "PASS", rep.summary())


# -- pass 2: pad inertness --------------------------------------------------

def check_inertness_refresh() -> CheckResult:
    from .inertness import prove_refresh_inertness, InertnessError
    try:
        prove_refresh_inertness()
    except InertnessError as e:
        return CheckResult("inertness/refresh", "FAIL", str(e))
    return CheckResult("inertness/refresh", "PASS",
                       "rSVD range finder preserves trailing zero rows")


def check_inertness_update(two_d: bool) -> CheckResult:
    import jax
    from ..core import SumoConfig
    from .inertness import prove_update_inertness, InertnessError

    name = "inertness/update-2d" if two_d else "inertness/update-1d"
    need = 8 if two_d else 2
    if _devices() < need:
        return CheckResult(name, "SKIP",
                           f"needs >={need} devices, have {_devices()}")
    if two_d:
        mesh = _mesh_2d()
        params = {f"r{i}": jax.ShapeDtypeStruct((102, 16), "float32")
                  for i in range(3)}
        cfg = SumoConfig(rank=4, update_freq=2, rsvd_oversample=4,
                         weight_decay=0.05)
    else:
        mesh = _mesh_1d()
        n = int(mesh.shape["data"])
        params = {f"l{i}": jax.ShapeDtypeStruct((64, 32), "float32")
                  for i in range(n + 1)}  # ragged B => padded B-slots
        cfg = SumoConfig(rank=4, update_freq=2, rsvd_oversample=4)
    try:
        prove_update_inertness(params, cfg, mesh=mesh)
    except InertnessError as e:
        return CheckResult(name, "FAIL", str(e))
    return CheckResult(name, "PASS",
                       "edge-pad rows / pad B-slots proven exactly zero")


# -- pass 3: donation / aliasing --------------------------------------------

def check_donation() -> CheckResult:
    from .donation import audit_train_step_donation
    rep = audit_train_step_donation()
    if not rep.ok:
        return CheckResult("donation", "FAIL", rep.summary())
    return CheckResult("donation", "PASS", rep.summary())


# -- pass 4: recompile boundaries -------------------------------------------

def check_recompile() -> CheckResult:
    from ..configs import get_smoke_config
    from ..configs.base import ShapeConfig
    from ..train.loop import TrainConfig, train
    from .recompile import CompileWatcher, audit_recompiles

    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("lint", seq_len=16, global_batch=2, kind="train")
    tcfg = TrainConfig(total_steps=4, optimizer="sumo", rank=4,
                       update_freq=2, log_every=100)
    with CompileWatcher(fn_name="train_step") as w:
        result = train(arch, shape, tcfg, log_fn=lambda *_: None)
    rep = audit_recompiles(
        w.events, fn_name="train_step", warmup_through=0,
        allowed_steps=[e[0] for e in result.controller_events])
    if not rep.ok:
        return CheckResult("recompile", "FAIL", rep.summary())
    if not rep.compiles:
        return CheckResult("recompile", "FAIL",
                           "no train_step compile observed — the watcher "
                           "is not seeing jax's compile log")
    return CheckResult("recompile", "PASS", rep.summary())


# -- pass 5: memory budgets (train step, Table 1, and the serving path) ------

def _smoke_train_setup():
    """The lint smoke recipe (same shapes as ``audit_train_step_donation``):
    SUMO rank=4 update_freq=8 on smollm-360m, seq 16, global batch 2."""
    import jax
    from ..configs import get_smoke_config
    from ..configs.base import ShapeConfig
    from ..data import DataConfig, make_batch
    from ..models import init_params
    from ..train.steps import make_optimizer, make_train_step

    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("lint", seq_len=16, global_batch=2, kind="train")
    params = init_params(arch, jax.random.PRNGKey(0))
    tx = make_optimizer("sumo", 3e-3, params, rank=4, update_freq=8)
    batch = make_batch(0, shape, arch, DataConfig(seed=0))
    return params, tx.init(params), batch, make_train_step(arch, tx)


def check_memory_train() -> CheckResult:
    import jax
    from ..configs import get_smoke_config
    from ..core.memory import (analytic_activation_bytes, predict_state_bytes,
                               tree_param_bytes, tree_state_bytes)
    from .memory import (audit_memory, measure_compiled_memory,
                         steady_memory_budget)

    params, opt_state, batch, step = _smoke_train_setup()
    compiled = jax.jit(step, donate_argnums=(0, 1)) \
        .lower(params, opt_state, batch).compile()
    meas = measure_compiled_memory(compiled)
    batch_bytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(batch))
    budget = steady_memory_budget(
        params, opt_state, batch_bytes=batch_bytes,
        activation_bytes=analytic_activation_bytes(
            get_smoke_config("smollm-360m"), 2, 16),
        state_plan_bytes=predict_state_bytes("sumo", params, rank=4))
    rep = audit_memory(meas, budget,
                       param_bytes=tree_param_bytes(params),
                       state_bytes=tree_state_bytes(opt_state))
    return CheckResult("memory/train-step",
                       "PASS" if rep.ok else "FAIL", rep.summary())


def check_memory_table1() -> CheckResult:
    from .memory import audit_table1_state

    results, violations = audit_table1_state(rank=8)
    if violations:
        return CheckResult("memory/table1", "FAIL",
                           "\n".join(str(v) for v in violations))
    ratio = results["sumo"][0] / results["adamw"][0]
    return CheckResult(
        "memory/table1", "PASS",
        f"5 optimizers' live state == exact layout predictor; "
        f"sumo/adamw = {ratio:.3f} (<= 0.80 claim)")


def check_serve_decode() -> CheckResult:
    """The serving-path extension: the compiled paged ``serve_decode`` must
    carry ZERO collectives, realize both KV-pool donations, and fit the
    BlockPool-derived memory budget (an un-donated pool is exactly a 2×
    peak bug, caught twice — by the donation audit and the alias floor)."""
    import jax
    from ..configs import get_smoke_config
    from ..models import init_params
    from ..serve.engine import (ContinuousConfig, PAGED_DECODE_DONATE,
                                paged_serve_decode_fn, serve_decode_audit_args)
    from .collectives import CollectiveBudget, audit_hlo
    from .donation import audit_donation
    from .memory import (audit_memory, measure_compiled_memory,
                         serve_decode_memory_budget)

    cfg = get_smoke_config("smollm-360m")
    ccfg = ContinuousConfig(num_slots=4, block_size=8, n_blocks=32,
                            max_prompt_len=16, max_new_cap=16)
    params = init_params(cfg, jax.random.PRNGKey(0))
    fn = paged_serve_decode_fn(cfg)
    args = serve_decode_audit_args(cfg, ccfg, params)

    don = audit_donation(fn, args, PAGED_DECODE_DONATE)
    compiled = jax.jit(fn, donate_argnums=PAGED_DECODE_DONATE) \
        .lower(*args).compile()
    coll = audit_hlo(compiled.as_text(),
                     CollectiveBudget(name="serve-decode-zero-collective",
                                      rules={}))
    mem = audit_memory(measure_compiled_memory(compiled),
                       serve_decode_memory_budget(cfg, ccfg, params))
    lines = [coll.summary().splitlines()[0],
             don.summary().splitlines()[0],
             mem.summary().splitlines()[0]]
    for rep in (coll, don, mem):
        for v in rep.violations:
            lines.append(f"  ✗ {v}")
    ok = don.ok and coll.ok and mem.ok
    return CheckResult("serve/decode-budget",
                       "PASS" if ok else "FAIL", "\n".join(lines))


def check_inertness_nullblock() -> CheckResult:
    from .inertness import InertnessError, prove_null_block_inertness
    try:
        prove_null_block_inertness()
    except InertnessError as e:
        return CheckResult("inertness/null-block", "FAIL", str(e))
    return CheckResult("inertness/null-block", "PASS",
                       "free slots' all-zero block tables keep decode writes "
                       "in the null block (zero-slab proof)")


def check_host_dtype() -> CheckResult:
    from .donation import audit_host_dtypes
    rep = audit_host_dtypes()
    return CheckResult("donation/host-dtype",
                       "PASS" if rep.ok else "FAIL", rep.summary())


# -- pass 6: precision flow & numerical stability ----------------------------

def check_precision_accumulation() -> CheckResult:
    """Every accumulating op on the SUMO hot path — Gram psums, loss
    reductions, pmeans and dots — must accumulate in >= f32 even when
    operands are bf16. Audited twice on the same real artifact: over the
    compiled sharded update's HLO (`iter_reductions`) and over the traced
    update jaxpr's dtype flow."""
    import jax
    from ..core import SumoConfig
    from ..core.sumo import update_closed_jaxpr
    from .precision import (PrecisionBudget, audit_accumulation_hlo,
                            audit_jaxpr_guards, merge_reports)

    if _devices() < 2:
        return CheckResult("precision/accumulation", "SKIP",
                           f"needs >=2 devices, have {_devices()}")
    mesh = _mesh_1d()
    params = _smoke_params(jax.random.PRNGKey(0), ragged=False)
    cfg = SumoConfig(rank=8, update_freq=4, weight_decay=0.05)
    hlo, _state = _compiled_update_hlo(params, cfg, mesh)
    bud = PrecisionBudget(name="sumo-hot-path")
    rep_hlo = audit_accumulation_hlo(hlo, bud, where="update-1d")
    trace = update_closed_jaxpr(params, cfg, mesh=mesh)
    rep_jx = audit_jaxpr_guards(trace.closed_jaxpr, bud,
                                where="update-jaxpr")
    rep = merge_reports(bud, rep_hlo, rep_jx)
    if rep.ok and rep_hlo.checked < 5:
        return CheckResult(
            "precision/accumulation", "FAIL",
            f"vacuous: only {rep_hlo.checked} accumulating ops found in "
            f"the compiled update — the HLO walk is not seeing the program")
    return CheckResult("precision/accumulation",
                       "PASS" if rep.ok else "FAIL", rep.summary())


def check_precision_wire_dtype() -> CheckResult:
    """The DP payload's TRUE-wire dtype, read from compiled HLO: every
    planned payload must appear as an all-reduce moving exactly
    ``hlo_bytes/elems`` bytes per element — the machine check that the wire
    plan's bf16-promotion dual view matches what XLA actually emits."""
    import jax
    import jax.numpy as jnp
    from ..parallel.compression import (CompressionConfig,
                                        dp_exchange_compiled_hlo)
    from .precision import PrecisionBudget, audit_wire_dtype

    if _devices() < 2:
        return CheckResult("precision/wire-dtype", "SKIP",
                           f"needs >=2 devices, have {_devices()}")
    mesh = _mesh_1d()
    cfg = CompressionConfig(rank=8, min_dim=64, seed=0,
                            payload_dtype="bfloat16")
    tmpl = {"w": jnp.ones((256, 96), jnp.float32),
            "b": jnp.ones((8,), jnp.float32)}
    hlo, plan = dp_exchange_compiled_hlo(mesh, cfg, tmpl)
    bud = PrecisionBudget(name="dp-wire", wire_dtype="bfloat16")
    rep = audit_wire_dtype(hlo, plan, bud)
    if rep.ok and rep.checked < 2:
        return CheckResult("precision/wire-dtype", "FAIL",
                           f"vacuous: only {rep.checked} payloads matched")
    return CheckResult("precision/wire-dtype",
                       "PASS" if rep.ok else "FAIL", rep.summary())


def check_precision_guards() -> CheckResult:
    """Eps-guard lint over the refresh/orthogonalization jaxprs: every
    div/rsqrt denominator must carry a provable positive floor and every
    Cholesky operand a shift on the eps*trace scale (the PR 5 bug class —
    a bare 1e-12 constant shift — has relative scale 0 and fails). Traces
    abstractly; needs no devices."""
    from ..core.orthogonalize import ORTH_METHODS, orth_closed_jaxpr
    from ..core.rsvd import cholesky_qr2_closed_jaxpr, refresh_closed_jaxpr
    from .precision import (PrecisionBudget, audit_jaxpr_guards,
                            merge_reports)

    bud = PrecisionBudget(name="refresh-guards")
    reports = [
        audit_jaxpr_guards(refresh_closed_jaxpr(64, 16, 4), bud,
                           where="rsvd/refresh"),
        audit_jaxpr_guards(cholesky_qr2_closed_jaxpr(64, 8), bud,
                           where="rsvd/cholesky-qr2"),
    ]
    for method in ORTH_METHODS:
        reports.append(audit_jaxpr_guards(orth_closed_jaxpr(method), bud,
                                          where=f"orth/{method}"))
    rep = merge_reports(bud, *reports)
    if rep.ok and (reports[0].checked < 10 or reports[1].checked < 4):
        return CheckResult(
            "precision/guards", "FAIL",
            "vacuous: the refresh/CholeskyQR2 jaxprs show almost no "
            "div/cholesky sites — the interpreter is not descending into "
            "the traced program")
    return CheckResult("precision/guards",
                       "PASS" if rep.ok else "FAIL", rep.summary())


def check_precision_ortho_bound() -> CheckResult:
    """The paper's kappa-dependent ortho error bound as an executable
    check, in two parts. (a) Tiering on an ill-conditioned synthetic
    moment: exact SVD must sit under the SVD-tier budget while NS5 must
    EXCEED it (yet respect its own plateau bound) — if NS5 passed the SVD
    tier the bound would be vacuous and this check FAILs. (b) A real
    telemetry-enabled SUMO run: every bucket's measured residual must sit
    under the configured method's bound at its measured kappa."""
    import math

    import jax
    import jax.numpy as jnp
    from ..core import SumoConfig, sumo
    from ..core.orthogonalize import (condition_number, newton_schulz5,
                                      orthogonality_error, orthogonalize_svd)
    from ..core.sumo import bucket_spectral_stats
    from .precision import PrecisionBudget, audit_ortho_bound

    bud = PrecisionBudget(name="ortho-bound")

    # (a) ill-conditioned synthetic moment, sigma from 1 to 1e-2.
    r, n = 16, 128
    key = jax.random.PRNGKey(0)
    u, _ = jnp.linalg.qr(jax.random.normal(key, (r, r)))
    v, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1),
                                           (n, r)))
    M = (u * jnp.linspace(1.0, 1e-2, r)) @ v.T
    kappa = float(condition_number(M))

    def stats_for(O):
        return {"sigma": [0.0] * r, "kappa": kappa,
                "ortho_residual": float(orthogonality_error(O))}

    svd_stats = {"synthetic": stats_for(orthogonalize_svd(M))}
    ns5_stats = {"synthetic": stats_for(newton_schulz5(M))}
    svd_vs_tier = audit_ortho_bound(svd_stats, "svd", bud)
    ns5_vs_tier = audit_ortho_bound(ns5_stats, "svd", bud)
    ns5_vs_own = audit_ortho_bound(ns5_stats, "ns5", bud)
    lines = [f"synthetic kappa={kappa:.3g}: svd vs svd-tier "
             f"{'OK' if svd_vs_tier.ok else 'FAIL'}, ns5 vs svd-tier "
             f"{'exceeds (expected)' if not ns5_vs_tier.ok else 'PASSES?!'},"
             f" ns5 vs ns5-bound {'OK' if ns5_vs_own.ok else 'FAIL'}"]
    tier_ok = svd_vs_tier.ok and ns5_vs_own.ok and not ns5_vs_tier.ok

    # (b) real run: telemetry stats from a short SUMO least-squares fit.
    cfg = SumoConfig(rank=8, update_freq=5, orth_method="polar",
                     telemetry=True)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 48)) * 0.1
    tx = sumo(0.01, cfg)
    state = tx.init({"w": w})
    params = {"w": w}
    for step in range(12):
        g = {"w": jax.random.normal(jax.random.fold_in(key, step),
                                    (64, 48))}
        upd, state = tx.update(g, state, params)
        params = jax.tree_util.tree_map(lambda p, u_: p + u_, params, upd)
    stats = bucket_spectral_stats(state)
    run_rep = audit_ortho_bound(stats, cfg.orth_method, bud,
                                where="telemetry")
    lines.append(run_rep.summary().splitlines()[0])
    lines += [f"  {viol}" for viol in run_rep.violations]
    ok = tier_ok and run_rep.ok and run_rep.checked >= 1
    if run_rep.ok and run_rep.checked < 1:
        lines.append("vacuous: telemetry produced no bucket stats")
    return CheckResult("precision/ortho-bound",
                       "PASS" if ok else "FAIL", "\n".join(lines))


# -- entry point ------------------------------------------------------------

#: The single source of truth for check names and lane membership —
#: ``list_checks`` (the --list mode) feeds tools/run_tier1.sh and
#: tools/analysis_diff.py so required-check sets are never hardcoded in
#: shell. mode tag: "1d" / "2d" lane-specific, "both" runs in every lane.
_CHECKS = (
    ("collectives/steady-1d", "1d", check_collectives_1d),
    ("inertness/refresh", "both", check_inertness_refresh),
    ("inertness/update-1d", "1d", lambda: check_inertness_update(False)),
    ("inertness/null-block", "1d", check_inertness_nullblock),
    ("donation", "1d", check_donation),
    ("donation/host-dtype", "1d", check_host_dtype),
    ("recompile", "1d", check_recompile),
    ("memory/train-step", "1d", check_memory_train),
    ("memory/table1", "1d", check_memory_table1),
    ("serve/decode-budget", "1d", check_serve_decode),
    ("precision/accumulation", "1d", check_precision_accumulation),
    ("precision/wire-dtype", "1d", check_precision_wire_dtype),
    ("precision/guards", "both", check_precision_guards),
    ("precision/ortho-bound", "both", check_precision_ortho_bound),
    ("collectives/steady-2d", "2d", check_collectives_2d),
    ("inertness/update-2d", "2d", lambda: check_inertness_update(True)),
)


def _selected(mode: str) -> list:
    return [(n, t, f) for n, t, f in _CHECKS
            if mode == "all" or t == "both" or t == mode]


def list_checks(mode: str = "all") -> list:
    """Check names + lane tags for a mode, WITHOUT running anything (and
    without importing jax) — the machine-readable contract consumers diff
    reports against."""
    return [{"name": n, "mode": t} for n, t, _ in _selected(mode)]


def run_checks(mode: str = "all") -> list:
    """Execute every check the mode asks for; returns [CheckResult...]."""
    return [f() for _, _, f in _selected(mode)]


def run(mode: str = "all", log=print) -> int:
    results = run_checks(mode)
    width = max(len(r.name) for r in results)
    failed = False
    for r in results:
        log(f"[{r.status:4s}] {r.name:<{width}}  "
            + (r.detail.splitlines()[0] if r.detail else ""))
        if r.status == "FAIL":
            failed = True
            for line in r.detail.splitlines()[1:]:
                log(" " * 8 + line)
    log("static analysis: " + ("FAIL" if failed else "OK")
        + f" ({sum(r.status == 'PASS' for r in results)} passed, "
        + f"{sum(r.status == 'SKIP' for r in results)} skipped)")
    return 1 if failed else 0


REPORT_SCHEMA = "static-analysis-v2"


def json_report(mode: str = "all") -> dict:
    """Machine-readable run: stable schema + per-check name/status/detail.
    tools/run_tier1.sh consumes this instead of grepping the human log."""
    results = run_checks(mode)
    return {
        "schema": REPORT_SCHEMA,
        "mode": mode,
        "ok": all(r.status != "FAIL" for r in results),
        "passed": sum(r.status == "PASS" for r in results),
        "skipped": sum(r.status == "SKIP" for r in results),
        "failed": sum(r.status == "FAIL" for r in results),
        "checks": [dataclasses.asdict(r) for r in results],
    }


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Run the repro static-analysis passes.")
    ap.add_argument("--mode", choices=("1d", "2d", "all"), default="all")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable report on stdout "
                         "(schema %s) instead of the human log"
                         % REPORT_SCHEMA)
    ap.add_argument("--list", action="store_true",
                    help="print the check names/lanes the mode requires "
                         "(JSON, no checks run, no jax import) and exit")
    args = ap.parse_args(argv)
    if args.list:
        import json
        print(json.dumps({"schema": REPORT_SCHEMA, "mode": args.mode,
                          "checks": list_checks(args.mode)}, indent=2))
        return 0
    if args.json:
        import json
        rep = json_report(args.mode)
        print(json.dumps(rep, indent=2))
        return 0 if rep["ok"] else 1
    return run(args.mode)
