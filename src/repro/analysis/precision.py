"""Pass 6 — precision-flow & numerical-stability lint.

Machine-checks the two numerics disciplines the repo's history shows are the
live failure modes, plus the paper's condition-number error bound:

  1. **Accumulation dtype** (`low-precision-accumulation`): every Gram psum,
     loss reduction, pmean and dot on the SUMO hot path must accumulate in
     >= f32 even when operands are bf16. Checked two ways — an HLO walk over
     compiled artifacts (``roofline.hlo_cost.iter_reductions`` exposes each
     reduce/dot/all-reduce's accumulation element type and its ``to_apply``
     computation root) and a jaxpr dtype-flow over the traced update.
  2. **Wire dtype** (`bf16-wire-promoted`): the DP payload's *true-wire*
     dtype read from compiled HLO, closing the loop on the wire plan's
     hand-carried ``hlo_bytes`` dual view (``WirePlanEntry.hlo_bytes``): a
     plan that claims bf16 stays bf16 on a backend whose all-reduce
     promotion pass upcasts it to f32 fails here, by name.
  3. **Eps-guard lint** (`unguarded-division` / `under-scaled-shift`): an
     abstract interpreter over the refresh/orthogonalization jaxprs proving
     every div/rsqrt denominator carries a positive floor and every Cholesky
     operand carries a shift on the eps * trace scale. This is the check that
     would have caught the PR 5 bug class — a pure-constant 1e-12 shift
     ~1000x below fp32 roundoff has relative scale 0 and fails.
  4. **Ortho error bound** (`ortho-error-bound-exceeded`): the paper's
     Lemma 3.2 bound ||NS_i(M) M^+ M - proj|| <= sqrt(r) (1 - 1/kappa)^(2^i)
     as an executable per-bucket check over telemetry ``SpectralStats``,
     with an SVD-tier budget that Newton-Schulz-5 demonstrably fails on
     ill-conditioned moments while exact SVD passes.

All audits return a ``PrecisionReport``; ``assert_precision`` raises
``PrecisionError`` (an AssertionError carrying the report). The module
imports neither jax nor numpy at top level so ``--list``-style driver uses
stay import-light; jaxpr objects are consumed duck-typed.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Optional

from ..roofline.hlo_cost import (_DTYPE_BYTES, _FLOAT_DTYPES, iter_collectives,
                                 iter_reductions)

# Machine-readable IDs, stable across refactors — tests and CI key on these.
PRECISION_VIOLATION_CODES = (
    "low-precision-accumulation",
    "bf16-wire-promoted",
    "unguarded-division",
    "under-scaled-shift",
    "ortho-error-bound-exceeded",
)

F32_EPS = 1.1920928955078125e-07

# Normalized fixed-point residual of the Muon quintic (3.4445, -4.7750,
# 2.0315): its iteration trades exact convergence for speed, so singular
# values land in a band around 1 rather than at 1 and ||OO^T - I||_F /
# sqrt(r) plateaus near 0.5 no matter how many steps run (worst measured
# excess over the Lemma 3.2 kappa term is 0.49/sqrt(r), at kappa -> 1).
# The ns5 bound tier adds this plateau on top of the kappa term; the SVD
# tier does NOT — which is exactly why ns5 fails the SVD-tier budget.
NS5_PLATEAU = 0.6


@dataclass(frozen=True)
class PrecisionViolation:
    code: str       # one of PRECISION_VIOLATION_CODES
    detail: str     # human-readable: what, where, expected vs got
    where: str      # jaxpr path / HLO computation / bucket key
    source: str = "?"   # HLO op_name metadata when available

    def __str__(self):
        return f"[{self.code}] {self.where}: {self.detail}"


@dataclass(frozen=True)
class PrecisionBudget:
    """Declarative precision policy one artifact is audited against.

    min_accum_bytes   floating accumulations (dot / reduce-add / all-reduce)
                      must run at >= this element size (4 = f32)
    min_shift_rel     Cholesky operands must carry a diagonal shift of at
                      least this fraction of trace(gram). The repo's own
                      CholeskyQR2 second pass uses 2*eps/l (~3e-8 for l=8),
                      legitimately BELOW f32 eps — so the floor defaults to
                      1e-9, three decades above the PR 5 bug's 1e-12-of-
                      nothing (relative scale 0) but under every real shift.
    wire_dtype        expected payload dtype name for wire audits (None =
                      take each plan entry's own hlo_bytes claim)
    allow_sources     op_name substrings exempt from the accumulation check
                      (e.g. integer bookkeeping fused into a float reduce)
    bound_scale       multiplier on the ortho error bound (1.0 = the paper's
                      bound as stated; tests loosen/tighten via this)
    ns_steps          Newton-Schulz iteration count the bound is evaluated at
    """
    name: str
    min_accum_bytes: int = 4
    min_shift_rel: float = 1e-9
    wire_dtype: Optional[str] = None
    allow_sources: tuple = ()
    bound_scale: float = 1.0
    ns_steps: int = 5
    note: str = ""


@dataclass(frozen=True)
class PrecisionReport:
    budget: PrecisionBudget
    ok: bool
    violations: tuple          # of PrecisionViolation
    checked: int               # sites actually inspected (non-vacuity)
    note: str = ""

    def summary(self) -> str:
        head = (f"precision budget '{self.budget.name}': "
                f"{'OK' if self.ok else 'FAIL'} "
                f"({self.checked} sites checked, "
                f"{len(self.violations)} violations)")
        lines = [head] + [f"  {v}" for v in self.violations]
        if self.note:
            lines.append(f"  note: {self.note}")
        return "\n".join(lines)


class PrecisionError(AssertionError):
    def __init__(self, report: PrecisionReport):
        super().__init__(report.summary())
        self.report = report


def assert_precision(report: PrecisionReport) -> PrecisionReport:
    if not report.ok:
        raise PrecisionError(report)
    return report


def merge_reports(budget: PrecisionBudget, *reports) -> PrecisionReport:
    """Fold several audits of one artifact family into a single verdict."""
    violations, checked, notes = [], 0, []
    for r in reports:
        violations.extend(r.violations)
        checked += r.checked
        if r.note:
            notes.append(r.note)
    return PrecisionReport(budget=budget, ok=not violations,
                           violations=tuple(violations), checked=checked,
                           note="; ".join(notes))


# ---------------------------------------------------------------------------
# 1. Accumulation dtype over compiled HLO
# ---------------------------------------------------------------------------

# reduce computations whose result is precision-sensitive: accumulating
# roots lose mass to rounding at low precision; max/min/and/or do not.
_ACCUM_ROOTS = {"add", "multiply"}


def audit_accumulation_hlo(hlo_text, budget: PrecisionBudget,
                           where: str = "hlo") -> PrecisionReport:
    """Every accumulating op in a compiled program must run at
    >= ``budget.min_accum_bytes`` per element (f32 by default), regardless
    of operand dtype — a bf16 x bf16 dot with an f32 result passes; an
    f16-accumulated Gram psum fails with `low-precision-accumulation`."""
    violations, checked = [], 0
    for ent in iter_reductions(hlo_text):
        if any(a in ent["source"] for a in budget.allow_sources):
            continue
        # Reductions with a non-accumulating computation root (max-pool,
        # arg-reduce bookkeeping, boolean any/all) are precision-neutral.
        if ent["op"] != "dot" and ent["comp_root"] is not None \
                and ent["comp_root"] not in _ACCUM_ROOTS:
            continue
        floats = [d for d in ent["accum_dtypes"] if d in _FLOAT_DTYPES]
        if not floats:
            continue  # integer/predicate reduction
        checked += 1
        bad = [d for d in floats if _DTYPE_BYTES[d] < budget.min_accum_bytes]
        if bad:
            violations.append(PrecisionViolation(
                code="low-precision-accumulation",
                detail=(f"{ent['op']} accumulates in {'/'.join(bad)} "
                        f"(< {budget.min_accum_bytes} B/elem) over operands "
                        f"{ent['operand_dtypes']} shape {ent['shape']}"),
                where=f"{where}/{ent['computation']}",
                source=ent["source"]))
    return PrecisionReport(
        budget=budget, ok=not violations, violations=tuple(violations),
        checked=checked,
        note=f"{checked} accumulating ops inspected in {where}")


# ---------------------------------------------------------------------------
# 2. True-wire dtype of the DP exchange
# ---------------------------------------------------------------------------

def _prod(dims) -> int:
    n = 1
    for d in dims:
        n *= int(d)
    return n


def audit_wire_dtype(hlo_text, plan, budget: PrecisionBudget,
                     where: str = "dp-exchange") -> PrecisionReport:
    """Check the wire plan's ``hlo_bytes`` dual view against the compiled
    program: for every planned payload there must be an all-reduce whose
    element count matches ``payload_dims`` and whose MEASURED bytes/element
    equal the plan's claim. A plan claiming a bf16 wire on a backend whose
    all-reduce promotion pass upcasts to f32 fails `bf16-wire-promoted` —
    the claim and the wire disagree, in either direction."""
    avail = [c for c in iter_collectives(hlo_text)
             if c["op"] in ("all-reduce", "reduce-scatter") and c["dims"]]
    violations, checked = [], 0
    for ent in plan:
        elems = _prod(ent.payload_dims)
        if elems <= 0:
            continue
        checked += 1
        want_isz = ent.hlo_bytes / elems
        # Prefer an exact (elems, itemsize) match; fall back to elems only.
        match = best = None
        for c in avail:
            if _prod(c["dims"]) != elems:
                continue
            best = best or c
            if abs(c["payload"] / elems - want_isz) < 0.5:
                match = c
                break
        if match is not None:
            avail.remove(match)
            continue
        if best is not None:
            avail.remove(best)
            got_isz = best["payload"] / _prod(best["dims"])
            violations.append(PrecisionViolation(
                code="bf16-wire-promoted",
                detail=(f"leaf '{ent.path}' planned {want_isz:g} B/elem on "
                        f"the wire (hlo_bytes={ent.hlo_bytes}) but the "
                        f"compiled all-reduce moves {got_isz:g} B/elem "
                        f"({best['payload']} B, dims {best['dims']})"),
                where=f"{where}/{best['computation']}",
                source=best["source"]))
        else:
            violations.append(PrecisionViolation(
                code="bf16-wire-promoted",
                detail=(f"no all-reduce carrying {elems} elems found for "
                        f"leaf '{ent.path}' (payload_dims "
                        f"{ent.payload_dims}) — wire plan and compiled "
                        f"program disagree"),
                where=where))
    return PrecisionReport(
        budget=budget, ok=not violations, violations=tuple(violations),
        checked=checked,
        note=f"{checked} planned payloads matched against compiled wire")


# ---------------------------------------------------------------------------
# 3. Guard lint + dtype flow over jaxprs (abstract interpretation)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _Abs:
    """Abstract value: what the lint knows about a jaxpr intermediate.

    nonneg      provably >= 0
    floor       provable lower bound (> 0 means 'guarded denominator')
    const       known compile-time scalar value, else None
    mask        0/1-valued (identity masks from eq(iota, iota) chains)
    shift_rel   'this value is >= shift_rel * trace(input matrix)' — the
                relative scale of a diagonal shift. Scalar full-reductions
                seed it at 1.0; multiplying by eps-scale constants scales
                it; adding to the Gram matrix preserves it. A Cholesky
                operand must arrive with shift_rel >= budget.min_shift_rel.
    """
    nonneg: bool = False
    floor: float = 0.0
    const: Optional[float] = None
    mask: bool = False
    shift_rel: float = 0.0


_TOP = _Abs()

_PASSTHROUGH = {
    "transpose", "broadcast_in_dim", "reshape", "squeeze", "expand_dims",
    "copy", "convert_element_type", "reduce_precision", "stop_gradient",
    "rev", "real", "slice", "dynamic_slice", "gather",
}

# Primitives whose OUTPUT dtype is an accumulation precision at jaxpr level.
_ACCUM_PRIMS = {"dot_general", "reduce_sum", "psum", "pmean", "pdot"}

_CALL_PRIMS = {"pjit", "closed_call", "core_call", "remat", "remat2",
               "checkpoint", "xla_call"}


def _is_lit(v) -> bool:
    return hasattr(v, "val")


def _lit_abs(v) -> _Abs:
    try:
        c = float(v.val)
    except (TypeError, ValueError):
        return _TOP
    return _Abs(nonneg=c >= 0.0, floor=c if c > 0.0 else 0.0, const=c)


def _read(env, v) -> _Abs:
    return _lit_abs(v) if _is_lit(v) else env.get(v, _TOP)


def _out_ndim(eqn) -> int:
    aval = getattr(eqn.outvars[0], "aval", None)
    return len(getattr(aval, "shape", ()) or ())


def _float_itemsize(var) -> Optional[int]:
    aval = getattr(var, "aval", None)
    dt = getattr(aval, "dtype", None)
    if dt is None:
        return None
    # ml_dtypes extension floats (bfloat16, float8_*) report numpy kind 'V',
    # so classify by name, not kind.
    name = getattr(dt, "name", "")
    if not (name.startswith("float") or name.startswith("bfloat")):
        return None
    return int(dt.itemsize)


def _mul_abs(a: _Abs, b: _Abs, same_var: bool) -> _Abs:
    if same_var:  # x * x
        return _Abs(nonneg=True, floor=a.floor * a.floor)
    const = None
    if a.const is not None and b.const is not None:
        const = a.const * b.const
    nonneg = (a.nonneg and b.nonneg)
    floor = a.floor * b.floor if nonneg else 0.0
    # Scaling a trace-scale scalar by a constant scales the shift claim;
    # multiplying it into a 0/1 identity mask preserves it (diagonal shift).
    shift_rel = 0.0
    if b.const is not None and b.const > 0:
        shift_rel = a.shift_rel * b.const
    elif a.const is not None and a.const > 0:
        shift_rel = b.shift_rel * a.const
    elif b.mask:
        shift_rel = a.shift_rel
    elif a.mask:
        shift_rel = b.shift_rel
    return _Abs(nonneg=nonneg, floor=floor, const=const,
                mask=a.mask and b.mask, shift_rel=shift_rel)


def _add_abs(a: _Abs, b: _Abs) -> _Abs:
    const = None
    if a.const is not None and b.const is not None:
        const = a.const + b.const
    nonneg = a.nonneg and b.nonneg
    return _Abs(nonneg=nonneg,
                floor=(a.floor + b.floor) if nonneg else 0.0, const=const,
                shift_rel=max(a.shift_rel, b.shift_rel))


def _sub_jaxpr(params):
    """The inner jaxpr of a call-like eqn, ClosedJaxpr or bare."""
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in params:
            inner = params[key]
            return getattr(inner, "jaxpr", inner)
    return None


def audit_jaxpr_guards(closed_jaxpr, budget: PrecisionBudget,
                       where: str = "jaxpr") -> PrecisionReport:
    """Abstract-interpret a jaxpr, proving (a) every div/rsqrt denominator
    carries a positive floor or nonzero constant, (b) every Cholesky operand
    carries a diagonal shift on the eps * trace scale (relative magnitude
    >= budget.min_shift_rel — a bare 1e-12 constant has relative scale 0
    and fails), and (c) every float dot/reduce/psum output dtype meets
    ``min_accum_bytes``. Control-flow bodies (scan/while/cond) are entered
    with unknown inputs, so guards established inside them still count but
    guards established outside them do not leak in (sound for linting)."""
    violations: list = []
    counts = {"div": 0, "rsqrt": 0, "cholesky": 0, "accum": 0}
    seen: set = set()

    def emit(code, detail, path):
        key = (code, path, detail)
        if key not in seen:
            seen.add(key)
            violations.append(PrecisionViolation(
                code=code, detail=detail, where=path))

    def run(jaxpr, in_abs, path):
        env: dict = {}
        invars = list(jaxpr.invars)
        if in_abs is not None:
            for v, a in zip(invars, list(in_abs)[:len(invars)]):
                env[v] = a
        for v in getattr(jaxpr, "constvars", ()):
            env[v] = _TOP
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            ins = [_read(env, v) for v in eqn.invars]
            out = _TOP

            if prim in _CALL_PRIMS:
                inner = _sub_jaxpr(eqn.params)
                name = eqn.params.get("name", prim)
                if inner is not None:
                    sub = run(inner, ins[-len(inner.invars):],
                              f"{path}/{name}")
                    for v, a in zip(eqn.outvars, sub):
                        env[v] = a
                continue
            if prim in ("custom_jvp_call", "custom_vjp_call",
                        "custom_jvp_call_jaxpr"):
                inner = _sub_jaxpr(eqn.params)
                if inner is not None:
                    sub = run(inner, ins[-len(inner.invars):],
                              f"{path}/{prim}")
                    for v, a in zip(eqn.outvars, sub):
                        env[v] = a
                continue
            if prim == "shard_map":
                inner = _sub_jaxpr(eqn.params)
                if inner is not None:
                    sub = run(inner, ins[-len(inner.invars):],
                              f"{path}/shard_map")
                    for v, a in zip(eqn.outvars, sub):
                        env[v] = a
                continue
            if prim in ("scan", "while"):
                # Loop-carried values change across iterations: enter with
                # unknowns so an outside guard can't vouch for inside uses.
                inners = [eqn.params.get(k)
                          for k in ("jaxpr", "cond_jaxpr", "body_jaxpr")]
                for inner in inners:
                    if inner is not None:
                        run(getattr(inner, "jaxpr", inner), None,
                            f"{path}/{prim}")
                for v in eqn.outvars:
                    env[v] = _TOP
                continue
            if prim == "cond":
                for br in eqn.params.get("branches", ()):
                    run(getattr(br, "jaxpr", br), None, f"{path}/cond")
                for v in eqn.outvars:
                    env[v] = _TOP
                continue

            if prim in _PASSTHROUGH and ins:
                out = ins[0]
            elif prim == "mul" and len(ins) == 2:
                same = (not _is_lit(eqn.invars[0])
                        and not _is_lit(eqn.invars[1])
                        and eqn.invars[0] is eqn.invars[1])
                out = _mul_abs(ins[0], ins[1], same)
            elif prim == "add" and len(ins) == 2:
                out = _add_abs(ins[0], ins[1])
            elif prim == "max" and len(ins) == 2:
                out = _Abs(nonneg=ins[0].nonneg or ins[1].nonneg,
                           floor=max(ins[0].floor, ins[1].floor),
                           shift_rel=max(ins[0].shift_rel,
                                         ins[1].shift_rel))
            elif prim == "min" and len(ins) == 2:
                out = _Abs(nonneg=ins[0].nonneg and ins[1].nonneg,
                           floor=min(ins[0].floor, ins[1].floor))
            elif prim in ("abs", "exp", "integer_pow") and ins:
                if prim == "integer_pow" and eqn.params.get("y", 0) % 2:
                    out = ins[0]
                else:
                    out = _Abs(nonneg=True,
                               floor=ins[0].floor if prim == "abs" else 0.0)
            elif prim == "sqrt" and ins:
                out = _Abs(nonneg=True, floor=math.sqrt(max(ins[0].floor,
                                                            0.0)))
            elif prim in ("eq", "ne", "lt", "le", "gt", "ge"):
                out = _Abs(nonneg=True, mask=True)
            elif prim == "iota":
                out = _Abs(nonneg=True)
            elif prim == "svd":
                # Singular values are nonnegative by definition; they are
                # the rank-(input-1) output (u/vt keep input rank).
                in_nd = len(getattr(getattr(eqn.invars[0], "aval", None),
                                    "shape", ()) or ())
                for ov in eqn.outvars:
                    nd = len(getattr(getattr(ov, "aval", None),
                                     "shape", ()) or ())
                    env[ov] = _Abs(nonneg=True) if nd == in_nd - 1 else _TOP
                continue
            elif prim == "select_n" and len(ins) >= 3:
                cases = ins[1:]
                out = _Abs(nonneg=all(c.nonneg for c in cases),
                           floor=min(c.floor for c in cases),
                           mask=all(c.mask or c.const in (0.0, 1.0)
                                    for c in cases),
                           shift_rel=min(c.shift_rel for c in cases))
            elif prim in ("reduce_sum", "reduce_max", "reduce_min",
                          "reduce_prod"):
                nonneg = ins[0].nonneg if ins else False
                # A full reduction of the matrix is on the trace scale —
                # the seed every relative shift claim is grown from.
                out = _Abs(nonneg=nonneg,
                           shift_rel=1.0 if _out_ndim(eqn) == 0 else 0.0)
            elif prim in ("psum", "pmean", "pmax", "pmin", "all_gather"):
                out = ins[0] if ins else _TOP
            elif prim in ("div", "rsqrt"):
                counts[prim] += 1
                den = ins[1] if prim == "div" else ins[0]
                num = ins[0]
                if den.const is not None and den.const != 0.0:
                    if prim == "div" and den.const > 0:
                        out = _Abs(nonneg=num.nonneg,
                                   floor=num.floor / den.const,
                                   shift_rel=num.shift_rel / den.const)
                elif den.floor <= 0.0:
                    emit("unguarded-division",
                         f"{prim} denominator has no provable positive "
                         f"floor (no eps guard on the path)", path)
            elif prim == "cholesky":
                counts["cholesky"] += 1
                rel = ins[0].shift_rel if ins else 0.0
                if rel < budget.min_shift_rel:
                    emit("under-scaled-shift",
                         f"cholesky operand shift has relative scale "
                         f"{rel:.3e} < {budget.min_shift_rel:.1e} of "
                         f"trace(gram) — a constant-only shift (the PR 5 "
                         f"bug class) proves nothing at scale", path)

            if prim in _ACCUM_PRIMS:
                for ov in eqn.outvars:
                    isz = _float_itemsize(ov)
                    if isz is not None:
                        counts["accum"] += 1
                        if isz < budget.min_accum_bytes:
                            emit("low-precision-accumulation",
                                 f"{prim} accumulates in a {isz} B/elem "
                                 f"float (< {budget.min_accum_bytes})",
                                 path)

            for v in eqn.outvars:
                env.setdefault(v, out)
        return [_read(env, v) for v in jaxpr.outvars]

    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    run(jaxpr, None, where)
    checked = sum(counts.values())
    return PrecisionReport(
        budget=budget, ok=not violations, violations=tuple(violations),
        checked=checked,
        note=(f"{counts['div']} div, {counts['rsqrt']} rsqrt, "
              f"{counts['cholesky']} cholesky, {counts['accum']} "
              f"accumulation sites inspected in {where}"))


# ---------------------------------------------------------------------------
# 4. The paper's ortho error bound as an executable check
# ---------------------------------------------------------------------------

def ns_error_bound(kappa: float, r: int, steps: int = 5) -> float:
    """Lemma 3.2: ||NS_i(M) - polar(M)||_F <= sqrt(r) (1 - 1/kappa)^(2^i),
    with kappa the squared-singular-value condition number the telemetry's
    ``condition_number`` reports. Unnormalized Frobenius bound."""
    kappa = max(float(kappa), 1.0)
    return math.sqrt(max(r, 1)) * (1.0 - 1.0 / kappa) ** (2 ** steps)


def svd_tier_bound(r: int, kappa: float = 1.0) -> float:
    """Roundoff-tier budget for EXACT orthogonalization (svd / polar):
    a few hundred ulps, growing mildly with conditioning. Any iterative
    scheme with a convergence plateau sits orders of magnitude above this."""
    kappa = max(float(kappa), 1.0)
    return 256.0 * F32_EPS * math.sqrt(max(r, 1)) * (1.0 + kappa ** 0.25)


def method_bound(method: str, kappa: float, r: int,
                 ns_steps: int = 5) -> float:
    """Unnormalized Frobenius bound on ||OO^T - I||_F for the configured
    orthogonalization method — the single bound code path shared by the
    lint, the driver check and benchmarks/ortho_error.py."""
    if method in ("svd", "polar"):
        return svd_tier_bound(r, kappa)
    if method == "ns5":
        # Muon's quintic never reaches exact orthogonality: kappa term
        # plus its fixed-point plateau.
        return (ns_error_bound(kappa, r, ns_steps)
                + NS5_PLATEAU * math.sqrt(max(r, 1)))
    if method == "cubic":
        return ns_error_bound(kappa, r, ns_steps) + svd_tier_bound(r, kappa)
    raise ValueError(f"unknown orthogonalization method: {method!r}")


def _stat(stats, key):
    v = stats[key] if isinstance(stats, dict) else getattr(stats, key)
    return v


def audit_ortho_bound(bucket_stats, method: str, budget: PrecisionBudget,
                      where: str = "telemetry") -> PrecisionReport:
    """Per-bucket: the measured ortho residual from telemetry
    ``SpectralStats`` (normalized, ||OO^T - I||_F / sqrt(r)) must sit under
    ``bound_scale * method_bound(method, kappa, r)``. Auditing an ns5 run
    against ``method='svd'`` applies the SVD-tier budget — the
    falsification the acceptance criteria demand."""
    violations, checked = [], 0
    for bucket, stats in dict(bucket_stats).items():
        sigma = _stat(stats, "sigma")
        r = int(len(sigma))
        kappa = float(_stat(stats, "kappa"))
        resid = float(_stat(stats, "ortho_residual"))
        if not math.isfinite(resid) or r == 0:
            continue
        checked += 1
        measured = resid * math.sqrt(r)   # un-normalize to Frobenius
        bound = budget.bound_scale * method_bound(method, kappa, r,
                                                  budget.ns_steps)
        if measured > bound:
            violations.append(PrecisionViolation(
                code="ortho-error-bound-exceeded",
                detail=(f"bucket {bucket}: measured ||OO^T-I||_F = "
                        f"{measured:.3e} exceeds the {method} bound "
                        f"{bound:.3e} at kappa={kappa:.3g}, r={r}"),
                where=f"{where}/{bucket}"))
    return PrecisionReport(
        budget=budget, ok=not violations, violations=tuple(violations),
        checked=checked,
        note=f"{checked} buckets audited against the {method} bound")
