"""End-to-end driver: pre-train a ~100M-class LLaMA (the paper's Table-3
family, width-reduced for CPU) for a few hundred steps with SUMO — with
checkpointing and TWO simulated node preemptions that the supervisor
recovers from mid-run. Demonstrates (train loop + checkpoint/restart +
deterministic data replay + straggler monitor) working together.

    PYTHONPATH=src python examples/pretrain_fault_tolerant.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

from repro.configs.llama_paper import LLAMA_60M
from repro.configs.base import ShapeConfig
from repro.train import FaultInjector, TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--optimizer", default="sumo")
    args = ap.parse_args()

    arch = dataclasses.replace(
        LLAMA_60M, n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=344, vocab=2048, remat=False, dtype="float32",
    )
    shape = ShapeConfig("pretrain", seq_len=128, global_batch=8, kind="train")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainConfig(
            optimizer=args.optimizer, learning_rate=3e-3, rank=32,
            update_freq=25, total_steps=args.steps,
            ckpt_dir=ckpt_dir, ckpt_every=25, log_every=20,
        )
        injector = FaultInjector(preempt_at=[args.steps // 3, 2 * args.steps // 3])
        res = train(arch, shape, tcfg, fault_injector=injector)

    first = sum(l for _, l in res.losses[:5]) / 5
    last = sum(l for _, l in res.losses[-5:]) / 5
    print(f"\npre-training done: {res.final_step} steps, "
          f"loss {first:.3f} -> {last:.3f}, recovered from {res.restarts} faults")
    assert res.restarts >= 2 and last < first


if __name__ == "__main__":
    main()
