"""Quickstart: train a small LM with SUMO in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core import SumoConfig, apply_updates, sumo_optimizer
from repro.data import make_batch
from repro.models import init_params, loss_fn


def main():
    # 1. pick an architecture (reduced config so it runs on CPU)
    cfg = get_smoke_config("qwen3-4b")
    shape = ShapeConfig("quickstart", seq_len=64, global_batch=8, kind="train")

    # 2. init params and the SUMO optimizer (paper Algorithm 1)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tx = sumo_optimizer(
        3e-3, params,
        SumoConfig(rank=8, update_freq=20, orth_method="polar"),
    )
    opt_state = tx.init(params)

    # 3. jitted train step
    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
        updates, opt_state = tx.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    # 4. train on the synthetic deterministic stream
    for i in range(40):
        batch = make_batch(i, shape, cfg)
        params, opt_state, loss = step(params, opt_state, batch)
        if i % 10 == 0:
            print(f"step {i:3d}  loss {float(loss):.4f}")
    print("done.")


if __name__ == "__main__":
    main()
