"""Reproduce the paper's central comparison (Fig. 2 / Table 2 ordering):
SUMO-SVD vs SUMO-NS5 vs GaLore vs AdamW at equal rank, on the same model and
data. Prints a loss-curve table and the steps-to-threshold speedup.

    PYTHONPATH=src python examples/optimizer_comparison.py [--steps 150]
"""
import argparse

import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--rank", type=int, default=8)
    args = ap.parse_args()

    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("cmp", seq_len=64, global_batch=16, kind="train")
    curves = {}
    for opt in ("sumo-svd", "sumo-ns5", "galore", "adamw"):
        res = train(
            arch, shape,
            TrainConfig(optimizer=opt, learning_rate=3e-3, rank=args.rank,
                        update_freq=25, total_steps=args.steps, log_every=10**9),
            log_fn=lambda s: None,
        )
        curves[opt] = np.array([l for _, l in res.losses])
        print(f"{opt:10s} start={curves[opt][:5].mean():.4f} "
              f"end={curves[opt][-10:].mean():.4f}")

    print("\nloss every 25 steps:")
    hdr = "step " + " ".join(f"{o:>10s}" for o in curves)
    print(hdr)
    for s in range(0, args.steps, 25):
        row = f"{s:4d} " + " ".join(
            f"{curves[o][s:s+5].mean():10.4f}" for o in curves)
        print(row)


if __name__ == "__main__":
    main()
