"""Serving across architecture families with the continuous-batching engine
(paged KV for attention archs, slot-indexed state for recurrent archs) —
``--static`` runs the original padded-batch engine instead.

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --static
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import (ContinuousConfig, ContinuousEngine, ServeConfig,
                         StaticEngine)

ARCHS = ["qwen3-4b", "mixtral-8x22b", "zamba2-7b", "xlstm-1.3b"]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--static", action="store_true")
    args = ap.parse_args()

    root = jax.random.PRNGKey(0)
    for i, arch_id in enumerate(ARCHS):
        # fold the arch index in, then split: every arch gets its own params
        # AND its own prompts (reusing one key for both init_params and the
        # prompts — and across archs — would correlate weights with inputs)
        arch_key = jax.random.fold_in(root, i)
        param_key, prompt_key = jax.random.split(arch_key)
        cfg = get_smoke_config(arch_id)
        params = init_params(cfg, param_key)
        prompts = jax.random.randint(prompt_key, (4, 12), 1, cfg.vocab)

        t0 = time.perf_counter()
        if args.static:
            eng = StaticEngine(cfg, params,
                               ServeConfig(max_new_tokens=16, temperature=0.8))
            out = eng.generate(prompts)
            sample = out[0][:8].tolist()
            shape = tuple(out.shape)
        else:
            ceng = ContinuousEngine(cfg, params, ContinuousConfig(
                num_slots=3, block_size=4, n_blocks=64,
                max_prompt_len=12, max_new_cap=16))
            for p in np.asarray(prompts):
                ceng.submit(p, max_new_tokens=16, temperature=0.8)
            results = ceng.run()
            sample = results[0][:8].tolist()
            shape = (len(results), 16)
        dt = time.perf_counter() - t0
        mode = "static" if args.static else "continuous"
        print(f"{arch_id:22s} [{cfg.family:6s}] {mode} generated {shape} "
              f"in {dt:5.1f}s  sample={sample}")


if __name__ == "__main__":
    main()
