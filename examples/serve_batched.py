"""Batched serving across architecture families: parallel prefill (including
recurrent-state extraction for the SSM/hybrid archs) + KV/state-cache decode.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import Engine, ServeConfig

ARCHS = ["qwen3-4b", "mixtral-8x22b", "zamba2-7b", "xlstm-1.3b"]


def main():
    key = jax.random.PRNGKey(0)
    for arch_id in ARCHS:
        cfg = get_smoke_config(arch_id)
        params = init_params(cfg, key)
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=16, temperature=0.8))
        prompts = jax.random.randint(key, (4, 12), 0, cfg.vocab)
        t0 = time.perf_counter()
        out = eng.generate(prompts)
        dt = time.perf_counter() - t0
        print(f"{arch_id:22s} [{cfg.family:6s}] generated {out.shape} "
              f"in {dt:5.1f}s  sample={out[0][:8].tolist()}")


if __name__ == "__main__":
    main()
