"""Paper Table 3 analogue: pre-training the paper's LLaMA-60M (reduced) from
scratch — SUMO vs GaLore vs full-rank AdamW at the paper's r/d pairing.
Reports final perplexity on held-out synthetic data.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs.llama_paper import LLAMA_60M
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, make_batch
from repro.train import TrainConfig, train
from repro.train.steps import make_eval_step

STEPS = 120


def run(csv_rows: list) -> None:
    # reduced 60M-family config (CPU budget) — same r/d ratio as the paper
    arch = dataclasses.replace(
        LLAMA_60M, n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=344, vocab=2048, remat=False, dtype="float32",
    )
    rank = 32                                  # r/d = 0.25 ≈ paper's 128/512
    shape = ShapeConfig("pt", seq_len=128, global_batch=8, kind="train")
    eval_batches = [make_batch(10_000 + i, shape, arch, DataConfig(seed=99))
                    for i in range(4)]

    for opt in ("sumo", "galore", "adamw"):
        t0 = time.perf_counter()
        res = train(
            arch, shape,
            TrainConfig(optimizer=opt, learning_rate=3e-3, rank=rank,
                        update_freq=25, total_steps=STEPS, log_every=10**9),
            log_fn=lambda s: None,
        )
        eval_step = jax.jit(make_eval_step(arch))
        losses = [float(eval_step(res.params, b)) for b in eval_batches]
        ppl = float(np.exp(np.mean(losses)))
        csv_rows.append((
            f"table3_pretrain/{opt}",
            (time.perf_counter() - t0) / STEPS * 1e6,
            f"val_ppl={ppl:.2f} train_loss_end={res.losses[-1][1]:.4f}",
        ))
