"""Paper Table 1: optimizer-state memory + computation comparison.

Analytic per-method state bytes for the paper's LLaMA sizes AND measured
live-state bytes from the real optimizer pytrees (asserting analytic ==
measured for SUMO), plus the per-step FLOPs column.
"""
from __future__ import annotations

import time

import jax

from repro.configs.llama_paper import LLAMA_60M, LLAMA_130M, RANK_60M, RANK_130M
from repro.core import SumoConfig, model_memory_report, sumo_optimizer, tree_state_bytes
from repro.core.memory import analytic_flops_per_step
from repro.models import init_params


def run(csv_rows: list) -> None:
    t0 = time.perf_counter()
    for cfg, rank in [(LLAMA_60M, RANK_60M), (LLAMA_130M, RANK_130M)]:
        params = jax.eval_shape(lambda c=cfg: init_params(c, jax.random.PRNGKey(0)))
        rep = model_memory_report(params, rank=rank)
        base = rep["adamw"]
        for method, byts in sorted(rep.items()):
            csv_rows.append((
                f"table1_memory/{cfg.name}/{method}",
                (time.perf_counter() - t0) * 1e6,
                f"state_MB={byts / 1e6:.1f} vs_adam={byts / base:.3f}",
            ))
        # measured live SUMO state on the smoke-scale model (real arrays)
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("smollm-360m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tx = sumo_optimizer(1e-3, params, SumoConfig(rank=8))
    measured = tree_state_bytes(tx.init(params))
    csv_rows.append((
        "table1_memory/measured_smoke_sumo_state",
        (time.perf_counter() - t0) * 1e6,
        f"bytes={measured}",
    ))
    # amortized optimizer FLOPs per step, paper's m=4096 n=4096 example
    for method in ("sumo", "galore", "adam", "muon", "shampoo"):
        fl = analytic_flops_per_step(method, (4096, 4096), rank=128, K=200)
        csv_rows.append((
            f"table1_flops/{method}_4096x4096",
            (time.perf_counter() - t0) * 1e6,
            f"mflops_per_step={fl / 1e6:.1f}",
        ))
