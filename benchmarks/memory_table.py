"""Paper Table 1: optimizer-state memory + computation comparison.

Analytic per-method state bytes for the paper's LLaMA sizes, PLUS a live
cross-check on the smoke model: for ALL FIVE optimizers (sumo, muon, galore,
adamw, lora) the exact layout predictor ``core.memory.predict_state_bytes``
must equal the bytes of the real optimizer pytree, and the measured
SUMO-vs-AdamW / SUMO-vs-GaLore ratios must honor the paper's memory-reduction
claim. Any drift emits a ``memory_violations`` row (same codes as
``analysis/memory.py``) and raises ``MemoryBudgetError`` so the harness exits
non-zero — the table cannot silently rot. tests/test_benchmarks_memory.py
pins both directions.
"""
from __future__ import annotations

import time

import jax

from repro.analysis.memory import MemoryBudgetError
from repro.configs.llama_paper import LLAMA_60M, LLAMA_130M, RANK_60M, RANK_130M
from repro.core import model_memory_report
from repro.core.memory import analytic_flops_per_step

MEASURED_METHODS = ("sumo", "muon", "galore", "adamw", "lora")


def check_measured_state(rank: int = 8, arch_id: str = "smollm-360m"):
    """Measure all five optimizers' live state vs the exact predictor plus
    the paper's SUMO-vs-baseline ratio caps — one shared code path with the
    analysis driver (``analysis.memory.audit_table1_state``). Returns
    ({method: (measured, predicted)}, [MemoryViolation...])."""
    from repro.analysis.memory import audit_table1_state

    return audit_table1_state(rank=rank, arch_id=arch_id,
                              methods=MEASURED_METHODS)


def run(csv_rows: list) -> None:
    t0 = time.perf_counter()
    for cfg, rank in [(LLAMA_60M, RANK_60M), (LLAMA_130M, RANK_130M)]:
        params = jax.eval_shape(lambda c=cfg: init_params_shape(c))
        rep = model_memory_report(params, rank=rank)
        base = rep["adamw"]
        for method, byts in sorted(rep.items()):
            csv_rows.append((
                f"table1_memory/{cfg.name}/{method}",
                (time.perf_counter() - t0) * 1e6,
                f"state_MB={byts / 1e6:.1f} vs_adam={byts / base:.3f}",
            ))
    # measured live state for ALL FIVE optimizers vs the exact predictor
    results, violations = check_measured_state(rank=8)
    for method, (measured, predicted) in results.items():
        csv_rows.append((
            f"table1_memory/measured/{method}",
            (time.perf_counter() - t0) * 1e6,
            f"bytes={measured} predicted={predicted} "
            f"drift={measured - predicted}",
        ))
    for v in violations:
        csv_rows.append((
            "table1_memory/memory_violations",
            (time.perf_counter() - t0) * 1e6,
            f"code={v.code} measured={v.measured:.0f} limit={v.limit:.0f}",
        ))
    # amortized optimizer FLOPs per step, paper's m=4096 n=4096 example
    for method in ("sumo", "galore", "adam", "muon", "shampoo"):
        fl = analytic_flops_per_step(method, (4096, 4096), rank=128, K=200)
        csv_rows.append((
            f"table1_flops/{method}_4096x4096",
            (time.perf_counter() - t0) * 1e6,
            f"mflops_per_step={fl / 1e6:.1f}",
        ))
    if violations:
        raise MemoryBudgetError(
            "Table 1 state-memory drift:\n"
            + "\n".join(f"  {v}" for v in violations))


def init_params_shape(cfg):
    from repro.models import init_params
    return init_params(cfg, jax.random.PRNGKey(0))
