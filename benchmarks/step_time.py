"""Paper Table 6 analogue: wall-time per optimizer step + optimizer-only
overhead (SUMO-SVD vs SUMO-NS5 vs GaLore vs AdamW vs Muon) on the smoke model.

Also benchmarks the three Pallas kernels (interpret mode ⇒ relative numbers
only; the roofline table carries the TPU projections).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data import make_batch
from repro.models import init_params
from repro.train.steps import make_optimizer, make_train_step

REPS = 5


def _time_step(fn, *args):
    out = fn(*args)                       # compile
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = fn(*args)
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
    return (time.perf_counter() - t0) / REPS


def run(csv_rows: list) -> None:
    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("st", seq_len=64, global_batch=8, kind="train")
    batch = make_batch(0, shape, arch)
    params = init_params(arch, jax.random.PRNGKey(0))

    for opt in ("adamw", "sumo", "sumo-svd", "sumo-ns5", "galore", "muon"):
        tx = make_optimizer(opt, 1e-3, params, rank=8, update_freq=20)
        step = jax.jit(make_train_step(arch, tx))
        st = tx.init(params)
        us = _time_step(step, params, st, batch) * 1e6
        csv_rows.append((f"table6_step_time/{opt}", us, "train_step"))

    # peak-HBM audit of the donated sumo train step at this bench shape —
    # the same code path as the analysis driver's memory/train-step check
    # (repro.analysis.memory, ANALYSIS.md pass 5), so the CSV numbers and
    # the lint verdict cannot drift apart.
    from repro.analysis.memory import (MemoryBudgetError, audit_memory,
                                       measure_compiled_memory,
                                       steady_memory_budget)
    from repro.core.memory import (analytic_activation_bytes,
                                   predict_state_bytes, tree_param_bytes,
                                   tree_state_bytes)

    tx = make_optimizer("sumo", 1e-3, params, rank=8, update_freq=20)
    st = tx.init(params)
    compiled = jax.jit(make_train_step(arch, tx), donate_argnums=(0, 1)) \
        .lower(params, st, batch).compile()
    meas = measure_compiled_memory(compiled)
    budget = steady_memory_budget(
        params, st,
        batch_bytes=sum(x.nbytes for x in jax.tree_util.tree_leaves(batch)),
        activation_bytes=analytic_activation_bytes(
            arch, shape.global_batch, shape.seq_len),
        state_plan_bytes=predict_state_bytes("sumo", params, rank=8))
    mem_rep = audit_memory(meas, budget, param_bytes=tree_param_bytes(params),
                           state_bytes=tree_state_bytes(st))
    csv_rows.append(("train_step_memory/peak_bytes", meas.peak_bytes,
                     f"alias={meas.alias_bytes:.0f} temp={meas.temp_bytes:.0f}"
                     f" budget_ok={mem_rep.ok}"))
    for v in mem_rep.violations:
        csv_rows.append(("train_step_memory/memory_violations", v.measured,
                         f"code={v.code} limit={v.limit:.0f}"))
    if not mem_rep.ok:
        raise MemoryBudgetError(mem_rep.summary())

    # optimizer-only update cost (no fwd/bwd), bigger matrices
    key = jax.random.PRNGKey(1)
    p = {"w1": jax.random.normal(key, (1024, 512)),
         "w2": jax.random.normal(key, (2048, 256))}
    g = jax.tree_util.tree_map(lambda x: x * 0.01, p)
    for opt in ("adamw", "sumo", "sumo-ns5", "galore", "muon"):
        tx = make_optimizer(opt, 1e-3, p, rank=32, update_freq=10)
        st = tx.init(p)
        upd = jax.jit(lambda g, s, p: tx.update(g, s, p))
        us = _time_step(upd, g, st, p) * 1e6
        csv_rows.append((f"optimizer_update_only/{opt}", us, "1024x512+2048x256 r=32"))

    # SUMO engine × state-layout axis on a 24-layer transformer-shaped tree
    # (96 matrix leaves; canonical orientation merges w_up/w_down, so 2
    # buckets): 2 refresh conds / batched rSVDs / fused dispatches against 96
    # per-leaf ones, and bucket-RESIDENT state (Q/M/prev_norm stored as the
    # stacked bucket arrays) against the leaf layout's per-step
    # concatenate/scatter round-trip. Steady-state step time (post-refresh,
    # the 1-in-K common path) plus compile wall time — the bucketed engine's
    # other headline is compiling ~2 update programs instead of ~96.
    key = jax.random.PRNGKey(2)
    p24 = {}
    for i in range(24):
        kk = jax.random.fold_in(key, i)
        p24[f"block{i:02d}"] = {
            "wq": jax.random.normal(jax.random.fold_in(kk, 0), (32, 32)),
            "wo": jax.random.normal(jax.random.fold_in(kk, 1), (32, 32)),
            "w_up": jax.random.normal(jax.random.fold_in(kk, 2), (32, 64)),
            "w_down": jax.random.normal(jax.random.fold_in(kk, 3), (64, 32)),
        }
    g24 = jax.tree_util.tree_map(lambda x: x * 0.01, p24)
    engine_us = {}
    variants = (
        ("bucketed/bucket_state", True, "bucket"),
        ("bucketed/leaf_state", True, "leaf"),
        ("per_leaf/leaf_state", False, "leaf"),
    )
    for label, bucketed, layout in variants:
        tx = make_optimizer("sumo", 1e-3, p24, rank=4, update_freq=10,
                            bucketed=bucketed, state_layout=layout)
        st = tx.init(p24)
        upd = jax.jit(lambda g, s, p: tx.update(g, s, p))
        t0 = time.perf_counter()
        _, st = upd(g24, st, p24)        # compile + advance past the refresh
        jax.block_until_ready(jax.tree_util.tree_leaves(st)[0])
        csv_rows.append((f"sumo_update_engine/compile_s/{label}",
                         time.perf_counter() - t0, "24-layer x4 proj"))
        engine_us[label] = _time_step(upd, g24, st, p24) * 1e6
        csv_rows.append((f"sumo_update_engine/{label}", engine_us[label],
                         "24-layer x4 proj steady-state"))
    csv_rows.append(("sumo_update_engine/speedup_x",
                     engine_us["per_leaf/leaf_state"]
                     / max(engine_us["bucketed/bucket_state"], 1e-9),
                     "per_leaf / bucketed+bucket_state"))
    csv_rows.append(("sumo_update_engine/state_layout_speedup_x",
                     engine_us["bucketed/leaf_state"]
                     / max(engine_us["bucketed/bucket_state"], 1e-9),
                     "leaf_state / bucket_state (stack/scatter copy removed)"))

    # Spectral-telemetry probe overhead (repro.telemetry). The acceptance
    # gate is the TRAIN step — the probes' extra norms/r×r ops must stay
    # ≤ 5% of a step that also pays fwd/bwd. Steady state = post-refresh
    # (advance one step before timing). Best-of-trials timing: the ~ms-level
    # deltas under test drown in scheduler noise at REPS=5, so each variant
    # takes the minimum over several multi-rep trials. The optimizer-only
    # number on the 24-layer tree is reported too (un-amortized worst case,
    # informational).
    def _interleaved_best(cases, trials=8, reps=16):
        """{label: (fn, args)} -> {label: best s/rep}, alternating the cases
        within every trial so machine drift hits all of them equally."""
        best = {}
        for label, (fn, args) in cases.items():
            out = fn(*args)                   # compile
            jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
            best[label] = float("inf")
        for _ in range(trials):
            for label, (fn, args) in cases.items():
                t0 = time.perf_counter()
                for _ in range(reps):
                    out = fn(*args)
                jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
                best[label] = min(best[label],
                                  (time.perf_counter() - t0) / reps)
        return best

    cases = {}
    for label, tel in (("probes_off", False), ("probes_on", True)):
        tx = make_optimizer("sumo", 1e-3, params, rank=8, update_freq=20,
                            telemetry=tel)
        step = jax.jit(make_train_step(arch, tx))
        st = tx.init(params)
        p1, st1, _ = step(params, st, batch)   # past the step-0 refresh
        cases[label] = (step, (p1, st1, batch))
    tel_us = {k: v * 1e6 for k, v in _interleaved_best(cases).items()}
    for label in cases:
        csv_rows.append((f"telemetry/train_step/{label}", tel_us[label],
                         "smoke model steady-state"))
    csv_rows.append((
        "telemetry/train_step_overhead_pct",
        (tel_us["probes_on"] / max(tel_us["probes_off"], 1e-9) - 1.0) * 100,
        "probes_on vs probes_off (acceptance gate: <= 5%)",
    ))
    opt_cases = {}
    for label, tel in (("probes_off", False), ("probes_on", True)):
        tx = make_optimizer("sumo", 1e-3, p24, rank=4, update_freq=10,
                            telemetry=tel)
        st = tx.init(p24)
        upd = jax.jit(lambda g, s, p: tx.update(g, s, p))
        _, st = upd(g24, st, p24)              # past the step-0 refresh
        opt_cases[label] = (upd, (g24, st, p24))
    tel_opt_us = {k: v * 1e6 for k, v in _interleaved_best(opt_cases).items()}
    for label in opt_cases:
        csv_rows.append((f"telemetry/optimizer_only/{label}",
                         tel_opt_us[label], "24-layer x4 proj steady-state"))
    csv_rows.append((
        "telemetry/optimizer_only_overhead_pct",
        (tel_opt_us["probes_on"] / max(tel_opt_us["probes_off"], 1e-9) - 1.0)
        * 100,
        "un-amortized optimizer-only overhead (informational)",
    ))

    _run_2d_mesh_axis(csv_rows)
    _run_dp_compress(csv_rows)


def _run_2d_mesh_axis(csv_rows: list) -> None:
    """2D-mesh (data=2, model=4) refresh-cost axis: step time for the
    steady-state and every-step-refresh regimes of the model-sharded bucket
    update, plus an HLO collective-bytes audit (roofline/hlo_cost, which
    charges the worst-case cond branch — i.e. the refresh's r-width panels).

    The tree deliberately mixes a divisible bucket (8× (256, 64)) with a
    RAGGED-long bucket (4× (250, 64): 250 % 4 == 2, edge-padded to 252) so
    the audit exercises the padded path and reports its overhead — the pad
    rows ride the delta all-gathers, so the padded-vs-true row ratio is
    exactly the extra interconnect the raggedness costs.

    Needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8 on
    CPU); under the default single-device container it emits a skip row so
    the CSV schema is stable. Wall times on forced host devices are
    relative numbers only — the collective-bytes rows are the portable
    signal (they are what the interconnect pays at any scale).
    """
    if jax.device_count() < 8:
        csv_rows.append(("sumo_2d_mesh/SKIPPED", 0.0,
                         "needs >= 8 devices (XLA_FLAGS host count)"))
        return
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.analysis.collectives import (
        audit_hlo,
        bucket_collective_plan,
        delta_bytes as plan_delta_bytes,
        pad_overhead_frac,
        padded_delta_bytes as plan_padded_delta_bytes,
        refresh_2d_budget,
    )
    from repro.core import SumoConfig, sumo
    from repro.launch.mesh import make_host_mesh
    from repro.parallel import opt_state_specs
    from repro.roofline.hlo_cost import analyze_hlo

    mesh = make_host_mesh(model=4)
    key = jax.random.PRNGKey(3)
    # 8× (256, 64): one B=8 bucket, long 256 sharded 4-way, B 2-way; plus
    # 4× (250, 64): a B=4 ragged-long bucket (250 -> 252 edge-padded).
    p2d = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), (256, 64))
           for i in range(8)}
    for i in range(4):
        p2d[f"r{i}"] = jax.random.normal(
            jax.random.fold_in(key, 100 + i), (250, 64))
    g2d = jax.tree_util.tree_map(lambda x: x * 0.01, p2d)

    cfg0 = SumoConfig(rank=16, update_freq=1000)
    cost = plan = report = None
    for regime, freq in (("steady", 1000), ("refresh_every_step", 1)):
        tx = sumo(1e-3, SumoConfig(rank=16, update_freq=freq), mesh=mesh)
        st = tx.init(p2d)
        named = lambda tree: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P))
        st_sh = named(opt_state_specs(st, mesh))
        rep = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), g2d)
        upd = jax.jit(lambda g, s, p: tx.update(g, s, p),
                      in_shardings=(rep, st_sh, rep))
        if cost is None:
            # one audit serves both regimes: the refresh lives in a cond
            # branch of the SAME program, and the walker charges the
            # worst-case branch — so this is the refresh-step bound. The
            # plan/budget come from repro.analysis.collectives — the SAME
            # code path the sharded tests and tier-1 lint assert against,
            # so these CSV numbers cannot drift from the machine check.
            hlo = upd.lower(g2d, st, p2d).compile().as_text()
            cost = analyze_hlo(hlo)
            plan = bucket_collective_plan(st, mesh)
            report = audit_hlo(hlo, refresh_2d_budget(
                plan, rank_plus_over=cfg0.rank + cfg0.rsvd_oversample,
                data_shards=int(mesh.shape["data"])))
        _, st = upd(g2d, st, p2d)          # compile + move past step 0
        us = _time_step(upd, g2d, st, p2d) * 1e6
        csv_rows.append((f"sumo_2d_mesh/step_us/{regime}", us,
                         "8x(256,64)+4x(250,64 ragged) r=16 (data=2,model=4)"))
    d_bytes = plan_delta_bytes(plan)
    pd_bytes = plan_padded_delta_bytes(plan)
    brk = ";".join(f"{k}={int(v)}" for k, v in
                   sorted(cost.collective_breakdown.items()))
    csv_rows.append(("sumo_2d_mesh/collective_bytes", cost.collective_bytes,
                     f"worst-case(refresh) {brk} delta_bytes={d_bytes} "
                     f"padded_delta_bytes={pd_bytes}"))
    # edge-padding overhead: the ragged bucket's zero pad rows ride the
    # delta gathers (and the shard-local matmuls) — report padded vs true
    # rows so a config whose shapes are pathologically ragged on the chosen
    # model axis shows up as a concrete interconnect tax in the CSV.
    csv_rows.append((
        "sumo_2d_mesh/pad_overhead_frac",
        pad_overhead_frac(plan),
        "extra delta-gather bytes from edge-padded ragged long dims, / true",
    ))
    # the portable headline: cross-shard traffic beyond the delta gather is
    # r-width — report the ratio so regressions (an accidental full-matrix
    # psum or re-gather) jump out of the CSV. The expected delta gathers
    # move padded_delta_bytes (the B-axis gather of the full stack) plus
    # padded_delta_bytes / data_size (the model-axis gather of each data
    # shard's B-block) — the walker counts result-buffer sizes.
    expected_gather = pd_bytes * (1 + 1 / mesh.shape["data"])
    csv_rows.append((
        "sumo_2d_mesh/nondelta_collective_frac",
        max(0.0, cost.collective_bytes - expected_gather) / d_bytes,
        "refresh-regime collective bytes beyond the delta gathers, / delta",
    ))
    # the budget verdict itself: 0 violations == the panel-width discipline
    # the tier-1 lint enforces also holds in this benchmark's exact program
    csv_rows.append((
        "sumo_2d_mesh/budget_violations", float(len(report.violations)),
        f"refresh-2d budget '{report.budget}': "
        + ("OK" if report.ok else "; ".join(str(v) for v in
                                            report.violations[:3])),
    ))


def _run_dp_compress(csv_rows: list) -> None:
    """Compressed DP gradient exchange (ROADMAP item 1): wall time and
    HLO-measured wire bytes of the standalone exchange program
    (``parallel.compression.make_dp_exchange_fn`` — the same
    ``exchange_shard`` the train step inlines), against the uncompressed
    full-gradient pmean on the same tree. The compiled exchange is audited
    against ``repro.analysis.collectives.steady_dp_compressed_budget`` — a
    named, machine-checked cap (violation CODES, not regexes) pinning every
    DP all-reduce to r×short payload bytes — and the measured bytes ratio is
    reported next to the byte-accurate ``dp_wire_plan`` prediction so the
    two cannot silently drift apart.

    Needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8 on
    CPU); emits a skip row otherwise so the CSV schema is stable.
    """
    if jax.device_count() < 8:
        csv_rows.append(("dp_compress_exchange/SKIPPED", 0.0,
                         "needs >= 8 devices (XLA_FLAGS host count)"))
        return
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.analysis.collectives import (
        audit_hlo,
        steady_dp_compressed_budget,
    )
    from repro.launch.mesh import make_host_mesh
    from repro.parallel import (
        CompressionConfig,
        compression_ratio,
        dp_wire_plan,
        full_wire_bytes,
        hlo_wire_bytes,
        init_worker_state,
        make_dp_exchange_fn,
        wire_bytes,
    )

    mesh = make_host_mesh(model=1)        # (data=8, model=1): pure DP
    n_data = int(mesh.shape["data"])
    arch = get_smoke_config("smollm-360m")
    params = init_params(arch, jax.random.PRNGKey(0))
    cfg = CompressionConfig(rank=8, min_dim=32)
    state = init_worker_state(params, cfg, n_data)

    stack_sh = NamedSharding(mesh, P("data"))
    rep_sh = NamedSharding(mesh, P())
    grads = jax.tree_util.tree_map(
        lambda x: jax.device_put(
            jnp.broadcast_to(x[None] * 0.01, (n_data,) + x.shape), stack_sh),
        params)
    state = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, stack_sh if x.ndim > 0 else rep_sh),
        state)

    exchange = jax.jit(make_dp_exchange_fn(mesh, cfg))
    us = _time_step(exchange, grads, state, None) * 1e6
    csv_rows.append(("dp_compress_exchange/step_us/compressed", us,
                     f"smoke-model grads r={cfg.rank} data={n_data}"))

    # uncompressed baseline: the classic full-gradient pmean over `data`
    full_mean = jax.jit(shard_map(
        lambda g: jax.tree_util.tree_map(
            lambda x: jax.lax.pmean(x[0], "data")[None], g),
        mesh, in_specs=(P("data"),), out_specs=P("data"), check_rep=False,
        auto=frozenset({"model"})))
    us_full = _time_step(full_mean, grads) * 1e6
    csv_rows.append(("dp_compress_exchange/step_us/uncompressed", us_full,
                     "full-gradient pmean on the same tree"))

    from repro.roofline.hlo_cost import analyze_hlo
    plan = dp_wire_plan(params, cfg)
    hlo = exchange.lower(grads, state, None).compile().as_text()
    hlo_full = full_mean.lower(grads).compile().as_text()
    meas = analyze_hlo(hlo).collective_bytes
    meas_full = analyze_hlo(hlo_full).collective_bytes
    # measured HLO shows the bf16 payloads PROMOTED to f32 all-reduces
    # (XLA collective promotion), so compare against the plan's hlo bytes;
    # the true bf16 wire ratio is reported alongside
    ratio_hlo = hlo_wire_bytes(plan) / max(full_wire_bytes(plan), 1)
    ratio_wire = compression_ratio(params, cfg)
    csv_rows.append((
        "dp_compress_exchange/wire_reduction_x", 1.0 / max(ratio_wire, 1e-12),
        f"HLO-measured {int(meas)}B vs full {int(meas_full)}B "
        f"(promoted-plan predicts {1.0 / max(ratio_hlo, 1e-12):.1f}x); "
        f"true bf16 wire {wire_bytes(plan)}B vs {full_wire_bytes(plan)}B "
        f"= {1.0 / max(ratio_wire, 1e-12):.1f}x"))

    report = audit_hlo(hlo, steady_dp_compressed_budget(plan))
    csv_rows.append((
        "dp_compress_exchange/budget_violations", float(len(report.violations)),
        f"steady-dp budget '{report.budget}': "
        + ("OK" if report.ok else "; ".join(str(v) for v in
                                            report.violations[:3])),
    ))
