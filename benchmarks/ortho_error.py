"""Paper Lemma 3.2 / Fig. 1 / Remark 3.7: Newton-Schulz error vs condition
number, moment ill-conditioning during training, and rank collapse (Lemma 3.1).

The theoretical bounds come from ``repro.analysis.precision`` — the SAME
code path the `precision/ortho-bound` lint checks telemetry against — so
the Figure-1a output doubles as evidence for that check: every per-bucket
row carries measured residual vs. bound columns.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.precision import method_bound, ns_error_bound
from repro.core import (
    SumoConfig,
    condition_number,
    newton_schulz_cubic,
    orthogonalize_svd,
    sumo,
)
from repro.telemetry import rank_one_residual_from_sigma


def _conditioned_matrix(key, r, n, kappa):
    U, _ = jnp.linalg.qr(jax.random.normal(key, (r, r)))
    V, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1), (n, n)))
    s = jnp.linspace(1.0, 1.0 / np.sqrt(kappa), r)
    return (U * s[None, :]) @ V[:r]


def run(csv_rows: list) -> None:
    key = jax.random.PRNGKey(0)
    # --- Lemma 3.2: NS error grows with κ; bound √r(1−1/κ)^(2^i) ------------
    r, n = 16, 128
    for kappa in (10, 100, 1000, 10000):
        t0 = time.perf_counter()
        M = _conditioned_matrix(key, r, n, kappa)
        exact = orthogonalize_svd(M)
        err5 = float(jnp.linalg.norm(exact - newton_schulz_cubic(M, steps=5)))
        k_meas = float(condition_number(M))
        bound = ns_error_bound(k_meas, r, steps=5)
        csv_rows.append((
            f"lemma32_ns_error/kappa_{kappa}",
            (time.perf_counter() - t0) * 1e6,
            f"err_ns5={err5:.4f} bound={bound:.4f} holds={err5 <= bound + 1e-3}",
        ))
    # Remark 3.7 numeric example: (1-eps)=0.99, 5 iterations -> err ≈ 0.725
    csv_rows.append((
        "remark37_example", 0.0,
        f"(0.99)^32={0.99 ** 32:.4f} (paper: ≈0.725)",
    ))

    # --- Fig. 1(a): moment condition number grows during training -----------
    # run SUMO on a least-squares model and track κ(M) of the projected
    # moment via the SAME spectral probes the telemetry subsystem emits
    # (SumoConfig.telemetry) — no private re-implementation, no extra SVDs.
    k1, k2 = jax.random.split(key)
    m_dim, n_dim = 64, 48
    Wt = jax.random.normal(k1, (m_dim, n_dim)) / 8
    X = jax.random.normal(k2, (512, m_dim))
    Y = X @ Wt
    params = {"w": jnp.zeros((m_dim, n_dim))}
    cfg = SumoConfig(rank=16, update_freq=10, beta=0.95, telemetry=True)
    tx = sumo(0.02, cfg)
    state = tx.init(params)

    def loss_grad(p):
        return jax.grad(lambda q: jnp.mean((X @ q["w"] - Y) ** 2))(p)

    kappas, res1 = [], []
    from repro.core import apply_updates
    p = params
    for step in range(60):
        g = loss_grad(p)
        u, state = tx.update(g, state, p)
        p = apply_updates(p, u)
        probe = state.stats["64x48"]   # the (m, n) leaf's canonical bucket
        # probe.kappa is κ(MMᵀ) = (σ_max/σ_min_eff)² — the same convention
        # core.orthogonalize.condition_number used here pre-telemetry.
        kappas.append(float(probe.kappa))
        res1.append(rank_one_residual_from_sigma(np.asarray(probe.sigma)))
    t0 = time.perf_counter()
    csv_rows.append((
        "fig1a_moment_condition_number", (time.perf_counter() - t0) * 1e6,
        f"kappa_step5={kappas[5]:.1f} kappa_step55={kappas[55]:.1f} "
        f"grows={kappas[55] > kappas[5]}",
    ))
    # Per-bucket measured residual vs. the κ-dependent theoretical bound for
    # the configured method — the same ``method_bound`` code path the
    # `precision/ortho-bound` lint audits telemetry against, so this CSV is
    # that check's evidence on a real training trajectory.
    from repro.core import bucket_spectral_stats
    for bucket, probe in sorted(bucket_spectral_stats(state).items()):
        rb = len(probe.sigma)
        measured = float(probe.ortho_residual) * np.sqrt(rb)
        bound = method_bound(cfg.orth_method, float(probe.kappa), rb,
                             cfg.ns_steps)
        csv_rows.append((
            f"fig1a_residual_vs_bound/{bucket}", 0.0,
            f"method={cfg.orth_method} kappa={float(probe.kappa):.3g} "
            f"measured={measured:.3e} bound={bound:.3e} "
            f"holds={measured <= bound}",
        ))
    # --- Lemma 3.1: rank-one residual decays over steps ----------------------
    csv_rows.append((
        "lemma31_rank_collapse", 0.0,
        f"kappa_M_step5={res1[5]:.4f} step55={res1[55]:.4f} "
        f"decays={res1[55] < res1[5]}",
    ))
