"""Paper Table 2 / Figure 2 analogue: fine-tuning convergence of SUMO-SVD vs
SUMO-NS5 vs GaLore vs AdamW on the synthetic task (GLUE is not available
offline; the paper's CLAIM under test is the ORDERING: SUMO-SVD converges
faster than SUMO-NS5 and GaLore at equal rank/memory).

Reports loss after a fixed step budget and steps-to-threshold (the ~1.6×
speedup claim of Fig. 2 maps to the steps-to-threshold ratio).
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.train import TrainConfig, train

STEPS = 150
THRESH_FRACTION = 0.6   # reach 60% of adamw's total improvement


def run(csv_rows: list) -> None:
    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("conv", seq_len=64, global_batch=16, kind="train")
    curves = {}
    for opt in ("sumo-svd", "sumo-ns5", "galore", "adamw"):
        t0 = time.perf_counter()
        res = train(
            arch, shape,
            TrainConfig(optimizer=opt, learning_rate=3e-3, rank=8,
                        update_freq=25, total_steps=STEPS, log_every=10**9),
            log_fn=lambda s: None,
        )
        dt = time.perf_counter() - t0
        losses = np.array([l for _, l in res.losses])
        curves[opt] = losses
        csv_rows.append((
            f"table2_convergence/{opt}",
            dt / STEPS * 1e6,
            f"loss_start={losses[:5].mean():.4f} loss_end={losses[-10:].mean():.4f}",
        ))

    # steps-to-threshold (Fig. 2's speedup metric)
    base = curves["adamw"]
    target = base[:5].mean() - THRESH_FRACTION * (base[:5].mean() - base[-10:].mean())

    def steps_to(losses):
        sm = np.convolve(losses, np.ones(5) / 5, mode="valid")
        hit = np.argmax(sm <= target)
        return int(hit) if sm.min() <= target else STEPS

    s_svd = steps_to(curves["sumo-svd"])
    s_ns5 = steps_to(curves["sumo-ns5"])
    s_gal = steps_to(curves["galore"])
    speedup_vs_ns5 = s_ns5 / max(s_svd, 1)
    speedup_vs_galore = s_gal / max(s_svd, 1)
    csv_rows.append((
        "fig2_speedup/sumo_svd_vs_ns5",
        0.0,
        f"steps_svd={s_svd} steps_ns5={s_ns5} speedup={speedup_vs_ns5:.2f}x",
    ))
    csv_rows.append((
        "fig2_speedup/sumo_svd_vs_galore",
        0.0,
        f"steps_svd={s_svd} steps_galore={s_gal} speedup={speedup_vs_galore:.2f}x",
    ))

    _ill_conditioned_probe(csv_rows)
    _dp_compression_parity(csv_rows)


def _dp_compression_parity(csv_rows: list) -> None:
    """ROADMAP item 1's convergence gate: the compressed DP gradient exchange
    (compress → pmean of the r×short payload → decompress, EF residuals)
    must track the uncompressed run at a ≥8× wire reduction, for BOTH bases
    (seeded sketch and the SUMO-resident rSVD Q). Runs the REAL sharded path
    — model_parallel=1 puts the whole step on the (data=N, model=1) mesh
    with the exchange inside its shard_map — so this is the training loop
    users get with --dp-compress, not a simulation."""
    import jax

    from repro.models import init_params
    from repro.parallel import (
        CompressionConfig,
        compression_ratio,
    )

    if jax.device_count() < 2:
        csv_rows.append((
            "dp_compress/parity", 0.0,
            f"skipped: needs >=2 devices, have {jax.device_count()} "
            "(run under XLA_FLAGS=--xla_force_host_platform_device_count=8)"))
        return

    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("dpc", seq_len=64, global_batch=16, kind="train")
    steps = 60
    rank = 8
    # The smoke arch's d_model is 60: min_dim=32 makes every matrix leaf
    # (attention included) compress, which is what the ≥8× wire gate needs —
    # at the paper-scale min_dim=256 the smoke model would exchange its
    # attention blocks exact and cap the measured reduction near 3×.
    min_dim = 32

    def final_loss(losses):
        return float(np.array([l for _, l in losses])[-10:].mean())

    curves = {}
    for label, extra in (
        ("uncompressed", {}),
        ("sketch", dict(dp_compress=True, dp_compress_rank=rank,
                        dp_compress_min_dim=min_dim)),
        ("sumo-q", dict(dp_compress=True, dp_compress_rank=rank,
                        dp_compress_min_dim=min_dim,
                        dp_compress_basis="sumo-q")),
    ):
        t0 = time.perf_counter()
        res = train(
            arch, shape,
            TrainConfig(optimizer="sumo", learning_rate=3e-3, rank=rank,
                        update_freq=20, total_steps=steps, log_every=10**9,
                        model_parallel=1, **extra),
            log_fn=lambda s: None,
        )
        dt = time.perf_counter() - t0
        curves[label] = final_loss(res.losses)
        csv_rows.append((
            f"dp_compress/{label}", dt / steps * 1e6,
            f"loss_end={curves[label]:.4f}"))

    # Wire reduction from the byte-accurate plan (the HLO-measured pmean
    # bytes are cross-checked against this same plan in
    # tests/test_compression_sharded.py).
    params = init_params(arch, jax.random.PRNGKey(0))
    ratio = compression_ratio(
        params, CompressionConfig(rank=rank, min_dim=min_dim))
    reduction = 1.0 / max(ratio, 1e-12)
    gap_sketch = abs(curves["sketch"] - curves["uncompressed"])
    gap_sumoq = abs(curves["sumo-q"] - curves["uncompressed"])
    # Parity: final loss within 2% of the uncompressed run's value.
    tol = 0.02 * abs(curves["uncompressed"])
    csv_rows.append((
        "dp_compress/parity", 0.0,
        f"wire_reduction={reduction:.1f}x (gate >=8) "
        f"gap_sketch={gap_sketch:.4f} gap_sumo_q={gap_sumoq:.4f} "
        f"tol={tol:.4f} "
        f"pass={reduction >= 8.0 and gap_sketch <= tol and gap_sumoq <= tol}"))


def _ill_conditioned_probe(csv_rows: list) -> None:
    """The regime the paper's theory targets (Lemma 3.2 / Remark 3.7): an
    ill-conditioned objective whose gradients/moments have fast-decaying
    spectra. Here the SVD-vs-NS5 gap is mechanistic, not noise: NS5's
    contraction stalls at κ ≫ 1 while exact orthogonalization doesn't.

    min_W ||A (W - W*)||² with A's spectrum decaying steeply WITHIN the top-r
    subspace, so the projected moment is exactly the ill-conditioned case of
    Lemma 3.2: κ(MMᵀ)|_r up to 1e10 — NS5's contraction stalls on the small
    directions while exact orthogonalization still equalizes the update.
    The SVD-vs-NS5 final-loss ratio should GROW with κ (the paper's story);
    at mild κ the two tie (also the paper's story — Remark 3.7).
    """
    import jax
    import jax.numpy as jnp

    from repro.core import SumoConfig, apply_updates, sumo

    key = jax.random.PRNGKey(0)
    kappa_probe = {}
    m, n, r = 96, 64, 8
    kA, kW = jax.random.split(key, 2)
    UA, _ = jnp.linalg.qr(jax.random.normal(kA, (m, m)))
    Wstar = jax.random.normal(kW, (m, n)) / 8

    for kappa_exp in (3, 4, 5):
        sA = jnp.concatenate(
            [jnp.logspace(0, -kappa_exp / 2, r), jnp.zeros((m - r,))]
        )
        A = (UA * sA[None, :]) @ UA.T
        params = {"w": jnp.zeros((m, n))}

        def loss_fn(p):
            return 0.5 * jnp.mean((A @ (p["w"] - Wstar)) ** 2) * m

        out = {}
        for method in ("svd", "ns5"):
            # telemetry probes verify we really are in the κ regime under
            # test — same SpectralStats the online subsystem emits, not a
            # private spectrum computation.
            tx = sumo(0.1, SumoConfig(rank=r, update_freq=10,
                                      orth_method=method,
                                      rms_scale=False, gamma=1e9,
                                      telemetry=True))
            state = tx.init(params)
            p = params

            @jax.jit
            def step(p, s):
                l, g = jax.value_and_grad(loss_fn)(p)
                u, s = tx.update(g, s, p)
                return apply_updates(p, u), s, l

            for _ in range(500):
                p, state, l = step(p, state)
            out[method] = float(l)
            kappa_probe[method] = float(state.stats["96x64"].kappa)
        csv_rows.append((
            f"fig2_speedup/illconditioned_kappaA_1e{kappa_exp}",
            0.0,
            f"final_svd={out['svd']:.3e} final_ns5={out['ns5']:.3e} "
            f"svd_advantage={out['ns5'] / out['svd']:.2f}x "
            f"probe_kappa_MMt_svd={kappa_probe['svd']:.2e}",
        ))
