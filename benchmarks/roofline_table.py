"""§Roofline: render the per-(arch × shape) roofline rows from the dry-run
artifacts — the paper-faithful BASELINE sweep (dryrun.json) and the §Perf
OPTIMIZED sweep (dryrun_optimized.json) side by side. Reads artifacts; does
not recompile (run ``python -m repro.launch.dryrun --all --mesh both --out
<file>`` to regenerate)."""
from __future__ import annotations

import json
import os

_ROOT = os.path.join(os.path.dirname(__file__), "..")
BASELINE_JSON = os.path.join(_ROOT, "dryrun.json")
OPTIMIZED_JSON = os.path.join(_ROOT, "dryrun_optimized.json")


def _load(path):
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        rows = json.load(f)
    return {(r["arch"], r["shape"]): r for r in rows if r.get("mesh") == "16x16"}


def run(csv_rows: list) -> None:
    base = _load(BASELINE_JSON)
    opt = _load(OPTIMIZED_JSON)
    if not opt and not base:
        csv_rows.append(("roofline/missing", 0.0,
                         "run: python -m repro.launch.dryrun --all --mesh both "
                         "--out dryrun_optimized.json"))
        return
    keys = sorted(opt or base)
    for k in keys:
        r = (opt or base)[k]
        name = f"roofline/{k[0]}/{k[1]}"
        if r["status"] == "skipped":
            csv_rows.append((name, 0.0, r["reason"]))
            continue
        if r["status"] != "ok":
            csv_rows.append((name, 0.0, f"FAIL {r.get('error', '')[:80]}"))
            continue
        dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        b = base.get(k)
        dom_b = (max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
                 if b and b.get("status") == "ok" else None)
        extra = f" baseline_dom={dom_b:.4f} gain={dom_b / dom:.2f}x" if dom_b else ""
        csv_rows.append((
            name,
            dom * 1e6,
            f"t_comp={r['t_compute_s']:.4f} t_mem={r['t_memory_s']:.4f} "
            f"t_coll={r['t_collective_s']:.4f} bottleneck={r['bottleneck']} "
            f"roofline_frac={r['roofline_fraction']:.4f}{extra}",
        ))
