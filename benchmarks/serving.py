"""Open-loop serving benchmark: continuous batching vs static batches.

Requests arrive by a Poisson process (open loop: arrivals don't wait for the
server) with mixed prompt and output lengths, and the SAME arrival trace is
served twice — by ``ContinuousEngine`` (paged KV / slot state, per-step
join/evict) and by ``StaticEngine`` (take up to a batch of arrived requests,
pad to a fixed shape, ride until the slowest member finishes). Reported per
engine: generated-token throughput over the makespan, TTFT / end-to-end /
inter-token latency percentiles. The arrival rate is calibrated from the
continuous engine's measured steady decode-step time so the run is loaded
but stable on whatever machine executes it.

Emits ``BENCH_serving.json`` (schema ``serving-bench-v1``, see SERVING.md).
The continuous runs execute under ``analysis.recompile.CompileWatcher``:
the audit result is part of the JSON, and ``--smoke`` exits non-zero unless
the document validates AND the decode step compiled exactly once per arch.

    PYTHONPATH=src python benchmarks/serving.py --out BENCH_serving.json
    PYTHONPATH=src python benchmarks/serving.py --smoke --out /tmp/b.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.analysis.memory import (
    MEMORY_VIOLATION_CODES,
    audit_memory,
    measure_compiled_memory,
    serve_decode_memory_budget,
)
from repro.analysis.recompile import CompileWatcher, audit_recompiles
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import (
    SERVE_DECODE_FN,
    ContinuousConfig,
    ContinuousEngine,
    ServeConfig,
    StaticEngine,
    bucket_len,
    serving_kind,
)

SCHEMA = "serving-bench-v1"
DEFAULT_ARCHS = ("smollm-360m", "xlstm-1.3b", "zamba2-7b")
ENGINE_METRIC_KEYS = (
    "n_requests", "total_tokens", "makespan_s", "tok_per_s",
    "ttft_p50_s", "ttft_p95_s", "e2e_p50_s", "e2e_p95_s",
    "tpt_p50_s", "tpt_p95_s",
)


@dataclasses.dataclass(frozen=True)
class Trace:
    """One open-loop arrival trace (shared by both engines)."""
    arrivals: np.ndarray        # (n,) seconds from trace start, sorted
    prompts: List[np.ndarray]   # per-request token ids
    max_new: List[int]
    rate: float                 # offered requests/s


def make_trace(n: int, rate: float, vocab: int, prompt_lens, new_tokens,
               seed: int) -> Trace:
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    lo_p, hi_p = prompt_lens
    lo_n, hi_n = new_tokens
    prompts = [rng.integers(1, vocab, size=int(rng.integers(lo_p, hi_p + 1)))
               .astype(np.int32) for _ in range(n)]
    max_new = [int(rng.integers(lo_n, hi_n + 1)) for _ in range(n)]
    return Trace(arrivals=arrivals, prompts=prompts, max_new=max_new,
                 rate=rate)


def _percentiles(xs: List[float]):
    a = np.asarray(xs, np.float64)
    return float(np.percentile(a, 50)), float(np.percentile(a, 95))


def _metrics(reqs: List[dict], makespan: float) -> Dict[str, float]:
    """reqs: per-request {arrival, ttft, finish, token_times} (absolute s)."""
    total = sum(len(r["token_times"]) for r in reqs)
    ttft_p50, ttft_p95 = _percentiles([r["ttft"] - r["arrival"] for r in reqs])
    e2e_p50, e2e_p95 = _percentiles([r["finish"] - r["arrival"] for r in reqs])
    deltas: List[float] = []
    for r in reqs:
        deltas.extend(np.diff(r["token_times"]).tolist())
    tpt_p50, tpt_p95 = _percentiles(deltas) if deltas else (0.0, 0.0)
    return {
        "n_requests": len(reqs), "total_tokens": total,
        "makespan_s": makespan,
        "tok_per_s": total / makespan if makespan > 0 else 0.0,
        "ttft_p50_s": ttft_p50, "ttft_p95_s": ttft_p95,
        "e2e_p50_s": e2e_p50, "e2e_p95_s": e2e_p95,
        "tpt_p50_s": tpt_p50, "tpt_p95_s": tpt_p95,
    }


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def _warmup_continuous(eng: ContinuousEngine) -> float:
    """Compile every admissible prefill bucket and the decode step; returns
    the measured steady decode-step seconds (slots saturated)."""
    bs = eng.ccfg.block_size
    buckets = list(range(bs, bucket_len(eng.ccfg.max_prompt_len, bs) + 1, bs))
    for b in buckets:
        eng.submit(np.ones(b, np.int32), max_new_tokens=1)
    eng.run()
    # saturate the slots and time steady decode
    for _ in range(eng.ccfg.num_slots):
        eng.submit(np.ones(buckets[0], np.int32),
                   max_new_tokens=eng.ccfg.max_new_cap)
    eng.step()
    t0 = time.perf_counter()
    n = 0
    while eng.busy and n < 16:
        eng.step()
        n += 1
    step_t = (time.perf_counter() - t0) / max(n, 1)
    while eng.busy:
        eng.step()
    eng.results.clear()
    eng.requests.clear()
    return step_t


def run_continuous(eng: ContinuousEngine, trace: Trace) -> Dict[str, float]:
    n = len(trace.prompts)
    i = 0
    t_start = time.perf_counter()
    while i < n or eng.busy:
        now = time.perf_counter() - t_start
        while i < n and trace.arrivals[i] <= now:
            eng.submit(trace.prompts[i], max_new_tokens=trace.max_new[i],
                       arrival=t_start + float(trace.arrivals[i]))
            i += 1
        if not eng.step() and i < n:
            wait = trace.arrivals[i] - (time.perf_counter() - t_start)
            if wait > 0:
                time.sleep(wait)
    makespan = time.perf_counter() - t_start
    reqs = [{"arrival": r.arrival, "ttft": r.first_token_time,
             "finish": r.finish_time, "token_times": r.token_times}
            for r in eng.requests.values()]
    assert all(r["ttft"] is not None and r["finish"] is not None for r in reqs)
    return _metrics(reqs, makespan)


def run_static(cfg, params, trace: Trace, batch: int, pad_len: int,
               max_new_cap: int) -> Dict[str, float]:
    """Static baseline on the same trace: whenever the engine is free, take
    up to ``batch`` ARRIVED requests FIFO, left-pad prompts to ``pad_len``,
    fill empty rows with dummies, decode until the slowest member is done."""
    eng = StaticEngine(cfg, params, ServeConfig(max_new_tokens=max_new_cap))
    # warmup batch compiling BOTH prefill and decode so compilation doesn't
    # pollute the measured trace (stop after two tokens = one decode step)
    eng.generate(np.zeros((batch, pad_len), np.int32),
                 stop_counts=[2] * batch)

    n = len(trace.prompts)
    i = 0
    done: List[dict] = []
    t_start = time.perf_counter()
    while i < n:
        now = time.perf_counter() - t_start
        if trace.arrivals[i] > now:
            time.sleep(trace.arrivals[i] - now)
            continue
        now = time.perf_counter() - t_start
        members = []
        while i < n and trace.arrivals[i] <= now and len(members) < batch:
            members.append(i)
            i += 1
        prompts = np.zeros((batch, pad_len), np.int32)
        stop = [1] * batch
        recs = []
        for row, j in enumerate(members):
            p = trace.prompts[j]
            prompts[row, pad_len - len(p):] = p
            stop[row] = trace.max_new[j]
            recs.append({"arrival": t_start + float(trace.arrivals[j]),
                         "budget": trace.max_new[j], "token_times": []})

        def on_token(step, tok, recs=recs):
            t = time.perf_counter()
            for r in recs:
                if step < r["budget"]:
                    r["token_times"].append(t)

        eng.generate(prompts, on_token=on_token, stop_counts=stop)
        for r in recs:
            r["ttft"] = r["token_times"][0]
            r["finish"] = r["token_times"][-1]
            done.append(r)
    makespan = time.perf_counter() - t_start
    return _metrics(done, makespan)


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------

def validate_bench(doc: dict) -> None:
    """Raise ValueError unless ``doc`` is a valid serving-bench-v1 report."""
    if doc.get("schema") != SCHEMA:
        raise ValueError(f"schema must be {SCHEMA!r}, got {doc.get('schema')!r}")
    for key in ("smoke", "archs"):
        if key not in doc:
            raise ValueError(f"missing top-level key {key!r}")
    if not doc["archs"]:
        raise ValueError("no archs in report")
    for arch, ent in doc["archs"].items():
        for key in ("family", "kind", "trace", "engines", "recompile_audit",
                    "continuous_wins"):
            if key not in ent:
                raise ValueError(f"{arch}: missing key {key!r}")
        if ent["kind"] not in ("paged", "slot"):
            raise ValueError(f"{arch}: bad kind {ent['kind']!r}")
        for eng in ("continuous", "static"):
            m = ent["engines"].get(eng)
            if m is None:
                raise ValueError(f"{arch}: missing engine {eng!r}")
            for mk in ENGINE_METRIC_KEYS:
                v = m.get(mk)
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    raise ValueError(f"{arch}/{eng}: metric {mk!r} bad: {v!r}")
        audit = ent["recompile_audit"]
        if not isinstance(audit.get("ok"), bool) or \
                not isinstance(audit.get("decode_compiles"), int):
            raise ValueError(f"{arch}: bad recompile_audit {audit!r}")
        mem = ent.get("memory_audit")
        if mem is not None:          # optional extra (emitted since PR 9)
            if not isinstance(mem.get("ok"), bool):
                raise ValueError(f"{arch}: bad memory_audit {mem!r}")
            for v in mem.get("memory_violations", ()):
                if v.get("code") not in MEMORY_VIOLATION_CODES:
                    raise ValueError(f"{arch}: unknown memory violation "
                                     f"code {v.get('code')!r}")


def memory_audit_entry(cfg, ccfg, params, kind: str) -> dict:
    """Peak-HBM audit of the compiled paged decode at the BENCH pool
    geometry — the same code path as the analysis driver's
    ``serve/decode-budget`` check (``repro.analysis.memory``), so the JSON
    report and the lint cannot drift apart."""
    if kind != "paged":
        return {"ok": True, "skipped": f"{kind} path has no KV BlockPool"}
    from repro.serve.engine import (PAGED_DECODE_DONATE, paged_serve_decode_fn,
                                    serve_decode_audit_args)
    fn = paged_serve_decode_fn(cfg)
    args = serve_decode_audit_args(cfg, ccfg, params)
    compiled = jax.jit(fn, donate_argnums=PAGED_DECODE_DONATE) \
        .lower(*args).compile()
    m = measure_compiled_memory(compiled)
    rep = audit_memory(m, serve_decode_memory_budget(cfg, ccfg, params))
    return {
        "ok": bool(rep.ok),
        "peak_bytes": int(m.peak_bytes),
        "alias_bytes": int(m.alias_bytes),
        "temp_bytes": int(m.temp_bytes),
        "memory_violations": [
            {"code": v.code, "measured": float(v.measured),
             "limit": float(v.limit)} for v in rep.violations],
    }


def bench_arch(arch: str, smoke: bool, seed: int) -> dict:
    cfg = get_smoke_config(arch)
    kind = serving_kind(cfg)
    params = init_params(cfg, jax.random.PRNGKey(seed))

    num_slots = 4
    block = 4
    prompt_lens = (4, 20)
    new_tokens = (4, 24) if not smoke else (2, 6)
    n_req = 8 if smoke else 48
    max_blocks_per_req = -(-(bucket_len(prompt_lens[1], block)
                             + new_tokens[1]) // block)
    ccfg = ContinuousConfig(
        num_slots=num_slots, block_size=block,
        n_blocks=1 + num_slots * max_blocks_per_req,
        max_prompt_len=prompt_lens[1], max_new_cap=new_tokens[1],
        seed=seed)
    if kind == "paged" and cfg.sliding_window is not None:
        ccfg.max_prompt_len = min(ccfg.max_prompt_len, cfg.sliding_window)

    with CompileWatcher(fn_name=SERVE_DECODE_FN) as watcher:
        eng = ContinuousEngine(cfg, params, ccfg)
        step_t = _warmup_continuous(eng)
        # offered load: ~80% of the continuous engine's token capacity
        mean_new = (new_tokens[0] + new_tokens[1]) / 2 + 1
        rate = 0.8 * num_slots / (mean_new * max(step_t, 1e-4))
        trace = make_trace(n_req, rate, cfg.vocab, prompt_lens, new_tokens,
                           seed + 1)
        cont = run_continuous(eng, trace)
    audit = audit_recompiles(watcher.events, fn_name=SERVE_DECODE_FN,
                             warmup_through=0)
    # outside the watcher: the audit re-compiles serve_decode on purpose
    mem_audit = memory_audit_entry(cfg, ccfg, params, kind)

    pad_len = bucket_len(max(len(p) for p in trace.prompts), block)
    static = run_static(cfg, params, trace, batch=num_slots, pad_len=pad_len,
                        max_new_cap=new_tokens[1])

    wins = (cont["tok_per_s"] > static["tok_per_s"]
            and cont["e2e_p95_s"] <= static["e2e_p95_s"])
    return {
        "family": cfg.family, "kind": kind,
        "trace": {"n_requests": n_req, "rate_req_s": rate,
                  "prompt_lens": list(prompt_lens),
                  "new_tokens": list(new_tokens), "seed": seed,
                  "steady_decode_step_s": step_t},
        "engines": {"continuous": cont, "static": static},
        "recompile_audit": {"ok": bool(audit.ok),
                            "decode_compiles": len(audit.compiles)},
        "memory_audit": mem_audit,
        "continuous_wins": wins,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--archs", nargs="+", default=list(DEFAULT_ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny traces; exit non-zero unless the JSON "
                         "validates and decode compiled exactly once")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    archs = args.archs if not args.smoke else ["smollm-360m", "xlstm-1.3b"]
    doc = {"schema": SCHEMA, "smoke": bool(args.smoke), "archs": {}}
    for arch in archs:
        print(f"== {arch}")
        ent = bench_arch(arch, smoke=args.smoke, seed=args.seed)
        doc["archs"][arch] = ent
        c, s = ent["engines"]["continuous"], ent["engines"]["static"]
        print(f"   continuous: {c['tok_per_s']:8.1f} tok/s  "
              f"ttft p95 {c['ttft_p95_s'] * 1e3:7.1f} ms  "
              f"e2e p95 {c['e2e_p95_s'] * 1e3:7.1f} ms")
        print(f"   static:     {s['tok_per_s']:8.1f} tok/s  "
              f"ttft p95 {s['ttft_p95_s'] * 1e3:7.1f} ms  "
              f"e2e p95 {s['e2e_p95_s'] * 1e3:7.1f} ms")
        print(f"   continuous_wins={ent['continuous_wins']}  "
              f"decode_compiles={ent['recompile_audit']['decode_compiles']} "
              f"audit_ok={ent['recompile_audit']['ok']}  "
              f"memory_ok={ent['memory_audit']['ok']}")

    validate_bench(doc)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")

    if args.smoke:
        bad = [a for a, e in doc["archs"].items()
               if not e["recompile_audit"]["ok"]
               or e["recompile_audit"]["decode_compiles"] != 1]
        if bad:
            print(f"SMOKE FAIL: off-boundary/extra decode compiles: {bad}")
            return 1
        bad_mem = [a for a, e in doc["archs"].items()
                   if not e["memory_audit"]["ok"]]
        if bad_mem:
            print(f"SMOKE FAIL: serve_decode memory budget violated: "
                  f"{bad_mem}")
            return 1
        print("SMOKE OK: schema valid, one decode compile per arch, "
              "decode memory within the BlockPool budget")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
