"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table2]

Prints ``name,us_per_call,derived`` CSV rows:
    table1_memory/*      paper Table 1  (optimizer state memory + flops)
    table2_convergence/* paper Table 2 / Fig 2 (SVD vs NS5 vs GaLore vs Adam)
    fig2_speedup/*       Fig 2's ~1.6× steps-to-threshold claim
    table3_pretrain/*    paper Table 3  (pre-training perplexity)
    lemma32_ns_error/*   Lemma 3.2 / Fig 1 (NS error vs condition number)
    fig1a_*/lemma31_*    Fig 1(a) / Lemma 3.1 (moment conditioning, rank)
    table6_step_time/*   Table 6       (wall time per step)
    roofline/*           §Roofline     (from the dry-run artifact)
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import convergence, memory_table, ortho_error, pretrain_small, roofline_table, step_time

MODULES = {
    "table1": memory_table,
    "table2": convergence,
    "table3": pretrain_small,
    "lemma32": ortho_error,
    "table6": step_time,
    "roofline": roofline_table,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=list(MODULES), default=None)
    args = ap.parse_args(argv)

    rows: list = []
    failed = 0
    for name, mod in MODULES.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run(rows)
        except Exception:
            traceback.print_exc()
            rows.append((f"{name}/ERROR", 0.0, "see stderr"))
            failed += 1
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
