"""Fast unit test for the Table-1 memory benchmark (benchmarks/memory_table.py).

Both directions of satellite 3: the live measured state of ALL FIVE
optimizers equals the exact layout predictor (drift would make the bench
exit non-zero), and the shared ``audit_table1_state`` code path is genuinely
falsifiable — an impossible ratio cap produces a ``state-bytes-mismatch``
violation, which ``run()`` turns into ``MemoryBudgetError``.
"""
import importlib.util
import pathlib
import sys

import pytest

from repro.analysis.memory import MemoryBudgetError, audit_table1_state


def _load_memory_table():
    spec = importlib.util.spec_from_file_location(
        "memory_table_bench",
        pathlib.Path(__file__).resolve().parents[1]
        / "benchmarks/memory_table.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules["memory_table_bench"] = mod
    spec.loader.exec_module(mod)
    return mod


def test_measured_state_matches_predictor_all_five():
    mod = _load_memory_table()
    results, violations = mod.check_measured_state(rank=8)
    assert set(results) == set(mod.MEASURED_METHODS) \
        == {"sumo", "muon", "galore", "adamw", "lora"}
    assert not violations, [str(v) for v in violations]
    for method, (measured, predicted) in results.items():
        assert measured == predicted, \
            f"{method}: measured {measured} != predicted {predicted}"
    # the paper's claims hold on the LIVE trees, with margin
    assert results["sumo"][0] <= 0.80 * results["adamw"][0]
    assert results["sumo"][0] <= 1.00 * results["galore"][0]


def test_table1_audit_is_falsifiable():
    """An impossible ratio cap must FAIL with the named code — proves the
    check can actually reject, so a silent-green regression is impossible."""
    _, violations = audit_table1_state(
        rank=8, ratios=(("adamw", 0.01),), methods=("sumo", "adamw"))
    assert violations
    assert {v.code for v in violations} == {"state-bytes-mismatch"}


def test_run_raises_on_violations(monkeypatch):
    """``run()`` must surface violations as MemoryBudgetError (exit-nonzero
    through benchmarks/run.py), never as a silent CSV row."""
    mod = _load_memory_table()
    fake = ({"sumo": (100, 100), "adamw": (100, 100)},
            audit_table1_state(rank=8, ratios=(("adamw", 0.01),),
                               methods=("sumo", "adamw"))[1])
    monkeypatch.setattr(mod, "check_measured_state", lambda rank=8: fake)
    rows = []
    with pytest.raises(MemoryBudgetError):
        mod.run(rows)
    assert any(name == "table1_memory/memory_violations"
               for name, _, _ in rows)
