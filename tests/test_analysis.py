"""Unit tests for the repro.analysis static passes (single-device).

Each pass has at least one NEGATIVE test — the lint must reject the bad
program with its stable violation code, not just accept the good one:

  * collectives: forbidden kind, disallowed shape, blown panel width, and
    a steady-path op that must live in a cond branch — over handcrafted
    HLO so the failure is unambiguous;
  * hlo_cost walker: async ``-start``/``-done`` pairs charged ONCE (on the
    destination buffer of the -start tuple), ``collective-broadcast``
    recognized, and collectives inside a cond-inside-cond charged at the
    worst case with the right branch_depth;
  * inertness: a pad followed by ``+ 1.0`` (a non-inert pad write) fails
    the trailing-zeros claim that the ``* 3.0`` version proves;
  * donation: a jit call site that keeps using a donated reference is
    flagged ``donated-arg-not-rebound``; dropped donations are flagged by
    the HLO cross-check;
  * recompile: an off-boundary compile event fails the audit, while
    warmup/boundary-adjacent ones pass;
  * memory (pass 5): every violation code is falsifiable — the donated
    smoke train step passes its steady budget while the UN-donated compile
    fails ``donation-not-realized``; the compiled paged ``serve_decode``
    passes at its own pool geometry but an oversized pool audited against
    the plan budget fails ``peak-bytes-exceeded`` + ``transient-exceeds-plan``;
    the Table-1 ratio lint fails when measured state exceeds the plan;
  * host-dtype lint: an implicit-dtype ``np.zeros(...)`` host buffer is
    flagged ``host-buffer-no-dtype``; the serve/train hot paths are clean;
  * null-block inertness: free serving slots' decode writes provably target
    physical block 0, and dropping the zero-table hypothesis breaks the
    proof;
  * precision (pass 6): every violation code is falsifiable — a bf16
    Gram dot / reduce-add fails ``low-precision-accumulation`` (HLO walk
    AND jaxpr dtype flow) while the f32 twin passes; a wire plan claiming
    bf16 stays bf16 against an f32-promoted all-reduce fails
    ``bf16-wire-promoted``; an eps-less normalize fails
    ``unguarded-division`` and the PR 5 bug class (bare 1e-12 shift)
    fails ``under-scaled-shift`` while the repo's own CholeskyQR2 and
    orthogonalizers pass; NS5 residuals on an ill-conditioned moment fail
    the SVD-tier ``ortho-error-bound-exceeded`` budget that exact SVD
    passes, and ``bound_scale`` provably loosens/tightens the verdict;
  * analysis_diff: newly-FAILed, silently-disappeared and
    missing-required (driver ``--list`` contract) all fail the report
    diff; PASS->SKIP and brand-new checks are warnings only.

The sharded end-to-end proofs (2D budgets on compiled HLO, full-update
inertness, the concatenate-seam regression) live in
tests/test_analysis_sharded.py under 8 forced host devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.collectives import (
    CollectiveBudget,
    OpBudget,
    BudgetError,
    assert_budget,
    audit_hlo,
)
from repro.analysis.donation import (
    audit_donation,
    audit_host_dtypes,
    lint_donation_source,
    lint_host_dtype_source,
)
from repro.analysis.inertness import (
    Claim,
    InertnessError,
    analyze_jaxpr,
    check_claims,
    prove_null_block_inertness,
    prove_refresh_inertness,
)
from repro.analysis.memory import (
    MEMORY_VIOLATION_CODES,
    MemoryBudget,
    MemoryMeasurement,
    audit_memory,
    audit_state_ratio,
    bucket_memory_plan,
    hlo_buffer_table,
    measure_compiled_memory,
    serve_decode_memory_budget,
    steady_memory_budget,
)
from repro.analysis.precision import (
    PRECISION_VIOLATION_CODES,
    PrecisionBudget,
    PrecisionError,
    assert_precision,
    audit_accumulation_hlo,
    audit_jaxpr_guards,
    audit_ortho_bound,
    audit_wire_dtype,
    merge_reports,
    method_bound,
    ns_error_bound,
    svd_tier_bound,
)
from repro.analysis.recompile import (
    CompileEvent,
    CompileWatcher,
    audit_recompiles,
    mark_step,
)
from repro.roofline.hlo_cost import (
    analyze_hlo,
    iter_collectives,
    iter_reductions,
)


# -- handcrafted HLO fixtures ------------------------------------------------
# Minimal but syntactically faithful optimized-HLO text: computation headers
# flush-left ending in "{", ops indented, attrs after the operand list.

_ADD = """\
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}
"""

HLO_SYNC = _ADD + """
ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16] parameter(0)
  ROOT %ar = f32[8,16] all-reduce(%p0), replica_groups={}, to_apply=%add
}
"""

HLO_ASYNC = _ADD + """
ENTRY %main (p0: f32[16]) -> f32[16] {
  %p0 = f32[16] parameter(0)
  %ars = (f32[16], f32[16]) all-reduce-start(%p0), to_apply=%add
  %ard = f32[16] all-reduce-done(%ars)
  %ags = (f32[4,16], f32[8,16]) all-gather-start(%p0), dimensions={0}
  %agd = f32[8,16] all-gather-done(%ags)
  ROOT %out = f32[16] add(%ard, %p0)
}
"""

HLO_BROADCAST = """\
ENTRY %main (p0: f32[32]) -> f32[32] {
  %p0 = f32[32] parameter(0)
  ROOT %cb = f32[32] collective-broadcast(%p0), replica_groups={{0,1}}
}
"""

# collective in the TRUE branch of a cond nested inside another cond; the
# outer FALSE branch holds a smaller gather so worst-case must keep both.
HLO_NESTED_COND = _ADD + """
%inner_true (t0: f32[8,16]) -> f32[8,16] {
  %t0 = f32[8,16] parameter(0)
  ROOT %ar.i = f32[8,16] all-reduce(%t0), to_apply=%add
}

%inner_false (f0: f32[8,16]) -> f32[8,16] {
  ROOT %f0 = f32[8,16] parameter(0)
}

%outer_true (ot: (pred[], f32[8,16])) -> f32[8,16] {
  %ot = (pred[], f32[8,16]) parameter(0)
  %pi = pred[] get-tuple-element(%ot), index=0
  %xi = f32[8,16] get-tuple-element(%ot), index=1
  ROOT %ci = f32[8,16] conditional(%pi, %xi, %xi), true_computation=%inner_true, false_computation=%inner_false
}

%outer_false (of: (pred[], f32[8,16])) -> f32[8,16] {
  %of = (pred[], f32[8,16]) parameter(0)
  %xf = f32[8,16] get-tuple-element(%of), index=1
  ROOT %ag.o = f32[8,16] all-gather(%xf), dimensions={0}
}

ENTRY %main (p: pred[], x: f32[8,16]) -> f32[8,16] {
  %p = pred[] parameter(0)
  %x = f32[8,16] parameter(1)
  %args = (pred[], f32[8,16]) tuple(%p, %x)
  ROOT %co = f32[8,16] conditional(%p, %args, %args), true_computation=%outer_true, false_computation=%outer_false
}
"""


# -- collective-budget lint: violation codes ---------------------------------

def _codes(report):
    return {v.code for v in report.violations}


def test_budget_forbidden_collective():
    budget = CollectiveBudget(name="gathers-only",
                              rules={"all-gather": OpBudget()})
    report = audit_hlo(HLO_SYNC, budget)
    assert not report.ok
    assert _codes(report) == {"forbidden-collective"}
    [v] = report.violations
    assert v.kind == "all-reduce"
    with pytest.raises(BudgetError, match="forbidden-collective"):
        assert_budget(HLO_SYNC, budget)


def test_budget_shape_not_allowed():
    budget = CollectiveBudget(
        name="one-shape",
        rules={"all-reduce": OpBudget(allowed_shapes=frozenset({(4, 4)}))})
    report = audit_hlo(HLO_SYNC, budget)
    assert _codes(report) == {"shape-not-allowed"}


def test_budget_panel_width_and_bytes_caps():
    budget = CollectiveBudget(
        name="narrow-panels",
        rules={"all-reduce": OpBudget(max_min_dim=4, max_elems=64,
                                      max_op_bytes=256)})
    report = audit_hlo(HLO_SYNC, budget)   # (8,16): min dim 8, 128 elems
    assert _codes(report) == {"panel-width-exceeded", "op-bytes-exceeded"}


def test_budget_totals_and_counts():
    budget = CollectiveBudget(
        name="tight-totals",
        rules={"all-reduce": OpBudget(max_count=0, max_total_bytes=1.0)},
        max_total_bytes=1.0)
    report = audit_hlo(HLO_SYNC, budget)
    assert _codes(report) == {"op-count-exceeded", "kind-total-bytes-exceeded",
                              "total-bytes-exceeded"}
    # all-reduce payload is charged 2x (reduce-scatter + broadcast halves)
    assert report.total_bytes == 2 * 8 * 16 * 4


def test_budget_cond_only_rule():
    budget = CollectiveBudget(
        name="refresh-only",
        rules={"all-reduce": OpBudget(cond_only=True),
               "all-gather": OpBudget(cond_only=True)})
    # top-level all-reduce: must be flagged
    report = audit_hlo(HLO_SYNC, budget)
    assert _codes(report) == {"cond-branch-required"}
    # the nested-cond program's collectives all sit inside branches: clean
    assert audit_hlo(HLO_NESTED_COND, budget).ok


def test_budget_accepts_clean_program():
    budget = CollectiveBudget(
        name="ok",
        rules={"all-reduce": OpBudget(
            allowed_shapes=frozenset({(8, 16)}), max_count=1)})
    report = assert_budget(HLO_SYNC, budget)
    assert report.ok and len(report.collectives) == 1


# -- hlo_cost walker: async pairs, broadcast, nested conds (satellites 1+2) --

def test_async_pairs_charged_once():
    entries = iter_collectives(HLO_ASYNC)
    assert [e["op"] for e in entries] == ["all-reduce", "all-gather"]
    ar, ag = entries
    # -start pays, -done is free; all-reduce still gets the 2x factor
    assert ar["payload"] == 16 * 4 and ar["bytes"] == 2 * 16 * 4
    assert ar["dims"] == (16,)
    # the all-gather tuple is (operand, result): payload = DESTINATION buffer
    assert ag["dims"] == (8, 16) and ag["payload"] == 8 * 16 * 4
    cost = analyze_hlo(HLO_ASYNC)
    assert cost.collective_bytes == ar["bytes"] + ag["bytes"]
    assert cost.collective_breakdown == {
        "all-reduce": ar["bytes"], "all-gather": ag["bytes"]}


def test_collective_broadcast_recognized():
    [e] = iter_collectives(HLO_BROADCAST)
    assert e["op"] == "collective-broadcast"
    assert e["bytes"] == 32 * 4 and e["dims"] == (32,)
    assert analyze_hlo(HLO_BROADCAST).collective_breakdown == {
        "collective-broadcast": 32 * 4.0}


def test_nested_cond_worst_case_accounting():
    """cond-inside-cond: the innermost branch's collective is visible to the
    walker at branch_depth=2, and analyze_hlo's field-wise-max keeps BOTH
    the inner all-reduce and the other outer branch's all-gather."""
    entries = iter_collectives(HLO_NESTED_COND)
    by_op = {e["op"]: e for e in entries}
    assert set(by_op) == {"all-reduce", "all-gather"}
    assert by_op["all-reduce"]["branch_depth"] == 2
    assert by_op["all-reduce"]["computation"] == "inner_true"
    assert by_op["all-gather"]["branch_depth"] == 1
    cost = analyze_hlo(HLO_NESTED_COND)
    buf = 8 * 16 * 4
    # worst case per kind: the 2x all-reduce through BOTH cond levels and
    # the sibling branch's gather both survive the max
    assert cost.collective_breakdown == {"all-reduce": 2.0 * buf,
                                         "all-gather": 1.0 * buf}
    assert cost.collective_bytes == 2.0 * buf


# -- inertness prover --------------------------------------------------------

def test_refresh_inertness_proof():
    """The machine proof that replaced core/rsvd.py's prose proof: a sketch
    with trailing zero rows yields a basis with the same zero rows."""
    result = prove_refresh_inertness(rows=102, pad=2, short=16, l=8)
    assert result.out_slabs[0].slabs[0].count >= 2


def test_inertness_propagates_through_scaling():
    def f(x):
        y = jnp.pad(x, ((0, 2), (0, 0)))
        return y * 3.0

    closed = jax.make_jaxpr(f)(jnp.zeros((4, 3), jnp.float32))
    result = analyze_jaxpr(closed)
    failures = check_claims(result, [
        Claim(what="pad rows of 3x-scaled pad", dim=0, count=2, out_index=0)])
    assert failures == []


def test_inertness_rejects_nonzero_pad_write():
    """NEGATIVE: `pad(x) + 1.0` writes 1.0 into the pad rows — the prover
    must refuse the trailing-zeros claim instead of rubber-stamping it."""
    def f(x):
        y = jnp.pad(x, ((0, 2), (0, 0)))
        return y + 1.0

    closed = jax.make_jaxpr(f)(jnp.zeros((4, 3), jnp.float32))
    result = analyze_jaxpr(closed)
    failures = check_claims(result, [
        Claim(what="pad rows after +1.0", dim=0, count=2, out_index=0)])
    assert len(failures) == 1
    assert "pad rows after +1.0" in failures[0]


def test_inertness_arg_claims_are_inductive_hypotheses():
    """arg_claims assert structured zeros of an INPUT (the state coming in);
    multiplication and masked-add keep them, an unpadded add does not."""
    def f(q, g):
        return q * 2.0 + g

    closed = jax.make_jaxpr(f)(jnp.zeros((6, 4), jnp.float32),
                               jnp.zeros((6, 4), jnp.float32))
    # both inputs claim 2 trailing zero rows -> sum keeps them
    ok = analyze_jaxpr(closed, arg_claims=[{0: 2}, {0: 2}])
    assert check_claims(ok, [Claim("sum", 0, 2, out_index=0)]) == []
    # only q claims them -> the prover must NOT carry the claim through g
    bad = analyze_jaxpr(closed, arg_claims=[{0: 2}, None])
    assert check_claims(bad, [Claim("sum", 0, 2, out_index=0)])


def test_inertness_masked_zero_slots():
    """The engine's ragged-B masking idiom: rows selected OFF by an iota
    comparison are provably zero even when the payload is arbitrary."""
    def f(x):
        keep = jnp.arange(x.shape[0]) < 3
        return jnp.where(keep[:, None], x, 0.0)

    closed = jax.make_jaxpr(f)(jnp.ones((5, 4), jnp.float32))
    result = analyze_jaxpr(closed)
    assert check_claims(result, [
        Claim("masked-off slots", 0, 2, out_index=0)]) == []


# -- donation audit ----------------------------------------------------------

def test_audit_donation_accepts_aliased_step():
    def step(state, g):
        return jax.tree_util.tree_map(lambda s, d: s - 0.1 * d, state, g)

    state = {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}
    g = {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}
    report = audit_donation(step, (state, g), donate_argnums=(0,))
    assert report.ok, report.summary()
    assert report.declared_leaves == 2
    assert len(report.compiled_aliases) >= 2


def test_audit_donation_flags_dropped_buffers():
    """NEGATIVE: donating a buffer no output can alias (shape mismatch)
    silently drops the donation — the audit must surface it."""
    def f(x, y):
        return y * 2.0

    report = audit_donation(
        f, (jnp.ones((16,)), jnp.ones((4,))), donate_argnums=(0,))
    assert not report.ok
    assert {v.code for v in report.violations} == {"donation-dropped"}


_GOOD_LOOP = """
import jax

def make(fn):
    step = jax.jit(fn, donate_argnums=(0, 1))
    def run(params, state, batch):
        for _ in range(3):
            params, state = step(params, state, batch)
        return params, state
    return run
"""

_BAD_LOOP = """
import jax

def make(fn):
    step = jax.jit(fn, donate_argnums=(0, 1))
    def run(params, state, batch):
        new_p, new_s = step(params, state, batch)
        loss = (params["w"] ** 2).sum()   # donated buffer read after call!
        return new_p, new_s, loss
    return run
"""


def test_donation_lint_accepts_rebinding_loop():
    assert lint_donation_source(_GOOD_LOOP, "good.py") == []


def test_donation_lint_rejects_use_after_donate():
    violations = lint_donation_source(_BAD_LOOP, "bad.py")
    assert violations, "use-after-donate must be flagged"
    assert {v.code for v in violations} == {"donated-arg-not-rebound"}
    assert any("params" in v.detail for v in violations)


# -- recompile audit ---------------------------------------------------------

def test_compile_watcher_tags_steps():
    with CompileWatcher() as w:
        mark_step(5)
        jax.jit(lambda x: x * 2.0 + 1.0)(jnp.arange(7.0))
    steps = [e.step for e in w.events]
    assert 5 in steps, w.events


def test_audit_recompiles_allows_warmup_and_boundaries():
    events = [
        CompileEvent("train_step", None, "trace-time"),
        CompileEvent("train_step", 0, "warmup"),
        CompileEvent("train_step", 12, "at boundary"),
        CompileEvent("train_step", 13, "boundary takes effect next step"),
        CompileEvent("other_fn", 99, "different function: not audited"),
    ]
    report = audit_recompiles(events, fn_name="train_step",
                              warmup_through=1, allowed_steps=(12,))
    assert report.ok, report.summary()
    assert len(report.compiles) == 4


def test_audit_recompiles_rejects_off_boundary():
    """NEGATIVE: a post-warmup compile at a step the controller never
    announced is exactly the silent-jit-cache-instability this pass exists
    to catch."""
    events = [CompileEvent("train_step", 7, "surprise")]
    report = audit_recompiles(events, fn_name="train_step",
                              warmup_through=1, allowed_steps=(12,))
    assert not report.ok
    assert [e.step for e in report.violations] == [7]
    assert "off-boundary-recompile" in report.summary()


# -- memory budgets (pass 5) -------------------------------------------------

def _mem_codes(report):
    return {v.code for v in report.violations}


def test_audit_memory_every_code_falsifiable_synthetic():
    """One synthetic measurement trips all four named codes at once."""
    m = MemoryMeasurement(argument_bytes=1000, output_bytes=1000,
                          temp_bytes=500, alias_bytes=0)
    budget = MemoryBudget(name="synthetic", max_peak_bytes=1200,
                          max_transient_bytes=300, min_alias_bytes=900,
                          state_plan_bytes=400)
    rep = audit_memory(m, budget, state_bytes=500)
    assert not rep.ok
    assert _mem_codes(rep) == set(MEMORY_VIOLATION_CODES)
    # and the same budget is satisfiable: full aliasing, small temps
    ok = audit_memory(
        MemoryMeasurement(argument_bytes=1000, output_bytes=1000,
                          temp_bytes=100, alias_bytes=950),
        budget, state_bytes=400)
    assert ok.ok, ok.summary()


def test_audit_state_ratio_fails_when_measured_exceeds_plan():
    """The ~20%-vs-AdamW claim as a lint: measured/baseline over the cap
    FAILS; at or under the cap passes."""
    bad = audit_state_ratio("sumo-vs-adamw", 90.0, 100.0, max_ratio=0.80)
    assert not bad.ok and _mem_codes(bad) == {"state-bytes-mismatch"}
    good = audit_state_ratio("sumo-vs-adamw", 70.0, 100.0, max_ratio=0.80)
    assert good.ok


def test_hlo_buffer_table_on_compiled_program():
    """The buffer-table walk and memory_analysis() must agree on a tiny
    donated program: two f32[8,8] params, one aliased into the output."""
    x = jnp.zeros((8, 8), jnp.float32)
    compiled = jax.jit(lambda a, b: a * b + 1.0, donate_argnums=(0,)) \
        .lower(x, x).compile()
    table = hlo_buffer_table(compiled.as_text())
    assert table.param_bytes == (256.0, 256.0)
    assert table.output_bytes == 256.0
    assert table.aliased_params == (0,)
    assert table.alias_bytes == 256.0
    m = measure_compiled_memory(compiled)
    assert m.argument_bytes == 512.0
    assert m.alias_bytes == 256.0
    assert m.table is table or m.table.aliased_params == (0,)
    # peak counts the donated buffer ONCE
    assert m.peak_bytes == m.argument_bytes + m.output_bytes \
        + m.temp_bytes + m.generated_code_bytes - 256.0


@pytest.fixture(scope="module")
def smoke_train():
    """(params, opt_state, batch, step) — the lint smoke recipe, shared
    with the analysis driver so the tests audit the exact same program."""
    from repro.analysis.driver import _smoke_train_setup
    return _smoke_train_setup()


def test_train_step_memory_budget_donated_vs_undonated(smoke_train):
    """Tentpole falsifiability: the donated smoke train step fits its
    steady budget (donation floor = params+state EXACTLY); the SAME program
    compiled WITHOUT donation fails ``donation-not-realized``."""
    from repro.configs import get_smoke_config
    from repro.core.memory import (analytic_activation_bytes,
                                   predict_state_bytes, tree_param_bytes,
                                   tree_state_bytes)

    params, opt_state, batch, step = smoke_train
    batch_bytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(batch))
    budget = steady_memory_budget(
        params, opt_state, batch_bytes=batch_bytes,
        activation_bytes=analytic_activation_bytes(
            get_smoke_config("smollm-360m"), 2, 16),
        state_plan_bytes=predict_state_bytes("sumo", params, rank=4))

    donated = jax.jit(step, donate_argnums=(0, 1)) \
        .lower(params, opt_state, batch).compile()
    rep = audit_memory(measure_compiled_memory(donated), budget,
                       param_bytes=tree_param_bytes(params),
                       state_bytes=tree_state_bytes(opt_state))
    assert rep.ok, rep.summary()

    undonated = jax.jit(step).lower(params, opt_state, batch).compile()
    bad = audit_memory(measure_compiled_memory(undonated), budget,
                       param_bytes=tree_param_bytes(params),
                       state_bytes=tree_state_bytes(opt_state))
    assert not bad.ok
    assert "donation-not-realized" in _mem_codes(bad)


def test_bucket_memory_plan_matches_live_state(smoke_train):
    """The analytic SumoState decomposition must cover the live tree
    EXACTLY — every budget derived from it inherits byte accuracy."""
    from repro.core.memory import tree_state_bytes

    _, opt_state, _, _ = smoke_train
    plan = bucket_memory_plan(opt_state)
    assert plan.entries, "no bucket entries found in SumoState"
    assert plan.total_bytes == tree_state_bytes(opt_state)


def test_serve_decode_memory_budget_falsifiable():
    """ONE oversized compile, both verdicts: a paged ``serve_decode``
    compiled with a 2x KV pool passes the budget built from its OWN
    geometry but fails the PLAN budget with ``peak-bytes-exceeded`` and
    ``transient-exceeds-plan`` — the un-sized-pool bug cannot hide."""
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serve.engine import (PAGED_DECODE_DONATE, ContinuousConfig,
                                    paged_serve_decode_fn,
                                    serve_decode_audit_args)

    cfg = get_smoke_config("smollm-360m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    plan_ccfg = ContinuousConfig(num_slots=4, block_size=8, n_blocks=32,
                                 max_prompt_len=16, max_new_cap=16)
    big_ccfg = ContinuousConfig(num_slots=4, block_size=8, n_blocks=64,
                                max_prompt_len=16, max_new_cap=16)
    fn = paged_serve_decode_fn(cfg)
    compiled = jax.jit(fn, donate_argnums=PAGED_DECODE_DONATE) \
        .lower(*serve_decode_audit_args(cfg, big_ccfg, params)).compile()
    m = measure_compiled_memory(compiled)

    ok = audit_memory(m, serve_decode_memory_budget(cfg, big_ccfg, params))
    assert ok.ok, ok.summary()
    bad = audit_memory(m, serve_decode_memory_budget(cfg, plan_ccfg, params))
    assert not bad.ok
    assert {"peak-bytes-exceeded",
            "transient-exceeds-plan"} <= _mem_codes(bad)


# -- host-dtype lint ---------------------------------------------------------

def test_host_dtype_lint_flags_implicit_dtypes():
    src = (
        "import numpy as np\n"
        "a = np.zeros(4)\n"                      # flagged
        "b = np.zeros(4, np.int32)\n"            # positional dtype: ok
        "c = np.array([1, 2], dtype=np.int32)\n"  # kwarg dtype: ok
        "d = np.asarray(x)\n"                    # dtype-preserving: exempt
        "e = np.full((2, 2), 0.0)\n"             # flagged (dtype is pos 2)
        "f = np.full((2, 2), 0.0, np.float32)\n"  # ok
    )
    v = lint_host_dtype_source(src, "fake.py")
    assert [x.code for x in v] == ["host-buffer-no-dtype"] * 2
    assert {x.where for x in v} == {"fake.py:2", "fake.py:6"}


def test_host_dtype_hot_paths_clean():
    rep = audit_host_dtypes()
    assert rep.ok, rep.summary()


# -- null-block inertness (serving) ------------------------------------------

def test_null_block_proof_and_falsification():
    """Free slots' decode writes provably land in physical block 0; the
    proof genuinely depends on the all-zero-table hypothesis — dropping the
    table claim (a free slot whose table rows were left dirty) breaks it."""
    result = prove_null_block_inertness()
    assert result is not None

    from repro.models.transformer import paged_write_targets
    closed = jax.make_jaxpr(
        lambda t, ln: paged_write_targets(t, ln, 8))(
        jnp.zeros((4, 8), jnp.int32), jnp.zeros((4,), jnp.int32))
    # hypothesis only on lengths, NOT on the table rows
    weakened = analyze_jaxpr(closed, arg_claims=[None, {0: 2}])
    failures = check_claims(weakened, [
        Claim(what="free slots' write block", dim=0, count=2, out_index=0)])
    assert failures, "proof must fail without the zero-table hypothesis"


# -- driver: --json machine-readable report ----------------------------------

def test_driver_json_report_schema(capsys):
    """``python -m repro.analysis --mode 2d --json`` on a single device:
    valid static-analysis-v2 JSON, stable check names, SKIPs (missing
    devices) not counted as failures, exit code 0. The device-free
    precision checks (guards, ortho-bound) must PASS, not SKIP, even
    here."""
    import json as _json

    from repro.analysis.driver import REPORT_SCHEMA, main

    rc = main(["--mode", "2d", "--json"])
    rep = _json.loads(capsys.readouterr().out)
    assert rc == 0
    assert rep["schema"] == REPORT_SCHEMA == "static-analysis-v2"
    assert rep["ok"] is True and rep["failed"] == 0
    by_name = {c["name"]: c["status"] for c in rep["checks"]}
    assert by_name["inertness/refresh"] == "PASS"
    assert by_name["precision/guards"] == "PASS"
    assert by_name["precision/ortho-bound"] == "PASS"
    assert by_name["collectives/steady-2d"] in ("PASS", "SKIP")
    assert by_name["inertness/update-2d"] in ("PASS", "SKIP")
    assert rep["passed"] + rep["skipped"] + rep["failed"] == len(rep["checks"])


def test_driver_list_is_the_check_contract(capsys):
    """``--list`` is the single source of required check names: it matches
    the registry per lane, runs nothing, and carries the schema tag
    tools/analysis_diff.py keys required-check sets on."""
    import json as _json

    from repro.analysis.driver import REPORT_SCHEMA, list_checks, main

    rc = main(["--mode", "1d", "--list"])
    out = _json.loads(capsys.readouterr().out)
    assert rc == 0
    assert out["schema"] == REPORT_SCHEMA and out["mode"] == "1d"
    assert out["checks"] == list_checks("1d")

    names_1d = {c["name"] for c in list_checks("1d")}
    names_2d = {c["name"] for c in list_checks("2d")}
    assert {"precision/accumulation", "precision/wire-dtype",
            "precision/guards", "precision/ortho-bound"} <= names_1d
    # device-free precision checks run in BOTH lanes; the artifact-bound
    # ones are 1d-lane only.
    assert {"precision/guards", "precision/ortho-bound"} <= names_2d
    assert "precision/accumulation" not in names_2d
    assert "precision/wire-dtype" not in names_2d
    all_names = [c["name"] for c in list_checks("all")]
    assert len(all_names) == len(set(all_names))
    assert set(all_names) == names_1d | names_2d


# -- precision lint (pass 6) -------------------------------------------------
# Handcrafted reduction HLO: an f32 Gram dot and loss reduce next to their
# bf16 twins, plus a max-reduce (precision-neutral root) that must be
# skipped, so checked/violation counts are exact.

_ADD_BF16 = """\
%add.b (a: bf16[], b: bf16[]) -> bf16[] {
  %a = bf16[] parameter(0)
  %b = bf16[] parameter(1)
  ROOT %r = bf16[] add(%a, %b)
}
"""

_MAX_BF16 = """\
%max.b (a: bf16[], b: bf16[]) -> bf16[] {
  %a = bf16[] parameter(0)
  %b = bf16[] parameter(1)
  ROOT %r = bf16[] maximum(%a, %b)
}
"""

HLO_REDUCTIONS = _ADD + _ADD_BF16 + _MAX_BF16 + """
ENTRY %main (p0: bf16[8,16], p1: f32[8,16]) -> f32[] {
  %p0 = bf16[8,16] parameter(0)
  %p1 = f32[8,16] parameter(1)
  %z = bf16[] constant(0)
  %zf = f32[] constant(0)
  %gram = f32[8,8] dot(%p1, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  %gram.b = bf16[8,8] dot(%p0, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={1}, metadata={op_name="jit(update)/gram_psum"}
  %red.b = bf16[] reduce(%p0, %z), dimensions={0,1}, to_apply=%add.b
  %mx = bf16[] reduce(%p0, %z), dimensions={0,1}, to_apply=%max.b
  ROOT %red.f = f32[] reduce(%p1, %zf), dimensions={0,1}, to_apply=%add
}
"""


def test_iter_reductions_handcrafted():
    """The HLO walk exposes each accumulating op's result element type and
    its to_apply ROOT opcode — the raw facts the accumulation lint keys on."""
    all_ents = iter_reductions(HLO_REDUCTIONS)
    assert len(all_ents) == 5
    reduces = {e["to_apply"]: e for e in all_ents if e["op"] == "reduce"}
    assert reduces["add.b"]["accum_dtypes"] == ("bf16",)
    assert reduces["add.b"]["comp_root"] == "add"
    assert reduces["max.b"]["comp_root"] == "maximum"
    assert reduces["add"]["accum_dtypes"] == ("f32",)
    dots = [e for e in all_ents if e["op"] == "dot"]
    assert {e["accum_dtypes"][0] for e in dots} == {"f32", "bf16"}
    bf_dot = next(e for e in dots if e["accum_dtypes"] == ("bf16",))
    assert bf_dot["operand_dtypes"] == ("bf16", "bf16")
    assert bf_dot["source"] == "jit(update)/gram_psum"


def test_accumulation_hlo_flags_bf16_not_f32():
    """`low-precision-accumulation` fires on the bf16 dot and the bf16
    reduce-add, skips the max-reduce (precision-neutral root) and passes
    both f32 twins; allow_sources exempts by op_name metadata."""
    bud = PrecisionBudget(name="t")
    rep = audit_accumulation_hlo(HLO_REDUCTIONS, bud)
    assert not rep.ok
    assert rep.checked == 4          # f32 dot, bf16 dot, 2 add-reduces
    assert _codes(rep) == {"low-precision-accumulation"}
    assert len(rep.violations) == 2
    with pytest.raises(PrecisionError):
        assert_precision(rep)

    allowed = audit_accumulation_hlo(
        HLO_REDUCTIONS, PrecisionBudget(name="t", allow_sources=("gram_psum",)))
    assert len(allowed.violations) == 1   # only the bf16 reduce remains

    relaxed = audit_accumulation_hlo(
        HLO_REDUCTIONS, PrecisionBudget(name="t", min_accum_bytes=2))
    assert relaxed.ok and relaxed.checked == 4


def test_jaxpr_guard_unguarded_division():
    """An eps-less normalize fails `unguarded-division`; the guarded twin
    proves a positive floor through mul/sum/sqrt/add."""
    x = jnp.ones((4, 4))
    bud = PrecisionBudget(name="t")

    bad = audit_jaxpr_guards(
        jax.make_jaxpr(lambda a: a / jnp.linalg.norm(a))(x), bud)
    assert not bad.ok and _codes(bad) == {"unguarded-division"}

    good = audit_jaxpr_guards(
        jax.make_jaxpr(lambda a: a / (jnp.linalg.norm(a) + 1e-7))(x), bud)
    assert good.ok, good.summary()
    assert good.checked >= 1


def test_jaxpr_guard_under_scaled_shift_pr5_class():
    """The PR 5 bug class: a bare 1e-12 diagonal shift (~1000x below f32
    roundoff, relative scale 0) fails `under-scaled-shift`; the eps*trace
    shift the repo's refresh actually uses passes, and the repo's OWN
    CholeskyQR2 jaxpr is clean."""
    g = jnp.ones((8, 4))
    bud = PrecisionBudget(name="t")

    def pr5_bug(a):
        gram = a.T @ a
        return jnp.linalg.cholesky(gram + 1e-12 * jnp.eye(4))

    bad = audit_jaxpr_guards(jax.make_jaxpr(pr5_bug)(g), bud)
    assert not bad.ok and "under-scaled-shift" in _codes(bad)

    def fixed(a):
        gram = a.T @ a
        shift = 1e-7 * jnp.trace(gram)
        return jnp.linalg.cholesky(gram + shift * jnp.eye(4))

    good = audit_jaxpr_guards(jax.make_jaxpr(fixed)(g), bud)
    assert good.ok, good.summary()

    # the real artifact: distributed CholeskyQR2's two factorizations carry
    # trace-scale shifts (its 2nd-pass 2*eps/l shift is legitimately below
    # f32 eps — the 1e-9 default floor must admit it).
    from repro.core.rsvd import cholesky_qr2_closed_jaxpr
    rep = audit_jaxpr_guards(cholesky_qr2_closed_jaxpr(64, 8), bud,
                             where="rsvd/cholesky-qr2")
    assert rep.ok, rep.summary()
    # tightening the floor above the real shifts must flip the verdict —
    # the min_shift_rel knob is live, not decorative.
    strict = audit_jaxpr_guards(
        cholesky_qr2_closed_jaxpr(64, 8),
        PrecisionBudget(name="strict", min_shift_rel=1e-2))
    assert not strict.ok and _codes(strict) == {"under-scaled-shift"}


def test_jaxpr_low_precision_accumulation():
    """A bf16 Gram dot (f32-demoted accumulation) is flagged in the dtype
    flow; the f32 twin and every repo orthogonalizer pass."""
    bud = PrecisionBudget(name="t")

    def gram(y):
        return y.T @ y

    bf16 = audit_jaxpr_guards(
        jax.make_jaxpr(gram)(jnp.ones((8, 4), jnp.bfloat16)), bud)
    assert not bf16.ok and _codes(bf16) == {"low-precision-accumulation"}

    f32 = audit_jaxpr_guards(jax.make_jaxpr(gram)(jnp.ones((8, 4))), bud)
    assert f32.ok and f32.checked >= 1

    from repro.core.orthogonalize import ORTH_METHODS, orth_closed_jaxpr
    reports = [audit_jaxpr_guards(orth_closed_jaxpr(m), bud, where=m)
               for m in ORTH_METHODS]
    merged = merge_reports(bud, *reports)
    assert merged.ok, merged.summary()
    assert merged.checked >= len(reports)   # non-vacuous on every method


HLO_WIRE = _ADD + """
ENTRY %main (p0: f32[4,16]) -> f32[4,16] {
  %p0 = f32[4,16] parameter(0)
  ROOT %ar = f32[4,16] all-reduce(%p0), replica_groups={}, to_apply=%add
}
"""


def test_wire_dtype_promotion_falsifiable():
    """`bf16-wire-promoted` closes the hlo_bytes dual-view loop: a plan
    entry whose claim matches the compiled f32 all-reduce (4 B/elem, the
    promoted bf16 wire) passes; a plan claiming bf16 STAYS bf16 on the
    same program fails; a payload with no matching all-reduce fails."""
    import dataclasses as _dc

    from repro.parallel.compression import WirePlanEntry

    bud = PrecisionBudget(name="t", wire_dtype="bfloat16")
    honest = WirePlanEntry(path="w", shape=(4, 16), eligible=True, rank=4,
                           payload_dims=(4, 16), payload_bytes=128,
                           full_bytes=256, hlo_bytes=256)
    rep = audit_wire_dtype(HLO_WIRE, [honest], bud)
    assert rep.ok and rep.checked == 1

    liar = _dc.replace(honest, hlo_bytes=honest.payload_bytes)
    bad = audit_wire_dtype(HLO_WIRE, [liar], bud)
    assert not bad.ok and _codes(bad) == {"bf16-wire-promoted"}
    assert "2 B/elem" in bad.violations[0].detail

    orphan = _dc.replace(honest, payload_dims=(99,), hlo_bytes=396)
    miss = audit_wire_dtype(HLO_WIRE, [orphan], bud)
    assert not miss.ok and "no all-reduce" in miss.violations[0].detail


def test_ortho_bound_tiering_and_scale():
    """`ortho-error-bound-exceeded`: an NS5-plateau residual fails the
    SVD-tier budget that a roundoff-tier residual passes, yet respects its
    own plateau bound; bound_scale provably loosens/tightens the verdict
    (a silently loosened bound cannot pass as the paper's)."""
    r, kappa = 16, 100.0
    svd_stats = {"b": {"sigma": [0.0] * r, "kappa": kappa,
                       "ortho_residual": 1e-7}}
    ns5_stats = {"b": {"sigma": [0.0] * r, "kappa": kappa,
                       "ortho_residual": 0.4}}
    bud = PrecisionBudget(name="t")

    assert audit_ortho_bound(svd_stats, "svd", bud).ok
    bad = audit_ortho_bound(ns5_stats, "svd", bud)
    assert not bad.ok and _codes(bad) == {"ortho-error-bound-exceeded"}
    assert audit_ortho_bound(ns5_stats, "ns5", bud).ok

    loose = PrecisionBudget(name="loose", bound_scale=1e7)
    assert audit_ortho_bound(ns5_stats, "svd", loose).ok
    tight = PrecisionBudget(name="tight", bound_scale=1e-9)
    assert not audit_ortho_bound(svd_stats, "svd", tight).ok

    # the bound pieces themselves: monotone in kappa, svd tier far below
    # the ns5 plateau at matched (r, kappa).
    assert ns_error_bound(1000.0, r) > ns_error_bound(10.0, r)
    assert svd_tier_bound(r, kappa) < method_bound("ns5", kappa, r)
    with pytest.raises(ValueError):
        method_bound("qr", kappa, r)
    assert set(PRECISION_VIOLATION_CODES) >= {
        "low-precision-accumulation", "bf16-wire-promoted",
        "unguarded-division", "under-scaled-shift",
        "ortho-error-bound-exceeded"}


# -- analysis_diff: report regression gate -----------------------------------

def _load_analysis_diff():
    import importlib.util
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "tools",
                        "analysis_diff.py")
    spec = importlib.util.spec_from_file_location("analysis_diff_tool", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_analysis_diff_regression_gate():
    """newly-FAILed and silently-disappeared checks fail the diff;
    PASS->SKIP and brand-new checks are warnings only; --require-mode
    pulls the required set from the driver's --list contract."""
    mod = _load_analysis_diff()
    golden = {"schema": "static-analysis-v2", "checks": [
        {"name": "a", "status": "PASS"}, {"name": "b", "status": "PASS"}]}

    ok = {"schema": "static-analysis-v2", "checks": [
        {"name": "a", "status": "PASS"}, {"name": "b", "status": "SKIP"},
        {"name": "c", "status": "PASS"}]}
    failures, warnings = mod.diff(golden, ok)
    assert not failures and len(warnings) == 2

    regressed = {"schema": "static-analysis-v2", "checks": [
        {"name": "a", "status": "PASS"}, {"name": "b", "status": "FAIL"}]}
    failures, _ = mod.diff(golden, regressed)
    assert any("newly-failed" in f for f in failures)

    dropped = {"schema": "static-analysis-v2",
               "checks": [{"name": "a", "status": "PASS"}]}
    failures, _ = mod.diff(golden, dropped)
    assert any("silently-disappeared" in f for f in failures)

    failures, _ = mod.diff(golden, ok, require_mode="1d")
    missing = [f for f in failures if "missing-required" in f]
    from repro.analysis.driver import list_checks
    assert len(missing) == len(list_checks("1d"))
    assert any("precision/guards" in f for f in missing)
