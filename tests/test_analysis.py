"""Unit tests for the repro.analysis static passes (single-device).

Each pass has at least one NEGATIVE test — the lint must reject the bad
program with its stable violation code, not just accept the good one:

  * collectives: forbidden kind, disallowed shape, blown panel width, and
    a steady-path op that must live in a cond branch — over handcrafted
    HLO so the failure is unambiguous;
  * hlo_cost walker: async ``-start``/``-done`` pairs charged ONCE (on the
    destination buffer of the -start tuple), ``collective-broadcast``
    recognized, and collectives inside a cond-inside-cond charged at the
    worst case with the right branch_depth;
  * inertness: a pad followed by ``+ 1.0`` (a non-inert pad write) fails
    the trailing-zeros claim that the ``* 3.0`` version proves;
  * donation: a jit call site that keeps using a donated reference is
    flagged ``donated-arg-not-rebound``; dropped donations are flagged by
    the HLO cross-check;
  * recompile: an off-boundary compile event fails the audit, while
    warmup/boundary-adjacent ones pass;
  * memory (pass 5): every violation code is falsifiable — the donated
    smoke train step passes its steady budget while the UN-donated compile
    fails ``donation-not-realized``; the compiled paged ``serve_decode``
    passes at its own pool geometry but an oversized pool audited against
    the plan budget fails ``peak-bytes-exceeded`` + ``transient-exceeds-plan``;
    the Table-1 ratio lint fails when measured state exceeds the plan;
  * host-dtype lint: an implicit-dtype ``np.zeros(...)`` host buffer is
    flagged ``host-buffer-no-dtype``; the serve/train hot paths are clean;
  * null-block inertness: free serving slots' decode writes provably target
    physical block 0, and dropping the zero-table hypothesis breaks the
    proof.

The sharded end-to-end proofs (2D budgets on compiled HLO, full-update
inertness, the concatenate-seam regression) live in
tests/test_analysis_sharded.py under 8 forced host devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.collectives import (
    CollectiveBudget,
    OpBudget,
    BudgetError,
    assert_budget,
    audit_hlo,
)
from repro.analysis.donation import (
    audit_donation,
    audit_host_dtypes,
    lint_donation_source,
    lint_host_dtype_source,
)
from repro.analysis.inertness import (
    Claim,
    InertnessError,
    analyze_jaxpr,
    check_claims,
    prove_null_block_inertness,
    prove_refresh_inertness,
)
from repro.analysis.memory import (
    MEMORY_VIOLATION_CODES,
    MemoryBudget,
    MemoryMeasurement,
    audit_memory,
    audit_state_ratio,
    bucket_memory_plan,
    hlo_buffer_table,
    measure_compiled_memory,
    serve_decode_memory_budget,
    steady_memory_budget,
)
from repro.analysis.recompile import (
    CompileEvent,
    CompileWatcher,
    audit_recompiles,
    mark_step,
)
from repro.roofline.hlo_cost import analyze_hlo, iter_collectives


# -- handcrafted HLO fixtures ------------------------------------------------
# Minimal but syntactically faithful optimized-HLO text: computation headers
# flush-left ending in "{", ops indented, attrs after the operand list.

_ADD = """\
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}
"""

HLO_SYNC = _ADD + """
ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16] parameter(0)
  ROOT %ar = f32[8,16] all-reduce(%p0), replica_groups={}, to_apply=%add
}
"""

HLO_ASYNC = _ADD + """
ENTRY %main (p0: f32[16]) -> f32[16] {
  %p0 = f32[16] parameter(0)
  %ars = (f32[16], f32[16]) all-reduce-start(%p0), to_apply=%add
  %ard = f32[16] all-reduce-done(%ars)
  %ags = (f32[4,16], f32[8,16]) all-gather-start(%p0), dimensions={0}
  %agd = f32[8,16] all-gather-done(%ags)
  ROOT %out = f32[16] add(%ard, %p0)
}
"""

HLO_BROADCAST = """\
ENTRY %main (p0: f32[32]) -> f32[32] {
  %p0 = f32[32] parameter(0)
  ROOT %cb = f32[32] collective-broadcast(%p0), replica_groups={{0,1}}
}
"""

# collective in the TRUE branch of a cond nested inside another cond; the
# outer FALSE branch holds a smaller gather so worst-case must keep both.
HLO_NESTED_COND = _ADD + """
%inner_true (t0: f32[8,16]) -> f32[8,16] {
  %t0 = f32[8,16] parameter(0)
  ROOT %ar.i = f32[8,16] all-reduce(%t0), to_apply=%add
}

%inner_false (f0: f32[8,16]) -> f32[8,16] {
  ROOT %f0 = f32[8,16] parameter(0)
}

%outer_true (ot: (pred[], f32[8,16])) -> f32[8,16] {
  %ot = (pred[], f32[8,16]) parameter(0)
  %pi = pred[] get-tuple-element(%ot), index=0
  %xi = f32[8,16] get-tuple-element(%ot), index=1
  ROOT %ci = f32[8,16] conditional(%pi, %xi, %xi), true_computation=%inner_true, false_computation=%inner_false
}

%outer_false (of: (pred[], f32[8,16])) -> f32[8,16] {
  %of = (pred[], f32[8,16]) parameter(0)
  %xf = f32[8,16] get-tuple-element(%of), index=1
  ROOT %ag.o = f32[8,16] all-gather(%xf), dimensions={0}
}

ENTRY %main (p: pred[], x: f32[8,16]) -> f32[8,16] {
  %p = pred[] parameter(0)
  %x = f32[8,16] parameter(1)
  %args = (pred[], f32[8,16]) tuple(%p, %x)
  ROOT %co = f32[8,16] conditional(%p, %args, %args), true_computation=%outer_true, false_computation=%outer_false
}
"""


# -- collective-budget lint: violation codes ---------------------------------

def _codes(report):
    return {v.code for v in report.violations}


def test_budget_forbidden_collective():
    budget = CollectiveBudget(name="gathers-only",
                              rules={"all-gather": OpBudget()})
    report = audit_hlo(HLO_SYNC, budget)
    assert not report.ok
    assert _codes(report) == {"forbidden-collective"}
    [v] = report.violations
    assert v.kind == "all-reduce"
    with pytest.raises(BudgetError, match="forbidden-collective"):
        assert_budget(HLO_SYNC, budget)


def test_budget_shape_not_allowed():
    budget = CollectiveBudget(
        name="one-shape",
        rules={"all-reduce": OpBudget(allowed_shapes=frozenset({(4, 4)}))})
    report = audit_hlo(HLO_SYNC, budget)
    assert _codes(report) == {"shape-not-allowed"}


def test_budget_panel_width_and_bytes_caps():
    budget = CollectiveBudget(
        name="narrow-panels",
        rules={"all-reduce": OpBudget(max_min_dim=4, max_elems=64,
                                      max_op_bytes=256)})
    report = audit_hlo(HLO_SYNC, budget)   # (8,16): min dim 8, 128 elems
    assert _codes(report) == {"panel-width-exceeded", "op-bytes-exceeded"}


def test_budget_totals_and_counts():
    budget = CollectiveBudget(
        name="tight-totals",
        rules={"all-reduce": OpBudget(max_count=0, max_total_bytes=1.0)},
        max_total_bytes=1.0)
    report = audit_hlo(HLO_SYNC, budget)
    assert _codes(report) == {"op-count-exceeded", "kind-total-bytes-exceeded",
                              "total-bytes-exceeded"}
    # all-reduce payload is charged 2x (reduce-scatter + broadcast halves)
    assert report.total_bytes == 2 * 8 * 16 * 4


def test_budget_cond_only_rule():
    budget = CollectiveBudget(
        name="refresh-only",
        rules={"all-reduce": OpBudget(cond_only=True),
               "all-gather": OpBudget(cond_only=True)})
    # top-level all-reduce: must be flagged
    report = audit_hlo(HLO_SYNC, budget)
    assert _codes(report) == {"cond-branch-required"}
    # the nested-cond program's collectives all sit inside branches: clean
    assert audit_hlo(HLO_NESTED_COND, budget).ok


def test_budget_accepts_clean_program():
    budget = CollectiveBudget(
        name="ok",
        rules={"all-reduce": OpBudget(
            allowed_shapes=frozenset({(8, 16)}), max_count=1)})
    report = assert_budget(HLO_SYNC, budget)
    assert report.ok and len(report.collectives) == 1


# -- hlo_cost walker: async pairs, broadcast, nested conds (satellites 1+2) --

def test_async_pairs_charged_once():
    entries = iter_collectives(HLO_ASYNC)
    assert [e["op"] for e in entries] == ["all-reduce", "all-gather"]
    ar, ag = entries
    # -start pays, -done is free; all-reduce still gets the 2x factor
    assert ar["payload"] == 16 * 4 and ar["bytes"] == 2 * 16 * 4
    assert ar["dims"] == (16,)
    # the all-gather tuple is (operand, result): payload = DESTINATION buffer
    assert ag["dims"] == (8, 16) and ag["payload"] == 8 * 16 * 4
    cost = analyze_hlo(HLO_ASYNC)
    assert cost.collective_bytes == ar["bytes"] + ag["bytes"]
    assert cost.collective_breakdown == {
        "all-reduce": ar["bytes"], "all-gather": ag["bytes"]}


def test_collective_broadcast_recognized():
    [e] = iter_collectives(HLO_BROADCAST)
    assert e["op"] == "collective-broadcast"
    assert e["bytes"] == 32 * 4 and e["dims"] == (32,)
    assert analyze_hlo(HLO_BROADCAST).collective_breakdown == {
        "collective-broadcast": 32 * 4.0}


def test_nested_cond_worst_case_accounting():
    """cond-inside-cond: the innermost branch's collective is visible to the
    walker at branch_depth=2, and analyze_hlo's field-wise-max keeps BOTH
    the inner all-reduce and the other outer branch's all-gather."""
    entries = iter_collectives(HLO_NESTED_COND)
    by_op = {e["op"]: e for e in entries}
    assert set(by_op) == {"all-reduce", "all-gather"}
    assert by_op["all-reduce"]["branch_depth"] == 2
    assert by_op["all-reduce"]["computation"] == "inner_true"
    assert by_op["all-gather"]["branch_depth"] == 1
    cost = analyze_hlo(HLO_NESTED_COND)
    buf = 8 * 16 * 4
    # worst case per kind: the 2x all-reduce through BOTH cond levels and
    # the sibling branch's gather both survive the max
    assert cost.collective_breakdown == {"all-reduce": 2.0 * buf,
                                         "all-gather": 1.0 * buf}
    assert cost.collective_bytes == 2.0 * buf


# -- inertness prover --------------------------------------------------------

def test_refresh_inertness_proof():
    """The machine proof that replaced core/rsvd.py's prose proof: a sketch
    with trailing zero rows yields a basis with the same zero rows."""
    result = prove_refresh_inertness(rows=102, pad=2, short=16, l=8)
    assert result.out_slabs[0].slabs[0].count >= 2


def test_inertness_propagates_through_scaling():
    def f(x):
        y = jnp.pad(x, ((0, 2), (0, 0)))
        return y * 3.0

    closed = jax.make_jaxpr(f)(jnp.zeros((4, 3), jnp.float32))
    result = analyze_jaxpr(closed)
    failures = check_claims(result, [
        Claim(what="pad rows of 3x-scaled pad", dim=0, count=2, out_index=0)])
    assert failures == []


def test_inertness_rejects_nonzero_pad_write():
    """NEGATIVE: `pad(x) + 1.0` writes 1.0 into the pad rows — the prover
    must refuse the trailing-zeros claim instead of rubber-stamping it."""
    def f(x):
        y = jnp.pad(x, ((0, 2), (0, 0)))
        return y + 1.0

    closed = jax.make_jaxpr(f)(jnp.zeros((4, 3), jnp.float32))
    result = analyze_jaxpr(closed)
    failures = check_claims(result, [
        Claim(what="pad rows after +1.0", dim=0, count=2, out_index=0)])
    assert len(failures) == 1
    assert "pad rows after +1.0" in failures[0]


def test_inertness_arg_claims_are_inductive_hypotheses():
    """arg_claims assert structured zeros of an INPUT (the state coming in);
    multiplication and masked-add keep them, an unpadded add does not."""
    def f(q, g):
        return q * 2.0 + g

    closed = jax.make_jaxpr(f)(jnp.zeros((6, 4), jnp.float32),
                               jnp.zeros((6, 4), jnp.float32))
    # both inputs claim 2 trailing zero rows -> sum keeps them
    ok = analyze_jaxpr(closed, arg_claims=[{0: 2}, {0: 2}])
    assert check_claims(ok, [Claim("sum", 0, 2, out_index=0)]) == []
    # only q claims them -> the prover must NOT carry the claim through g
    bad = analyze_jaxpr(closed, arg_claims=[{0: 2}, None])
    assert check_claims(bad, [Claim("sum", 0, 2, out_index=0)])


def test_inertness_masked_zero_slots():
    """The engine's ragged-B masking idiom: rows selected OFF by an iota
    comparison are provably zero even when the payload is arbitrary."""
    def f(x):
        keep = jnp.arange(x.shape[0]) < 3
        return jnp.where(keep[:, None], x, 0.0)

    closed = jax.make_jaxpr(f)(jnp.ones((5, 4), jnp.float32))
    result = analyze_jaxpr(closed)
    assert check_claims(result, [
        Claim("masked-off slots", 0, 2, out_index=0)]) == []


# -- donation audit ----------------------------------------------------------

def test_audit_donation_accepts_aliased_step():
    def step(state, g):
        return jax.tree_util.tree_map(lambda s, d: s - 0.1 * d, state, g)

    state = {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}
    g = {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}
    report = audit_donation(step, (state, g), donate_argnums=(0,))
    assert report.ok, report.summary()
    assert report.declared_leaves == 2
    assert len(report.compiled_aliases) >= 2


def test_audit_donation_flags_dropped_buffers():
    """NEGATIVE: donating a buffer no output can alias (shape mismatch)
    silently drops the donation — the audit must surface it."""
    def f(x, y):
        return y * 2.0

    report = audit_donation(
        f, (jnp.ones((16,)), jnp.ones((4,))), donate_argnums=(0,))
    assert not report.ok
    assert {v.code for v in report.violations} == {"donation-dropped"}


_GOOD_LOOP = """
import jax

def make(fn):
    step = jax.jit(fn, donate_argnums=(0, 1))
    def run(params, state, batch):
        for _ in range(3):
            params, state = step(params, state, batch)
        return params, state
    return run
"""

_BAD_LOOP = """
import jax

def make(fn):
    step = jax.jit(fn, donate_argnums=(0, 1))
    def run(params, state, batch):
        new_p, new_s = step(params, state, batch)
        loss = (params["w"] ** 2).sum()   # donated buffer read after call!
        return new_p, new_s, loss
    return run
"""


def test_donation_lint_accepts_rebinding_loop():
    assert lint_donation_source(_GOOD_LOOP, "good.py") == []


def test_donation_lint_rejects_use_after_donate():
    violations = lint_donation_source(_BAD_LOOP, "bad.py")
    assert violations, "use-after-donate must be flagged"
    assert {v.code for v in violations} == {"donated-arg-not-rebound"}
    assert any("params" in v.detail for v in violations)


# -- recompile audit ---------------------------------------------------------

def test_compile_watcher_tags_steps():
    with CompileWatcher() as w:
        mark_step(5)
        jax.jit(lambda x: x * 2.0 + 1.0)(jnp.arange(7.0))
    steps = [e.step for e in w.events]
    assert 5 in steps, w.events


def test_audit_recompiles_allows_warmup_and_boundaries():
    events = [
        CompileEvent("train_step", None, "trace-time"),
        CompileEvent("train_step", 0, "warmup"),
        CompileEvent("train_step", 12, "at boundary"),
        CompileEvent("train_step", 13, "boundary takes effect next step"),
        CompileEvent("other_fn", 99, "different function: not audited"),
    ]
    report = audit_recompiles(events, fn_name="train_step",
                              warmup_through=1, allowed_steps=(12,))
    assert report.ok, report.summary()
    assert len(report.compiles) == 4


def test_audit_recompiles_rejects_off_boundary():
    """NEGATIVE: a post-warmup compile at a step the controller never
    announced is exactly the silent-jit-cache-instability this pass exists
    to catch."""
    events = [CompileEvent("train_step", 7, "surprise")]
    report = audit_recompiles(events, fn_name="train_step",
                              warmup_through=1, allowed_steps=(12,))
    assert not report.ok
    assert [e.step for e in report.violations] == [7]
    assert "off-boundary-recompile" in report.summary()


# -- memory budgets (pass 5) -------------------------------------------------

def _mem_codes(report):
    return {v.code for v in report.violations}


def test_audit_memory_every_code_falsifiable_synthetic():
    """One synthetic measurement trips all four named codes at once."""
    m = MemoryMeasurement(argument_bytes=1000, output_bytes=1000,
                          temp_bytes=500, alias_bytes=0)
    budget = MemoryBudget(name="synthetic", max_peak_bytes=1200,
                          max_transient_bytes=300, min_alias_bytes=900,
                          state_plan_bytes=400)
    rep = audit_memory(m, budget, state_bytes=500)
    assert not rep.ok
    assert _mem_codes(rep) == set(MEMORY_VIOLATION_CODES)
    # and the same budget is satisfiable: full aliasing, small temps
    ok = audit_memory(
        MemoryMeasurement(argument_bytes=1000, output_bytes=1000,
                          temp_bytes=100, alias_bytes=950),
        budget, state_bytes=400)
    assert ok.ok, ok.summary()


def test_audit_state_ratio_fails_when_measured_exceeds_plan():
    """The ~20%-vs-AdamW claim as a lint: measured/baseline over the cap
    FAILS; at or under the cap passes."""
    bad = audit_state_ratio("sumo-vs-adamw", 90.0, 100.0, max_ratio=0.80)
    assert not bad.ok and _mem_codes(bad) == {"state-bytes-mismatch"}
    good = audit_state_ratio("sumo-vs-adamw", 70.0, 100.0, max_ratio=0.80)
    assert good.ok


def test_hlo_buffer_table_on_compiled_program():
    """The buffer-table walk and memory_analysis() must agree on a tiny
    donated program: two f32[8,8] params, one aliased into the output."""
    x = jnp.zeros((8, 8), jnp.float32)
    compiled = jax.jit(lambda a, b: a * b + 1.0, donate_argnums=(0,)) \
        .lower(x, x).compile()
    table = hlo_buffer_table(compiled.as_text())
    assert table.param_bytes == (256.0, 256.0)
    assert table.output_bytes == 256.0
    assert table.aliased_params == (0,)
    assert table.alias_bytes == 256.0
    m = measure_compiled_memory(compiled)
    assert m.argument_bytes == 512.0
    assert m.alias_bytes == 256.0
    assert m.table is table or m.table.aliased_params == (0,)
    # peak counts the donated buffer ONCE
    assert m.peak_bytes == m.argument_bytes + m.output_bytes \
        + m.temp_bytes + m.generated_code_bytes - 256.0


@pytest.fixture(scope="module")
def smoke_train():
    """(params, opt_state, batch, step) — the lint smoke recipe, shared
    with the analysis driver so the tests audit the exact same program."""
    from repro.analysis.driver import _smoke_train_setup
    return _smoke_train_setup()


def test_train_step_memory_budget_donated_vs_undonated(smoke_train):
    """Tentpole falsifiability: the donated smoke train step fits its
    steady budget (donation floor = params+state EXACTLY); the SAME program
    compiled WITHOUT donation fails ``donation-not-realized``."""
    from repro.configs import get_smoke_config
    from repro.core.memory import (analytic_activation_bytes,
                                   predict_state_bytes, tree_param_bytes,
                                   tree_state_bytes)

    params, opt_state, batch, step = smoke_train
    batch_bytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(batch))
    budget = steady_memory_budget(
        params, opt_state, batch_bytes=batch_bytes,
        activation_bytes=analytic_activation_bytes(
            get_smoke_config("smollm-360m"), 2, 16),
        state_plan_bytes=predict_state_bytes("sumo", params, rank=4))

    donated = jax.jit(step, donate_argnums=(0, 1)) \
        .lower(params, opt_state, batch).compile()
    rep = audit_memory(measure_compiled_memory(donated), budget,
                       param_bytes=tree_param_bytes(params),
                       state_bytes=tree_state_bytes(opt_state))
    assert rep.ok, rep.summary()

    undonated = jax.jit(step).lower(params, opt_state, batch).compile()
    bad = audit_memory(measure_compiled_memory(undonated), budget,
                       param_bytes=tree_param_bytes(params),
                       state_bytes=tree_state_bytes(opt_state))
    assert not bad.ok
    assert "donation-not-realized" in _mem_codes(bad)


def test_bucket_memory_plan_matches_live_state(smoke_train):
    """The analytic SumoState decomposition must cover the live tree
    EXACTLY — every budget derived from it inherits byte accuracy."""
    from repro.core.memory import tree_state_bytes

    _, opt_state, _, _ = smoke_train
    plan = bucket_memory_plan(opt_state)
    assert plan.entries, "no bucket entries found in SumoState"
    assert plan.total_bytes == tree_state_bytes(opt_state)


def test_serve_decode_memory_budget_falsifiable():
    """ONE oversized compile, both verdicts: a paged ``serve_decode``
    compiled with a 2x KV pool passes the budget built from its OWN
    geometry but fails the PLAN budget with ``peak-bytes-exceeded`` and
    ``transient-exceeds-plan`` — the un-sized-pool bug cannot hide."""
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serve.engine import (PAGED_DECODE_DONATE, ContinuousConfig,
                                    paged_serve_decode_fn,
                                    serve_decode_audit_args)

    cfg = get_smoke_config("smollm-360m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    plan_ccfg = ContinuousConfig(num_slots=4, block_size=8, n_blocks=32,
                                 max_prompt_len=16, max_new_cap=16)
    big_ccfg = ContinuousConfig(num_slots=4, block_size=8, n_blocks=64,
                                max_prompt_len=16, max_new_cap=16)
    fn = paged_serve_decode_fn(cfg)
    compiled = jax.jit(fn, donate_argnums=PAGED_DECODE_DONATE) \
        .lower(*serve_decode_audit_args(cfg, big_ccfg, params)).compile()
    m = measure_compiled_memory(compiled)

    ok = audit_memory(m, serve_decode_memory_budget(cfg, big_ccfg, params))
    assert ok.ok, ok.summary()
    bad = audit_memory(m, serve_decode_memory_budget(cfg, plan_ccfg, params))
    assert not bad.ok
    assert {"peak-bytes-exceeded",
            "transient-exceeds-plan"} <= _mem_codes(bad)


# -- host-dtype lint ---------------------------------------------------------

def test_host_dtype_lint_flags_implicit_dtypes():
    src = (
        "import numpy as np\n"
        "a = np.zeros(4)\n"                      # flagged
        "b = np.zeros(4, np.int32)\n"            # positional dtype: ok
        "c = np.array([1, 2], dtype=np.int32)\n"  # kwarg dtype: ok
        "d = np.asarray(x)\n"                    # dtype-preserving: exempt
        "e = np.full((2, 2), 0.0)\n"             # flagged (dtype is pos 2)
        "f = np.full((2, 2), 0.0, np.float32)\n"  # ok
    )
    v = lint_host_dtype_source(src, "fake.py")
    assert [x.code for x in v] == ["host-buffer-no-dtype"] * 2
    assert {x.where for x in v} == {"fake.py:2", "fake.py:6"}


def test_host_dtype_hot_paths_clean():
    rep = audit_host_dtypes()
    assert rep.ok, rep.summary()


# -- null-block inertness (serving) ------------------------------------------

def test_null_block_proof_and_falsification():
    """Free slots' decode writes provably land in physical block 0; the
    proof genuinely depends on the all-zero-table hypothesis — dropping the
    table claim (a free slot whose table rows were left dirty) breaks it."""
    result = prove_null_block_inertness()
    assert result is not None

    from repro.models.transformer import paged_write_targets
    closed = jax.make_jaxpr(
        lambda t, ln: paged_write_targets(t, ln, 8))(
        jnp.zeros((4, 8), jnp.int32), jnp.zeros((4,), jnp.int32))
    # hypothesis only on lengths, NOT on the table rows
    weakened = analyze_jaxpr(closed, arg_claims=[None, {0: 2}])
    failures = check_claims(weakened, [
        Claim(what="free slots' write block", dim=0, count=2, out_index=0)])
    assert failures, "proof must fail without the zero-table hypothesis"


# -- driver: --json machine-readable report ----------------------------------

def test_driver_json_report_schema(capsys):
    """``python -m repro.analysis --mode 2d --json`` on a single device:
    valid static-analysis-v1 JSON, stable check names, SKIPs (missing
    devices) not counted as failures, exit code 0."""
    import json as _json

    from repro.analysis.driver import REPORT_SCHEMA, main

    rc = main(["--mode", "2d", "--json"])
    rep = _json.loads(capsys.readouterr().out)
    assert rc == 0
    assert rep["schema"] == REPORT_SCHEMA == "static-analysis-v1"
    assert rep["ok"] is True and rep["failed"] == 0
    by_name = {c["name"]: c["status"] for c in rep["checks"]}
    assert by_name["inertness/refresh"] == "PASS"
    assert by_name["collectives/steady-2d"] in ("PASS", "SKIP")
    assert by_name["inertness/update-2d"] in ("PASS", "SKIP")
    assert rep["passed"] + rep["skipped"] + rep["failed"] == len(rep["checks"])
