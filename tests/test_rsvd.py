"""Randomized SVD (Block 1): subspace quality + hypothesis properties.

The property tests need `hypothesis`, which the offline container may not
have: they are gated on its presence (reported as a single importorskip'd
skip when absent) and the deterministic smoke tests below always run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = st = None

from repro.core import (
    randomized_range_finder,
    randomized_svd,
    rsvd_effective_rank,
    subspace_overlap,
    truncated_svd,
)


def _low_rank(key, m, n, r, decay=0.1):
    k1, k2 = jax.random.split(key)
    U = jnp.linalg.qr(jax.random.normal(k1, (m, r)))[0]
    V = jnp.linalg.qr(jax.random.normal(k2, (n, r)))[0]
    s = jnp.exp(-decay * jnp.arange(r)) * 10
    return (U * s[None]) @ V.T


def test_range_finder_captures_low_rank():
    key = jax.random.PRNGKey(0)
    G = _low_rank(key, 128, 64, 8) + 1e-4 * jax.random.normal(key, (128, 64))
    Q = randomized_range_finder(G, key, rank=8)
    assert Q.shape == (128, 8)
    # orthonormal
    np.testing.assert_allclose(np.asarray(Q.T @ Q), np.eye(8), atol=1e-5)
    # captures the range: ‖G − QQᵀG‖ small
    resid = G - Q @ (Q.T @ G)
    assert float(jnp.linalg.norm(resid)) < 1e-2 * float(jnp.linalg.norm(G))


def test_rsvd_matches_truncated_svd():
    key = jax.random.PRNGKey(1)
    G = _low_rank(key, 96, 48, 16, decay=0.4)   # clear spectral gaps
    U1, s1, Vt1 = randomized_svd(G, key, rank=8, n_iter=6, oversample=8)
    U2, s2, Vt2 = truncated_svd(G, rank=8)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-2)
    # reconstruction agreement (the subspace, not individual vectors)
    np.testing.assert_allclose(
        np.asarray((U1 * s1) @ Vt1), np.asarray((U2 * s2) @ Vt2), atol=5e-2
    )


def test_truncation_is_spectral_not_positional():
    """Regression for the Q[:, :rank] truncation bug: QR columns of the
    oversampled sketch are NOT ordered by singular mass, so positional
    truncation can miss top directions outright. A spiked spectrum with the
    spike count equal to the kept rank makes the failure deterministic: the
    fixed truncation (SVD of B = QᵀG) must capture all spikes, while the
    positional slice of the same sketch basis provably leaks mass."""
    key = jax.random.PRNGKey(42)
    m, n, spikes, rank, over = 96, 48, 4, 4, 8
    k1, k2, k3 = jax.random.split(key, 3)
    U = jnp.linalg.qr(jax.random.normal(k1, (m, spikes + over)))[0]
    V = jnp.linalg.qr(jax.random.normal(k2, (n, spikes + over)))[0]
    # 4 dominant spikes + a shelf of near-ties the oversampled sketch drags
    # into its basis in QR (= sketch-column) order, not spectral order
    s = jnp.concatenate([jnp.full((spikes,), 100.0),
                         jnp.full((over,), 1.0)])
    G = (U * s[None]) @ V.T
    # no power iteration: the raw sketch keeps the shelf well-mixed
    Q = randomized_range_finder(G, k3, rank=rank, n_iter=0, oversample=over)
    cap = float(jnp.linalg.norm(Q.T @ G)) / float(jnp.linalg.norm(G))
    # all four spikes captured: energy >= spike mass / total mass
    spike_frac = float(jnp.sqrt(spikes * 100.0**2 / (spikes * 100.0**2 + over)))
    assert cap >= spike_frac - 1e-4, (cap, spike_frac)
    # the OLD truncation on the same sketch: orthonormal basis of the
    # oversampled range, positionally sliced — demonstrably worse
    G32 = G.astype(jnp.float32)
    Omega = jax.random.normal(k3, (n, rank + over), dtype=jnp.float32)
    Q_old = jnp.linalg.qr(G32 @ Omega)[0][:, :rank]
    cap_old = float(jnp.linalg.norm(Q_old.T @ G)) / float(jnp.linalg.norm(G))
    assert cap > cap_old + 1e-3, (cap, cap_old)


def test_rank_above_sketch_width_clamps_consistently():
    """rank > l (sketch width clamped by the short dim) used to silently
    return fewer than `rank` columns — a controller rank-grow on a
    small-short-dim bucket would hand downstream code a mis-shaped Q. All
    factors now clamp to rsvd_effective_rank, consistently."""
    key = jax.random.PRNGKey(7)
    G = jax.random.normal(key, (64, 6))
    r_eff = rsvd_effective_rank(32, 6)
    assert r_eff == 6
    U, s, Vt = randomized_svd(G, key, rank=32, oversample=4)
    assert U.shape == (64, r_eff) and s.shape == (r_eff,) \
        and Vt.shape == (r_eff, 6)
    Q = randomized_range_finder(G, key, rank=32, oversample=4)
    assert Q.shape == (64, r_eff)
    np.testing.assert_allclose(np.asarray(Q.T @ Q), np.eye(r_eff), atol=1e-5)
    # with the full short dim delivered, the factorization is near-exact
    np.testing.assert_allclose(np.asarray(U @ (s[:, None] * Vt)),
                               np.asarray(G), atol=1e-4)
    # a representative non-clamped case is unchanged
    assert rsvd_effective_rank(4, 64) == 4
    assert randomized_range_finder(G, key, rank=4).shape == (64, 4)


def test_rsvd_reuses_range_finder_factorization():
    """randomized_svd's U and randomized_range_finder's Q are the SAME ops in
    the same order (shared _halko_factor) — bit-identical."""
    key = jax.random.PRNGKey(5)
    G = jax.random.normal(key, (80, 40))
    Q = randomized_range_finder(G, key, rank=8)
    U, s, Vt = randomized_svd(G, key, rank=8)
    np.testing.assert_array_equal(np.asarray(Q), np.asarray(U))
    assert s.shape == (8,) and Vt.shape == (8, 40)


def test_subspace_overlap_bounds():
    key = jax.random.PRNGKey(2)
    Q1 = jnp.linalg.qr(jax.random.normal(key, (64, 8)))[0]
    assert abs(float(subspace_overlap(Q1, Q1)) - 1.0) < 1e-5
    Q2 = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1), (64, 8)))[0]
    assert 0.0 <= float(subspace_overlap(Q1, Q2)) <= 1.0


def test_subspace_overlap_mixed_ranks():
    """Regression for the Q1.shape[1]-only normalization: across a rank
    resize (exactly what the PR-3 controller produces) overlap must stay in
    [0, 1] and be symmetric; a contained subspace scores 1."""
    key = jax.random.PRNGKey(3)
    Q12 = jnp.linalg.qr(jax.random.normal(key, (64, 12)))[0]
    Q4 = Q12[:, :4]                       # contained rank-4 subspace
    hi = float(subspace_overlap(Q12, Q4))
    lo = float(subspace_overlap(Q4, Q12))
    assert abs(hi - 1.0) < 1e-5           # old code: 4/12 ≈ 0.33 here
    assert abs(hi - lo) < 1e-6            # symmetric across the resize
    # unrelated bases stay bounded (old code could exceed 1 with r1 < r2)
    Qr = jnp.linalg.qr(
        jax.random.normal(jax.random.fold_in(key, 9), (64, 32)))[0]
    v = float(subspace_overlap(Q4, Qr))
    assert 0.0 <= v <= 1.0 + 1e-6


def _check_range_finder_orthonormal(m, n, r, seed):
    key = jax.random.PRNGKey(seed)
    r = min(r, min(m, n))
    G = jax.random.normal(key, (m, n))
    Q = randomized_range_finder(G, key, rank=r)
    np.testing.assert_allclose(np.asarray(Q.T @ Q), np.eye(r), atol=1e-4)


def _check_rsvd_never_worse_than_noise(seed, r):
    """rSVD rank-r residual ≤ 1.5× optimal rank-r residual (oversampled)."""
    key = jax.random.PRNGKey(seed)
    G = jax.random.normal(key, (64, 32))
    Q = randomized_range_finder(G, key, rank=r, n_iter=3, oversample=6)
    resid = float(jnp.linalg.norm(G - Q @ (Q.T @ G)))
    s = jnp.linalg.svd(G, compute_uv=False)
    opt = float(jnp.sqrt(jnp.sum(s[r:] ** 2)))
    assert resid <= 1.5 * opt + 1e-4


@pytest.mark.parametrize("m,n,r,seed", [
    (16, 96, 1, 0), (96, 16, 8, 1), (33, 47, 5, 2), (64, 64, 8, 3),
])
def test_smoke_range_finder_orthonormal(m, n, r, seed):
    """Deterministic replay of the orthonormality property (no hypothesis)."""
    _check_range_finder_orthonormal(m, n, r, seed)


@pytest.mark.parametrize("seed,r", [(0, 2), (7, 10), (1234, 5)])
def test_smoke_rsvd_never_worse_than_noise(seed, r):
    """Deterministic replay of the residual-bound property (no hypothesis)."""
    _check_rsvd_never_worse_than_noise(seed, r)


if hypothesis is not None:
    @hypothesis.given(
        m=st.integers(16, 96), n=st.integers(16, 96),
        r=st.integers(1, 8), seed=st.integers(0, 2**16),
    )
    @hypothesis.settings(max_examples=15, deadline=None)
    def test_property_range_finder_orthonormal(m, n, r, seed):
        _check_range_finder_orthonormal(m, n, r, seed)

    @hypothesis.given(seed=st.integers(0, 2**16), r=st.integers(2, 10))
    @hypothesis.settings(max_examples=15, deadline=None)
    def test_property_rsvd_never_worse_than_noise(seed, r):
        _check_rsvd_never_worse_than_noise(seed, r)
else:
    def test_property_suite_requires_hypothesis():
        pytest.importorskip("hypothesis")
