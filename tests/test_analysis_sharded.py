"""8-device end-to-end checks for the repro.analysis static passes.

These need real multi-device meshes, so they skip under the default
single-device tier-1 run and execute via (a) the slow subprocess wrapper at
the bottom or (b) the sharded tier-1 invocation in tools/run_tier1.sh.

What is pinned here:
  * the pad-inertness prover establishes the FULL bucketed update's
    invariant on both mesh shapes — edge-pad rows (2D, ragged long) and
    masked pad B-slots (1D, ragged B) are exactly zero in the outgoing
    state and the gathered deltas;
  * the concatenate-seam regression (satellite of the PR 5 bugfix): a
    ragged stack re-assembled with `concatenate` instead of Pad makes
    GSPMD move a full (B, long, short) all-reduce, and the steady-2d
    budget REJECTS it with named violations, while the Pad version of the
    same computation compiles to zero collectives;
  * the analysis driver's 2D lane (`python -m repro.analysis --mode 2d`)
    is green end to end — the same entry point tier-1 pass 4 invokes.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from jax.sharding import NamedSharding, PartitionSpec as P

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _mesh24():
    return jax.make_mesh((2, 4), ("data", "model"))


@needs_8_devices
def test_update_inertness_2d_ragged_long():
    """Machine proof over the real 2D bucketed update jaxpr: assuming the
    incoming Q pad rows are zero (true at init), the outgoing Q pad rows
    and the gathered delta pad rows are exactly zero."""
    from repro.analysis.inertness import prove_update_inertness
    from repro.core import SumoConfig

    params = {f"r{i}": jax.ShapeDtypeStruct((102, 16), "float32")
              for i in range(3)}
    cfg = SumoConfig(rank=4, update_freq=2, rsvd_oversample=4,
                     weight_decay=0.05)
    result = prove_update_inertness(params, cfg, mesh=_mesh24())
    assert result.records, "expected a shard_map region in the update"


@needs_8_devices
def test_update_inertness_1d_ragged_b():
    """1D mesh, B % data != 0: the masked pad B-slots stay exactly zero
    through the update (up to the one-shard-block abstraction limit the
    prover documents)."""
    from repro.analysis.inertness import prove_update_inertness
    from repro.core import SumoConfig

    mesh = jax.make_mesh((8,), ("data",))
    params = {f"l{i}": jax.ShapeDtypeStruct((64, 32), "float32")
              for i in range(9)}  # B=9 on 8 shards -> 7 pad slots
    cfg = SumoConfig(rank=4, update_freq=2, rsvd_oversample=4)
    prove_update_inertness(params, cfg, mesh=mesh)


@needs_8_devices
def test_update_inertness_fails_on_false_claim():
    """NEGATIVE: claiming MORE pad rows than the bucket actually has must
    raise — the prover is checking something, not rubber-stamping."""
    from repro.analysis.inertness import Claim, analyze_jaxpr, check_claims
    from repro.core import SumoConfig
    from repro.core.sumo import update_closed_jaxpr

    params = {f"r{i}": jax.ShapeDtypeStruct((102, 16), "float32")
              for i in range(3)}
    cfg = SumoConfig(rank=4, update_freq=2, rsvd_oversample=4)
    trace = update_closed_jaxpr(params, cfg, _mesh24(), 0.01)
    result = analyze_jaxpr(trace.closed_jaxpr, arg_claims=trace.arg_claims)
    [entry] = trace.plan
    overclaim = Claim(
        what="more pad rows than exist", dim=1,
        count=entry["long_padded"] - entry["long"] + 10,
        out_index=entry["q_out_index"])
    failures = check_claims(result, [overclaim])
    assert failures and "more pad rows than exist" in failures[0]


@needs_8_devices
def test_concat_seam_rejected_by_budget():
    """The PR 5 seam, as a machine-checked regression: re-zeroing a ragged
    2D stack's pad rows with `concatenate` (seam crossing the last model
    shard) makes GSPMD emit a full (B, ~long, short) all-reduce; the SAME
    steady-2d budget that accepts the real engine rejects it with named
    violations. The Pad formulation compiles to zero collectives."""
    from repro.analysis.collectives import (
        BudgetError,
        assert_budget,
        audit_hlo,
        bucket_collective_plan,
        steady_2d_budget,
    )
    from repro.core import SumoConfig, padded_long, sumo

    mesh = _mesh24()
    key = jax.random.PRNGKey(5)
    params = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), (102, 16))
              for i in range(4)}
    rank, over = 4, 4
    tx = sumo(0.01, SumoConfig(rank=rank, update_freq=4,
                               rsvd_oversample=over), mesh=mesh)
    state = tx.init(params)
    plan = bucket_collective_plan(state, mesh)
    budget = steady_2d_budget(plan, rank_plus_over=rank + over,
                              data_shards=int(mesh.shape["data"]))

    lp = padded_long(102, 4)                    # 104, divisible by model=4
    sh = NamedSharding(mesh, P("data", "model", None))
    stack = jnp.ones((4, lp, 16))

    def repad_with_pad(x):                      # what the engine does
        return jnp.pad(x[:, :102, :], ((0, 0), (0, lp - 102), (0, 0)))

    def repad_with_concat(x):                   # the pre-fix seam
        z = jnp.zeros((4, lp - 102, 16), x.dtype)
        return jnp.concatenate([x[:, :102, :], z], axis=1)

    def compile_text(f):
        return jax.jit(f, in_shardings=sh, out_shardings=sh).lower(
            stack).compile().as_text()

    good = assert_budget(compile_text(repad_with_pad), budget)
    assert good.ok and not good.collectives    # Pad partitions locally

    report = audit_hlo(compile_text(repad_with_concat), budget)
    assert not report.ok
    codes = {v.code for v in report.violations}
    assert "panel-width-exceeded" in codes and "op-bytes-exceeded" in codes
    assert all(v.kind == "all-reduce" for v in report.violations)
    with pytest.raises(BudgetError, match="panel-width-exceeded"):
        assert_budget(compile_text(repad_with_concat), budget)


@needs_8_devices
def test_driver_2d_lane_green():
    """`python -m repro.analysis --mode 2d` — the tier-1 pass-4 entry point
    — runs all its checks green on an 8-device backend."""
    from repro.analysis.driver import run

    lines = []
    assert run("2d", log=lines.append) == 0
    out = "\n".join(lines)
    assert "[PASS] collectives/steady-2d" in out
    assert "[PASS] inertness/update-2d" in out
    assert "FAIL" not in out


@pytest.mark.slow
@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="already running with 8 devices")
def test_subprocess_8_device_suite():
    """Run the in-process tests above on a forced 8-host-device CPU backend
    (the main pytest process must keep 1 device — see tests/conftest.py)."""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         "tests/test_analysis_sharded.py", "-k", "not subprocess"],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
