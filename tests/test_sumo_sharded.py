"""shard_map bucket updates on a multi-device CPU mesh.

The in-process tests need 8 devices, so they skip under the default
single-device tier-1 run and execute via either (a) the slow subprocess
wrapper at the bottom (plain `pytest` covers everything) or (b) the second
tier-1 invocation in tools/run_tier1.sh, which re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

What is pinned here:
  * the shard_map bucket update is bit-identical to the single-device
    bucketed engine across a refresh boundary (the rSVD sketch, projection,
    moment and orthogonalization are all per-matrix, so sharding B changes
    nothing);
  * RAGGED buckets (B % axis_size != 0, e.g. an odd layer count) run under
    shard_map via masked zero-padding slots and still bit-match — only
    singleton (B == 1) buckets keep the vmap fallback;
  * steady state moves NO optimizer state across devices: the only
    collective in the compiled update is the explicit all-gather of the
    delta stacks (asserted via the roofline HLO cost parser);
  * spectral telemetry probes (SumoConfig.telemetry) are bit-identical
    between the sharded and unsharded engines (per-matrix stats are
    all-gathered and reduced by the same host-visible code path).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _params(key):
    """8× (64, 32) leaves + an expert stack -> B=16 bucket (divides 8);
    a lone wide leaf -> B=1 bucket (does NOT divide 8: vmap fallback)."""
    p = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), (64, 32))
         for i in range(8)}
    p["experts"] = jax.random.normal(jax.random.fold_in(key, 50), (8, 32, 64))
    p["wide"] = jax.random.normal(jax.random.fold_in(key, 99), (16, 48))
    return p


def _run(tx, params, grads, steps):
    state = tx.init(params)
    out = []
    for _ in range(steps):
        u, state = tx.update(grads, state, params)
        out.append(u)
    return out, state


@needs_8_devices
@pytest.mark.parametrize("refresh_quality", [0.0, 0.5],
                         ids=["cadence-only", "adaptive"])
def test_shard_map_matches_single_device(refresh_quality):
    """5 steps with update_freq=3 (refresh boundary at step 3): bit-identical
    deltas and state vs the unsharded bucketed engine, including the
    pmax-combined adaptive-refresh predicate."""
    from repro.core import SumoConfig, sumo

    mesh = jax.make_mesh((8,), ("data",))
    params = _params(jax.random.PRNGKey(0))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=8, update_freq=3, weight_decay=0.05,
                     refresh_quality=refresh_quality)

    us, ss = _run(sumo(0.01, cfg, mesh=mesh), params, grads, 5)
    up, sp = _run(sumo(0.01, cfg), params, grads, 5)

    for step, (a, b) in enumerate(zip(us, up)):
        for k in params:
            np.testing.assert_array_equal(
                np.asarray(a[k]), np.asarray(b[k]),
                err_msg=f"step {step} leaf {k}")
    for fa, fb in zip(jax.tree_util.tree_leaves(ss), jax.tree_util.tree_leaves(sp)):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


@needs_8_devices
@pytest.mark.parametrize("refresh_quality", [0.0, 0.5],
                         ids=["cadence-only", "adaptive"])
def test_ragged_bucket_pads_and_matches(refresh_quality):
    """Odd layer count: 5× (64, 32) leaves -> a B=5 bucket on an 8-device
    axis. The shard_map path pads to B=8 with masked zero slots (which must
    NOT trip the adaptive-refresh predicate) and stays bit-identical to the
    unsharded engine across a refresh boundary."""
    from repro.core import SumoConfig, sumo

    mesh = jax.make_mesh((8,), ("data",))
    key = jax.random.PRNGKey(3)
    params = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), (64, 32))
              for i in range(5)}
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=8, update_freq=3, weight_decay=0.05,
                     refresh_quality=refresh_quality)

    us, ss = _run(sumo(0.01, cfg, mesh=mesh), params, grads, 5)
    up, sp = _run(sumo(0.01, cfg), params, grads, 5)

    for step, (a, b) in enumerate(zip(us, up)):
        for k in params:
            np.testing.assert_array_equal(
                np.asarray(a[k]), np.asarray(b[k]),
                err_msg=f"step {step} leaf {k}")
    for fa, fb in zip(jax.tree_util.tree_leaves(ss),
                      jax.tree_util.tree_leaves(sp)):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    assert ss.Q["64x32"].shape == (5, 64, 8)   # state itself is NOT padded


@needs_8_devices
def test_sharded_telemetry_stats_match_unsharded():
    """SpectralStats from the shard_map path (per-matrix stats all-gathered,
    reduced outside the shard) are bit-identical to the unsharded engine's,
    for divisible, ragged and fallback-singleton buckets alike."""
    from repro.core import SumoConfig, sumo

    mesh = jax.make_mesh((8,), ("data",))
    key = jax.random.PRNGKey(4)
    params = _params(key)                       # B=16, B=1 buckets
    params["ragged"] = jax.random.normal(jax.random.fold_in(key, 7), (3, 80, 24))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=8, update_freq=3, telemetry=True)

    _, ss = _run(sumo(0.01, cfg, mesh=mesh), params, grads, 4)
    _, sp = _run(sumo(0.01, cfg), params, grads, 4)
    assert set(ss.stats) == set(sp.stats) == {"64x32", "48x16", "80x24"}
    for bucket in ss.stats:
        for field, a, b in zip(ss.stats[bucket]._fields, ss.stats[bucket],
                               sp.stats[bucket]):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{bucket}.{field}")


@needs_8_devices
def test_sharded_state_is_resident_no_unexpected_collectives():
    """Compile the sharded update with the bucket state placed by
    opt_state_specs (B over `data`): the steady-state HLO's ONLY collective
    is the explicit all-gather of the sharded buckets' delta stacks —
    Q/M/prev_norm never cross devices, and nothing all-reduces."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.analysis.collectives import (
        assert_budget,
        bucket_collective_plan,
        delta_bytes,
        steady_1d_budget,
    )
    from repro.core import SumoConfig, sumo
    from repro.parallel import opt_state_specs

    mesh = jax.make_mesh((8,), ("data",))
    params = _params(jax.random.PRNGKey(1))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    tx = sumo(0.01, SumoConfig(rank=8, update_freq=4, weight_decay=0.05),
              mesh=mesh)
    state = tx.init(params)

    named = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    st_sh = named(opt_state_specs(state, mesh))
    # the B axis of every divisible bucket stack is data-sharded
    assert st_sh.Q["64x32"].spec == P("data", None, None)
    assert st_sh.prev_norm["64x32"].spec == P("data")
    rep = NamedSharding(mesh, P())
    g_sh = jax.tree_util.tree_map(lambda _: rep, grads)

    compiled = jax.jit(
        lambda g, s, p: tx.update(g, s, p),
        in_shardings=(g_sh, st_sh, g_sh),
    ).lower(grads, state, params).compile()

    # the declarative budget (shared with tools/lint_static.py and
    # benchmarks/step_time.py): only the sharded buckets' delta all-gathers
    # may appear, bounded by their padded delta bytes
    plan = bucket_collective_plan(state, mesh)
    report = assert_budget(compiled.as_text(), steady_1d_budget(plan))
    assert report.total_bytes > 0
    # the wide B=1 bucket keeps the vmap fallback: not in the gather plan
    assert not [e for e in plan if e.key == "48x16" and e.sharded]
    # plan-derived bound matches the old hand computation (fp32 deltas of
    # every sharded bucket; divisible buckets pad nothing)
    sharded_delta_bytes = sum(
        int(np.prod(v.shape)) * 4 for k, v in params.items() if k != "wide")
    assert delta_bytes(plan) == sharded_delta_bytes


@needs_8_devices
def test_sharded_update_under_jit_close_to_eager():
    """jit with sharded state in/out stays numerically equivalent. Bit
    parity only holds within a compilation mode (eager-vs-eager is pinned
    above); across modes XLA's fusion/FMA reassociation moves the last ulp,
    so this asserts tight allclose instead."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import SumoConfig, sumo
    from repro.parallel import opt_state_specs

    mesh = jax.make_mesh((8,), ("data",))
    params = _params(jax.random.PRNGKey(2))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    tx = sumo(0.01, SumoConfig(rank=8, update_freq=4), mesh=mesh)
    state = tx.init(params)
    named = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    st_sh = named(opt_state_specs(state, mesh))
    rep = NamedSharding(mesh, P())
    g_sh = jax.tree_util.tree_map(lambda _: rep, grads)
    u_j, s_j = jax.jit(lambda g, s, p: tx.update(g, s, p),
                       in_shardings=(g_sh, st_sh, g_sh))(grads, state, params)
    u_e, s_e = tx.update(grads, state, params)
    for k in params:
        np.testing.assert_allclose(np.asarray(u_j[k]), np.asarray(u_e[k]),
                                   atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(s_j),
                    jax.tree_util.tree_leaves(s_e)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.slow
@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="already running with 8 devices")
def test_subprocess_8_device_suite():
    """Run the in-process tests above on a forced 8-host-device CPU backend
    (the main pytest process must keep 1 device — see tests/conftest.py)."""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         "tests/test_sumo_sharded.py", "-k", "not subprocess"],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
