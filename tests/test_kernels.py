"""Pallas kernels vs ref.py oracles — shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import backproject, flash_attention, newton_schulz5, project
from repro.kernels import ref

DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", [(8, 64), (16, 128), (32, 256), (4, 32)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_ns5_kernel(shape, dtype):
    M = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    out = newton_schulz5(M)
    expect = ref.ns5_ref(M)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol
    )


@pytest.mark.parametrize("batch", [1, 3])
def test_ns5_kernel_batched(batch):
    M = jax.random.normal(jax.random.PRNGKey(1), (batch, 8, 64))
    np.testing.assert_allclose(
        np.asarray(newton_schulz5(M)), np.asarray(ref.ns5_ref(M)), atol=1e-5
    )


@pytest.mark.parametrize("m,r,n", [(512, 16, 300), (1000, 8, 128), (2048, 64, 700),
                                   (100, 4, 50)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_projection_kernel(m, r, n, dtype):
    key = jax.random.PRNGKey(2)
    Q = jax.random.normal(key, (m, r)).astype(dtype)
    G = jax.random.normal(jax.random.fold_in(key, 1), (m, n)).astype(dtype)
    out = project(Q, G, block_m=256, block_n=128)
    expect = ref.project_ref(Q, G)
    tol = 2e-3 * np.sqrt(m) if dtype == jnp.float32 else 0.5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol
    )


@pytest.mark.parametrize("m,r,n", [(512, 16, 300), (100, 4, 50)])
def test_backprojection_kernel(m, r, n):
    key = jax.random.PRNGKey(3)
    Q = jax.random.normal(key, (m, r))
    O = jax.random.normal(jax.random.fold_in(key, 1), (r, n))
    np.testing.assert_allclose(
        np.asarray(backproject(Q, O, block_m=256, block_n=128)),
        np.asarray(ref.backproject_ref(Q, O)), atol=1e-4,
    )


@pytest.mark.parametrize("L,H,KV,hd", [(256, 4, 2, 64), (130, 2, 2, 32),
                                        (512, 8, 1, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel(L, H, KV, hd, causal):
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, L, H, hd))
    k = jax.random.normal(ks[1], (2, L, KV, hd))
    v = jax.random.normal(ks[2], (2, L, KV, hd))
    out = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


def test_flash_kernel_sliding_window():
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 300, 4, 32))
    k = jax.random.normal(ks[1], (1, 300, 2, 32))
    v = jax.random.normal(ks[2], (1, 300, 2, 32))
    out = flash_attention(q, k, v, causal=True, sliding_window=64,
                          block_q=128, block_k=128)
    expect = ref.flash_attention_ref(q, k, v, causal=True, sliding_window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


def test_flash_kernel_bf16():
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=5e-2
    )
