"""Continuous-batching serving: allocator/scheduler invariants, paged/slot
state isolation, static-vs-continuous greedy parity, the zero-recompile slot
contract, and the serving telemetry round trip (SERVING.md)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.recompile import CompileWatcher, audit_recompiles
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import (
    NULL_BLOCK,
    SERVE_DECODE_FN,
    BlockPool,
    ContinuousConfig,
    ContinuousEngine,
    Request,
    RequestState,
    Scheduler,
    ServeConfig,
    StaticEngine,
    blocks_for_request,
    bucket_len,
    serving_kind,
)
from repro.telemetry import JsonlWriter, TelemetrySink, read_jsonl
from repro.telemetry.serving import (
    serving_record,
    serving_stats_to_records,
    validate_serving_record,
)

_PARAMS = {}


def _setup(arch_id, seed=0):
    cfg = get_smoke_config(arch_id)
    if arch_id not in _PARAMS:
        _PARAMS[arch_id] = init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, _PARAMS[arch_id]


def _ccfg(**kw):
    base = dict(num_slots=3, block_size=4, n_blocks=16,
                max_prompt_len=12, max_new_cap=8)
    base.update(kw)
    return ContinuousConfig(**base)


# ---------------------------------------------------------------------------
# BlockPool (pure Python)
# ---------------------------------------------------------------------------

def test_pool_never_hands_out_null_block_and_cannot_fragment():
    pool = BlockPool(n_blocks=9, block_size=4)
    assert pool.capacity == 8
    rng = np.random.default_rng(0)
    held = []
    # random alloc/free interleaving: alloc(n) must succeed iff n <= num_free
    # (table indirection means any free block serves any request)
    for _ in range(200):
        if held and rng.random() < 0.5:
            pool.free(held.pop(rng.integers(len(held))))
        n = int(rng.integers(1, 4))
        got = pool.alloc(n)
        if n <= 8 - sum(len(h) for h in held):
            assert got is not None and len(got) == n
            assert NULL_BLOCK not in got
            held.append(got)
        else:
            assert got is None
    flat = [b for h in held for b in h]
    assert len(flat) == len(set(flat))          # no block handed out twice
    assert pool.num_free + pool.num_allocated == pool.capacity


def test_pool_exhaustion_returns_none_without_side_effect():
    pool = BlockPool(n_blocks=4, block_size=2)
    assert pool.alloc(3) is not None
    before = pool.num_free
    assert pool.alloc(1) is None
    assert pool.num_free == before


def test_pool_free_rejects_null_double_and_foreign_blocks():
    pool = BlockPool(n_blocks=4, block_size=2)
    ids = pool.alloc(2)
    pool.free(ids)
    with pytest.raises(ValueError):
        pool.free(ids)                          # double free
    with pytest.raises(ValueError):
        pool.free([NULL_BLOCK])
    pool.alloc(1)
    with pytest.raises(ValueError):
        pool.free([99])                         # never allocated


# ---------------------------------------------------------------------------
# Scheduler (pure Python)
# ---------------------------------------------------------------------------

def _req(rid, cost_tokens=4, max_new=4):
    return Request(rid=rid, prompt=np.ones(cost_tokens, np.int32),
                   max_new_tokens=max_new)


def _mk_sched(num_slots=2, n_blocks=9, per_req=2):
    pool = BlockPool(n_blocks=n_blocks, block_size=4)
    return Scheduler(num_slots, pool, lambda r: per_req), pool


def test_scheduler_fifo_admission_and_slot_recycling():
    sched, pool = _mk_sched(num_slots=2, per_req=2)
    for i in range(4):
        sched.submit(_req(i))
    admitted = sched.admit()
    assert [r.rid for r in admitted] == [0, 1]          # strict FIFO
    assert {r.slot for r in admitted} == {0, 1}
    assert all(r.state is RequestState.PREFILL for r in admitted)
    assert sched.admit() == []                          # no free slots
    freed_slot = admitted[0].slot
    sched.release(admitted[0])
    assert admitted[0].state is RequestState.DONE
    nxt = sched.admit()
    assert [r.rid for r in nxt] == [2]
    assert nxt[0].slot == freed_slot                    # slot recycled
    assert pool.num_allocated == 4                      # 2 live requests


def test_scheduler_head_of_line_blocks_until_blocks_free():
    # 4 usable blocks; big request (rid 1) needs 3, the others need 1
    pool = BlockPool(n_blocks=5, block_size=4)
    external = pool.alloc(2)                            # pool pressure
    sched = Scheduler(3, pool, lambda r: 3 if r.rid == 1 else 1)
    for i in range(3):
        sched.submit(_req(i))
    assert [r.rid for r in sched.admit()] == [0]        # 1 free block left
    assert sched.queue_depth == 2                       # head (needs 3) waits
    sched.release(sched.active[0])
    assert sched.admit() == []                          # 2 free: still waits,
    assert sched.queue_depth == 2                       # rid 2 NOT bypassed
    pool.free(external)                                 # pressure released
    assert [r.rid for r in sched.admit()] == [1, 2]


def test_scheduler_rejects_never_fitting_request_at_submit():
    sched, _ = _mk_sched(n_blocks=3, per_req=99)
    with pytest.raises(ValueError):
        sched.submit(_req(0))


def test_blocks_for_request_worst_case():
    cfg, _ = _setup("smollm-360m")
    # bucketed prompt 5->8, + 7 generated = 15 tokens -> 4 blocks of 4
    assert blocks_for_request(cfg, 5, 7, 4) == 4
    xcfg = get_smoke_config("xlstm-1.3b")
    assert blocks_for_request(xcfg, 5, 7, 4) == 1       # degenerate slot state
    with pytest.raises(ValueError):
        bucket_len(0, 4)


# ---------------------------------------------------------------------------
# engine: parity, isolation, recompiles, pool hygiene
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id", ["smollm-360m", "xlstm-1.3b", "zamba2-7b"])
def test_continuous_matches_static_greedy(arch_id):
    """Same-arrival batch, equal block-multiple prompt lengths, temp 0:
    the continuous engine must reproduce the static engine token-for-token —
    including requests that queue and join only after earlier ones retire."""
    cfg, params = _setup(arch_id)
    eng = ContinuousEngine(cfg, params, _ccfg())
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab, size=8).astype(np.int32)
               for _ in range(5)]
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    res = eng.run()
    static = np.asarray(
        StaticEngine(cfg, params, ServeConfig(max_new_tokens=6))
        .generate(jnp.asarray(np.stack(prompts))))
    for i in range(5):
        assert res[i].tolist() == static[i].tolist(), f"request {i} diverged"


@pytest.mark.parametrize("arch_id", [
    "smollm-360m",
    "xlstm-1.3b",
    pytest.param("mixtral-8x22b", marks=pytest.mark.xfail(
        reason="expert-capacity coupling: MoE capacity dispatch is a "
               "function of ALL co-batched slot tokens, so a neighbor slot "
               "joining can reroute/drop this request's expert assignment "
               "(see SERVING.md); pinned here so the coupling is a named "
               "xfail, not an undocumented gap", strict=False)),
])
def test_request_isolation_under_churn(arch_id):
    """A request's tokens must be identical served solo vs served while
    neighbor slots join, generate and retire around it (no cross-slot leak
    through the pool/store). The dense + recurrent lanes must hold exactly;
    the MoE lane is an explicit xfail — expert capacity couples co-batched
    tokens by design (not a pool/store leak)."""
    cfg, params = _setup(arch_id)
    rng = np.random.default_rng(3)
    target = rng.integers(1, cfg.vocab, size=8).astype(np.int32)

    solo = ContinuousEngine(cfg, params, _ccfg())
    solo.submit(target, max_new_tokens=8)
    want = solo.run()[0].tolist()

    churn = ContinuousEngine(cfg, params, _ccfg())
    rid = churn.submit(target, max_new_tokens=8)
    # neighbors with different lengths/budgets join and retire mid-flight
    for i in range(6):
        churn.submit(rng.integers(1, cfg.vocab, size=int(rng.integers(1, 12))),
                     max_new_tokens=int(rng.integers(1, 5)),
                     temperature=0.7)
    got = churn.run()[rid].tolist()
    assert got == want


@pytest.mark.parametrize("arch_id", ["smollm-360m", "xlstm-1.3b", "zamba2-7b"])
def test_zero_recompiles_after_warmup_and_pool_drains(arch_id):
    """The slot contract: after the first decode compile, joins/evictions/
    mixed lengths/mixed temperatures cause ZERO further serve_decode
    compiles; when the queue drains, every block returns to the pool."""
    cfg, params = _setup(arch_id)
    eng = ContinuousEngine(cfg, params, _ccfg())
    rng = np.random.default_rng(4)
    with CompileWatcher(fn_name=SERVE_DECODE_FN) as w:
        for _ in range(7):
            eng.submit(rng.integers(1, cfg.vocab, size=int(rng.integers(1, 12))),
                       max_new_tokens=int(rng.integers(1, 8)),
                       temperature=float(rng.choice([0.0, 0.9])))
        eng.run()
        for _ in range(3):                       # second wave after idle
            eng.submit(rng.integers(1, cfg.vocab, size=6), max_new_tokens=3)
        eng.run()
    rep = audit_recompiles(w.events, fn_name=SERVE_DECODE_FN, warmup_through=0)
    assert rep.ok, rep.summary()
    assert len(rep.compiles) == 1, [e.message for e in w.events]
    assert eng.pool.num_free == eng.pool.capacity
    assert eng.scheduler.num_active == 0 and eng.scheduler.queue_depth == 0
    assert sorted(eng.results) == list(range(10))


def test_per_request_sampling_params_are_honored():
    """Greedy and sampled requests coexist in one batch; equal seeds give
    equal streams, different seeds differ (same prompt, temp > 0)."""
    cfg, params = _setup("smollm-360m")
    eng = ContinuousEngine(cfg, params, _ccfg(num_slots=4))
    p = np.arange(1, 9, dtype=np.int32)
    r_greedy = eng.submit(p, max_new_tokens=8, temperature=0.0)
    r_a = eng.submit(p, max_new_tokens=8, temperature=1.5, seed=7)
    r_b = eng.submit(p, max_new_tokens=8, temperature=1.5, seed=7)
    r_c = eng.submit(p, max_new_tokens=8, temperature=1.5, seed=8)
    res = eng.run()
    static = np.asarray(
        StaticEngine(cfg, params, ServeConfig(max_new_tokens=8))
        .generate(jnp.asarray(p)[None]))[0]
    assert res[r_greedy].tolist() == static.tolist()
    assert res[r_a].tolist() == res[r_b].tolist()
    assert res[r_a].tolist() != res[r_c].tolist()


def test_admission_control_refuses_oversized_and_engine_validates():
    cfg, params = _setup("smollm-360m")
    eng = ContinuousEngine(cfg, params,
                           _ccfg(n_blocks=4, max_prompt_len=12, max_new_cap=8))
    with pytest.raises(ValueError):
        eng.submit(np.ones(13, np.int32))        # prompt too long
    with pytest.raises(ValueError):
        eng.submit(np.ones(4, np.int32), max_new_tokens=9)
    with pytest.raises(ValueError):              # can never fit in 3 blocks
        eng.submit(np.ones(12, np.int32), max_new_tokens=8)


def test_serve_config_instances_are_independent():
    """Regression: a shared mutable default ServeConfig would alias every
    engine's settings to one object."""
    cfg, params = _setup("smollm-360m")
    a = StaticEngine(cfg, params)
    b = StaticEngine(cfg, params)
    assert a.scfg is not b.scfg
    a.scfg.max_new_tokens = 99
    assert b.scfg.max_new_tokens != 99


# ---------------------------------------------------------------------------
# serving telemetry
# ---------------------------------------------------------------------------

def test_serving_record_schema_validation():
    rec = serving_record(step=1, event="ttft", request_id=0, t=1.0,
                         value=0.5, queue_depth=0, active_slots=1,
                         free_blocks=3)
    validate_serving_record(rec)
    with pytest.raises(ValueError):
        validate_serving_record({**rec, "event": "nonsense"})
    with pytest.raises(ValueError):
        validate_serving_record({k: v for k, v in rec.items() if k != "t"})
    with pytest.raises(ValueError):
        validate_serving_record({**rec, "extra": 1})


def test_engine_streams_telemetry_through_sink(tmp_path):
    """End to end: engine -> TelemetrySink(serving schema) -> JSONL ->
    read_jsonl round trip, with every lifecycle event present per request."""
    cfg, params = _setup("smollm-360m")
    out = tmp_path / "serve.jsonl"
    sink = TelemetrySink(writers=[JsonlWriter(str(out))],
                         to_records=serving_stats_to_records,
                         validate_fn=validate_serving_record)
    eng = ContinuousEngine(cfg, params, _ccfg(), sink=sink)
    rids = [eng.submit(np.ones(4, np.int32), max_new_tokens=3)
            for _ in range(4)]
    eng.run()
    sink.close()
    recs = read_jsonl(str(out))
    assert recs and sink.records_written == len(recs)
    for rec in recs:
        validate_serving_record(rec)
        json.dumps(rec)                          # JSON-clean types
    by_event = {}
    for rec in recs:
        by_event.setdefault(rec["event"], []).append(rec)
    for ev in ("queued", "prefill", "ttft", "finish"):
        assert sorted(r["request_id"] for r in by_event[ev]) == sorted(rids)
    assert by_event["decode_step"], "no decode_step records"
    # gauges must reflect the drained end state on the last finish record
    last_finish = by_event["finish"][-1]
    assert last_finish["active_slots"] == 0
    assert last_finish["free_blocks"] == eng.pool.capacity


# ---------------------------------------------------------------------------
# benchmark schema
# ---------------------------------------------------------------------------

def test_bench_schema_validator_rejects_malformed():
    import importlib.util
    import pathlib
    import sys
    spec = importlib.util.spec_from_file_location(
        "serving_bench",
        pathlib.Path(__file__).resolve().parents[1] / "benchmarks/serving.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules["serving_bench"] = mod       # dataclasses need the registry
    spec.loader.exec_module(mod)
    metrics = {k: 1.0 for k in mod.ENGINE_METRIC_KEYS}
    good = {"schema": mod.SCHEMA, "smoke": True, "archs": {
        "a": {"family": "dense", "kind": "paged", "trace": {},
              "engines": {"continuous": dict(metrics), "static": dict(metrics)},
              "recompile_audit": {"ok": True, "decode_compiles": 1},
              "continuous_wins": True}}}
    mod.validate_bench(good)
    with pytest.raises(ValueError):
        mod.validate_bench({**good, "schema": "nope"})
    bad = json.loads(json.dumps(good))
    del bad["archs"]["a"]["engines"]["static"]
    with pytest.raises(ValueError):
        mod.validate_bench(bad)
    bad2 = json.loads(json.dumps(good))
    bad2["archs"]["a"]["engines"]["continuous"]["tok_per_s"] = "fast"
    with pytest.raises(ValueError):
        mod.validate_bench(bad2)


def test_serving_kind_split():
    assert serving_kind(get_smoke_config("smollm-360m")) == "paged"
    assert serving_kind(get_smoke_config("mixtral-8x22b")) == "paged"
    assert serving_kind(get_smoke_config("xlstm-1.3b")) == "slot"
    assert serving_kind(get_smoke_config("zamba2-7b")) == "slot"
    with pytest.raises(ValueError):
        serving_kind(get_smoke_config("hubert-xlarge"))  # encoder-only
