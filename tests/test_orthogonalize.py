"""Orthogonalization operators: exactness, the paper's Lemma 3.2 error bound,
and hypothesis property tests.

Property tests are gated on `hypothesis` being importable (the offline
container lacks it); the deterministic smoke replays below always run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = st = None

from repro.core import (
    condition_number,
    newton_schulz5,
    newton_schulz_cubic,
    orthogonality_error,
    orthogonalize_polar,
    orthogonalize_svd,
    rank_one_residual,
)

SHAPES = [(4, 16), (16, 16), (16, 64), (64, 16), (128, 96)]


@pytest.mark.parametrize("shape", SHAPES)
def test_polar_equals_svd(shape):
    M = jax.random.normal(jax.random.PRNGKey(0), shape)
    np.testing.assert_allclose(
        orthogonalize_polar(M), orthogonalize_svd(M), atol=5e-5
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_polar_is_orthogonal(shape):
    M = jax.random.normal(jax.random.PRNGKey(1), shape)
    O = orthogonalize_polar(M)
    assert float(orthogonality_error(O)) < 1e-5


def test_polar_rank_deficient():
    """Rank-deficient input: zero directions are dropped, not amplified."""
    key = jax.random.PRNGKey(2)
    A = jax.random.normal(key, (8, 3))
    B = jax.random.normal(jax.random.fold_in(key, 1), (3, 32))
    M = A @ B                      # rank 3, shape (8, 32)
    O = orthogonalize_polar(M)
    s = jnp.linalg.svd(O, compute_uv=False)
    # top-3 singular values ~1, rest ~0
    np.testing.assert_allclose(s[:3], 1.0, atol=1e-3)
    assert float(s[3]) < 1e-3


def test_ns5_error_grows_with_condition_number():
    """Lemma 3.2: NS error increases with κ — the paper's core motivation."""
    key = jax.random.PRNGKey(3)
    errs = []
    for kappa in (2.0, 50.0, 5000.0):
        U, _ = jnp.linalg.qr(jax.random.normal(key, (32, 32)))
        V, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1), (64, 64)))
        s = jnp.linspace(1.0, 1.0 / np.sqrt(kappa), 32)   # κ(MMᵀ) = kappa
        M = (U * s[None, :]) @ V[:32]
        exact = orthogonalize_svd(M)
        approx = newton_schulz_cubic(M, steps=5)
        errs.append(float(jnp.linalg.norm(exact - approx)))
    assert errs[0] < errs[1] < errs[2]


def test_ns_cubic_bound_lemma32():
    """‖E_i‖_F ≤ √r (1 − 1/κ)^{2^i} for the cubic iteration (σ ≤ 1 scaling)."""
    key = jax.random.PRNGKey(4)
    r = 16
    U, _ = jnp.linalg.qr(jax.random.normal(key, (r, r)))
    V, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1), (48, 48)))
    s = jnp.linspace(1.0, 0.5, r)
    M = (U * s[None, :]) @ V[:r]
    kappa = float(condition_number(M))       # κ of MMᵀ
    exact = orthogonalize_svd(M)
    for i in (3, 5, 8):
        err = float(jnp.linalg.norm(exact - newton_schulz_cubic(M, steps=i)))
        bound = np.sqrt(r) * (1 - 1 / kappa) ** (2 ** i)
        assert err <= bound + 1e-3, (i, err, bound)


def test_rank_one_residual_range():
    M = jax.random.normal(jax.random.PRNGKey(5), (16, 32))
    k = float(rank_one_residual(M))
    assert 0.0 <= k <= 1.0
    u = jnp.ones((16, 1)); v = jnp.ones((1, 32))
    assert float(rank_one_residual(u @ v)) < 1e-5


def _check_polar_idempotent(r, n, seed):
    """orth(orth(M)) == orth(M) — orthogonalization is idempotent."""
    M = jax.random.normal(jax.random.PRNGKey(seed), (r, n))
    O1 = orthogonalize_polar(M)
    O2 = orthogonalize_polar(O1)
    np.testing.assert_allclose(np.asarray(O1), np.asarray(O2), atol=5e-4)


def _check_polar_scale_invariant(r, n, scale, seed):
    """orth(cM) == orth(M) for c > 0 — spectral direction is scale-free."""
    M = jax.random.normal(jax.random.PRNGKey(seed), (r, n))
    np.testing.assert_allclose(
        np.asarray(orthogonalize_polar(M * scale)),
        np.asarray(orthogonalize_polar(M)),
        atol=5e-4,
    )


@pytest.mark.parametrize("r,n,seed", [(2, 12, 0), (12, 48, 1), (7, 23, 42)])
def test_smoke_polar_idempotent(r, n, seed):
    """Deterministic replay of the idempotence property (no hypothesis)."""
    _check_polar_idempotent(r, n, seed)


@pytest.mark.parametrize("r,n,scale,seed", [
    (2, 12, 0.01, 0), (12, 48, 100.0, 1), (5, 19, 3.7, 2),
])
def test_smoke_polar_scale_invariant(r, n, scale, seed):
    """Deterministic replay of the scale-invariance property (no hypothesis)."""
    _check_polar_scale_invariant(r, n, scale, seed)


if hypothesis is not None:
    @hypothesis.given(
        r=st.integers(2, 12), n=st.integers(12, 48), seed=st.integers(0, 2**16)
    )
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_property_polar_idempotent(r, n, seed):
        _check_polar_idempotent(r, n, seed)

    @hypothesis.given(
        r=st.integers(2, 12), n=st.integers(12, 48),
        scale=st.floats(0.01, 100.0), seed=st.integers(0, 2**16),
    )
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_property_polar_scale_invariant(r, n, scale, seed):
        _check_polar_scale_invariant(r, n, scale, seed)
else:
    def test_property_suite_requires_hypothesis():
        pytest.importorskip("hypothesis")


def test_ns5_spectral_range():
    """Muon's quintic drives singular values into ≈[0.7, 1.3] (not exact 1)."""
    M = jax.random.normal(jax.random.PRNGKey(6), (32, 128))
    s = jnp.linalg.svd(newton_schulz5(M), compute_uv=False)
    assert float(s[0]) < 1.6 and float(s[-1]) > 0.3
