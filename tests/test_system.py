"""End-to-end system behaviour: training improves loss, serving generates,
LoRA baseline, straggler machinery, adapter extraction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core import LoraConfig, apply_lora, extract_adapter, init_lora_params
from repro.serve import Engine, ServeConfig
from repro.train import StragglerMonitor, StragglerTimeout, TrainConfig, train
from repro.models import init_params


def test_train_end_to_end_loss_improves():
    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("t", seq_len=64, global_batch=16, kind="train")
    res = train(arch, shape,
                TrainConfig(optimizer="sumo", learning_rate=3e-3, rank=8,
                            update_freq=20, total_steps=60, log_every=1000),
                log_fn=lambda s: None)
    first = np.mean([l for _, l in res.losses[:5]])
    last = np.mean([l for _, l in res.losses[-5:]])
    assert last < first


def test_serving_generates_tokens():
    arch = get_smoke_config("qwen3-4b")
    params = init_params(arch, jax.random.PRNGKey(0))
    eng = Engine(arch, params, ServeConfig(max_new_tokens=8))
    out = eng.generate(jnp.ones((3, 5), jnp.int32))
    assert out.shape == (3, 8)
    assert int(jnp.max(out)) < arch.vocab


def test_serving_recurrent_arch():
    arch = get_smoke_config("xlstm-1.3b")
    params = init_params(arch, jax.random.PRNGKey(0))
    eng = Engine(arch, params, ServeConfig(max_new_tokens=4))
    out = eng.generate(jnp.ones((2, 4), jnp.int32))
    assert out.shape == (2, 4)


def test_serving_greedy_deterministic():
    arch = get_smoke_config("stablelm-1.6b")
    params = init_params(arch, jax.random.PRNGKey(0))
    eng = Engine(arch, params, ServeConfig(max_new_tokens=6, temperature=0.0))
    p = jnp.ones((2, 5), jnp.int32)
    np.testing.assert_array_equal(np.asarray(eng.generate(p)),
                                  np.asarray(eng.generate(p)))


def test_lora_baseline_and_adapter_extraction():
    arch = get_smoke_config("smollm-360m")
    params = init_params(arch, jax.random.PRNGKey(0))
    adapters = init_lora_params(params, LoraConfig(rank=4))
    merged = apply_lora(params, adapters)
    # B=0 at init: merged == base
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # post-hoc extraction (paper App. B): rank-r delta is recovered exactly
    key = jax.random.PRNGKey(1)
    W0 = jax.random.normal(key, (32, 24))
    delta_A = jax.random.normal(jax.random.fold_in(key, 1), (4, 24))
    delta_B = jax.random.normal(jax.random.fold_in(key, 2), (32, 4))
    W1 = W0 + delta_B @ delta_A
    A, B = extract_adapter(W0, W1, rank=4)
    np.testing.assert_allclose(np.asarray(B @ A), np.asarray(W1 - W0), atol=1e-4)


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(factor=3.0, warmup=2)
    for i in range(5):
        mon.observe(i, 0.1)
    with pytest.raises(StragglerTimeout):
        mon.observe(5, 1.0)
    assert mon.events


def test_vlm_arch_trains():
    arch = get_smoke_config("llava-next-mistral-7b")
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    res = train(arch, shape,
                TrainConfig(optimizer="sumo", learning_rate=3e-3, rank=4,
                            update_freq=10, total_steps=6, log_every=1000),
                log_fn=lambda s: None)
    assert all(np.isfinite(l) for _, l in res.losses)


def test_encoder_arch_trains():
    arch = get_smoke_config("hubert-xlarge")
    shape = ShapeConfig("t", seq_len=48, global_batch=4, kind="train")
    res = train(arch, shape,
                TrainConfig(optimizer="sumo", learning_rate=3e-3, rank=4,
                            update_freq=10, total_steps=6, log_every=1000),
                log_fn=lambda s: None)
    assert all(np.isfinite(l) for _, l in res.losses)
