"""Recurrent substrates: SSD (mamba2) chunked-vs-sequential oracle, mLSTM
chunked linear attention oracle, zamba2/xlstm parallel-prefill parity, and
hypothesis properties for the chunked scans.

Property tests are gated on `hypothesis` being importable (the offline
container lacks it); the deterministic smoke replays below always run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = st = None

from repro.configs import get_smoke_config
from repro.models import decode_step, forward_logits, init_params, prefill
from repro.models.mamba2 import ssd_chunked
from repro.models.xlstm import linear_attn_chunked


def _ssd_sequential(x, dt, A, Bm, Cm):
    """Token-by-token SSD recurrence oracle: S ← a·S + dt·B⊗x, y = C·S."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    S = jnp.zeros((Bsz, H, P, N))
    ys = []
    for t in range(L):
        a = jnp.exp(-dt[:, t] * A[None, :])                  # (B, H)
        S = S * a[:, :, None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, t], x[:, t], Bm[:, t]
        )
        ys.append(jnp.einsum("bhpn,bn->bhp", S, Cm[:, t]))
    return jnp.stack(ys, axis=1), S


@pytest.mark.parametrize("L,chunk", [(16, 4), (20, 8), (7, 16)])
def test_ssd_chunked_matches_sequential(L, chunk):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    Bsz, H, P, N = 2, 3, 4, 5
    x = jax.random.normal(ks[0], (Bsz, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, L, H)))
    A = jnp.abs(jax.random.normal(ks[2], (H,))) + 0.1
    Bm = jax.random.normal(ks[3], (Bsz, L, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 9), (Bsz, L, N))
    y_chunk, S_final = ssd_chunked(x, dt, A, Bm, Cm, chunk, return_state=True)
    y_seq, S_seq = _ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_final), np.asarray(S_seq),
                               atol=1e-4)


def _check_ssd_chunk_invariance(L, chunk, seed):
    """The chunk size is an implementation detail: outputs must not change."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    Bsz, H, P, N = 1, 2, 3, 4
    x = jax.random.normal(ks[0], (Bsz, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, L, H)))
    A = jnp.abs(jax.random.normal(ks[2], (H,))) + 0.1
    Bm = jax.random.normal(ks[3], (Bsz, L, N))
    Cm = jax.random.normal(ks[4], (Bsz, L, N))
    y1 = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y2 = ssd_chunked(x, dt, A, Bm, Cm, L)       # single chunk
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)


def _linattn_sequential(q, k, v, w, log_a):
    Bsz, L, H, Dk = q.shape
    Dv = v.shape[-1]
    S = jnp.zeros((Bsz, H, Dk, Dv))
    ys = []
    for t in range(L):
        a = jnp.exp(log_a[:, t])
        S = S * a[:, :, None, None] + jnp.einsum(
            "bh,bhd,bhv->bhdv", w[:, t], k[:, t], v[:, t]
        )
        ys.append(jnp.einsum("bhdv,bhd->bhv", S, q[:, t]))
    return jnp.stack(ys, axis=1), S


def _check_linear_attn_matches_sequential(L, chunk, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    Bsz, H, Dk, Dv = 1, 2, 3, 4
    q = jax.random.normal(ks[0], (Bsz, L, H, Dk))
    k = jax.random.normal(ks[1], (Bsz, L, H, Dk))
    v = jax.random.normal(ks[2], (Bsz, L, H, Dv))
    w = jnp.abs(jax.random.normal(ks[3], (Bsz, L, H)))
    log_a = jax.nn.log_sigmoid(jax.random.normal(ks[4], (Bsz, L, H)))
    y1, S1 = linear_attn_chunked(q, k, v, w, log_a, chunk, return_state=True)
    y2, S2 = _linattn_sequential(q, k, v, w, log_a)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-3)
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S2), atol=1e-3)


@pytest.mark.parametrize("L,chunk,seed", [(2, 16, 0), (24, 2, 1), (13, 5, 7)])
def test_smoke_ssd_chunk_invariance(L, chunk, seed):
    """Deterministic replay of the chunk-invariance property (no hypothesis)."""
    _check_ssd_chunk_invariance(L, chunk, seed)


@pytest.mark.parametrize("L,chunk,seed", [(2, 8, 0), (20, 3, 1), (11, 4, 9)])
def test_smoke_linear_attn_matches_sequential(L, chunk, seed):
    """Deterministic replay of the mLSTM-chunked oracle property."""
    _check_linear_attn_matches_sequential(L, chunk, seed)


if hypothesis is not None:
    @hypothesis.given(L=st.integers(2, 24), chunk=st.integers(2, 16),
                      seed=st.integers(0, 2**16))
    @hypothesis.settings(max_examples=15, deadline=None)
    def test_property_ssd_chunk_invariance(L, chunk, seed):
        _check_ssd_chunk_invariance(L, chunk, seed)

    @hypothesis.given(L=st.integers(2, 20), chunk=st.integers(2, 8),
                      seed=st.integers(0, 2**16))
    @hypothesis.settings(max_examples=15, deadline=None)
    def test_property_linear_attn_matches_sequential(L, chunk, seed):
        _check_linear_attn_matches_sequential(L, chunk, seed)
else:
    def test_property_suite_requires_hypothesis():
        pytest.importorskip("hypothesis")


@pytest.mark.parametrize("arch_id", ["zamba2-7b", "xlstm-1.3b"])
def test_recurrent_parallel_prefill_parity(arch_id):
    """Parallel prefill (state extraction from chunked scans) + one decode
    step must match the teacher-forced forward exactly."""
    cfg = get_smoke_config(arch_id)
    key = jax.random.PRNGKey(11)
    params = init_params(cfg, key)
    B, L = 2, 18
    toks = jax.random.randint(key, (B, L), 0, cfg.vocab)
    full = forward_logits(params, cfg, {"tokens": toks}, attn_impl="ref")
    lg, cache = prefill(params, cfg, {"tokens": toks[:, : L - 1]},
                        cache_len=L + 4, attn_impl="ref")
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, L - 2]),
                               atol=2e-4)
    lg2, _ = decode_step(params, cfg, toks[:, L - 1 :], cache)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(full[:, L - 1]),
                               atol=2e-4)


def test_zamba_swa_ring_prefill_long_prompt():
    """Prompt longer than the sliding window: ring cache + decode stays
    consistent with the windowed teacher-forced forward."""
    import dataclasses
    cfg = get_smoke_config("zamba2-7b")
    cfg = dataclasses.replace(cfg, sliding_window=8)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    B, L = 1, 21          # > window
    toks = jax.random.randint(key, (B, L), 0, cfg.vocab)
    full = forward_logits(params, cfg, {"tokens": toks}, attn_impl="ref")
    lg, cache = prefill(params, cfg, {"tokens": toks[:, : L - 1]},
                        cache_len=L + 4, attn_impl="ref")
    lg2, _ = decode_step(params, cfg, toks[:, L - 1 :], cache)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(full[:, L - 1]),
                               atol=2e-4)
