"""Optimizer correctness: convergence, state memory (paper Table 1), subspace
rotation (Block 1.1), norm-growth limiter (Block 3), param partitioning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GaloreConfig,
    SumoConfig,
    adamw,
    apply_updates,
    galore_optimizer,
    model_memory_report,
    muon_optimizer,
    partition_params,
    sumo,
    sumo_optimizer,
    tree_state_bytes,
)
from repro.core.memory import analytic_state_floats


def _lsq_problem(key, m=32, n=48, batch=256):
    k1, k2 = jax.random.split(key)
    Wtrue = jax.random.normal(k1, (m, n)) / 6
    X = jax.random.normal(k2, (batch, m))
    Y = X @ Wtrue
    params = {"layer": {"kernel": jnp.zeros((m, n))}, "bias": jnp.zeros((n,))}

    def loss_fn(p):
        return jnp.mean((X @ p["layer"]["kernel"] + p["bias"] - Y) ** 2)

    return params, loss_fn


@pytest.mark.parametrize("builder", [
    lambda p: sumo_optimizer(0.05, p, SumoConfig(rank=8, update_freq=10)),
    lambda p: sumo_optimizer(0.05, p, SumoConfig(rank=8, update_freq=10,
                                                 orth_method="svd")),
    lambda p: sumo_optimizer(0.05, p, SumoConfig(rank=8, update_freq=10,
                                                 orth_method="ns5")),
    lambda p: galore_optimizer(0.05, p, GaloreConfig(rank=8, update_freq=10)),
    lambda p: muon_optimizer(0.05, p),
    lambda p: adamw(0.05),
], ids=["sumo-polar", "sumo-svd", "sumo-ns5", "galore", "muon", "adamw"])
def test_optimizers_converge_least_squares(builder):
    params, loss_fn = _lsq_problem(jax.random.PRNGKey(0))
    tx = builder(params)
    state = tx.init(params)

    @jax.jit
    def step(p, s):
        l, g = jax.value_and_grad(loss_fn)(p)
        u, s = tx.update(g, s, p)
        return apply_updates(p, u), s, l

    p, l0 = params, float(loss_fn(params))
    for _ in range(80):
        p, state, l = step(p, state)
    assert float(l) < 0.3 * l0, f"loss {float(l)} vs init {l0}"


def test_sumo_state_memory_matches_table1():
    """SUMO state = mr + rn (+scalars) < GaLore (mr + 2rn) < Adam (2mn)."""
    m, n, r = 256, 128, 16
    params = {"w": jnp.zeros((m, n))}
    sizes = {}
    for name, tx in [
        ("sumo", sumo(0.1, SumoConfig(rank=r))),
        ("adamw", adamw(0.1)),
    ]:
        sizes[name] = tree_state_bytes(tx.init(params))
    # analytic: per Table 1 (fp32)
    assert sizes["sumo"] < 0.55 * sizes["adamw"]
    expected_sumo = 4 * (m * r + r * n)
    assert abs(sizes["sumo"] - expected_sumo) < 4 * (m + n + 64)  # + scalars/key
    assert analytic_state_floats("sumo", (m, n), r) < analytic_state_floats(
        "galore", (m, n), r
    ) < analytic_state_floats("adam", (m, n), r)


def test_model_memory_report_ordering():
    params = {
        "embed_tokens": jnp.zeros((1000, 64)),
        "blocks": {"wq": jnp.zeros((64, 64)), "w_up": jnp.zeros((64, 256))},
    }
    rep = model_memory_report(params, rank=8)
    assert rep["sumo"] < rep["galore"] < rep["adamw"]
    assert rep["adamw"] < rep["soap"]


def test_moment_rotation_preserves_direction():
    """Block 1.1: after a subspace refresh, M is rotated with R = Q_newᵀQ_old.
    If the gradient subspace is static, rotation must preserve the projected
    moment exactly (R is then orthonormal on the shared subspace)."""
    key = jax.random.PRNGKey(1)
    m, n, r = 64, 32, 4
    # fixed rank-r gradient: same subspace every step
    U = jnp.linalg.qr(jax.random.normal(key, (m, r)))[0]
    C = jax.random.normal(jax.random.fold_in(key, 1), (r, n))
    G = U @ C
    params = {"w": jnp.zeros((m, n))}
    cfg = SumoConfig(rank=r, update_freq=1, beta=0.9,   # refresh EVERY step
                     state_layout="leaf")               # per-leaf introspection
    tx = sumo(0.01, cfg)
    state = tx.init(params)
    prev_proj = None
    for i in range(6):
        updates, state = tx.update({"w": G}, state, params)
        Q = state.Q["w"]
        M = state.M["w"]
        # back-projected moment must stay in span(U)
        back = Q @ M
        resid = back - U @ (U.T @ back)
        assert float(jnp.linalg.norm(resid)) < 1e-3 * float(jnp.linalg.norm(back))
        if prev_proj is not None:
            # the *represented* moment (QM) evolves smoothly: no basis-flip jumps
            delta = float(jnp.linalg.norm(back - prev_proj)) / (
                float(jnp.linalg.norm(back)) + 1e-9
            )
            assert delta < 1.0
        prev_proj = back


def test_norm_growth_limiter():
    """Block 3: ‖O_t‖ may grow at most γ× per step."""
    key = jax.random.PRNGKey(2)
    params = {"w": jnp.zeros((32, 16))}
    cfg = SumoConfig(rank=4, update_freq=100, gamma=1.1, rms_scale=False, alpha=1.0)
    tx = sumo(1.0, cfg)
    state = tx.init(params)
    # step 1: small gradient; step 2: huge gradient
    g_small = jax.random.normal(key, (32, 16)) * 1e-3
    g_big = jax.random.normal(key, (32, 16)) * 1e3
    u1, state = tx.update({"w": g_small}, state, params)
    n1 = float(jnp.linalg.norm(u1["w"]))
    u2, state = tx.update({"w": g_big}, state, params)
    n2 = float(jnp.linalg.norm(u2["w"]))
    assert n2 <= 1.1 * n1 * 1.01, (n1, n2)


def test_partition_params_rules():
    params = {
        "embed_tokens": jnp.zeros((100, 8)),
        "lm_head": jnp.zeros((8, 100)),
        "final_norm": {"norm_scale": jnp.zeros((8,))},
        "blocks": {
            "attn": {"wq": jnp.zeros((8, 8))},
            "mlp": {"w_up": jnp.zeros((8, 32))},
            "moe": {"experts": {"w_gate": jnp.zeros((4, 8, 32))}},
        },
        "bias": jnp.zeros((4, 4)),
    }
    labels = partition_params(params)
    assert labels["embed_tokens"] == "fallback"
    assert labels["lm_head"] == "fallback"
    assert labels["final_norm"]["norm_scale"] == "fallback"
    assert labels["blocks"]["attn"]["wq"] == "matrix"
    assert labels["blocks"]["mlp"]["w_up"] == "matrix"
    assert labels["blocks"]["moe"]["experts"]["w_gate"] == "matrix"
    assert labels["bias"] == "fallback"


def test_sumo_expert_stack_3d():
    """3D expert stacks get vmapped SUMO treatment."""
    key = jax.random.PRNGKey(3)
    params = {"experts": {"w_gate": jax.random.normal(key, (4, 32, 16))}}
    tx = sumo(0.1, SumoConfig(rank=4, update_freq=2, state_layout="leaf"))
    state = tx.init(params)
    g = {"experts": {"w_gate": jax.random.normal(key, (4, 32, 16))}}
    u, state = tx.update(g, state, params)
    assert u["experts"]["w_gate"].shape == (4, 32, 16)
    assert state.Q["experts"]["w_gate"].shape == (4, 32, 4)
    assert state.M["experts"]["w_gate"].shape == (4, 4, 16)
    assert not bool(jnp.any(jnp.isnan(u["experts"]["w_gate"])))


def test_sumo_projects_long_side():
    """m < n matrices project from the right (paper's transpose remark)."""
    params = {"w": jnp.zeros((16, 64))}
    tx = sumo(0.1, SumoConfig(rank=4, state_layout="leaf"))
    state = tx.init(params)
    assert state.Q["w"].shape == (64, 4)     # long side
    assert state.M["w"].shape == (4, 16)     # r × short
