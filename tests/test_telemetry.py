"""Spectral telemetry + adaptive rank/refresh controller.

Pinned here:
  * probes-on is BIT-parity with probes-off — the stats are a pure aux
    output, the trajectory (updates and Q/M/prev_norm) is unchanged;
  * the emitted stats mean what the schema says (refresh_fired pattern,
    energy capture in [0,1], κ ≥ 1, ‖M‖ = √Σσ²);
  * the sink's JSONL output round-trips through the schema (and the CSV
    writer emits parseable rows);
  * controller decisions are deterministic and move the right way on
    synthetic moments: SHRINK rank on a well-conditioned low-rank bucket,
    TIGHTEN refresh on an ill-conditioned one, GROW rank when energy sags;
  * applying decisions resizes the bucket-resident state and the optimizer
    continues (adopting the new rank at the next refresh);
  * the train loop wiring writes schema-valid JSONL end to end.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SumoConfig, apply_updates, sumo
from repro.telemetry import (
    BucketSetting,
    ControllerConfig,
    CsvWriter,
    JsonlWriter,
    RankRefreshController,
    TelemetrySink,
    WindowAggregate,
    apply_decisions,
    extract_stats,
    read_jsonl,
    resize_opt_state,
    tail_mass,
    validate_record,
)


def _tree(key):
    """Two buckets: (64, 32) from 2D + transpose partner + expert stack, and
    a wide (16, 48) singleton."""
    return {
        "a": jax.random.normal(key, (64, 32)),
        "a_t": jax.random.normal(jax.random.fold_in(key, 1), (32, 64)),
        "experts": jax.random.normal(jax.random.fold_in(key, 2), (3, 64, 32)),
        "wide": jax.random.normal(jax.random.fold_in(key, 3), (16, 48)),
    }


def _run(cfg, params, grads, steps):
    tx = sumo(0.01, cfg)
    state = tx.init(params)
    out = []
    for _ in range(steps):
        u, state = tx.update(grads, state, params)
        out.append(u)
    return out, state


# ---------------------------------------------------------------------------
# probes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("orth", ["polar", "svd", "ns5"])
def test_probes_on_is_bit_parity_with_probes_off(orth):
    """Across a refresh boundary (update_freq=3, 5 steps), with weight decay
    and the adaptive-refresh criterion on: identical deltas and identical
    Q/M/prev_norm — probes are observation only."""
    params = _tree(jax.random.PRNGKey(0))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    base = dict(rank=8, update_freq=3, weight_decay=0.05,
                refresh_quality=0.5, orth_method=orth)
    us_off, st_off = _run(SumoConfig(**base), params, grads, 5)
    us_on, st_on = _run(SumoConfig(**base, telemetry=True), params, grads, 5)
    for step, (a, b) in enumerate(zip(us_off, us_on)):
        for k in params:
            np.testing.assert_array_equal(
                np.asarray(a[k]), np.asarray(b[k]),
                err_msg=f"step {step} leaf {k}")
    for field in ("Q", "M", "prev_norm"):
        for x, y in zip(jax.tree_util.tree_leaves(getattr(st_off, field)),
                        jax.tree_util.tree_leaves(getattr(st_on, field))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=field)
    assert st_off.stats is None
    assert set(st_on.stats) == {"64x32", "48x16"}


def test_stats_semantics():
    params = _tree(jax.random.PRNGKey(1))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=8, update_freq=3, telemetry=True)
    tx = sumo(0.01, cfg)
    state = tx.init(params)
    fired = []
    for _ in range(5):
        _, state = tx.update(grads, state, params)
        s = state.stats["64x32"]
        fired.append(int(s.refresh_fired))
        assert 0.0 <= float(s.energy) <= 1.0 + 1e-6
        assert float(s.kappa) >= 1.0 - 1e-6
        sig = np.asarray(s.sigma)
        assert sig.shape == (8,) and np.all(np.diff(sig) <= 1e-6)
        # trace identity: mean ‖M‖ = mean √Σσ² only holds per matrix, but
        # with one shared gradient all bucket members see similar spectra —
        # just check ‖M‖ > 0 once the moment is live.
        assert float(s.moment_norm) > 0.0
    assert fired == [1, 0, 0, 1, 0]   # update_freq=3: steps 0 and 3


def test_extract_stats_walks_opt_state_trees():
    from repro.train.steps import make_optimizer

    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (32, 16)),
              "bias": jnp.zeros((16,))}
    tx = make_optimizer("sumo", 1e-3, params, rank=4, update_freq=2,
                        telemetry=True)
    state = tx.init(params)
    _, state = tx.update(
        jax.tree_util.tree_map(lambda x: x * 0.01, params), state, params)
    stats = extract_stats(state)        # multi_transform dict
    assert set(stats) == {"32x16"}
    assert stats["32x16"].sigma.shape == (4,)


# ---------------------------------------------------------------------------
# sink
# ---------------------------------------------------------------------------

def _emit_steps(sink, steps=5, rank=4, freq=3):
    params = {"w": jax.random.normal(jax.random.PRNGKey(3), (64, 32))}
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    tx = sumo(0.01, SumoConfig(rank=rank, update_freq=freq, telemetry=True))
    state = tx.init(params)
    sink.set_settings(
        {"64x32": BucketSetting(rank=rank, update_freq=freq,
                                long=64, short=32)},
        default_freq=freq)
    for t in range(steps):
        _, state = tx.update(grads, state, params)
        sink.emit(t, state.stats)


def test_sink_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "telemetry.jsonl")
    sink = TelemetrySink(writers=[JsonlWriter(path)], window=4)
    _emit_steps(sink, steps=5)
    drained = sink.drain()
    sink.close()
    assert len(drained) == 5
    recs = read_jsonl(path)
    assert recs == drained              # exact round-trip through JSON
    for rec in recs:
        validate_record(rec)
    assert [r["step"] for r in recs] == list(range(5))
    assert all(r["bucket"] == "64x32" and r["rank"] == 4 and
               r["update_freq"] == 3 for r in recs)
    assert [r["refresh_fired"] for r in recs] == [1, 0, 0, 1, 0]


def test_sink_csv_writer(tmp_path):
    import csv as csv_mod

    path = str(tmp_path / "telemetry.csv")
    sink = TelemetrySink(writers=[CsvWriter(path)], window=4)
    _emit_steps(sink, steps=3)
    sink.drain()
    sink.close()
    with open(path) as f:
        rows = list(csv_mod.DictReader(f))
    assert len(rows) == 3
    assert rows[0]["bucket"] == "64x32"
    assert len(json.loads(rows[0]["sigma"])) == 4


def test_sink_windows_and_background_drain():
    sink = TelemetrySink(window=3)
    sink.start(interval=0.01)
    _emit_steps(sink, steps=6)
    sink.stop()                          # joins the thread + final drain
    agg = sink.window_aggregate("64x32")
    assert agg is not None and agg.n == 3            # window, not history
    assert agg.last_step == 5
    assert 0.0 <= agg.energy_mean <= 1.0 + 1e-6
    assert sink.records_written == 6 and sink.dropped == 0


def test_validate_record_rejects_bad_records():
    sink = TelemetrySink(window=2)
    _emit_steps(sink, steps=1)
    (rec,) = sink.drain()
    validate_record(rec)
    bad = dict(rec)
    del bad["kappa"]
    with pytest.raises(ValueError, match="missing"):
        validate_record(bad)
    bad = dict(rec, kappa="high")
    with pytest.raises(ValueError, match="kappa"):
        validate_record(bad)
    bad = dict(rec, extra_field=1)
    with pytest.raises(ValueError, match="extra"):
        validate_record(bad)


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------

def _run_telemetry(make_grad, rank, steps, freq, window=8):
    params = {"w": jnp.zeros((64, 32))}
    tx = sumo(0.01, SumoConfig(rank=rank, update_freq=freq, telemetry=True,
                               rms_scale=False))
    state = tx.init(params)
    sink = TelemetrySink(window=window)
    settings = {"64x32": BucketSetting(rank=rank, update_freq=freq,
                                       long=64, short=32)}
    sink.set_settings(settings, default_freq=freq)
    p = params
    for t in range(steps):
        u, state = tx.update({"w": make_grad(t)}, state, p)
        p = apply_updates(p, u)
        sink.emit(t, state.stats)
    sink.drain()
    return sink, settings, state


def test_controller_shrinks_rank_on_well_conditioned_bucket():
    """True rank-2 gradients under a rank-16 subspace: the spectral tail is
    dead mass ⇒ shrink; effective κ stays tiny ⇒ refresh RELAXES (the
    rank-deficiency must not masquerade as ill-conditioning)."""
    key = jax.random.PRNGKey(0)
    U = jnp.linalg.qr(jax.random.normal(key, (64, 2)))[0]
    V = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1), (32, 2)))[0]
    grad = lambda t: U @ jnp.diag(jnp.array([1.0, 0.7])) @ V.T
    sink, settings, _ = _run_telemetry(grad, rank=16, steps=12, freq=4)
    agg = sink.window_aggregates()["64x32"]
    assert agg.kappa_mean < 1e2          # effective κ, not σ_min≈0 blowup
    assert tail_mass(agg.sigma_mean) < 1e-3
    ctrl = RankRefreshController(ControllerConfig(window=8))
    decisions = ctrl.decide(sink.window_aggregates(), settings)
    d = decisions["64x32"]
    assert d.rank == 8 and d.update_freq == 8, d
    assert any("shrink rank" in r for r in d.reasons)
    # deterministic: same inputs, same decisions (twice, fresh controller)
    again = RankRefreshController(ControllerConfig(window=8)).decide(
        sink.window_aggregates(), settings)
    assert again == decisions


def test_controller_tightens_refresh_on_ill_conditioned_bucket():
    """Gradients with a 6-decade spectrum: κ(M) ≫ kappa_high ⇒ halve the
    refresh interval; the full-rank spectrum carries tail mass ⇒ rank holds."""
    key = jax.random.PRNGKey(1)
    U = jnp.linalg.qr(jax.random.normal(key, (64, 8)))[0]
    V = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1), (32, 8)))[0]
    s = jnp.logspace(0, -6, 8)
    grad = lambda t: U @ jnp.diag(s) @ V.T
    sink, settings, _ = _run_telemetry(grad, rank=8, steps=12, freq=8)
    agg = sink.window_aggregates()["64x32"]
    assert agg.kappa_mean > 1e6
    ctrl = RankRefreshController(ControllerConfig(window=8, tail_mass_low=0.0,
                                                  freq_min=2))
    decisions = ctrl.decide(sink.window_aggregates(), settings)
    d = decisions["64x32"]
    assert d.update_freq == 4 and d.rank == 8, d
    assert any("tighten refresh" in r for r in d.reasons)


def test_controller_grows_rank_on_sagging_energy():
    """Synthetic window: mean energy capture 0.1 < energy_low ⇒ grow rank,
    capped at the bucket's short dim."""
    agg = WindowAggregate(n=8, last_step=7, kappa_mean=10.0, kappa_max=12.0,
                          energy_mean=0.1, energy_min=0.05, ortho_max=1e-6,
                          sigma_mean=np.linspace(1.0, 0.5, 8),
                          refresh_rate=0.25)
    ctrl = RankRefreshController(ControllerConfig(window=8, rank_step=8))
    settings = {"64x32": BucketSetting(rank=8, update_freq=100,
                                       long=64, short=32),
                "48x12": BucketSetting(rank=8, update_freq=100,
                                       long=48, short=12)}
    decisions = ctrl.decide({"64x32": agg, "48x12": agg}, settings)
    assert decisions["64x32"].rank == 16
    assert decisions["48x12"].rank == 12          # capped at short
    # below-window buckets keep their settings
    small = agg.__class__(**{**agg.__dict__, "n": 3})
    keep = ctrl.decide({"64x32": small}, settings)
    assert keep["64x32"].rank == 8 and keep["64x32"].reasons == ()


def test_apply_decisions_resizes_state_and_training_continues():
    """Shrink 16→8 mid-run: Q/M/stats resize, the rebuilt optimizer steps,
    and the next refresh re-derives the basis at the new rank."""
    key = jax.random.PRNGKey(0)
    U = jnp.linalg.qr(jax.random.normal(key, (64, 2)))[0]
    V = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1), (32, 2)))[0]
    grad = lambda t: U @ jnp.diag(jnp.array([1.0, 0.7])) @ V.T
    sink, settings, state = _run_telemetry(grad, rank=16, steps=12, freq=4)
    ctrl = RankRefreshController(ControllerConfig(window=8))
    decisions = ctrl.decide(sink.window_aggregates(), settings)
    new_state, new_settings, overrides, reasons = apply_decisions(
        state, settings, decisions)
    assert reasons and new_settings["64x32"].rank == 8
    assert new_state.Q["64x32"].shape == (1, 64, 8)
    assert new_state.M["64x32"].shape == (1, 8, 32)
    assert new_state.stats["64x32"].sigma.shape == (8,)
    assert overrides == (("64x32", 8, 8, 0.0),)
    # spectral shrink: the new basis stays orthonormal and the lifted moment
    # QM is preserved up to the discarded tail mass (negligible here)
    Qn = np.asarray(new_state.Q["64x32"][0])
    np.testing.assert_allclose(Qn.T @ Qn, np.eye(8), atol=1e-5)
    lifted_old = np.asarray(state.Q["64x32"][0] @ state.M["64x32"][0])
    lifted_new = np.asarray(Qn @ new_state.M["64x32"][0])
    np.testing.assert_allclose(lifted_new, lifted_old, atol=1e-5)
    tx2 = sumo(0.01, SumoConfig(rank=16, update_freq=4, telemetry=True,
                                rms_scale=False, bucket_overrides=overrides))
    p = {"w": jnp.zeros((64, 32))}
    st = new_state
    for t in range(12, 18):              # crosses the step-16 refresh
        u, st = tx2.update({"w": grad(t)}, st, p)
        p = apply_updates(p, u)
    assert st.Q["64x32"].shape == (1, 64, 8)
    assert float(st.stats["64x32"].energy) > 0.9   # rank 8 still captures all


def test_resize_opt_state_walks_multi_transform():
    from repro.train.steps import make_optimizer

    params = {"w": jax.random.normal(jax.random.PRNGKey(5), (32, 16)),
              "bias": jnp.zeros((16,))}
    tx = make_optimizer("sumo", 1e-3, params, rank=8, update_freq=2,
                        telemetry=True)
    state = tx.init(params)
    resized = resize_opt_state(state, {"32x16": 4})
    stats = extract_stats(resized)
    assert stats["32x16"].sigma.shape == (4,)


# ---------------------------------------------------------------------------
# engine parity under overrides + loop integration
# ---------------------------------------------------------------------------

def test_bucket_overrides_bitmatch_across_engines():
    """Per-bucket rank/freq overrides produce identical trajectories in the
    bucketed and per-leaf engines (the cadence/rank are pure functions of the
    canonical shape in both)."""
    params = _tree(jax.random.PRNGKey(7))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    over = (("64x32", 4, 2), ("48x16", 6, 5))
    a, sa = _run(SumoConfig(rank=8, update_freq=3, bucket_overrides=over),
                 params, grads, 6)
    b, sb = _run(SumoConfig(rank=8, update_freq=3, bucket_overrides=over,
                            bucketed=False, state_layout="bucket"),
                 params, grads, 6)
    for step, (x, y) in enumerate(zip(a, b)):
        for k in params:
            np.testing.assert_array_equal(np.asarray(x[k]), np.asarray(y[k]),
                                          err_msg=f"step {step} {k}")
    assert sa.Q["64x32"].shape[-1] == 4 and sa.Q["48x16"].shape[-1] == 6


def test_controller_arms_and_disarms_refresh_quality():
    """ς policy: the worst in-window energy capture sagging below
    ``quality_arm`` (while the mean stays healthy — that case grows rank
    instead) arms the bucket's in-step refresh trigger; a recovered minimum
    disarms it back to the global default."""
    base = dict(n=8, last_step=7, kappa_mean=1e4, kappa_max=1e4,
                ortho_max=1e-6, sigma_mean=np.linspace(1.0, 0.5, 8),
                refresh_rate=0.25)           # κ between relax and tighten
    ctrl = RankRefreshController(ControllerConfig(
        window=8, tail_mass_low=0.0))        # isolate the quality policy
    settings = {"64x32": BucketSetting(rank=8, update_freq=100,
                                       long=64, short=32)}
    sag = WindowAggregate(energy_mean=0.9, energy_min=0.3, **base)
    d = ctrl.decide({"64x32": sag}, settings)["64x32"]
    assert d.refresh_quality == 0.5
    assert d.rank == 8 and d.update_freq == 100   # only ς moved
    assert any("arm refresh_quality" in r for r in d.reasons)
    # fold in and recover: the armed setting disarms
    _, armed, overrides, _ = apply_decisions(
        {}, settings, {"64x32": d})
    assert overrides == (("64x32", 8, 100, 0.5),)
    ok = WindowAggregate(energy_mean=0.95, energy_min=0.9, **base)
    d2 = ctrl.decide({"64x32": ok}, armed)["64x32"]
    assert d2.refresh_quality == 0.0
    assert any("disarm refresh_quality" in r for r in d2.reasons)
    # a sagging MEAN is the grow-rank case, not the arm case
    starved = WindowAggregate(energy_mean=0.1, energy_min=0.05, **base)
    d3 = ctrl.decide({"64x32": starved}, settings)["64x32"]
    assert d3.refresh_quality == 0.0 and d3.rank == 16


def test_bucket_quality_override_bitmatch_across_engines():
    """A per-bucket ς override (4-tuple bucket_overrides entry) triggers the
    adaptive refresh for exactly that bucket, bit-identically in the
    bucketed and per-leaf engines; legacy 3-tuples still parse."""
    key = jax.random.PRNGKey(11)
    params = _tree(key)
    # gradients whose subspace flips mid-run: the stale basis captures ~0
    g1 = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    g2 = jax.tree_util.tree_map(
        lambda x: jax.random.normal(jax.random.fold_in(key, 77), x.shape) * 0.01,
        params)
    over = (("64x32", 0, 0, 0.5), ("16x48", 4, 2))   # mixed 4- and 3-tuples
    cfg_b = SumoConfig(rank=8, update_freq=100, telemetry=True,
                       bucket_overrides=over)
    cfg_l = SumoConfig(rank=8, update_freq=100, bucket_overrides=over,
                       bucketed=False, state_layout="bucket")
    assert cfg_b.bucket_refresh_quality(64, 32) == 0.5
    assert cfg_b.bucket_refresh_quality(16, 48) == 0.0   # 3-tuple: global
    assert cfg_b.bucket_rank(16, 48) == 4

    def run(cfg):
        tx = sumo(0.01, cfg)
        st = tx.init(params)
        out = []
        for t in range(6):
            u, st = tx.update(g1 if t < 3 else g2, st, params)
            out.append(u)
        return out, st

    ub, sb = run(cfg_b)
    ul, sl = run(cfg_l)
    for step, (a, b) in enumerate(zip(ub, ul)):
        for k in params:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                          err_msg=f"step {step} {k}")
    for x, y in zip(jax.tree_util.tree_leaves(sb.Q),
                    jax.tree_util.tree_leaves(sl.Q)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # only the ς-armed bucket re-refreshed at the subspace flip (step 3);
    # the un-armed wide bucket held its stale basis (update_freq=100... but
    # its 3-tuple override tightened K to 2, so exclude it: check via a
    # no-override control run instead)
    _, s_ctl = run(SumoConfig(rank=8, update_freq=100, telemetry=True))
    assert int(sb.stats["64x32"].refresh_fired) == 0      # steady at step 5
    tx = sumo(0.01, cfg_b)
    st = tx.init(params)
    fired = []
    for t in range(6):
        _, st = tx.update(g1 if t < 3 else g2, st, params)
        fired.append(int(st.stats["64x32"].refresh_fired))
    assert fired[0] == 1 and fired[3] == 1    # flip re-fired via ς
    ctl_fired = []
    st = sumo(0.01, SumoConfig(rank=8, update_freq=100, telemetry=True)
              ).init(params)
    tx_ctl = sumo(0.01, SumoConfig(rank=8, update_freq=100, telemetry=True))
    for t in range(6):
        _, st = tx_ctl.update(g1 if t < 3 else g2, st, params)
        ctl_fired.append(int(st.stats["64x32"].refresh_fired))
    assert ctl_fired[3] == 0                  # without ς the flip is missed


def test_train_loop_telemetry_and_controller(tmp_path):
    """End-to-end wiring: probes + sink + controller through train(),
    including a controller decision that rebuilds the optimizer mid-run
    (kappa_low=1e30 forces a relax-refresh decision at the first full
    window) — the stream shows the cadence change, training continues."""
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.train import TrainConfig, train

    out = str(tmp_path / "telemetry.jsonl")
    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("tel-test", seq_len=32, global_batch=4, kind="train")
    res = train(arch, shape,
                TrainConfig(optimizer="sumo", learning_rate=3e-3, rank=8,
                            update_freq=2, total_steps=7, log_every=10**9,
                            telemetry=True, telemetry_out=out,
                            controller=True, telemetry_window=4,
                            controller_interval=4,
                            controller_config=ControllerConfig(
                                window=4, kappa_low=1e30, freq_min=1)),
                log_fn=lambda s: None)
    recs = read_jsonl(out)
    assert recs and res.telemetry_records == len(recs)
    for rec in recs:
        validate_record(rec)
    buckets = {r["bucket"] for r in recs}
    assert len(recs) == 7 * len(buckets)
    assert res.losses[-1][0] == 6           # all 7 steps ran post-rebuild
    # the decision fired at step 4 and the stream records the new cadence
    assert {e[0] for e in res.controller_events} == {4}
    assert {r["update_freq"] for r in recs} == {2, 4}
    assert os.path.getsize(out) > 0


def test_fault_recovery_across_controller_decision(tmp_path):
    """A preemption AFTER a controller decision restores cleanly: the
    checkpoint manifest records the per-bucket settings its state was shaped
    by, and recovery adopts them before building the restore template."""
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.train import FaultInjector, TrainConfig, train

    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("ctl-fault", seq_len=32, global_batch=4, kind="train")
    tcfg = TrainConfig(
        optimizer="sumo", learning_rate=3e-3, rank=8, update_freq=2,
        total_steps=10, ckpt_dir=str(tmp_path), ckpt_every=6,
        ckpt_async=False, log_every=10**9,
        telemetry=True, controller=True, telemetry_window=4,
        controller_interval=4,
        controller_config=ControllerConfig(window=4, kappa_low=1e30,
                                           freq_min=1))
    res = train(arch, shape, tcfg,
                fault_injector=FaultInjector(preempt_at=[8]),
                log_fn=lambda s: None)
    # decision at step 4 (relax refresh), ckpt at 6, preempt at 8, resume
    assert res.restarts == 1
    assert any(e[0] == 4 for e in res.controller_events)
    assert res.losses[-1][0] == 9            # ran to completion post-restore
    with pytest.raises(ValueError, match="bucketed"):
        sumo(0.01, SumoConfig(telemetry=True, bucketed=False,
                              state_layout="leaf"))


def test_checkpoint_probes_off_restores_into_probes_on(tmp_path):
    """A checkpoint written with probes off restores into a probes-on
    template: the template's zero stats are kept, Q/M/prev_norm load."""
    from repro.train.checkpoint import CheckpointManager

    params = {"w": jax.random.normal(jax.random.PRNGKey(9), (32, 16))}
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    tx_off = sumo(0.01, SumoConfig(rank=4, update_freq=2))
    st = tx_off.init(params)
    for _ in range(3):
        _, st = tx_off.update(grads, st, params)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(3, {"opt_state": st})

    tx_on = sumo(0.01, SumoConfig(rank=4, update_freq=2, telemetry=True))
    template = {"opt_state": tx_on.init(params)}
    restored, manifest = mgr.restore(template)
    r = restored["opt_state"]
    np.testing.assert_array_equal(np.asarray(r.Q["32x16"]),
                                  np.asarray(st.Q["32x16"]))
    assert float(jnp.sum(r.stats["32x16"].sigma)) == 0.0   # template zeros
    # reverse direction: probes-on checkpoint into probes-off template
    mgr.save(4, {"opt_state": r})
    tmpl_off = {"opt_state": tx_off.init(params)}
    restored2, _ = mgr.restore(tmpl_off)
    assert restored2["opt_state"].stats is None
