import gc

import jax
import pytest

# NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
# must see the real (single) device. Multi-device compile tests spawn
# subprocesses with their own flags (test_sharding.py).

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_executables_between_modules():
    """Each retained compiled executable holds mmap'd code regions; across
    the whole suite the process otherwise brushes vm.max_map_count (65530
    on stock kernels) and malloc failures surface as segfaults in whichever
    module compiles last. Nothing shares jit caches across module
    boundaries, so the flush is free apart from recompiles."""
    yield
    jax.clear_caches()
    gc.collect()


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
