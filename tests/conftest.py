import jax
import pytest

# NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
# must see the real (single) device. Multi-device compile tests spawn
# subprocesses with their own flags (test_sharding.py).

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
