"""Checkpoint manager: roundtrip, rotation, atomicity, fault-tolerant resume
determinism, and mesh-independence (restore with different sharding)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.train import CheckpointManager, FaultInjector, TrainConfig, train


def _state(key):
    return {
        "params": {"w": jax.random.normal(key, (8, 4)),
                   "blocks": [jnp.ones((2, 3)), jnp.zeros((5,))]},
        "step_things": {"count": jnp.asarray(7, jnp.int32), "none": None},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = _state(jax.random.PRNGKey(0))
    mgr.save(12, state, extra={"foo": "bar"})
    restored, manifest = mgr.restore(state)
    assert manifest["step"] == 12 and manifest["foo"] == "bar"
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]


def test_no_partial_checkpoints_visible(tmp_path):
    """tmp dirs never count as checkpoints (atomic rename discipline)."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    os.makedirs(os.path.join(str(tmp_path), "tmp.99"))
    assert mgr.latest_step() is None
    mgr.save(5, _state(jax.random.PRNGKey(2)))
    assert mgr.latest_step() == 5


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros((8, 8))})


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _state(jax.random.PRNGKey(3)), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 3


def test_fault_tolerant_resume_is_deterministic(tmp_path):
    """Training with a mid-run preemption reproduces the no-fault run exactly
    (checkpoint + deterministic data replay)."""
    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")

    def run(fault, d):
        tcfg = TrainConfig(optimizer="sumo", learning_rate=1e-2, rank=4,
                           update_freq=5, total_steps=14, ckpt_dir=d,
                           ckpt_every=7, ckpt_async=False, log_every=1000)
        inj = FaultInjector(preempt_at=[9]) if fault else None
        return train(arch, shape, tcfg, fault_injector=inj, log_fn=lambda s: None)

    r_clean = run(False, str(tmp_path / "a"))
    r_fault = run(True, str(tmp_path / "b"))
    assert r_fault.restarts == 1
    clean = dict(r_clean.losses)
    fault = dict(r_fault.losses)
    for step in range(10, 14):   # post-recovery steps must match bit-for-bit
        assert abs(clean[step] - fault[step]) < 1e-6, step
